//! Pipeline clock-cycle schedule model (LayerPipe's throughput side).
//!
//! Models a `K`-stage training pipeline at per-clock granularity. Each
//! stage is a *forward-backward scheduling unit* (the paper trains with
//! "eight forward-backward scheduling units"): its forward and backward
//! sub-units run concurrently, so in steady state one batch enters the
//! pipeline per clock. Stage `s` forwards batch `t` at clock `t + s` and
//! runs the matching backward at clock `t + 2K − 2 − s` — exactly the
//! temporal separation the retimed DFG's boundary delays impose. From
//! the timeline the module derives makespan, per-unit utilization,
//! speedup over sequential execution, per-boundary communication volume,
//! and — crucially — the observed gradient staleness per stage, which
//! must equal `2·S` (Eq. 1): the schedule-level confirmation of the
//! retiming-level derivation.

pub mod adaptive;
pub mod multiproc;

pub use adaptive::{choose_stages, AdaptiveChoice, AdaptiveLimits};
pub use multiproc::{assign_contiguous, assign_lpt, simulate as simulate_multiproc, Assignment, MultiprocPerf};

use crate::retiming::StagePartition;

/// What one lane of a scheduling unit does in one clock slot.
pub type Slot = Option<u64>;

/// Per-layer compute cost model (abstract time units).
#[derive(Clone, Debug)]
pub struct CostModel {
    /// Forward cost per layer.
    pub fwd: Vec<f64>,
    /// Backward cost per layer (δ + G; typically ≈ 2× forward).
    pub bwd: Vec<f64>,
    /// Activation bytes crossing each stage boundary per batch.
    pub boundary_bytes: usize,
}

impl CostModel {
    /// Uniform costs: forward 1.0, backward 2.0 per layer.
    pub fn uniform(layers: usize) -> Self {
        CostModel { fwd: vec![1.0; layers], bwd: vec![2.0; layers], boundary_bytes: 0 }
    }

    /// Conv-aware model from per-layer [`LayerCost`] reports (the same
    /// reports [`StagePartition::balanced`] consumes via
    /// `total_flops()`), so the adaptive stage-count choice and the
    /// trainers' cost-balanced partitioning reason about the *same*
    /// heterogeneous stack instead of assuming uniform per-layer cost.
    /// `boundary_bytes` is the largest activation any boundary could
    /// carry (conservative: which boundaries exist depends on the
    /// partition under evaluation).
    pub fn from_layer_costs(costs: &[crate::layers::LayerCost]) -> Self {
        CostModel {
            fwd: costs.iter().map(|c| c.fwd_flops as f64).collect(),
            bwd: costs.iter().map(|c| c.bwd_flops as f64).collect(),
            boundary_bytes: costs.iter().map(|c| c.act_bytes as usize).max().unwrap_or(0),
        }
    }

    /// Integer per-layer totals (`fwd + bwd`, the balancing objective)
    /// for [`StagePartition::balanced`]. Exact when built by
    /// [`CostModel::from_layer_costs`]; rounds for hand-built fractional
    /// models (where only relative magnitudes matter).
    pub fn layer_costs_u64(&self) -> Vec<u64> {
        self.fwd
            .iter()
            .zip(&self.bwd)
            .map(|(f, b)| (f + b).round().max(0.0) as u64)
            .collect()
    }

    pub fn stage_cost(&self, part: &StagePartition, stage: usize) -> f64 {
        part.layers_in_stage(stage)
            .into_iter()
            .map(|l| self.fwd[l] + self.bwd[l])
            .sum()
    }
}

/// The simulated schedule of a pipelined training run.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// `fwd[stage][clock]` — batch forwarded by stage `stage` at `clock`.
    pub fwd: Vec<Vec<Slot>>,
    /// `bwd[stage][clock]` — batch whose backward runs at `clock`.
    pub bwd: Vec<Vec<Slot>>,
    pub partition: StagePartition,
    pub batches: u64,
}

impl Schedule {
    /// Build the steady-state schedule: stage `s` forwards batch `t` at
    /// clock `t + s` and backwards batch `t` at clock `t + 2K − 2 − s`,
    /// the slot assignment induced by the retimed DFG (one delay per
    /// boundary per direction ⇒ one clock of separation per crossing).
    pub fn build(partition: &StagePartition, batches: u64) -> Schedule {
        assert!(batches > 0);
        let k = partition.stages();
        // Last event: backward of batch B−1 at stage 0 at clock
        // (B−1) + 2K − 2, so the span is B + 2K − 2 slots.
        let span = batches as usize + 2 * k - 2;
        let mut fwd = vec![vec![None; span]; k];
        let mut bwd = vec![vec![None; span]; k];
        for t in 0..batches {
            for s in 0..k {
                let fc = t as usize + s;
                debug_assert_eq!(fwd[s][fc], None);
                fwd[s][fc] = Some(t);
                let bc = t as usize + 2 * k - 2 - s;
                debug_assert_eq!(bwd[s][bc], None);
                bwd[s][bc] = Some(t);
            }
        }
        Schedule { fwd, bwd, partition: partition.clone(), batches }
    }

    /// Number of clock slots until all work completes.
    pub fn makespan_slots(&self) -> usize {
        let last = |rows: &Vec<Vec<Slot>>| {
            rows.iter()
                .map(|row| row.iter().rposition(Option::is_some).map_or(0, |p| p + 1))
                .max()
                .unwrap_or(0)
        };
        last(&self.fwd).max(last(&self.bwd))
    }

    /// Fraction of non-idle slots per scheduling unit (both lanes),
    /// within the makespan.
    pub fn utilization(&self) -> Vec<f64> {
        let span = self.makespan_slots();
        (0..self.partition.stages())
            .map(|s| {
                let busy = self.fwd[s][..span].iter().filter(|x| x.is_some()).count()
                    + self.bwd[s][..span].iter().filter(|x| x.is_some()).count();
                busy as f64 / (2 * span) as f64
            })
            .collect()
    }

    /// Observed gradient staleness per stage: the number of batches whose
    /// forward launches after `Fwd(t)` and at-or-before the clock where
    /// `Bwd(t)` produces the gradient — i.e. how many updates the
    /// gradient misses. This is the execution-level quantity Eq. 1
    /// predicts as `2·S(stage)`.
    pub fn observed_staleness(&self) -> Vec<usize> {
        let k = self.partition.stages();
        assert!(
            self.batches as usize >= 4 * k,
            "need >= 4K batches to probe steady state (got {} for K={k})",
            self.batches
        );
        let mut out = Vec::with_capacity(k);
        // Use a mid-pipeline batch to avoid fill/drain edges.
        let probe = self.batches / 2;
        for s in 0..k {
            let fpos = self.fwd[s].iter().position(|x| *x == Some(probe)).expect("fwd scheduled");
            let bpos = self.bwd[s].iter().position(|x| *x == Some(probe)).expect("bwd scheduled");
            let stale = self.fwd[s][fpos + 1..=bpos]
                .iter()
                .filter(|x| x.is_some())
                .count();
            out.push(stale);
        }
        out
    }

    /// Weight versions a stashing implementation must retain per stage:
    /// staleness + 1 (current + in-flight) — the O(L·S) term of §III-D.
    pub fn stash_versions(&self) -> Vec<usize> {
        self.observed_staleness().iter().map(|s| s + 1).collect()
    }
}

/// Timed performance summary under a cost model.
#[derive(Clone, Debug)]
pub struct PipelinePerf {
    /// Total time for `batches` iterations, pipelined.
    pub pipelined_time: f64,
    /// Total time sequentially (sum of all layer costs × batches).
    pub sequential_time: f64,
    /// Speedup (sequential / pipelined).
    pub speedup: f64,
    /// Mean processor utilization in steady state.
    pub mean_utilization: f64,
    /// Bytes crossing stage boundaries over the whole run (activations
    /// forward + gradients backward).
    pub comm_bytes: usize,
    /// The slowest stage's per-iteration cost (the pipeline's clock).
    pub bottleneck_cost: f64,
}

/// Evaluate throughput of a partition under a cost model.
///
/// In steady state the pipeline completes one iteration per
/// `max_stage_cost` time; fill/drain add `(K−1)` stage times at each end.
pub fn evaluate(partition: &StagePartition, cost: &CostModel, batches: u64) -> PipelinePerf {
    let k = partition.stages();
    let stage_costs: Vec<f64> = (0..k).map(|s| cost.stage_cost(partition, s)).collect();
    let bottleneck = stage_costs.iter().cloned().fold(0.0, f64::max);
    let total_per_batch: f64 = stage_costs.iter().sum();
    let sequential_time = total_per_batch * batches as f64;
    // Fill with per-stage costs, then bottleneck-paced steady state.
    let fill: f64 = stage_costs.iter().take(k - 1).sum();
    let pipelined_time = fill + bottleneck * batches as f64;
    let speedup = sequential_time / pipelined_time;
    let mean_utilization = total_per_batch / (k as f64 * bottleneck);
    // Each boundary moves activations forward and gradients backward once
    // per batch: 2 transfers per boundary per batch.
    let comm_bytes = 2 * (k - 1) * cost.boundary_bytes * batches as usize;
    PipelinePerf {
        pipelined_time,
        sequential_time,
        speedup,
        mean_utilization: mean_utilization.min(1.0),
        comm_bytes,
        bottleneck_cost: bottleneck,
    }
}

/// Sweep stage counts for a fixed layer count, reporting the
/// communication-computation tradeoff the paper's conclusion discusses.
pub fn sweep_stages(
    layers: usize,
    cost: &CostModel,
    batches: u64,
    stage_counts: &[usize],
) -> Vec<(usize, PipelinePerf)> {
    stage_counts
        .iter()
        .filter(|&&k| k >= 1 && k <= layers)
        .map(|&k| {
            let p = StagePartition::even(layers, k).expect("valid partition");
            (k, evaluate(&p, cost, batches))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::retiming::delay_formula;
    use crate::testing::property;

    #[test]
    fn schedule_slots_are_conflict_free() {
        let p = StagePartition::even(4, 4).unwrap();
        let s = Schedule::build(&p, 6);
        // Each stage does each batch's F and B exactly once, one per slot.
        for st in 0..4 {
            let fwd = s.fwd[st].iter().filter(|x| x.is_some()).count();
            let bwd = s.bwd[st].iter().filter(|x| x.is_some()).count();
            assert_eq!(fwd, 6);
            assert_eq!(bwd, 6);
            // Batches appear in order in each lane.
            let batches: Vec<u64> = s.fwd[st].iter().flatten().copied().collect();
            assert_eq!(batches, (0..6).collect::<Vec<_>>());
        }
    }

    #[test]
    fn makespan_is_batches_plus_fill_drain() {
        let p = StagePartition::even(4, 4).unwrap();
        let s = Schedule::build(&p, 10);
        // Last event at clock B−1 + 2K−2 ⇒ makespan B + 2K − 2.
        assert_eq!(s.makespan_slots(), 10 + 2 * 4 - 2);
    }

    #[test]
    fn observed_staleness_matches_eq1() {
        // The schedule-level check of Delay(l) = 2·S(l): a per-layer
        // pipeline over 5 layers must show staleness [8, 6, 4, 2, 0].
        let p = StagePartition::even(5, 5).unwrap();
        let s = Schedule::build(&p, 20);
        assert_eq!(s.observed_staleness(), vec![8, 6, 4, 2, 0]);
    }

    #[test]
    fn property_schedule_staleness_equals_retiming_delays() {
        // The paper's two derivations agree: schedule simulation and
        // retiming closed form give identical delays for ANY partition.
        property(30, |rng, _case| {
            let layers = 2 + rng.index(8);
            let stages = 1 + rng.index(layers);
            let p = StagePartition::even(layers, stages).unwrap();
            let s = Schedule::build(&p, 64);
            let per_stage = s.observed_staleness();
            let per_layer: Vec<usize> =
                (0..layers).map(|l| per_stage[p.stage_of()[l]]).collect();
            assert_eq!(
                per_layer,
                delay_formula(p.stage_of()),
                "layers={layers} stages={stages}"
            );
        });
    }

    #[test]
    fn stash_versions_are_staleness_plus_one() {
        let p = StagePartition::even(4, 4).unwrap();
        let s = Schedule::build(&p, 16);
        assert_eq!(s.stash_versions(), vec![7, 5, 3, 1]);
    }

    #[test]
    fn speedup_grows_with_stages_on_uniform_costs() {
        let cost = CostModel::uniform(8);
        let r = sweep_stages(8, &cost, 1000, &[1, 2, 4, 8]);
        let speedups: Vec<f64> = r.iter().map(|(_, p)| p.speedup).collect();
        assert!(speedups.windows(2).all(|w| w[1] > w[0]), "{speedups:?}");
        // 8 uniform stages → near-8× in the long-batch limit.
        assert!(speedups[3] > 7.0, "{}", speedups[3]);
        // Sequential (1 stage) is exactly 1.0.
        assert!((speedups[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn bottleneck_stage_limits_speedup() {
        // One expensive layer caps the pipeline clock.
        let mut cost = CostModel::uniform(4);
        cost.fwd[2] = 10.0;
        cost.bwd[2] = 20.0;
        let p = StagePartition::even(4, 4).unwrap();
        let perf = evaluate(&p, &cost, 1000);
        assert!((perf.bottleneck_cost - 30.0).abs() < 1e-9);
        // total per batch = 3·3 + 30 = 39 → speedup ≤ 39/30.
        assert!(perf.speedup < 39.0 / 30.0 + 1e-6);
    }

    #[test]
    fn comm_volume_scales_with_boundaries() {
        let mut cost = CostModel::uniform(8);
        cost.boundary_bytes = 100;
        let r = sweep_stages(8, &cost, 10, &[1, 2, 4, 8]);
        let bytes: Vec<usize> = r.iter().map(|(_, p)| p.comm_bytes).collect();
        assert_eq!(bytes, vec![0, 2000, 6000, 14000]);
    }

    #[test]
    fn utilization_bounded_and_sane() {
        let cost = CostModel::uniform(6);
        let p = StagePartition::even(6, 3).unwrap();
        let perf = evaluate(&p, &cost, 100);
        assert!(perf.mean_utilization > 0.9 && perf.mean_utilization <= 1.0);
    }
}
