//! Adaptive stage/delay selection (the paper's §V future-work item:
//! "incorporating adaptive delay selection into the training process").
//!
//! Picks the pipeline depth that maximizes modeled throughput subject to
//! two constraints the paper's analysis exposes:
//!
//! 1. **Staleness budget** — the deepest layer's delay `2·(K−1)` must
//!    stay under a DLMS-style stability margin `max_delay` (derived from
//!    the optimizer's effective step size; callers may obtain it from
//!    [`crate::dlms::stable_mu_bound`]-style reasoning or empirics).
//! 2. **Communication budget** — bytes crossing stage boundaries per
//!    batch must not exceed `max_comm_bytes` (the paper's
//!    communication-computation tradeoff).

use super::{evaluate, CostModel};
use crate::retiming::StagePartition;

/// Constraints for adaptive selection.
#[derive(Clone, Copy, Debug)]
pub struct AdaptiveLimits {
    /// Largest tolerable gradient delay (`2·(K−1) ≤ max_delay`).
    pub max_delay: usize,
    /// Per-batch boundary traffic budget in bytes (0 = unlimited).
    pub max_comm_bytes: usize,
}

impl Default for AdaptiveLimits {
    fn default() -> Self {
        AdaptiveLimits { max_delay: usize::MAX, max_comm_bytes: 0 }
    }
}

/// Outcome of the selection.
#[derive(Clone, Debug)]
pub struct AdaptiveChoice {
    pub stages: usize,
    pub speedup: f64,
    pub max_delay: usize,
    pub comm_bytes_per_batch: usize,
    /// The chosen cost-balanced partition — the exact boundaries a
    /// trainer built on the same cost reports will pick, so callers can
    /// act on the choice without re-deriving it.
    pub partition: StagePartition,
    /// (stages, speedup, feasible) for every candidate — the audit trail.
    pub candidates: Vec<(usize, f64, bool)>,
}

/// Choose the stage count in `1..=layers` with the best modeled speedup
/// that satisfies the limits. Always feasible: K=1 has zero delay and
/// zero communication.
///
/// Conv-aware: every candidate `K` is evaluated on its **cost-balanced**
/// partition (`StagePartition::balanced` over the model's per-layer
/// totals) — the same boundaries `Trainer::with_spec` derives from the
/// `LayerCost` reports — so the choice and the trainers agree on
/// heterogeneous stacks. Uniform costs balance to the even split, which
/// keeps the legacy behavior bit-for-bit.
pub fn choose_stages(layers: usize, cost: &CostModel, limits: &AdaptiveLimits) -> AdaptiveChoice {
    assert!(layers >= 1);
    assert_eq!(cost.fwd.len(), layers, "cost model covers every layer");
    let costs_u64 = cost.layer_costs_u64();
    let mut best: Option<(usize, f64)> = None;
    let mut candidates = Vec::with_capacity(layers);
    for k in 1..=layers {
        let p = StagePartition::balanced(&costs_u64, k).expect("valid partition");
        let perf = evaluate(&p, cost, 10_000);
        let delay = p.max_delay();
        let comm = 2 * (k - 1) * cost.boundary_bytes;
        let feasible = delay <= limits.max_delay
            && (limits.max_comm_bytes == 0 || comm <= limits.max_comm_bytes);
        candidates.push((k, perf.speedup, feasible));
        if feasible && best.map_or(true, |(_, s)| perf.speedup > s) {
            best = Some((k, perf.speedup));
        }
    }
    let (stages, speedup) = best.expect("K=1 is always feasible");
    let partition = StagePartition::balanced(&costs_u64, stages).expect("valid partition");
    AdaptiveChoice {
        stages,
        speedup,
        max_delay: partition.max_delay(),
        comm_bytes_per_batch: 2 * (stages - 1) * cost.boundary_bytes,
        partition,
        candidates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_picks_max_stages_on_uniform_costs() {
        let cost = CostModel::uniform(8);
        let c = choose_stages(8, &cost, &AdaptiveLimits::default());
        assert_eq!(c.stages, 8);
        assert!(c.speedup > 7.0);
    }

    #[test]
    fn staleness_budget_caps_depth() {
        let cost = CostModel::uniform(8);
        // max delay 6 ⇒ 2(K−1) ≤ 6 ⇒ K ≤ 4.
        let c = choose_stages(8, &cost, &AdaptiveLimits { max_delay: 6, max_comm_bytes: 0 });
        assert_eq!(c.stages, 4);
        assert_eq!(c.max_delay, 6);
    }

    #[test]
    fn comm_budget_caps_depth() {
        let mut cost = CostModel::uniform(8);
        cost.boundary_bytes = 100;
        // comm = 2(K−1)·100 ≤ 500 ⇒ K ≤ 3.
        let c = choose_stages(8, &cost, &AdaptiveLimits { max_delay: usize::MAX, max_comm_bytes: 500 });
        assert_eq!(c.stages, 3);
        assert!(c.comm_bytes_per_batch <= 500);
    }

    #[test]
    fn skewed_costs_prefer_fewer_stages() {
        // When one layer dominates, deeper pipelines add staleness and
        // comm for little speedup; the selector should notice the
        // flattening speedup curve and every candidate be reported.
        let mut cost = CostModel::uniform(4);
        cost.fwd[0] = 50.0;
        cost.bwd[0] = 100.0;
        let c = choose_stages(4, &cost, &AdaptiveLimits::default());
        assert_eq!(c.candidates.len(), 4);
        // Speedup is essentially flat (≤ ~1.06x) — bottleneck-capped.
        assert!(c.speedup < 1.1, "speedup {}", c.speedup);
    }

    #[test]
    fn hetero_costs_drive_balanced_partitions() {
        use crate::layers::LayerCost;
        // Conv-heavy head + cheap/zero-cost tail: the model must carry
        // the LayerCost totals exactly, and the chosen partition must be
        // the same cost-balanced split the trainers derive.
        let costs = [
            LayerCost { fwd_flops: 9000, bwd_flops: 18000, act_bytes: 4096, param_bytes: 512 },
            LayerCost { fwd_flops: 300, bwd_flops: 600, act_bytes: 1024, param_bytes: 0 },
            LayerCost { fwd_flops: 0, bwd_flops: 0, act_bytes: 1024, param_bytes: 0 },
            LayerCost { fwd_flops: 400, bwd_flops: 800, act_bytes: 256, param_bytes: 128 },
        ];
        let cm = CostModel::from_layer_costs(&costs);
        assert_eq!(cm.boundary_bytes, 4096);
        let totals: Vec<u64> = costs.iter().map(LayerCost::total_flops).collect();
        assert_eq!(cm.layer_costs_u64(), totals);
        let c = choose_stages(4, &cm, &AdaptiveLimits { max_delay: 2, max_comm_bytes: 0 });
        assert_eq!(c.stages, 2, "delay budget 2 caps K at 2");
        let want = StagePartition::balanced(&totals, 2).unwrap();
        assert_eq!(c.partition.stage_of(), want.stage_of(), "choice ≡ balanced");
        // The conv layer dominates: it gets a stage to itself.
        assert_eq!(c.partition.stage_of(), &[0, 1, 1, 1]);
    }

    #[test]
    fn always_feasible_fallback_is_sequential() {
        let cost = CostModel::uniform(4);
        let c = choose_stages(
            4,
            &cost,
            &AdaptiveLimits { max_delay: 0, max_comm_bytes: 0 },
        );
        assert_eq!(c.stages, 1);
        assert_eq!(c.max_delay, 0);
    }
}
