//! Multiprocessor scheduling of forward/backward units (the
//! "multiprocessor scheduling" half of LayerPipe [11] that LayerPipe2
//! §I builds on).
//!
//! Each layer contributes two schedulable units — F_l and B_l (the δ+G
//! pair) — which the retimed delays make independent across stage
//! boundaries. This module maps units onto `P` processors:
//!
//! - [`assign_lpt`] — longest-processing-time list scheduling of whole
//!   stages onto processors (the classic 4/3-approximation), used when
//!   `P <` number of stages;
//! - [`simulate`] — per-clock simulation of the resulting system,
//!   reporting makespan, per-processor busy time, utilization and
//!   speedup over one processor.
//!
//! The paper's headline scheduling behaviour to reproduce: speedup
//! scales with P until the bottleneck stage dominates, and assigning
//! *adjacent* stages to one processor keeps communication local.

use crate::retiming::StagePartition;

use super::CostModel;

/// A processor assignment: `proc_of_stage[s]` = processor running stage `s`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub proc_of_stage: Vec<usize>,
    pub processors: usize,
}

impl Assignment {
    /// Stages owned by processor `p`, in order.
    pub fn stages_of(&self, p: usize) -> Vec<usize> {
        (0..self.proc_of_stage.len())
            .filter(|&s| self.proc_of_stage[s] == p)
            .collect()
    }

    /// Number of boundary crossings that are *remote* (between stages on
    /// different processors) — the communication the paper trades
    /// against computation.
    pub fn remote_boundaries(&self) -> usize {
        self.proc_of_stage
            .windows(2)
            .filter(|w| w[0] != w[1])
            .count()
    }
}

/// Longest-processing-time list scheduling of stages onto `processors`,
/// with a contiguity repair pass: stages are sorted by cost descending,
/// greedily placed on the least-loaded processor, then relabelled so
/// that each processor's stage set is renumbered in pipeline order
/// (keeps the measurement of remote boundaries meaningful).
pub fn assign_lpt(partition: &StagePartition, cost: &CostModel, processors: usize) -> Assignment {
    let k = partition.stages();
    assert!(processors >= 1);
    let p_eff = processors.min(k);
    let costs: Vec<f64> = (0..k).map(|s| cost.stage_cost(partition, s)).collect();
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by(|&a, &b| costs[b].partial_cmp(&costs[a]).unwrap());
    let mut load = vec![0.0f64; p_eff];
    let mut proc_of_stage = vec![0usize; k];
    for &s in &order {
        let (p, _) = load
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .expect("nonempty");
        proc_of_stage[s] = p;
        load[p] += costs[s];
    }
    Assignment { proc_of_stage, processors: p_eff }
}

/// Contiguous block assignment: stage `s` → processor `s·P/K` (adjacent
/// stages share processors — minimal remote communication, possibly
/// worse balance). The baseline LPT is compared against.
pub fn assign_contiguous(partition: &StagePartition, processors: usize) -> Assignment {
    let k = partition.stages();
    let p_eff = processors.min(k);
    let proc_of_stage = (0..k).map(|s| s * p_eff / k).collect();
    Assignment { proc_of_stage, processors: p_eff }
}

/// Simulation outcome.
#[derive(Clone, Debug)]
pub struct MultiprocPerf {
    pub makespan: f64,
    pub busy: Vec<f64>,
    pub utilization: f64,
    /// Speedup over running everything on one processor.
    pub speedup: f64,
    pub remote_boundaries: usize,
}

/// Evaluate an assignment under the cost model for `batches` iterations:
/// each processor's steady-state period is the sum of its stages' costs;
/// the pipeline clock is the slowest processor; utilization is
/// Σbusy / (P · makespan).
pub fn simulate(
    partition: &StagePartition,
    cost: &CostModel,
    assign: &Assignment,
    batches: u64,
) -> MultiprocPerf {
    let k = partition.stages();
    assert_eq!(assign.proc_of_stage.len(), k);
    let mut per_proc = vec![0.0f64; assign.processors];
    for s in 0..k {
        per_proc[assign.proc_of_stage[s]] += cost.stage_cost(partition, s);
    }
    let period = per_proc.iter().cloned().fold(0.0, f64::max);
    let total: f64 = per_proc.iter().sum();
    // Fill latency ≈ one traversal of all stages, then period-paced.
    let fill: f64 = (0..k.saturating_sub(1))
        .map(|s| cost.stage_cost(partition, s))
        .sum();
    let makespan = fill + period * batches as f64;
    let busy: Vec<f64> = per_proc.iter().map(|c| c * batches as f64).collect();
    let utilization =
        busy.iter().sum::<f64>() / (assign.processors as f64 * makespan);
    let speedup = (total * batches as f64) / makespan;
    MultiprocPerf {
        makespan,
        busy,
        utilization: utilization.min(1.0),
        speedup,
        remote_boundaries: assign.remote_boundaries(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(layers: usize, stages: usize) -> StagePartition {
        StagePartition::even(layers, stages).unwrap()
    }

    #[test]
    fn lpt_balances_uniform_stages() {
        let p = part(8, 8);
        let cost = CostModel::uniform(8);
        let a = assign_lpt(&p, &cost, 4);
        // 8 uniform stages on 4 procs → exactly 2 each.
        for proc in 0..4 {
            assert_eq!(a.stages_of(proc).len(), 2, "proc {proc}");
        }
    }

    #[test]
    fn lpt_handles_skew_better_than_contiguous() {
        // One giant stage: LPT isolates it; contiguous blocks may pair it.
        let p = part(8, 8);
        let mut cost = CostModel::uniform(8);
        cost.fwd[0] = 10.0;
        cost.bwd[0] = 20.0;
        let lpt = simulate(&p, &cost, &assign_lpt(&p, &cost, 4), 1000);
        let contig = simulate(&p, &cost, &assign_contiguous(&p, 4), 1000);
        assert!(lpt.speedup >= contig.speedup - 1e-9);
    }

    #[test]
    fn contiguous_minimizes_remote_boundaries() {
        let p = part(8, 8);
        let cost = CostModel::uniform(8);
        let contig = assign_contiguous(&p, 4);
        let lpt = assign_lpt(&p, &cost, 4);
        assert_eq!(contig.remote_boundaries(), 3); // P−1 cuts
        assert!(lpt.remote_boundaries() >= contig.remote_boundaries());
    }

    #[test]
    fn speedup_scales_until_stage_count() {
        let p = part(8, 8);
        let cost = CostModel::uniform(8);
        let mut prev = 0.0;
        for procs in [1usize, 2, 4, 8] {
            let perf = simulate(&p, &cost, &assign_contiguous(&p, procs), 10_000);
            assert!(perf.speedup > prev, "procs {procs}");
            prev = perf.speedup;
        }
        // Beyond K processors nothing improves (stages are atomic units).
        let at_k = simulate(&p, &cost, &assign_contiguous(&p, 8), 10_000).speedup;
        let past_k = simulate(&p, &cost, &assign_contiguous(&p, 16), 10_000).speedup;
        assert!((at_k - past_k).abs() < 1e-9);
    }

    #[test]
    fn single_processor_is_sequential() {
        let p = part(4, 4);
        let cost = CostModel::uniform(4);
        let perf = simulate(&p, &cost, &assign_contiguous(&p, 1), 100);
        assert!((perf.speedup - 1.0).abs() < 0.05);
        assert!(perf.utilization > 0.95);
    }

    #[test]
    fn utilization_bounded() {
        let p = part(6, 3);
        let mut cost = CostModel::uniform(6);
        cost.fwd[5] = 7.0;
        let perf = simulate(&p, &cost, &assign_lpt(&p, &cost, 3), 500);
        assert!(perf.utilization > 0.0 && perf.utilization <= 1.0);
        assert_eq!(perf.busy.len(), 3);
    }
}
