//! The five weight-handling strategies of the paper's Fig. 5.
//!
//! In pipelined execution, the backward pass for the batch launched at
//! iteration `t` runs at iteration `t + d` (layer delay `d = 2·S(l)`,
//! Eq. 1). Each strategy answers one question: *which weight version does
//! that delayed backward use?*
//!
//! | strategy            | backward weights                  | extra memory |
//! |---------------------|-----------------------------------|--------------|
//! | sequential          | (no delay; reference)             | none         |
//! | weight stashing     | true stored `W(t)`                | `O(d)`/layer |
//! | latest-weight       | current `W(t+d)`                  | none         |
//! | fixed-decay EMA     | `W(t+d) + lr_sum·Ḡ_β`, `β=0.9`    | `O(1)`/layer |
//! | pipeline-aware EMA  | `W(t+d) + lr_sum·Ḡ(n)`, Eqs. 7–9  | `O(1)`/layer |

use crate::ema::{FixedEma, GradientAverager, PipelineAwareEma};
use crate::stash::WeightStash;
use crate::tensor::{Dtype, Tensor};
use anyhow::bail;

/// Identifier for a weight-handling strategy (config / CLI surface).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// Standard non-pipelined backpropagation (delay 0 everywhere).
    Sequential,
    /// Pipelined with exact historical weight storage (PipeDream-style).
    Stashing,
    /// Pipelined, delayed gradients computed against current weights.
    Latest,
    /// Pipelined, historical weights approximated with a fixed-β EMA.
    FixedEma,
    /// Pipelined, the paper's delay-conditioned EMA reconstruction.
    PipelineAwareEma,
}

impl StrategyKind {
    pub fn all() -> &'static [StrategyKind] {
        &[
            StrategyKind::Sequential,
            StrategyKind::Stashing,
            StrategyKind::Latest,
            StrategyKind::FixedEma,
            StrategyKind::PipelineAwareEma,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            StrategyKind::Sequential => "sequential",
            StrategyKind::Stashing => "stashing",
            StrategyKind::Latest => "latest",
            StrategyKind::FixedEma => "fixed_ema",
            StrategyKind::PipelineAwareEma => "pipeline_ema",
        }
    }

    pub fn parse(s: &str) -> crate::Result<StrategyKind> {
        Ok(match s {
            "sequential" | "seq" => StrategyKind::Sequential,
            "stashing" | "stash" => StrategyKind::Stashing,
            "latest" | "latest_weight" => StrategyKind::Latest,
            "fixed_ema" | "fixed-ema" => StrategyKind::FixedEma,
            "pipeline_ema" | "pipeline-ema" | "pipeline_aware" => StrategyKind::PipelineAwareEma,
            other => bail!(
                "unknown strategy '{other}' (expected one of: sequential, stashing, latest, fixed_ema, pipeline_ema)"
            ),
        })
    }

    /// Whether this strategy executes with pipeline delays.
    pub fn is_pipelined(&self) -> bool {
        !matches!(self, StrategyKind::Sequential)
    }
}

/// Fixed-decay β for the conventional-EMA baseline (paper §IV-B).
pub const FIXED_EMA_BETA: f32 = 0.9;

/// Per-layer staleness-handling state for one strategy.
///
/// Lifecycle per pipelined iteration `t` for a layer with delay `d`:
/// 1. `on_forward(t, &weights)` when the batch launches;
/// 2. `backward_weights(t, &weights_now, lr_sum)` at `t + d`, returning
///    the weight version the backward pass must use;
/// 3. after the optimizer applies the resulting gradient,
///    `on_update(&applied_update)`.
pub struct LayerStrategy {
    kind: StrategyKind,
    /// Gradient delay `d = 2·S(l)` for this layer.
    delay: usize,
    stash: Option<WeightStash>,
    averager: Option<Box<dyn GradientAverager>>,
    /// While `true`, EMA strategies fall back to latest weights (the
    /// paper's warm-up period during which the averages stabilize).
    warmup: bool,
    /// Persistent workspace for EMA weight reconstruction: reused every
    /// backward, so the hot path performs copy + axpy with zero
    /// allocation. A scratch buffer, not state — excluded from the
    /// staleness-byte accounting.
    recon_buf: Tensor,
}

impl LayerStrategy {
    pub fn new(kind: StrategyKind, delay: usize) -> Self {
        LayerStrategy::new_with_dtype(kind, delay, Dtype::F32)
    }

    /// [`LayerStrategy::new`] with staleness state (EMA accumulators)
    /// stored in `dtype`. The stash needs no parameter: it clones the
    /// weight tensors it is handed and so inherits their dtype; the
    /// reconstruction workspace stays f32 (`reconstruct_into` widens).
    pub fn new_with_dtype(kind: StrategyKind, delay: usize, dtype: Dtype) -> Self {
        let stash = match kind {
            StrategyKind::Stashing if delay > 0 => Some(WeightStash::new(delay + 1)),
            _ => None,
        };
        let averager: Option<Box<dyn GradientAverager>> = match kind {
            StrategyKind::FixedEma => {
                Some(Box::new(FixedEma::new_with_dtype(FIXED_EMA_BETA, dtype)))
            }
            StrategyKind::PipelineAwareEma => {
                // Window matched to the layer's own delay (Eq. 8–9);
                // a zero-delay layer needs no reconstruction but keep a
                // width-1 window so the state machine is uniform.
                Some(Box::new(PipelineAwareEma::new_with_dtype(delay.max(1), dtype)))
            }
            _ => None,
        };
        LayerStrategy { kind, delay, stash, averager, warmup: false, recon_buf: Tensor::empty() }
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    pub fn delay(&self) -> usize {
        self.delay
    }

    /// Enable/disable the EMA warm-up fallback.
    pub fn set_warmup(&mut self, on: bool) {
        self.warmup = on;
    }

    /// Record the weight version used by the forward pass of iteration `t`.
    pub fn on_forward(&mut self, t: u64, weights: &Tensor) {
        if let Some(stash) = &mut self.stash {
            stash.push(t, weights);
        }
    }

    /// The weight version for the backward pass of the batch launched at
    /// iteration `t` (running now, `delay` iterations later).
    ///
    /// `current` are the live weights; `lr_sum` is the sum of learning
    /// rates over the `delay` intervening optimizer steps (Eq. 9's
    /// `α(2n+1)` term under a constant lr, exact under schedules).
    ///
    /// Always returns a borrow: latest/stashed versions already exist,
    /// and EMA reconstruction writes into the strategy's persistent
    /// workspace — the hot path never allocates here.
    pub fn backward_weights<'a>(&'a mut self, t: u64, current: &'a Tensor, lr_sum: f32) -> &'a Tensor {
        if self.delay == 0 {
            return current;
        }
        match self.kind {
            StrategyKind::Sequential | StrategyKind::Latest => current,
            StrategyKind::Stashing => {
                let stash = self.stash.as_ref().expect("stashing strategy has a stash");
                stash.get(t).unwrap_or_else(|| {
                    panic!(
                        "weight stash miss: iteration {t} not retained (oldest {:?})",
                        stash.oldest()
                    )
                })
            }
            StrategyKind::FixedEma | StrategyKind::PipelineAwareEma => {
                if self.warmup {
                    current
                } else {
                    let avg = self.averager.as_ref().expect("ema strategy has an averager");
                    avg.reconstruct_into(current, lr_sum, &mut self.recon_buf);
                    &self.recon_buf
                }
            }
        }
    }

    /// Feed the applied optimizer update (for the EMA accumulators).
    pub fn on_update(&mut self, update: &Tensor) {
        if let Some(avg) = &mut self.averager {
            avg.push(update);
        }
    }

    /// Bytes of staleness-handling state (stash + EMA accumulators).
    pub fn staleness_nbytes(&self) -> usize {
        self.stash.as_ref().map_or(0, |s| s.nbytes())
            + self.averager.as_ref().map_or(0, |a| a.state_nbytes())
    }

    /// Peak bytes (stash high-water mark + EMA state).
    pub fn peak_staleness_nbytes(&self) -> usize {
        self.stash.as_ref().map_or(0, |s| s.peak_nbytes())
            + self.averager.as_ref().map_or(0, |a| a.state_nbytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(v: f32) -> Tensor {
        Tensor::from_vec(&[2], vec![v, 2.0 * v])
    }

    #[test]
    fn parse_roundtrip() {
        for k in StrategyKind::all() {
            assert_eq!(StrategyKind::parse(k.name()).unwrap(), *k);
        }
        assert!(StrategyKind::parse("bogus").is_err());
    }

    #[test]
    fn stashing_returns_the_launch_version() {
        let mut s = LayerStrategy::new(StrategyKind::Stashing, 2);
        s.on_forward(0, &w(0.0));
        s.on_forward(1, &w(1.0));
        s.on_forward(2, &w(2.0));
        // backward for t=0 runs now (t=2): must see W(0), not W(2).
        let cur = w(2.0);
        let bw = s.backward_weights(0, &cur, 0.0);
        assert_eq!(bw.data(), w(0.0).data());
    }

    #[test]
    #[should_panic(expected = "stash miss")]
    fn stashing_misses_beyond_window() {
        let mut s = LayerStrategy::new(StrategyKind::Stashing, 1);
        for t in 0..4 {
            s.on_forward(t, &w(t as f32));
        }
        let cur = w(3.0);
        let _ = s.backward_weights(0, &cur, 0.0);
    }

    #[test]
    fn latest_returns_current() {
        let mut s = LayerStrategy::new(StrategyKind::Latest, 3);
        s.on_forward(0, &w(0.0));
        let cur = w(9.0);
        let bw = s.backward_weights(0, &cur, 0.5);
        assert_eq!(bw.data(), cur.data());
    }

    #[test]
    fn ema_reconstructs_toward_history() {
        // Constant update u ⇒ W(t−d) = W(t) + lr·d·u exactly; pipeline-
        // aware EMA of a constant stream equals u, so reconstruction is
        // exact here.
        let d = 4;
        let lr = 0.1;
        let mut s = LayerStrategy::new(StrategyKind::PipelineAwareEma, d);
        let u = w(1.0);
        let mut cur = w(10.0);
        for t in 0..10u64 {
            s.on_forward(t, &cur);
            cur.axpy(-lr, &u);
            s.on_update(&u);
        }
        let lr_sum = lr * d as f32;
        let recon = s.backward_weights(5, &cur, lr_sum);
        let mut expect = cur.clone();
        expect.axpy(lr_sum, &u);
        assert!(recon.max_abs_diff(&expect) < 1e-6);
    }

    #[test]
    fn warmup_falls_back_to_latest() {
        let mut s = LayerStrategy::new(StrategyKind::PipelineAwareEma, 4);
        s.set_warmup(true);
        s.on_update(&w(100.0));
        let cur = w(1.0);
        let bw = s.backward_weights(0, &cur, 1.0);
        assert_eq!(bw.data(), cur.data());
        s.set_warmup(false);
        let bw2 = s.backward_weights(0, &cur, 1.0);
        assert!(bw2.max_abs_diff(&cur) > 1.0, "reconstruction active after warmup");
    }

    #[test]
    fn zero_delay_is_transparent_for_all() {
        for k in StrategyKind::all() {
            let mut s = LayerStrategy::new(*k, 0);
            s.on_forward(0, &w(1.0));
            let cur = w(5.0);
            let bw = s.backward_weights(0, &cur, 0.3);
            assert_eq!(bw.data(), cur.data(), "{k:?}");
        }
    }

    #[test]
    fn bf16_state_halves_and_reconstruction_is_f32() {
        // Mixed-precision staleness state: EMA accumulators store bf16
        // (half the bytes), the stash inherits the dtype of the weights
        // pushed into it, and EMA reconstruction always emits f32.
        let delay = 3;
        let mut q = LayerStrategy::new_with_dtype(StrategyKind::PipelineAwareEma, delay, Dtype::Bf16);
        let mut full = LayerStrategy::new(StrategyKind::PipelineAwareEma, delay);
        let u = w(1.0);
        for _ in 0..5 {
            q.on_update(&u);
            full.on_update(&u);
        }
        assert_eq!(q.staleness_nbytes() * 2, full.staleness_nbytes());
        let cur = w(10.0).to_dtype(Dtype::Bf16);
        let bw = q.backward_weights(0, &cur, 0.5);
        assert_eq!(bw.dtype(), Dtype::F32, "reconstruction widens");
        // Constant stream: mean is exactly u (representable in bf16), so
        // recon = widen(cur) + 0.5·u exactly.
        let mut expect = cur.to_dtype(Dtype::F32);
        expect.axpy(0.5, &u);
        assert_eq!(bw, &expect);

        let mut st = LayerStrategy::new_with_dtype(StrategyKind::Stashing, delay, Dtype::Bf16);
        for t in 0..4u64 {
            st.on_forward(t, &w(t as f32).to_dtype(Dtype::Bf16));
        }
        let stashed = st.backward_weights(0, &cur, 0.0);
        assert_eq!(stashed.dtype(), Dtype::Bf16, "stash keeps storage dtype");
        assert_eq!(stashed, &w(0.0).to_dtype(Dtype::Bf16));
    }

    #[test]
    fn memory_ordering_stash_vs_ema() {
        let delay = 14;
        let mut stash = LayerStrategy::new(StrategyKind::Stashing, delay);
        let mut ema = LayerStrategy::new(StrategyKind::PipelineAwareEma, delay);
        let big = Tensor::zeros(&[64, 64]);
        for t in 0..20u64 {
            stash.on_forward(t, &big);
            ema.on_forward(t, &big);
            ema.on_update(&big);
        }
        assert!(stash.staleness_nbytes() >= delay * big.nbytes());
        assert_eq!(ema.staleness_nbytes(), big.nbytes());
    }
}
