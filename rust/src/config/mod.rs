//! Experiment configuration system.
//!
//! [`toml`] is a TOML-subset parser (sections, `key = value` with strings,
//! ints, floats, bools, and homogeneous arrays — the subset every config in
//! `configs/` uses; serde/toml crates are unavailable offline). The typed
//! layer ([`ExperimentConfig`] et al.) validates and defaults every field,
//! so binaries fail fast with a readable message instead of panicking deep
//! in a run.

pub mod toml;

use crate::strategy::StrategyKind;
use crate::tensor::Dtype;
use anyhow::{bail, Context, Result};
use toml::TomlDoc;

/// Shape preset shared with the Python AOT compiler. Must match a manifest
/// produced by `python -m compile.aot --preset <name>`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Batch size (fixed at lowering time).
    pub batch: usize,
    /// Input feature dimension.
    pub input_dim: usize,
    /// Hidden width (all hidden layers share it so one artifact serves all).
    pub hidden_dim: usize,
    /// Number of classes.
    pub classes: usize,
    /// Total dense layers, input and output layers included (≥ 2).
    pub layers: usize,
    /// Parameter-init scale multiplier on He init.
    pub init_scale: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // The `small` preset: an 8-layer MLP giving the paper's 8
        // forward-backward scheduling units (see DESIGN.md substitutions).
        ModelConfig {
            batch: 32,
            input_dim: 64,
            hidden_dim: 64,
            classes: 16,
            layers: 8,
            init_scale: 1.0,
        }
    }
}

impl ModelConfig {
    pub fn validate(&self) -> Result<()> {
        if self.layers < 2 {
            bail!("model.layers must be >= 2 (input + output), got {}", self.layers);
        }
        for (name, v) in [
            ("batch", self.batch),
            ("input_dim", self.input_dim),
            ("hidden_dim", self.hidden_dim),
            ("classes", self.classes),
        ] {
            if v == 0 {
                bail!("model.{name} must be positive");
            }
        }
        Ok(())
    }
}

/// Optimizer hyper-parameters (paper §IV-A: SGD momentum + weight decay,
/// cosine-annealed lr starting at 0.1).
#[derive(Clone, Debug, PartialEq)]
pub struct OptimConfig {
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// `true` → cosine annealing over the full training horizon.
    pub cosine: bool,
    /// Floor for the cosine schedule.
    pub min_lr: f32,
}

impl Default for OptimConfig {
    fn default() -> Self {
        // The paper trains ResNet-18 with lr 0.1 / momentum 0.9. On this
        // substitute workload the same settings put delayed-gradient
        // training past the DLMS stability bound at the deepest delay
        // (2·(8−1) = 14), so the *stashing baseline itself* diverges.
        // lr 0.05 / momentum 0.7 is the regime that reproduces the
        // paper's Fig. 5 contrast: stashing converges, latest-weight
        // degrades, EMA reconstruction recovers (see DESIGN.md
        // substitutions; all strategies share these settings).
        OptimConfig { lr: 0.05, momentum: 0.7, weight_decay: 5e-4, cosine: true, min_lr: 1e-4 }
    }
}

impl OptimConfig {
    pub fn validate(&self) -> Result<()> {
        if !(self.lr > 0.0) {
            bail!("optim.lr must be > 0, got {}", self.lr);
        }
        if !(0.0..1.0).contains(&self.momentum) {
            bail!("optim.momentum must be in [0,1), got {}", self.momentum);
        }
        if self.weight_decay < 0.0 {
            bail!("optim.weight_decay must be >= 0");
        }
        Ok(())
    }
}

/// Pipeline shape: how layers are grouped into stages.
#[derive(Clone, Debug, PartialEq)]
pub struct PipelineConfig {
    /// Number of pipeline stages. Layers are partitioned contiguously and
    /// as evenly as possible; `stages == layers` is the per-layer case.
    pub stages: usize,
    /// EMA warm-up in epochs before reconstruction is trusted (paper: 2).
    pub warmup_epochs: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        // warmup_epochs = 0: the paper describes a 2-epoch warm-up during
        // which the EMA stabilizes before being trusted, with latest
        // weights used meanwhile. On this workload the latest-weight
        // fallback is itself unstable, and it turns out the warm-up is
        // structurally unnecessary: Eq. 7's β(n)=n/(n+1) ramp *is* a
        // warm-up (exact cumulative mean during pipeline fill), and with
        // update-aware lr_sum accounting (train/mod.rs) reconstruction is
        // near-exact from the first delayed backward. The ablation bench
        // sweeps warmup ∈ {0,1,2} to document this.
        PipelineConfig { stages: 8, warmup_epochs: 0 }
    }
}

/// Synthetic-dataset parameters (the CIFAR-100 substitute; DESIGN.md).
#[derive(Clone, Debug, PartialEq)]
pub struct DataConfig {
    pub train_samples: usize,
    pub test_samples: usize,
    /// Hidden width of the teacher MLP that labels the data.
    pub teacher_hidden: usize,
    /// Fraction of labels resampled uniformly (label noise).
    pub label_noise: f64,
    pub seed: u64,
}

impl Default for DataConfig {
    fn default() -> Self {
        DataConfig {
            train_samples: 4096,
            test_samples: 1024,
            teacher_hidden: 48,
            label_noise: 0.05,
            seed: 1234,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentConfig {
    pub model: ModelConfig,
    pub optim: OptimConfig,
    pub pipeline: PipelineConfig,
    pub data: DataConfig,
    pub epochs: usize,
    pub seed: u64,
    /// Storage dtype for weights, activations and gradient wire traffic
    /// (DESIGN.md §11). `F32` is the bitwise-frozen default; `Bf16`
    /// halves the hot-path footprint while optimizer masters and every
    /// multi-element accumulation stay f32.
    pub dtype: Dtype,
    /// Which weight-handling strategies a sweep covers.
    pub strategies: Vec<StrategyKind>,
    /// Directory with `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: String,
    /// Optional CSV output path for per-epoch metrics.
    pub csv_out: Option<String>,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: ModelConfig::default(),
            optim: OptimConfig::default(),
            pipeline: PipelineConfig::default(),
            data: DataConfig::default(),
            epochs: 12,
            seed: 7,
            dtype: Dtype::F32,
            strategies: StrategyKind::all().to_vec(),
            artifacts_dir: "artifacts".to_string(),
            csv_out: None,
        }
    }
}

impl ExperimentConfig {
    pub fn validate(&self) -> Result<()> {
        self.model.validate()?;
        self.optim.validate()?;
        if self.pipeline.stages == 0 {
            bail!("pipeline.stages must be positive");
        }
        if self.pipeline.stages > self.model.layers {
            bail!(
                "pipeline.stages ({}) cannot exceed model.layers ({})",
                self.pipeline.stages,
                self.model.layers
            );
        }
        if self.epochs == 0 {
            bail!("epochs must be positive");
        }
        if self.strategies.is_empty() {
            bail!("at least one strategy required");
        }
        Ok(())
    }

    /// Load from a TOML file, overlaying defaults.
    pub fn load(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_toml_str(&text).with_context(|| format!("parsing config {path}"))
    }

    /// Parse from TOML text, overlaying defaults.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let doc = TomlDoc::parse(text)?;
        let mut c = ExperimentConfig::default();

        if let Some(v) = doc.get_usize("", "epochs")? {
            c.epochs = v;
        }
        if let Some(v) = doc.get_u64("", "seed")? {
            c.seed = v;
        }
        if let Some(v) = doc.get_str("", "artifacts_dir")? {
            c.artifacts_dir = v;
        }
        if let Some(v) = doc.get_str("", "csv_out")? {
            c.csv_out = Some(v);
        }
        if let Some(v) = doc.get_str("", "dtype")? {
            c.dtype = match Dtype::parse(&v) {
                Some(d) => d,
                None => bail!("unknown dtype {v:?} (expected \"f32\" or \"bf16\")"),
            };
        }
        if let Some(items) = doc.get_str_array("", "strategies")? {
            c.strategies = items
                .iter()
                .map(|s| StrategyKind::parse(s))
                .collect::<Result<Vec<_>>>()?;
        }

        if let Some(v) = doc.get_usize("model", "batch")? {
            c.model.batch = v;
        }
        if let Some(v) = doc.get_usize("model", "input_dim")? {
            c.model.input_dim = v;
        }
        if let Some(v) = doc.get_usize("model", "hidden_dim")? {
            c.model.hidden_dim = v;
        }
        if let Some(v) = doc.get_usize("model", "classes")? {
            c.model.classes = v;
        }
        if let Some(v) = doc.get_usize("model", "layers")? {
            c.model.layers = v;
        }
        if let Some(v) = doc.get_f64("model", "init_scale")? {
            c.model.init_scale = v as f32;
        }

        if let Some(v) = doc.get_f64("optim", "lr")? {
            c.optim.lr = v as f32;
        }
        if let Some(v) = doc.get_f64("optim", "momentum")? {
            c.optim.momentum = v as f32;
        }
        if let Some(v) = doc.get_f64("optim", "weight_decay")? {
            c.optim.weight_decay = v as f32;
        }
        if let Some(v) = doc.get_bool("optim", "cosine")? {
            c.optim.cosine = v;
        }
        if let Some(v) = doc.get_f64("optim", "min_lr")? {
            c.optim.min_lr = v as f32;
        }

        if let Some(v) = doc.get_usize("pipeline", "stages")? {
            c.pipeline.stages = v;
        }
        if let Some(v) = doc.get_usize("pipeline", "warmup_epochs")? {
            c.pipeline.warmup_epochs = v;
        }

        if let Some(v) = doc.get_usize("data", "train_samples")? {
            c.data.train_samples = v;
        }
        if let Some(v) = doc.get_usize("data", "test_samples")? {
            c.data.test_samples = v;
        }
        if let Some(v) = doc.get_usize("data", "teacher_hidden")? {
            c.data.teacher_hidden = v;
        }
        if let Some(v) = doc.get_f64("data", "label_noise")? {
            c.data.label_noise = v;
        }
        if let Some(v) = doc.get_u64("data", "seed")? {
            c.data.seed = v;
        }

        c.validate()?;
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn parse_overlays_defaults() {
        let c = ExperimentConfig::from_toml_str(
            r#"
epochs = 3
seed = 99
strategies = ["stashing", "latest"]

[model]
layers = 4
hidden_dim = 32

[optim]
lr = 0.05
cosine = false

[pipeline]
stages = 4
"#,
        )
        .unwrap();
        assert_eq!(c.epochs, 3);
        assert_eq!(c.seed, 99);
        assert_eq!(c.model.layers, 4);
        assert_eq!(c.model.hidden_dim, 32);
        assert_eq!(c.model.batch, 32); // default preserved
        assert_eq!(c.optim.lr, 0.05);
        assert!(!c.optim.cosine);
        assert_eq!(c.pipeline.stages, 4);
        assert_eq!(c.strategies.len(), 2);
    }

    #[test]
    fn rejects_more_stages_than_layers() {
        let r = ExperimentConfig::from_toml_str("[model]\nlayers = 2\n[pipeline]\nstages = 4\n");
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_strategy_name() {
        let r = ExperimentConfig::from_toml_str(r#"strategies = ["nonsense"]"#);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_zero_epochs() {
        assert!(ExperimentConfig::from_toml_str("epochs = 0").is_err());
    }

    #[test]
    fn dtype_key_parses_and_defaults_to_f32() {
        assert_eq!(ExperimentConfig::default().dtype, Dtype::F32);
        let c = ExperimentConfig::from_toml_str(r#"dtype = "bf16""#).unwrap();
        assert_eq!(c.dtype, Dtype::Bf16);
        let c = ExperimentConfig::from_toml_str(r#"dtype = "f32""#).unwrap();
        assert_eq!(c.dtype, Dtype::F32);
        assert!(ExperimentConfig::from_toml_str(r#"dtype = "fp8""#).is_err());
    }
}
