//! TOML-subset parser.
//!
//! Supports the subset used by `configs/*.toml`: `[section]` headers
//! (one level), `key = value` pairs with basic strings, integers, floats,
//! booleans, and flat homogeneous arrays, plus `#` comments. Duplicate
//! keys within a section are an error (catches config typos). This is a
//! deliberate substitute for the `toml` crate, which the offline registry
//! does not carry.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

/// A parsed document: `(section, key) → value`. The root section is `""`.
#[derive(Debug, Default)]
pub struct TomlDoc {
    map: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(src: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header '{raw}'", lineno + 1);
                };
                section = name.trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected 'key = value', got '{raw}'", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let val_src = line[eq + 1..].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(val_src)
                .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
            if doc
                .map
                .insert((section.clone(), key.clone()), value)
                .is_some()
            {
                bail!("line {}: duplicate key '{key}' in section '[{section}]'", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.map.get(&(section.to_string(), key.to_string()))
    }

    /// All `(section, key)` pairs (used by config linting).
    pub fn keys(&self) -> impl Iterator<Item = (&str, &str)> {
        self.map.keys().map(|(s, k)| (s.as_str(), k.as_str()))
    }

    pub fn get_str(&self, section: &str, key: &str) -> Result<Option<String>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Str(s)) => Ok(Some(s.clone())),
            Some(v) => bail!("[{section}].{key}: expected string, got {v:?}"),
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Bool(b)) => Ok(Some(*b)),
            Some(v) => bail!("[{section}].{key}: expected bool, got {v:?}"),
        }
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Result<Option<f64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Float(f)) => Ok(Some(*f)),
            Some(TomlValue::Int(i)) => Ok(Some(*i as f64)),
            Some(v) => bail!("[{section}].{key}: expected number, got {v:?}"),
        }
    }

    pub fn get_u64(&self, section: &str, key: &str) -> Result<Option<u64>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Int(i)) if *i >= 0 => Ok(Some(*i as u64)),
            Some(v) => bail!("[{section}].{key}: expected non-negative int, got {v:?}"),
        }
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>> {
        Ok(self.get_u64(section, key)?.map(|v| v as usize))
    }

    pub fn get_str_array(&self, section: &str, key: &str) -> Result<Option<Vec<String>>> {
        match self.get(section, key) {
            None => Ok(None),
            Some(TomlValue::Array(items)) => items
                .iter()
                .map(|v| match v {
                    TomlValue::Str(s) => Ok(s.clone()),
                    other => bail!("[{section}].{key}: expected string array item, got {other:?}"),
                })
                .collect::<Result<Vec<_>>>()
                .map(Some),
            Some(v) => bail!("[{section}].{key}: expected array, got {v:?}"),
        }
    }
}

/// Strip a `#` comment, respecting string literals.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(src: &str) -> Result<TomlValue> {
    let src = src.trim();
    if src.is_empty() {
        bail!("empty value");
    }
    if let Some(body) = src.strip_prefix('"') {
        let Some(end) = body.find('"') else { bail!("unterminated string") };
        if !body[end + 1..].trim().is_empty() {
            bail!("trailing characters after string");
        }
        return Ok(TomlValue::Str(body[..end].to_string()));
    }
    if src == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if src == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = src.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else { bail!("unterminated array") };
        let body = body.trim();
        if body.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = split_array_items(body)?
            .into_iter()
            .map(|s| parse_value(&s))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    // Numbers: int if it parses as i64 and has no '.', 'e', 'E'.
    let clean = src.replace('_', "");
    if !clean.contains(['.', 'e', 'E']) {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{src}'")
}

/// Split `a, b, "c,d"` on commas outside string literals.
fn split_array_items(body: &str) -> Result<Vec<String>> {
    let mut items = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in body.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                items.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if in_str {
        bail!("unterminated string in array");
    }
    if !cur.trim().is_empty() {
        items.push(cur.trim().to_string());
    }
    Ok(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# top comment
epochs = 50            # trailing comment
lr = 0.1
name = "fig5 # not a comment"
flag = true

[model]
layers = 8
dims = [32, 64]
tags = ["a", "b"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_usize("", "epochs").unwrap(), Some(50));
        assert_eq!(doc.get_f64("", "lr").unwrap(), Some(0.1));
        assert_eq!(doc.get_str("", "name").unwrap().unwrap(), "fig5 # not a comment");
        assert_eq!(doc.get_bool("", "flag").unwrap(), Some(true));
        assert_eq!(doc.get_usize("model", "layers").unwrap(), Some(8));
        assert_eq!(
            doc.get_str_array("model", "tags").unwrap().unwrap(),
            vec!["a".to_string(), "b".to_string()]
        );
    }

    #[test]
    fn int_vs_float_coercion() {
        let doc = TomlDoc::parse("x = 3\ny = 3.5\nz = 1e-3\nu = 1_000\n").unwrap();
        assert_eq!(doc.get_f64("", "x").unwrap(), Some(3.0));
        assert_eq!(doc.get_f64("", "y").unwrap(), Some(3.5));
        assert_eq!(doc.get_f64("", "z").unwrap(), Some(1e-3));
        assert_eq!(doc.get_u64("", "u").unwrap(), Some(1000));
    }

    #[test]
    fn duplicate_key_is_error() {
        assert!(TomlDoc::parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn type_mismatch_is_error() {
        let doc = TomlDoc::parse("a = \"str\"\n").unwrap();
        assert!(doc.get_usize("", "a").is_err());
    }

    #[test]
    fn malformed_lines_error() {
        assert!(TomlDoc::parse("[unclosed\n").is_err());
        assert!(TomlDoc::parse("justakey\n").is_err());
        assert!(TomlDoc::parse("k = [1, 2\n").is_err());
        assert!(TomlDoc::parse("k = \"oops\n").is_err());
    }

    #[test]
    fn missing_returns_none() {
        let doc = TomlDoc::parse("a = 1\n").unwrap();
        assert_eq!(doc.get_usize("model", "nope").unwrap(), None);
    }
}
