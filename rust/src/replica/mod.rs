//! Weight-ring replica parallelism: 2D (pipeline × data) training with
//! a deterministic all-reduce.
//!
//! LayerPipe2's per-layer delays come purely from downstream stage
//! count, so the stage pipeline composes cleanly with data parallelism:
//! N in-process replica workers each drive their own deferred-step
//! [`Trainer`] over a shard of the batch stream, and gradients are
//! combined with a fixed-geometry tree reduction before anyone steps.
//!
//! **The invariance trick.** Summing N per-replica gradients in an
//! N-shaped tree would give different f32 bits at different replica
//! counts. Instead every global batch is decomposed into `S` fixed
//! micro-**shards** (`S` chosen once, independent of N): shard lane `j`
//! always trains on rows `j·(B/S) .. (j+1)·(B/S)` of every global
//! batch, and the all-reduce combines the `S` shard gradients in the
//! gap-doubling pairwise order keyed on `S` alone —
//! `((g0+g1)+(g2+g3))+…` — the same fixed-pairwise geometry the matmul
//! `dw` tree reduction uses for worker-count stability. Replica count
//! only decides which thread hosts which contiguous block of lanes
//! (`S % N == 0`), so N=1,2,4,8 produce bit-identical weights by
//! construction. The semantics are mean-of-shard-gradients: each
//! lane's loss kernel already averages over its `B/S` rows, and the
//! reduce scales by `1/S` — a mean of equal-shard means, i.e. the
//! global batch mean up to f32 summation order.
//!
//! **Deferred steps.** Within one `Trainer` iteration each layer
//! backwards at most once, every event reads only its *own* layer's
//! pre-step weights, and cross-event dataflow is the `dx`→`dy` chain —
//! so postponing all optimizer steps to end-of-iteration is
//! bit-identical to stock immediate stepping. That is what lets a
//! thread owning k lanes run split-phase (compute + ship all lanes,
//! then receive + apply all lanes) without a blocking rendezvous in
//! the middle of an iteration, and what makes the single-lane ring an
//! exact bitwise replay of the stock trainer.
//!
//! **The ring.** Staged gradients flatten (event order) into each
//! lane's [`RingLink`] — a WeiPipe-style ping-pong buffer pair — and
//! ship over bounded std channels (array-based, allocation-free sends)
//! to the coordinator thread, which gathers them into shard-indexed
//! slots, tree-reduces, and ships the mean back in the same buffers.
//! Buffers circulate: nothing is allocated in steady state, and the
//! returned allocation becomes the next iteration's send side via
//! `pingpong`. Weights move through the same flat codec
//! ([`model_to_tensor`] / [`tensor_to_model`], v2 checkpoint record
//! order: per-layer stack order, `w` then `b`) — used to broadcast the
//! initial model and to verify end-of-run lane agreement bitwise.
//!
//! The replica count defaults from `LAYERPIPE2_REPLICAS` (mirroring
//! `LAYERPIPE2_WORKERS`), clamped to the largest divisor of the shard
//! count.

use crate::backend::Backend;
use crate::config::ExperimentConfig;
use crate::data::{BatchIter, Dataset, Splits};
use crate::layers::{Network, NetworkSpec};
use crate::obs;
use crate::strategy::StrategyKind;
use crate::tensor::{bf16_to_f32, f32_to_bf16, workers, Dtype, Tensor};
use crate::train::Trainer;
use crate::util::Rng;
use anyhow::{anyhow, ensure, Result};
use std::sync::mpsc::sync_channel;
use std::time::Instant;

/// Env knob for the default replica count (mirrors `LAYERPIPE2_WORKERS`).
pub const REPLICAS_ENV: &str = "LAYERPIPE2_REPLICAS";

/// Gradient bytes shipped over ring links (both legs, all channels) —
/// the wire-traffic counter behind `layerpipe2 stats` (DESIGN.md §12).
static LINK_BYTES: obs::LazyCounter = obs::LazyCounter::new("ring/link_bytes");

/// Receives that found the channel empty and had to block: each stall
/// is a replica waiting on a slower neighbor (the ring's bubble
/// analogue; the blocked time itself lands in the `ring/recv` span).
static LINK_STALLS: obs::LazyCounter = obs::LazyCounter::new("ring/stalls");

/// `LAYERPIPE2_FAULT_RING=<seed>`: chaos hook — every ring participant
/// injects short seeded stalls at the top of its link phase (the same
/// discipline as the serving `fault_stall_seed` knob). Stalls reorder
/// *time* only: the lockstep protocol and ordered channels mean final
/// weights stay bitwise identical to an un-faulted run, and the replica
/// tests assert exactly that. `0`, unset, or unparseable = off.
pub const FAULT_RING_ENV: &str = "LAYERPIPE2_FAULT_RING";

/// Stalls injected by the `LAYERPIPE2_FAULT_RING` hook.
static RING_FAULTS: obs::LazyCounter = obs::LazyCounter::new("ring/faults_injected");

fn fault_ring_seed() -> u64 {
    std::env::var(FAULT_RING_ENV).ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// One ring participant's stall injector, seeded per participant so the
/// schedule is reproducible for a given seed and replica count.
struct LinkFault(Option<Rng>);

impl LinkFault {
    fn new(seed: u64, participant: u64) -> LinkFault {
        LinkFault((seed != 0).then(|| Rng::new(seed.wrapping_add(participant))))
    }

    /// Maybe sleep 50–500µs (seeded, 25% of iterations). Time-only.
    fn maybe_stall(&mut self) {
        if let Some(rng) = self.0.as_mut() {
            if rng.chance(0.25) {
                RING_FAULTS.inc();
                std::thread::sleep(std::time::Duration::from_micros(50 + rng.below(450)));
            }
        }
    }
}

/// Upper bound on the shard-lane count: the elementwise combine keeps
/// its partials in a stack array of this size.
pub const MAX_SHARDS: usize = 64;

/// Default replica count: `LAYERPIPE2_REPLICAS` if set (≥1), else the
/// machine's available parallelism — in either case clamped to the
/// largest divisor of `shards` (lanes are distributed in equal
/// contiguous blocks, so the replica count must divide the lane count).
pub fn default_replicas(shards: usize) -> usize {
    let want = std::env::var(REPLICAS_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()));
    largest_divisor_leq(shards, want)
}

/// Largest divisor of `shards` that is ≤ `want` (≥ 1).
fn largest_divisor_leq(shards: usize, want: usize) -> usize {
    let cap = want.min(shards).max(1);
    (1..=cap).rev().find(|d| shards % d == 0).unwrap_or(1)
}

/// Ring geometry: `shards` fixed micro-shard lanes distributed over
/// `replicas` threads. The bits of the training run depend on `shards`
/// only; `replicas` is pure placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RingConfig {
    pub replicas: usize,
    pub shards: usize,
}

impl RingConfig {
    pub fn new(replicas: usize, shards: usize) -> RingConfig {
        RingConfig { replicas, shards }
    }

    /// Geometry with the replica count taken from `LAYERPIPE2_REPLICAS`
    /// (or the machine) — see [`default_replicas`].
    pub fn from_env(shards: usize) -> RingConfig {
        RingConfig { replicas: default_replicas(shards), shards }
    }

    pub fn lanes_per_replica(&self) -> usize {
        self.shards / self.replicas.max(1)
    }

    pub fn validate(&self, batch: usize) -> Result<()> {
        ensure!(
            self.shards >= 1 && self.shards <= MAX_SHARDS,
            "shards must be in 1..={MAX_SHARDS}, got {}",
            self.shards
        );
        ensure!(
            self.replicas >= 1 && self.replicas <= self.shards,
            "replicas must be in 1..=shards ({}), got {}",
            self.shards,
            self.replicas
        );
        ensure!(
            self.shards % self.replicas == 0,
            "replicas ({}) must divide shards ({}) — lanes are placed in equal contiguous blocks",
            self.replicas,
            self.shards
        );
        ensure!(
            batch % self.shards == 0,
            "shards ({}) must divide the global batch ({batch}) — every lane owns an equal slice",
            self.shards
        );
        Ok(())
    }
}

// ---- deterministic tree reduce -----------------------------------------

/// One output element of the fixed-pairwise combine: load the `n`
/// partials into a stack array and fold with gap doubling —
/// `((p0+p1)+(p2+p3))+…` — the PR 4 tree-reduction order, a pure
/// function of `parts.len()`. Never arrival order, never thread count.
fn combine_elem(parts: &[Tensor], i: usize) -> f32 {
    let n = parts.len();
    debug_assert!(n >= 1 && n <= MAX_SHARDS);
    let mut acc = [0.0f32; MAX_SHARDS];
    for (k, p) in parts.iter().enumerate() {
        // `get` widens bf16 wire gradients exactly; the fold below runs
        // entirely in f32 (the mandatory-accumulation rule, DESIGN §11).
        acc[k] = p.get(i);
    }
    let mut gap = 1;
    while gap < n {
        let mut k = 0;
        while k + gap < n {
            acc[k] += acc[k + gap];
            k += 2 * gap;
        }
        gap *= 2;
    }
    acc[0]
}

/// Deterministic all-reduce: `out[i] = inv_scale · treeΣ_k parts[k][i]`.
///
/// The combine is elementwise, so the result is independent of how the
/// output range is chunked across workers — thread count is picked by
/// the usual work threshold and cannot change a single bit. A scale of
/// exactly 1.0 skips the multiply, so the single-shard ring replays the
/// raw gradient bits untouched.
pub fn tree_reduce_into(parts: &[Tensor], out: &mut Tensor, inv_scale: f32) {
    crate::obs::span!("ring/reduce");
    let len = parts.first().map_or(0, Tensor::len);
    let threads = workers::unit_threads(parts.len() * len, len.div_ceil(4096));
    tree_reduce_into_with_threads(parts, out, inv_scale, threads);
}

/// [`tree_reduce_into`] with an explicit worker count — exposed so the
/// property fuzz can sweep thread counts and assert bitwise stability.
pub fn tree_reduce_into_with_threads(
    parts: &[Tensor],
    out: &mut Tensor,
    inv_scale: f32,
    threads: usize,
) {
    assert!(
        !parts.is_empty() && parts.len() <= MAX_SHARDS,
        "all-reduce over {} parts (must be 1..={MAX_SHARDS})",
        parts.len()
    );
    let len = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), len, "all-reduce parts must have equal length");
    }
    out.resize(&[len]);
    if len == 0 {
        return;
    }
    let body = |off: usize, chunk: &mut [f32]| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let v = combine_elem(parts, off + i);
            *o = if inv_scale == 1.0 { v } else { v * inv_scale };
        }
    };
    if threads <= 1 {
        body(0, out.data_mut());
    } else {
        let chunk = len.div_ceil(threads);
        workers::run_chunked(out.data_mut(), chunk, &|ci, c| body(ci * chunk, c));
    }
}

// ---- flat weight codec --------------------------------------------------

/// Flatten a network's parameters into one rank-1 **f32** tensor, in the
/// v2 checkpoint record order (layer stack order, `w` then `b`;
/// parameter-free layers contribute their zero-length params
/// uniformly). bf16 parameters widen exactly — widening is injective,
/// so bitwise equality of two flats is equivalent to bitwise equality
/// of the underlying storage tensors, and the ring's drift guards keep
/// working unchanged in mixed precision. `out` is resized in place.
pub fn model_to_tensor(net: &Network, out: &mut Tensor) {
    out.resize(&[net.num_params()]);
    let d = out.data_mut();
    let mut at = 0;
    for nl in &net.layers {
        for t in [&nl.w, &nl.b] {
            match t.dtype() {
                Dtype::F32 => d[at..at + t.len()].copy_from_slice(t.data()),
                Dtype::Bf16 => {
                    for (o, &b) in d[at..at + t.len()].iter_mut().zip(t.bits()) {
                        *o = bf16_to_f32(b);
                    }
                }
            }
            at += t.len();
        }
    }
    debug_assert_eq!(at, d.len());
}

/// Inverse of [`model_to_tensor`]: scatter a flat f32 buffer back into
/// the network's parameter tensors (shapes *and dtypes* stay
/// authoritative on the network side; only the value bits move —
/// re-quantized for bf16 tensors, which round-trips exactly because
/// every widened bf16 value quantizes back to the same bits).
pub fn tensor_to_model(flat: &Tensor, net: &mut Network) -> Result<()> {
    ensure!(
        flat.len() == net.num_params(),
        "flat weight buffer holds {} values but the network carries {} parameters",
        flat.len(),
        net.num_params()
    );
    let d = flat.data();
    let mut at = 0;
    for nl in &mut net.layers {
        for t in [&mut nl.w, &mut nl.b] {
            let n = t.len();
            match t.dtype() {
                Dtype::F32 => t.data_mut().copy_from_slice(&d[at..at + n]),
                Dtype::Bf16 => {
                    for (o, &v) in t.bits_mut().iter_mut().zip(&d[at..at + n]) {
                        *o = f32_to_bf16(v);
                    }
                }
            }
            at += n;
        }
    }
    Ok(())
}

// ---- staged-gradient codec ----------------------------------------------

/// Total flat length of the gradients staged by the last iteration.
fn staged_len(tr: &mut Trainer) -> usize {
    let mut total = 0;
    for i in 0..tr.pending_steps().len() {
        let l = tr.pending_steps()[i].0;
        let (dw, db) = tr.staged_grads_mut(l);
        total += dw.len() + db.len();
    }
    total
}

/// Flatten the staged gradients into `out`, in event order (`dw` then
/// `db` per event). Every lane runs the identical schedule, so the
/// layout agrees across lanes without any header. The wire tensor
/// carries the trainer's storage dtype: under bf16 the staged f32
/// gradients are quantized here, halving RingLink traffic (the flat
/// buffer is the only thing the channels ship).
fn staged_to_flat(tr: &mut Trainer, out: &mut Tensor) {
    crate::obs::span!("ring/codec");
    let total = staged_len(tr);
    let wire = tr.dtype();
    out.resize_dtype(&[total], wire);
    let mut at = 0;
    for i in 0..tr.pending_steps().len() {
        let l = tr.pending_steps()[i].0;
        let (dw, db) = tr.staged_grads_mut(l);
        for t in [&*dw, &*db] {
            match wire {
                Dtype::F32 => out.data_mut()[at..at + t.len()].copy_from_slice(t.data()),
                Dtype::Bf16 => {
                    for (o, &v) in out.bits_mut()[at..at + t.len()].iter_mut().zip(t.data()) {
                        *o = f32_to_bf16(v);
                    }
                }
            }
            at += t.len();
        }
    }
    debug_assert_eq!(at, total);
}

/// Scatter the reduced mean back into the staged-gradient workspaces,
/// ready for [`Trainer::apply_pending`]. The flat buffer is
/// self-describing: a bf16 wire widens exactly into the f32 workspaces,
/// so every lane applies the identical gradient bits regardless of how
/// many replicas contributed to the mean.
fn flat_to_staged(flat: &Tensor, tr: &mut Trainer) -> Result<()> {
    crate::obs::span!("ring/codec");
    let mut at = 0;
    for i in 0..tr.pending_steps().len() {
        let l = tr.pending_steps()[i].0;
        let (dw, db) = tr.staged_grads_mut(l);
        for t in [dw, db] {
            let n = t.len();
            ensure!(
                at + n <= flat.len(),
                "reduced gradient buffer too short: {} < {}",
                flat.len(),
                at + n
            );
            match flat.dtype() {
                Dtype::F32 => t.data_mut().copy_from_slice(&flat.data()[at..at + n]),
                Dtype::Bf16 => {
                    for (o, &b) in t.data_mut().iter_mut().zip(&flat.bits()[at..at + n]) {
                        *o = bf16_to_f32(b);
                    }
                }
            }
            at += n;
        }
    }
    ensure!(
        at == flat.len(),
        "reduced gradient buffer length {} != staged total {at}",
        flat.len()
    );
    Ok(())
}

// ---- ring link ----------------------------------------------------------

/// WeiPipe-style ping-pong buffer pair for one lane's gradient traffic.
///
/// Per iteration: `take_send` hands out the active buffer (the codec
/// fills it, the channel ships it), the *same allocation* comes back
/// carrying the reduced mean, `put_recv` parks it on the opposite slot
/// and `pingpong` flips roles — so one allocation circulates
/// indefinitely and the send slot is free for refill before the
/// previous exchange has landed (the overlap window of the split-phase
/// schedule). Steady state allocates nothing.
pub struct RingLink {
    bufs: [Tensor; 2],
    idx: usize,
}

impl RingLink {
    pub fn new() -> RingLink {
        RingLink { bufs: [Tensor::empty(), Tensor::empty()], idx: 0 }
    }

    /// Take the send-side buffer (leaves an empty placeholder).
    pub fn take_send(&mut self) -> Tensor {
        std::mem::replace(&mut self.bufs[self.idx], Tensor::empty())
    }

    /// Park the returned (reduced) buffer on the recv side.
    pub fn put_recv(&mut self, t: Tensor) {
        self.bufs[1 - self.idx] = t;
    }

    /// Flip roles: the parked recv buffer becomes the next send buffer.
    pub fn pingpong(&mut self) {
        self.idx = 1 - self.idx;
    }
}

impl Default for RingLink {
    fn default() -> Self {
        Self::new()
    }
}

// ---- lanes --------------------------------------------------------------

/// One shard lane: a full deferred-step trainer plus its ring link.
struct Lane {
    trainer: Trainer,
    link: RingLink,
}

/// The contiguous block of lanes hosted by one replica thread.
struct LaneBlock {
    lanes: Vec<Lane>,
    /// Global index of `lanes[0]`.
    first: usize,
    /// Rows each lane takes from every global batch.
    shard_rows: usize,
}

impl LaneBlock {
    /// Phase 1 of the split-phase iteration: every owned lane runs one
    /// trainer iteration on its shard of the global batch (`idx`, or a
    /// drain tick when `None`), flattens its staged gradients into its
    /// ring buffer and ships it via `ship(global_lane, buffer)`.
    fn compute(
        &mut self,
        idx: Option<&[usize]>,
        train: &Dataset,
        mut ship: impl FnMut(usize, Tensor) -> Result<()>,
    ) -> Result<()> {
        for i in 0..self.lanes.len() {
            let j = self.first + i;
            let lane = &mut self.lanes[i];
            let batch = match idx {
                Some(idx) => {
                    let shard = &idx[j * self.shard_rows..(j + 1) * self.shard_rows];
                    let (mut x, mut oh) =
                        lane.trainer.take_feed(shard.len(), train.input_dim(), train.classes);
                    train.batch_into(shard, &mut x, &mut oh);
                    Some((x, oh))
                }
                None => None,
            };
            lane.trainer.iteration(batch)?;
            let mut buf = lane.link.take_send();
            staged_to_flat(&mut lane.trainer, &mut buf);
            ship(j, buf)?;
        }
        Ok(())
    }

    /// Phase 2: write the reduced mean back into lane `j`'s staged
    /// workspaces, replay its deferred optimizer steps, and park the
    /// buffer for the next iteration.
    fn apply(&mut self, j: usize, reduced: Tensor) -> Result<()> {
        let lane = &mut self.lanes[j - self.first];
        flat_to_staged(&reduced, &mut lane.trainer)?;
        lane.trainer.apply_pending();
        lane.link.put_recv(reduced);
        lane.link.pingpong();
        Ok(())
    }

    /// Lockstep drain condition: identical schedules make every lane's
    /// in-flight count agree, so checking lane 0 stands for the block —
    /// and for every other block, with no communication.
    fn in_flight(&self) -> usize {
        self.lanes[0].trainer.in_flight()
    }
}

/// Build one thread's lane block. Lane 0 consumes its build draws from
/// the returned feed rng — the exact stock pattern (`Trainer::new` then
/// `train` on one rng), so the single-lane ring replays the oracle's
/// batch stream bit for bit. Extra lanes burn an identical-seed clone,
/// keeping the feed-rng state independent of how many lanes this
/// thread owns (replica-count invariance hinges on that).
fn build_block(
    backend: &Backend,
    cfg: &ExperimentConfig,
    spec: Option<&NetworkSpec>,
    kind: StrategyKind,
    first: usize,
    count: usize,
    shard_rows: usize,
) -> Result<(LaneBlock, Rng)> {
    let mut feed_rng = Rng::new(cfg.seed);
    let mut lanes = Vec::with_capacity(count);
    for i in 0..count {
        let mut fresh = Rng::new(cfg.seed);
        let rng = if i == 0 { &mut feed_rng } else { &mut fresh };
        let mut trainer = match spec {
            Some(sp) => Trainer::with_spec(backend.clone(), cfg, sp, kind, rng)?,
            None => Trainer::new(backend.clone(), cfg, kind, rng)?,
        };
        trainer.set_defer_steps(true);
        lanes.push(Lane { trainer, link: RingLink::new() });
    }
    // Broadcast lane 0's weights through the flat codec. Identical
    // seeds make this a re-sync no-op, but it exercises the codec on
    // every construction and guards against init drift.
    if count > 1 {
        let mut flat = Tensor::empty();
        model_to_tensor(&lanes[0].trainer.net, &mut flat);
        for lane in &mut lanes[1..] {
            tensor_to_model(&flat, &mut lane.trainer.net)?;
        }
    }
    Ok((LaneBlock { lanes, first, shard_rows }, feed_rng))
}

/// The shared epoch/drain loop every replica thread runs: feed
/// `cfg.epochs` epochs of shuffled global batches (every thread draws
/// the identical stream from its identically-seeded feed rng), then
/// drain in lockstep until the pipelines empty. `exchange` performs one
/// full split-phase iteration. Returns the feeding iteration count.
fn run_lane_loop(
    block: &mut LaneBlock,
    data: &Splits,
    cfg: &ExperimentConfig,
    feed_rng: &mut Rng,
    exchange: &mut dyn FnMut(&mut LaneBlock, Option<&[usize]>, &Dataset) -> Result<()>,
) -> Result<u64> {
    let mut iterations = 0u64;
    for _ in 0..cfg.epochs {
        let mut iter = BatchIter::new(&data.train, cfg.model.batch, feed_rng);
        while let Some(idx) = iter.next_indices() {
            exchange(block, Some(idx), &data.train)?;
            iterations += 1;
        }
    }
    while block.in_flight() > 0 {
        exchange(block, None, &data.train)?;
    }
    Ok(iterations)
}

// ---- single-replica ring ------------------------------------------------

/// The replicas == 1 ring: all shard lanes co-resident on the calling
/// thread, exchange running in place (no channels, no spawns). This is
/// both the fast path for `train_ring` at N=1 and a stepwise-drivable
/// harness for the allocation-discipline test.
pub struct LocalRing {
    block: LaneBlock,
    slots: Vec<Tensor>,
    reduced: Tensor,
    inv: f32,
    feed_rng: Rng,
}

impl LocalRing {
    pub fn new(
        backend: &Backend,
        cfg: &ExperimentConfig,
        spec: Option<&NetworkSpec>,
        kind: StrategyKind,
        shards: usize,
    ) -> Result<LocalRing> {
        cfg.validate()?;
        RingConfig::new(1, shards).validate(cfg.model.batch)?;
        let shard_rows = cfg.model.batch / shards;
        let (block, feed_rng) = build_block(backend, cfg, spec, kind, 0, shards, shard_rows)?;
        Ok(LocalRing {
            block,
            slots: (0..shards).map(|_| Tensor::empty()).collect(),
            reduced: Tensor::empty(),
            inv: 1.0 / shards as f32,
            feed_rng,
        })
    }

    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Rows each lane takes from a global batch.
    pub fn shard_rows(&self) -> usize {
        self.block.shard_rows
    }

    /// The feed rng (positioned exactly as the stock trainer's rng after
    /// construction) — drive `BatchIter` with it for oracle-identical
    /// batch streams.
    pub fn feed_rng(&mut self) -> &mut Rng {
        &mut self.feed_rng
    }

    /// One global iteration: every lane computes on its shard of `idx`
    /// (`None` = drain tick), gradients tree-reduce in place, and every
    /// lane applies the identical mean. Allocation-free in steady state.
    pub fn iteration(&mut self, idx: Option<&[usize]>, train: &Dataset) -> Result<()> {
        let slots = &mut self.slots;
        self.block.compute(idx, train, |j, buf| {
            slots[j] = buf;
            Ok(())
        })?;
        tree_reduce_into(&self.slots, &mut self.reduced, self.inv);
        // The reduced mean is f32 (mandatory accumulation); the return
        // leg re-quantizes it onto a bf16 wire so every lane receives —
        // and applies — the identical bf16 bits, keeping the drift
        // guard valid independent of the replica count.
        let wire = self.block.lanes[0].trainer.dtype();
        for j in 0..self.slots.len() {
            let mut buf = std::mem::replace(&mut self.slots[j], Tensor::empty());
            match wire {
                Dtype::F32 => buf.copy_from(&self.reduced),
                Dtype::Bf16 => buf.quantize_from(&self.reduced),
            }
            self.block.apply(j, buf)?;
        }
        Ok(())
    }

    pub fn in_flight(&self) -> usize {
        self.block.in_flight()
    }

    /// Lane 0's parameters through the flat codec.
    pub fn weights_flat(&self, out: &mut Tensor) {
        model_to_tensor(&self.block.lanes[0].trainer.net, out);
    }

    /// Drift guard: every lane's parameters must stay bitwise equal to
    /// lane 0's (they apply identical reduced gradients to identical
    /// initial weights, so any divergence is a bug).
    pub fn lanes_bitwise_equal(&self) -> bool {
        let mut a = Tensor::empty();
        let mut b = Tensor::empty();
        model_to_tensor(&self.block.lanes[0].trainer.net, &mut a);
        for lane in &self.block.lanes[1..] {
            model_to_tensor(&lane.trainer.net, &mut b);
            if a.data() != b.data() {
                return false;
            }
        }
        true
    }

    /// Test accuracy of lane 0 (all lanes are bitwise equal).
    pub fn evaluate(&mut self, data: &Splits) -> Result<f32> {
        self.block.lanes[0].trainer.evaluate(data)
    }

    /// Mean training loss observed by lane 0 over the whole run.
    pub fn mean_loss(&self) -> f32 {
        let losses = self.block.lanes[0].trainer.observed_losses();
        if losses.is_empty() {
            f32::NAN
        } else {
            losses.iter().sum::<f32>() / losses.len() as f32
        }
    }
}

/// Receive from a ring channel, counting a stall (and timing the wait
/// in the `ring/recv` span) when the message has not arrived yet. The
/// fast path is one `try_recv` — no clock read, no counter bump.
fn recv_counting_stalls<T>(
    rx: &std::sync::mpsc::Receiver<T>,
) -> Result<T, std::sync::mpsc::RecvError> {
    match rx.try_recv() {
        Ok(m) => Ok(m),
        Err(std::sync::mpsc::TryRecvError::Disconnected) => Err(std::sync::mpsc::RecvError),
        Err(std::sync::mpsc::TryRecvError::Empty) => {
            LINK_STALLS.inc();
            crate::obs::span!("ring/recv");
            rx.recv()
        }
    }
}

// ---- full ring driver ---------------------------------------------------

/// Outcome of a ring training run.
#[derive(Debug)]
pub struct RingReport {
    pub replicas: usize,
    pub shards: usize,
    /// Feeding iterations (global batches consumed).
    pub iterations: u64,
    /// Training samples consumed (`iterations · batch`).
    pub samples: u64,
    pub seconds: f64,
    pub samples_per_sec: f64,
    /// Mean training loss over the whole run (lane 0).
    pub train_loss: f32,
    pub test_accuracy: f32,
    /// Final parameters through the flat codec — bitwise comparable
    /// across replica counts.
    pub final_weights: Tensor,
}

/// Train `cfg.epochs` epochs on the weight ring and return the report.
///
/// Bits depend on `ring.shards` (and the usual cfg/seed/strategy), not
/// on `ring.replicas`: rerunning with any replica count that divides
/// the shard count yields a bitwise-identical `final_weights`.
pub fn train_ring(
    backend: &Backend,
    cfg: &ExperimentConfig,
    spec: Option<&NetworkSpec>,
    kind: StrategyKind,
    ring: &RingConfig,
    data: &Splits,
) -> Result<RingReport> {
    cfg.validate()?;
    ring.validate(cfg.model.batch)?;
    ensure!(data.train.len() >= cfg.model.batch, "train split smaller than one global batch");
    if ring.replicas == 1 {
        return train_ring_local(backend, cfg, spec, kind, ring.shards, data);
    }
    train_ring_threaded(backend, cfg, spec, kind, ring, data)
}

fn train_ring_local(
    backend: &Backend,
    cfg: &ExperimentConfig,
    spec: Option<&NetworkSpec>,
    kind: StrategyKind,
    shards: usize,
    data: &Splits,
) -> Result<RingReport> {
    let mut ring = LocalRing::new(backend, cfg, spec, kind, shards)?;
    let t0 = Instant::now();
    let mut iterations = 0u64;
    for _ in 0..cfg.epochs {
        let mut iter = BatchIter::new(&data.train, cfg.model.batch, &mut ring.feed_rng);
        while let Some(idx) = iter.next_indices() {
            ring.iteration(Some(idx), &data.train)?;
            iterations += 1;
        }
    }
    while ring.in_flight() > 0 {
        ring.iteration(None, &data.train)?;
    }
    let seconds = t0.elapsed().as_secs_f64();
    ensure!(ring.lanes_bitwise_equal(), "replica lanes drifted (single-replica ring)");
    let mut final_weights = Tensor::empty();
    ring.weights_flat(&mut final_weights);
    let test_accuracy = ring.evaluate(data)?;
    finish_report(1, shards, iterations, cfg, seconds, ring.mean_loss(), test_accuracy, final_weights)
}

fn train_ring_threaded(
    backend: &Backend,
    cfg: &ExperimentConfig,
    spec: Option<&NetworkSpec>,
    kind: StrategyKind,
    ring: &RingConfig,
    data: &Splits,
) -> Result<RingReport> {
    let lanes_per = ring.lanes_per_replica();
    let shard_rows = cfg.model.batch / ring.shards;
    let inv = 1.0 / ring.shards as f32;
    let fault_seed = fault_ring_seed();

    // Coordinator block (lanes 0..lanes_per) lives on the calling thread.
    let (mut coord, mut coord_rng) =
        build_block(backend, cfg, spec, kind, 0, lanes_per, shard_rows)?;
    let mut slots: Vec<Tensor> = (0..ring.shards).map(|_| Tensor::empty()).collect();
    let mut reduced = Tensor::empty();

    let t0 = Instant::now();
    let mut iterations = 0u64;
    let worker_weights = std::thread::scope(|s| -> Result<Vec<(usize, Tensor)>> {
        // Per-worker bounded channels: gradients up, reduced means back.
        // Bounded std channels are array-based, so steady-state sends
        // allocate nothing; capacity lanes_per makes phase-1 sends
        // non-blocking, which is what keeps the lockstep deadlock-free.
        let mut grads_rxs = Vec::with_capacity(ring.replicas - 1);
        let mut resp_txs = Vec::with_capacity(ring.replicas - 1);
        let mut handles = Vec::with_capacity(ring.replicas - 1);
        for r in 1..ring.replicas {
            let (gtx, grx) = sync_channel::<(usize, Tensor)>(lanes_per);
            let (rtx, rrx) = sync_channel::<(usize, Tensor)>(lanes_per);
            grads_rxs.push(grx);
            resp_txs.push(rtx);
            let first = r * lanes_per;
            handles.push(s.spawn(move || -> Result<Vec<(usize, Tensor)>> {
                if crate::obs::enabled() {
                    crate::obs::set_thread_name(&format!("ring-worker-{r}"));
                }
                let (mut block, mut rng) =
                    build_block(backend, cfg, spec, kind, first, lanes_per, shard_rows)?;
                let mut fault = LinkFault::new(fault_seed, r as u64);
                let mut step = |block: &mut LaneBlock,
                                idx: Option<&[usize]>,
                                train: &Dataset|
                 -> Result<()> {
                    fault.maybe_stall();
                    block.compute(idx, train, |j, buf| {
                        LINK_BYTES.add(buf.nbytes() as u64);
                        gtx.send((j, buf)).map_err(|_| anyhow!("ring torn down (coordinator gone)"))
                    })?;
                    for _ in 0..block.lanes.len() {
                        let (j, buf) = recv_counting_stalls(&rrx)
                            .map_err(|_| anyhow!("ring torn down (coordinator gone)"))?;
                        block.apply(j, buf)?;
                    }
                    Ok(())
                };
                run_lane_loop(&mut block, data, cfg, &mut rng, &mut step)?;
                Ok(block
                    .lanes
                    .iter()
                    .enumerate()
                    .map(|(i, lane)| {
                        let mut flat = Tensor::empty();
                        model_to_tensor(&lane.trainer.net, &mut flat);
                        (first + i, flat)
                    })
                    .collect())
            }));
        }

        let mut coord_fault = LinkFault::new(fault_seed, 0);
        let mut step = |block: &mut LaneBlock,
                        idx: Option<&[usize]>,
                        train: &Dataset|
         -> Result<()> {
            coord_fault.maybe_stall();
            block.compute(idx, train, |j, buf| {
                slots[j] = buf;
                Ok(())
            })?;
            for rx in &grads_rxs {
                for _ in 0..lanes_per {
                    let (j, buf) = recv_counting_stalls(rx)
                        .map_err(|_| anyhow!("ring torn down (worker died)"))?;
                    LINK_BYTES.add(buf.nbytes() as u64);
                    slots[j] = buf;
                }
            }
            tree_reduce_into(&slots, &mut reduced, inv);
            // Same return-leg re-quantization as `LocalRing::iteration`:
            // a bf16 wire ships — and every lane applies — identical
            // bf16 mean bits, at half the f32 channel traffic.
            let wire = block.lanes[0].trainer.dtype();
            for j in 0..slots.len() {
                let mut buf = std::mem::replace(&mut slots[j], Tensor::empty());
                match wire {
                    Dtype::F32 => buf.copy_from(&reduced),
                    Dtype::Bf16 => buf.quantize_from(&reduced),
                }
                if j < lanes_per {
                    block.apply(j, buf)?;
                } else {
                    LINK_BYTES.add(buf.nbytes() as u64);
                    resp_txs[j / lanes_per - 1]
                        .send((j, buf))
                        .map_err(|_| anyhow!("ring torn down (worker died)"))?;
                }
            }
            Ok(())
        };
        let coord_result = run_lane_loop(&mut coord, data, cfg, &mut coord_rng, &mut step);
        drop(step);
        // Close the channels so any worker still blocked in the torn-down
        // case unblocks, then surface the most specific error available.
        drop(grads_rxs);
        drop(resp_txs);
        let mut weights = Vec::with_capacity(ring.shards - lanes_per);
        let mut worker_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(w)) => weights.extend(w),
                Ok(Err(e)) => worker_err = Some(e),
                Err(_) => worker_err = Some(anyhow!("replica worker panicked")),
            }
        }
        if let Some(e) = worker_err {
            return Err(e);
        }
        iterations = coord_result?;
        Ok(weights)
    })?;
    let seconds = t0.elapsed().as_secs_f64();

    // Drift guard, now across threads: every lane must agree bitwise.
    let mut final_weights = Tensor::empty();
    model_to_tensor(&coord.lanes[0].trainer.net, &mut final_weights);
    let mut tmp = Tensor::empty();
    for lane in &coord.lanes[1..] {
        model_to_tensor(&lane.trainer.net, &mut tmp);
        ensure!(tmp.data() == final_weights.data(), "replica lanes drifted (coordinator block)");
    }
    for (j, w) in &worker_weights {
        ensure!(
            w.data() == final_weights.data(),
            "replica lane {j} drifted from lane 0 — all-reduce determinism violated"
        );
    }

    let test_accuracy = coord.lanes[0].trainer.evaluate(data)?;
    let losses = coord.lanes[0].trainer.observed_losses();
    let train_loss = if losses.is_empty() {
        f32::NAN
    } else {
        losses.iter().sum::<f32>() / losses.len() as f32
    };
    finish_report(
        ring.replicas,
        ring.shards,
        iterations,
        cfg,
        seconds,
        train_loss,
        test_accuracy,
        final_weights,
    )
}

#[allow(clippy::too_many_arguments)]
fn finish_report(
    replicas: usize,
    shards: usize,
    iterations: u64,
    cfg: &ExperimentConfig,
    seconds: f64,
    train_loss: f32,
    test_accuracy: f32,
    final_weights: Tensor,
) -> Result<RingReport> {
    let samples = iterations * cfg.model.batch as u64;
    let samples_per_sec = samples as f64 / seconds.max(1e-9);
    crate::log_info!(
        "[ring x{replicas}/{shards}] {iterations} iters, {samples} samples in {seconds:.2}s \
         ({samples_per_sec:.0} samples/s), loss {train_loss:.4} acc {test_accuracy:.4}"
    );
    Ok(RingReport {
        replicas,
        shards,
        iterations,
        samples,
        seconds,
        samples_per_sec,
        train_loss,
        test_accuracy,
        final_weights,
    })
}

// Unit tests for the pure pieces; ring-vs-oracle equivalence and the
// thread-count sweeps live in rust/tests/ (integration + property).
#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn largest_divisor_clamps_to_divisors() {
        assert_eq!(largest_divisor_leq(8, 8), 8);
        assert_eq!(largest_divisor_leq(8, 5), 4);
        assert_eq!(largest_divisor_leq(8, 3), 2);
        assert_eq!(largest_divisor_leq(8, 1), 1);
        assert_eq!(largest_divisor_leq(6, 4), 3);
        assert_eq!(largest_divisor_leq(1, 64), 1);
    }

    #[test]
    fn ring_config_validation() {
        assert!(RingConfig::new(2, 8).validate(32).is_ok());
        assert!(RingConfig::new(0, 8).validate(32).is_err()); // replicas < 1
        assert!(RingConfig::new(3, 8).validate(32).is_err()); // 3 ∤ 8
        assert!(RingConfig::new(16, 8).validate(32).is_err()); // replicas > shards
        assert!(RingConfig::new(1, 5).validate(32).is_err()); // 5 ∤ 32
        assert!(RingConfig::new(1, 0).validate(32).is_err()); // shards < 1
        assert!(RingConfig::new(1, MAX_SHARDS + 1).validate(4 * (MAX_SHARDS + 1)).is_err());
    }

    /// Reference combine: the same gap-doubling recursion written as
    /// plain recursion over index ranges.
    fn reference_combine(vals: &[f32]) -> f32 {
        fn tree(vals: &[f32], lo: usize, n: usize, span: usize) -> f32 {
            if span == 1 {
                return vals[lo];
            }
            let half = span / 2;
            let left = tree(vals, lo, n, half);
            if lo + half < n {
                left + tree(vals, lo + half, n, half)
            } else {
                left
            }
        }
        let span = vals.len().next_power_of_two();
        tree(vals, 0, vals.len(), span)
    }

    #[test]
    fn tree_reduce_matches_reference_order() {
        for n in 1..=9usize {
            let parts: Vec<Tensor> = (0..n)
                .map(|k| Tensor::from_vec(&[3], vec![0.1 + k as f32, -2.5 * k as f32, 1e-3]))
                .collect();
            let mut out = Tensor::empty();
            tree_reduce_into_with_threads(&parts, &mut out, 1.0, 1);
            for i in 0..3 {
                let vals: Vec<f32> = parts.iter().map(|p| p.data()[i]).collect();
                assert_eq!(out.data()[i].to_bits(), reference_combine(&vals).to_bits());
            }
        }
    }

    #[test]
    fn tree_reduce_identity_at_single_part() {
        let p = Tensor::from_vec(&[4], vec![1.5, -0.25, 3.75, f32::MIN_POSITIVE]);
        let mut out = Tensor::empty();
        tree_reduce_into(std::slice::from_ref(&p), &mut out, 1.0);
        assert_eq!(out.data(), p.data());
    }

    #[test]
    fn ring_link_circulates_one_allocation() {
        let mut link = RingLink::new();
        let mut t = link.take_send();
        t.resize(&[4]);
        t.fill(7.0);
        let ptr = t.data().as_ptr();
        link.put_recv(t);
        link.pingpong();
        let t2 = link.take_send();
        assert_eq!(t2.data().as_ptr(), ptr, "ping-pong must hand back the parked allocation");
        assert_eq!(t2.data(), &[7.0; 4]);
        link.put_recv(t2);
        link.pingpong();
        assert_eq!(link.take_send().data().as_ptr(), ptr);
    }

    #[test]
    fn weight_codec_roundtrips() {
        let mcfg = ModelConfig {
            batch: 8,
            input_dim: 6,
            hidden_dim: 5,
            classes: 4,
            layers: 3,
            init_scale: 1.0,
        };
        let mut rng = Rng::new(11);
        let mut net = Network::build(&NetworkSpec::mlp(&mcfg), &mut rng).unwrap();
        let mut flat = Tensor::empty();
        model_to_tensor(&net, &mut flat);
        assert_eq!(flat.len(), net.num_params());
        let golden = flat.clone();
        for nl in &mut net.layers {
            nl.w.fill(0.0);
            nl.b.fill(0.0);
        }
        tensor_to_model(&golden, &mut net).unwrap();
        model_to_tensor(&net, &mut flat);
        assert_eq!(flat.data(), golden.data());

        let short = Tensor::zeros(&[golden.len() - 1]);
        assert!(tensor_to_model(&short, &mut net).is_err());
    }

    #[test]
    fn weight_codec_widens_and_requantizes_bf16_exactly() {
        let mcfg = ModelConfig {
            batch: 8,
            input_dim: 6,
            hidden_dim: 5,
            classes: 4,
            layers: 3,
            init_scale: 1.0,
        };
        let mut rng = Rng::new(12);
        let mut net = Network::build(&NetworkSpec::mlp(&mcfg), &mut rng).unwrap();
        for nl in &mut net.layers {
            nl.w = nl.w.to_dtype(Dtype::Bf16);
        }
        let golden_bits: Vec<Vec<u16>> = net.layers.iter().map(|nl| nl.w.bits().to_vec()).collect();

        // Flatten widens bf16 exactly: every flat value must round-trip
        // through quantization back to the stored bits.
        let mut flat = Tensor::empty();
        model_to_tensor(&net, &mut flat);
        assert_eq!(flat.dtype(), Dtype::F32, "the flat weight codec is always f32");
        assert_eq!(flat.len(), net.num_params());

        // Scatter re-quantizes; widen∘quantize is the identity on bf16
        // bits, so the storage comes back bitwise and dtype intact.
        for nl in &mut net.layers {
            nl.w.fill(0.0);
        }
        tensor_to_model(&flat, &mut net).unwrap();
        for (nl, golden) in net.layers.iter().zip(&golden_bits) {
            assert_eq!(nl.w.dtype(), Dtype::Bf16);
            assert_eq!(nl.w.bits(), &golden[..]);
        }
    }

    #[test]
    fn tree_reduce_widens_bf16_parts_bitwise() {
        // bf16 wire parts must reduce to exactly the same f32 mean as
        // their pre-widened f32 images: the combine reads elements via
        // `get`, so the summation geometry never sees the storage dtype.
        for n in 1..=5usize {
            let mut rng = Rng::new(31 + n as u64);
            let parts_q: Vec<Tensor> =
                (0..n).map(|_| Tensor::randn(&[33], 0.7, &mut rng).to_dtype(Dtype::Bf16)).collect();
            let parts_w: Vec<Tensor> = parts_q.iter().map(|p| p.to_dtype(Dtype::F32)).collect();
            let (mut a, mut b) = (Tensor::empty(), Tensor::empty());
            tree_reduce_into_with_threads(&parts_q, &mut a, 1.0 / n as f32, 1);
            tree_reduce_into_with_threads(&parts_w, &mut b, 1.0 / n as f32, 1);
            assert_eq!(a.dtype(), Dtype::F32, "reduced mean accumulates and lands in f32");
            assert_eq!(a, b);
        }
    }
}
