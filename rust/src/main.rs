//! `layerpipe2` — CLI launcher for the LayerPipe2 reproduction.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!
//! ```text
//! layerpipe2 train   [--config F] [--strategy S]... [--epochs N] [--stages K] [--csv PATH]
//! layerpipe2 retime  [--layers L] [--groups a,b,c]
//! layerpipe2 dlms    [--delays 0,1,4,16] [--mu MU] [--taps T]
//! layerpipe2 schedule [--layers L] [--stages K] [--batches B]
//! layerpipe2 throughput [--stages 1,2,4,8] [--batches B] [--artifacts DIR]
//! layerpipe2 serve   [--clients N] [--requests M] [--rows R] [--max-batch B]
//!                    [--wait-ticks T] [--stages K] [--reloads X] [--checkpoint F]
//! layerpipe2 soak    [--seed N] [--smoke] [--json PATH]
//! layerpipe2 train-ring [--replicas 1,2,4] [--shards S] [--strategy S]
//!                    [--epochs N] [--stages K] [--seed N]
//! layerpipe2 stats   [--strategy S] [--epochs N] [--stages K] [--json PATH]
//! layerpipe2 info    [--artifacts DIR]
//! ```
//!
//! Every command honours `LAYERPIPE2_TRACE=<path>` (Chrome-trace span
//! dump written at exit) and `LAYERPIPE2_OBS=off` (span timing off).

use anyhow::{bail, Context, Result};
use layerpipe2::backend::{self, Exec};
use layerpipe2::config::ExperimentConfig;
use layerpipe2::coordinator::{check_fig5_shape, Coordinator, ExecutorKind};
use layerpipe2::data::teacher_dataset;
use layerpipe2::dlms;
use layerpipe2::model::Mlp;
use layerpipe2::pipeline;
use layerpipe2::retiming::{Derivation, StagePartition};
use layerpipe2::layers::{Network, NetworkSpec};
use layerpipe2::model::checkpoint;
use layerpipe2::obs;
use layerpipe2::replica;
use layerpipe2::runtime::Manifest;
use layerpipe2::schedule::{sweep_stages, CostModel, Schedule};
use layerpipe2::serving::{Server, ServerConfig};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::{Dtype, Tensor};
use layerpipe2::util::Rng;
use std::path::Path;

/// Minimal flag parser: `--key value` pairs after the subcommand;
/// repeated keys accumulate.
struct Args {
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let k = &argv[i];
            if !k.starts_with("--") {
                bail!("expected --flag, got '{k}'");
            }
            let v = argv
                .get(i + 1)
                .with_context(|| format!("flag {k} needs a value"))?;
            flags.push((k[2..].to_string(), v.clone()));
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got '{v}'")),
        }
    }

    fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().with_context(|| format!("bad list item '{s}' in --{key}")))
                .collect(),
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    // LAYERPIPE2_TRACE=<path>: arm the span trace for the whole command
    // and dump Chrome-trace JSON at exit (load in chrome://tracing or
    // Perfetto). Tracing implies span timing, so force the gate on.
    let trace_path = std::env::var(obs::TRACE_ENV).ok().filter(|p| !p.is_empty());
    if trace_path.is_some() {
        obs::set_enabled(true);
        obs::trace_begin();
    }
    let mut code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    if let Some(path) = trace_path {
        let json = obs::trace_end_to_json();
        match std::fs::write(&path, json.to_string()) {
            Ok(()) => eprintln!("chrome trace written to {path}"),
            Err(e) => {
                eprintln!("error: writing trace to {path}: {e}");
                code = 2;
            }
        }
    }
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    if cmd == "soak" {
        // `soak` takes bare flags (`--smoke`), which the `--key value`
        // parser cannot express; it parses its own argv.
        return cmd_soak(&argv[1..]);
    }
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "retime" => cmd_retime(&args),
        "dlms" => cmd_dlms(&args),
        "schedule" => cmd_schedule(&args),
        "throughput" => cmd_throughput(&args),
        "serve" => cmd_serve(&args),
        "train-ring" => cmd_train_ring(&args),
        "stats" => cmd_stats(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'layerpipe2 help')"),
    }
}

fn print_usage() {
    println!(
        "layerpipe2 — multistage pipelined training with EMA weight recompute

USAGE: layerpipe2 <COMMAND> [--flag value]...

COMMANDS:
  train       run the Fig. 5 strategy sweep (pipelined training)
              --config F --strategy S (repeatable) --epochs N --stages K
              --csv PATH --artifacts DIR --seed N
              --dtype f32|bf16 (storage dtype; LAYERPIPE2_DTYPE also works)
              --executor iteration|threaded (threaded = one thread/stage)
  retime      derive pipeline delays via retiming (Figs. 3/4)
              --layers L  --groups a,b,c (group sizes)
  dlms        delayed-LMS convergence sweep (Fig. 2)
              --delays 0,1,4,16 --mu 0.01 --taps 16 --samples 20000
  schedule    clock-schedule analysis (utilization/speedup/staleness)
              --layers L --stages K --batches B
  throughput  threaded pipeline throughput on real XLA compute
              --stages 1,2,4,8 --batches B --artifacts DIR
  serve       batched inference serving with checkpoint hot-reload
              --clients N --requests M --rows R --max-batch B
              --wait-ticks T --stages K --reloads X --checkpoint F
              (responses verified bitwise vs the sequential oracle)
  soak        deterministic serving chaos/soak harness: client churn,
              slow clients, reload storms, saturation bursts, injected
              stage stalls — asserts zero lost/duplicated/reordered
              accepted responses and bitwise payloads
              --seed N --smoke --json PATH (merges a \"soak\" section
              into BENCH_serving.json; LAYERPIPE2_BENCH_SERVING_JSON
              overrides the default path)
  train-ring  2D (pipeline x data) training on the weight ring
              --replicas 1,2,4 --shards S --strategy S --epochs N
              --stages K --seed N --dtype f32|bf16
              (LAYERPIPE2_REPLICAS sets the default replica count;
              final weights verified bitwise across counts)
  stats       run a short pipelined training with telemetry on and
              print the full runtime telemetry table
              --strategy S --epochs N --stages K --json PATH
  info        print artifact manifest details  --artifacts DIR

ENVIRONMENT:
  LAYERPIPE2_TRACE=<path>  dump a Chrome-trace span timeline at exit
  LAYERPIPE2_OBS=off       disable span timing (counters stay on)
  LAYERPIPE2_LOG=off|error|warn|info|debug  log level (default info)
  LAYERPIPE2_LOG_TS=1      prefix log lines with elapsed time"
    );
}

/// Resolve the storage dtype: `--dtype` beats `LAYERPIPE2_DTYPE`, which
/// beats the config file's `dtype` key (already in `cfg`), which beats
/// the f32 default.
fn apply_dtype(args: &Args, cfg: &mut ExperimentConfig) -> Result<()> {
    if let Some(d) = Dtype::from_env() {
        cfg.dtype = d;
    }
    if let Some(s) = args.get("dtype") {
        cfg.dtype = match Dtype::parse(s) {
            Some(d) => d,
            None => bail!("--dtype expects f32|bf16, got '{s}'"),
        };
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    apply_dtype(args, &mut cfg)?;
    cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
    cfg.pipeline.stages = args.usize_or("stages", cfg.pipeline.stages)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    if let Some(csv) = args.get("csv") {
        cfg.csv_out = Some(csv.to_string());
    }
    let requested = args.get_all("strategy");
    if !requested.is_empty() {
        cfg.strategies = requested
            .iter()
            .map(|s| StrategyKind::parse(s))
            .collect::<Result<_>>()?;
    }
    cfg.validate()?;
    let executor = match args.get("executor").unwrap_or("iteration") {
        "iteration" | "oracle" => ExecutorKind::Iteration,
        "threaded" | "pipelined" => ExecutorKind::Threaded,
        other => bail!("unknown --executor '{other}' (expected iteration|threaded)"),
    };

    if cfg.dtype != Dtype::F32 {
        println!("storage dtype: {} (f32 masters + f32 accumulation)", cfg.dtype);
    }
    let coord = Coordinator::new(cfg)?;
    let result = coord.sweep_on(executor)?;
    println!("{}", result.table());
    let problems = check_fig5_shape(&result);
    if problems.is_empty() {
        println!("fig5 shape: REPRODUCED (orderings + memory reduction hold)");
    } else {
        for p in &problems {
            println!("fig5 shape deviation: {p}");
        }
    }
    Ok(())
}

fn cmd_retime(args: &Args) -> Result<()> {
    let layers = args.usize_or("layers", 8)?;
    let partition = match args.get("groups") {
        Some(_) => {
            let sizes = args.usize_list("groups", &[])?;
            StagePartition::from_group_sizes(&sizes)?
        }
        None => StagePartition::even(layers, layers)?,
    };
    let d = Derivation::derive(partition.layers(), partition.stage_of())?;
    d.verify()?;
    println!("layers: {}  stages: {}", partition.layers(), partition.stages());
    println!(
        "{:<8} {:>6} {:>16} {:>12} {:>12}",
        "layer", "stage", "Delay(l)=2S(l)", "act stash", "wt stash"
    );
    for l in 0..partition.layers() {
        println!(
            "{:<8} {:>6} {:>16} {:>12} {:>12}",
            l,
            partition.stage_of()[l],
            d.gradient_delay[l],
            d.act_stash_depth[l],
            d.weight_stash_depth[l]
        );
    }
    println!("verified: retimed graph legal, Eq.1 closed form holds");
    Ok(())
}

fn cmd_dlms(args: &Args) -> Result<()> {
    let delays = args.usize_list("delays", &[0, 1, 4, 16, 64])?;
    let mu = args.f64_or("mu", 0.01)?;
    let taps = args.usize_or("taps", 16)?;
    let samples = args.usize_or("samples", 20_000)?;
    println!(
        "{:<8} {:>12} {:>16} {:>14} {:>10}",
        "delay", "misalign", "steady MSE", "conv@1e-3", "stable"
    );
    for &delay in &delays {
        let cfg = dlms::DlmsConfig { taps, mu, delay, samples, ..Default::default() };
        let r = dlms::run(&cfg);
        let conv = dlms::convergence_time(&r.mse_curve, 1e-3)
            .map(|t| t.to_string())
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<8} {:>12.3e} {:>16.3e} {:>14} {:>10}",
            delay, r.misalignment, r.steady_state_mse, conv, r.converged
        );
    }
    println!("μ stability bound (white input): μ < 2/(σ²(T+2M))");
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let layers = args.usize_or("layers", 8)?;
    let stages = args.usize_or("stages", 8)?;
    let batches = args.usize_or("batches", 64)? as u64;
    let p = StagePartition::even(layers, stages)?;
    let s = Schedule::build(&p, batches);
    println!("observed staleness per stage: {:?}", s.observed_staleness());
    println!("stash versions per stage:     {:?}", s.stash_versions());
    println!(
        "utilization per stage:        {:?}",
        s.utilization().iter().map(|u| format!("{u:.3}")).collect::<Vec<_>>()
    );
    let cost = CostModel::uniform(layers);
    for (k, perf) in sweep_stages(layers, &cost, batches, &[1, 2, 4, stages.min(layers)]) {
        println!(
            "stages={k}: speedup {:.2}x  util {:.3}  bottleneck {:.1}",
            perf.speedup, perf.mean_utilization, perf.bottleneck_cost
        );
    }
    Ok(())
}

fn cmd_throughput(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let stage_counts = args.usize_list("stages", &[1, 2, 4, 8])?;
    let batches = args.usize_or("batches", 200)?;
    let depth = args.usize_or("depth", 4)?;
    let backend = backend::from_env(dir)?;
    // Manifest shapes when present (the PJRT backend is locked to them),
    // the default preset otherwise (the host backend takes any shape).
    let cfg = Manifest::model_config_or_default(dir);
    println!("backend: {}", backend.name());
    let mut rng = Rng::new(7);
    let mlp = Mlp::init(&cfg, &mut rng);
    let inputs: Vec<Tensor> =
        (0..8).map(|_| Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng)).collect();
    let seq = pipeline::forward_sequential(&backend, &mlp, &inputs, batches)?;
    println!("sequential: {:.1} batches/s", seq.batches_per_sec);
    for &k in &stage_counts {
        if k < 1 || k > cfg.layers {
            continue;
        }
        let p = StagePartition::even(cfg.layers, k)?;
        let r = pipeline::forward_throughput(&backend, &mlp, &p, inputs.clone(), batches, depth)?;
        println!(
            "stages={k}: {:.1} batches/s  speedup {:.2}x",
            r.batches_per_sec,
            r.batches_per_sec / seq.batches_per_sec
        );
    }
    Ok(())
}

/// Batched inference serving demo: N client threads push M requests each
/// through the live server while the main thread hot-reloads weights;
/// every response is checked bitwise against the sequential forward
/// oracle of the exact weight version that served it.
fn cmd_serve(args: &Args) -> Result<()> {
    let clients = args.usize_or("clients", 4)?;
    let requests = args.usize_or("requests", 128)?;
    let rows = args.usize_or("rows", 4)?;
    let max_batch = args.usize_or("max-batch", 32)?;
    let wait_ticks = args.usize_or("wait-ticks", 2)? as u64;
    let stages = args.usize_or("stages", 2)?;
    let reloads = args.usize_or("reloads", 1)?;
    let dir = args.get("artifacts").unwrap_or("artifacts");
    if rows < 1 || rows > max_batch {
        bail!("--rows must be in 1..=max-batch ({max_batch})");
    }

    let backend = backend::from_env(dir)?;
    let mcfg = Manifest::model_config_or_default(dir);
    let spec = NetworkSpec::mlp(&mcfg);
    // Weight versions: epoch 0 serves first; each reload swaps in the
    // next. A checkpoint (v2 network format) replaces version 0.
    let mut versions = Vec::with_capacity(reloads + 1);
    for k in 0..=reloads {
        let mut net = Network::build(&spec, &mut Rng::new(7 + k as u64))?;
        if k == 0 {
            if let Some(path) = args.get("checkpoint") {
                checkpoint::load_network(&mut net, path)
                    .with_context(|| format!("loading checkpoint {path}"))?;
            }
        }
        versions.push(net);
    }

    // Distinct request payloads + the per-version sequential oracle,
    // computed on the *same* backend the server dispatches to (host and
    // PJRT kernels are not bit-comparable with each other).
    let mut rng = Rng::new(42);
    let n_inputs = 16usize;
    let inputs: Vec<Tensor> =
        (0..n_inputs).map(|_| Tensor::randn(&[rows, mcfg.input_dim], 1.0, &mut rng)).collect();
    let mut expected: Vec<Vec<Tensor>> = Vec::with_capacity(versions.len());
    for v in &versions {
        let mut oracle = v.snapshot()?;
        expected.push(
            inputs
                .iter()
                .map(|x| oracle.forward_full(backend.as_ref(), x))
                .collect::<Result<_>>()?,
        );
    }

    let cfg = ServerConfig {
        max_batch,
        max_wait_ticks: wait_ticks,
        shrink_under: 0,
        queue_depth: 64,
        stages,
        ..ServerConfig::default()
    };
    let server = Server::start(backend.clone(), &versions[0], &cfg)?;
    println!(
        "serving: backend {}  {} stages  partition {:?}",
        backend.name(),
        stages,
        server.partition().stage_of()
    );
    println!(
        "traffic: {clients} clients x {requests} requests x {rows} rows, max_batch {max_batch}, {reloads} hot reload(s)"
    );

    let mut per_version = vec![0u64; versions.len()];
    let sw = std::time::Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let inputs = &inputs;
        let expected = &expected;
        let mut handles = Vec::with_capacity(clients);
        for c in 0..clients {
            let mut cl = server.client();
            handles.push(s.spawn(move || {
                let pick = |i: usize| (c + 3 * i) % inputs.len();
                layerpipe2::serving::drive_and_verify(&mut cl, inputs, expected, pick, requests, 8)
            }));
        }
        // Hot reloads spread over the run.
        for v in versions.iter().skip(1) {
            std::thread::sleep(std::time::Duration::from_millis(5));
            server.reload(v)?;
        }
        for h in handles {
            let counts = h.join().expect("client thread")?;
            for (k, n) in counts.iter().enumerate() {
                per_version[k] += n;
            }
        }
        Ok(())
    })?;
    let elapsed = sw.elapsed().as_secs_f64();

    let total = (clients * requests) as u64;
    let lat = server.latency_hist();
    let stats = server.shutdown()?;
    println!("served {total} requests in {elapsed:.3}s = {:.0} req/s ({:.0} rows/s)", total as f64 / elapsed, (total as usize * rows) as f64 / elapsed);
    for (v, n) in per_version.iter().enumerate() {
        println!("  version {v}: {n} responses");
    }
    if lat.count > 0 {
        let ms = |q: f64| lat.quantile_ns(q) as f64 / 1e6;
        println!(
            "request latency: p50 {:.3}ms  p90 {:.3}ms  p99 {:.3}ms  mean {:.3}ms  ({} samples)",
            ms(0.50),
            ms(0.90),
            ms(0.99),
            lat.mean_ns() as f64 / 1e6,
            lat.count
        );
    }
    println!(
        "batches {}  occupancy {:.2}  flushes full/shrank/force/wait {}/{}/{}/{}  queue depth {}",
        stats.batches,
        stats.occupancy,
        stats.flush_full,
        stats.flush_shrank,
        stats.flush_force,
        stats.flush_wait,
        stats.queue_depth
    );
    println!(
        "survival: rejected rate/budget {}/{}  shed deadline/backpressure/shutdown {}/{}/{}  late {}  faults {}",
        stats.rejected_rate,
        stats.rejected_budget,
        stats.shed_deadline,
        stats.shed_backpressure,
        stats.shed_shutdown,
        stats.late,
        stats.faults_injected
    );
    println!(
        "reloads {}  pool {}h/{}m  (all responses bitwise == oracle)",
        stats.reloads, stats.pool_hits, stats.pool_misses
    );
    Ok(())
}

/// Deterministic serving chaos/soak harness (see `serving::chaos`).
/// Flags: `--seed N`, `--smoke` (CI-sized run), `--json PATH` (report
/// destination; default `BENCH_serving.json`, overridable with
/// `LAYERPIPE2_BENCH_SERVING_JSON`). The report is merged into the
/// bench file as a `"soak"` section, preserving other sections.
fn cmd_soak(argv: &[String]) -> Result<()> {
    let mut smoke = false;
    let mut seed: u64 = 0xC0FFEE;
    let mut json_path: Option<String> = None;
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--smoke" => {
                smoke = true;
                i += 1;
            }
            "--seed" => {
                let v = argv.get(i + 1).context("--seed needs a value")?;
                seed = v.parse().with_context(|| format!("--seed expects an integer, got '{v}'"))?;
                i += 2;
            }
            "--json" => {
                json_path = Some(argv.get(i + 1).context("--json needs a path")?.clone());
                i += 2;
            }
            other => bail!("unknown soak flag '{other}' (expected --seed N, --smoke, --json PATH)"),
        }
    }
    let cfg = layerpipe2::serving::chaos::SoakConfig { seed, smoke };
    println!("soak: seed {seed}  mode {}", if smoke { "smoke" } else { "full" });
    let report = layerpipe2::serving::chaos::run_soak(&cfg)?;
    println!(
        "{:<14} {:>9} {:>9} {:>8} {:>8} {:>6} {:>6} {:>7} {:>7}",
        "scenario", "submitted", "completed", "dropped", "rejected", "shed", "late", "faults", "reloads"
    );
    for s in &report.scenarios {
        println!(
            "{:<14} {:>9} {:>9} {:>8} {:>8} {:>6} {:>6} {:>7} {:>7}",
            s.name, s.submitted, s.completed, s.dropped, s.rejected, s.shed, s.late, s.faults, s.reloads
        );
    }
    println!(
        "steady state: {:.0} req/s  p50 {:.3}ms  p99 {:.3}ms",
        report.req_per_s, report.p50_ms, report.p99_ms
    );
    println!(
        "invariants: lost {}  duplicated {}  reordered {}  (payloads bitwise == pinned-epoch oracle)",
        report.lost, report.duplicated, report.reordered
    );
    let path = json_path
        .or_else(|| std::env::var("LAYERPIPE2_BENCH_SERVING_JSON").ok().filter(|p| !p.is_empty()))
        .unwrap_or_else(|| "BENCH_serving.json".to_string());
    merge_json_section(&path, "soak", &report.to_json())?;
    println!("soak report merged into {path} (\"soak\" section)");
    Ok(())
}

/// Set `key` to `value` (a serialized JSON value) inside the top-level
/// JSON object stored at `path`, preserving every other section —
/// creates the file as `{"key":value}` when missing or empty. The
/// splice is a balanced scan, not a full parser: enough to make
/// repeated soak runs idempotent against the bench writer's output.
fn merge_json_section(path: &str, key: &str, value: &str) -> Result<()> {
    let body = std::fs::read_to_string(path).unwrap_or_default();
    let merged = splice_json_key(&body, key, value)?;
    std::fs::write(path, merged).with_context(|| format!("writing {path}"))?;
    Ok(())
}

fn splice_json_key(body: &str, key: &str, value: &str) -> Result<String> {
    let trimmed = body.trim();
    if trimmed.is_empty() {
        return Ok(format!("{{\"{key}\":{value}}}\n"));
    }
    anyhow::ensure!(
        trimmed.starts_with('{') && trimmed.ends_with('}'),
        "cannot merge into non-object JSON"
    );
    let needle = format!("\"{key}\"");
    if let Some(kpos) = trimmed.find(&needle) {
        // Replace the existing value: skip whitespace + ':', then a
        // balanced JSON value.
        let mut vstart = kpos + needle.len();
        let bytes = trimmed.as_bytes();
        while vstart < bytes.len() && (bytes[vstart].is_ascii_whitespace() || bytes[vstart] == b':')
        {
            vstart += 1;
        }
        let vlen = json_value_len(&trimmed[vstart..])?;
        Ok(format!("{}{}{}", &trimmed[..vstart], value, &trimmed[vstart + vlen..]))
    } else {
        let head = trimmed[..trimmed.len() - 1].trim_end();
        let sep = if head.ends_with('{') { "" } else { "," };
        Ok(format!("{head}{sep}\"{key}\":{value}}}"))
    }
}

/// Length of the JSON value at the start of `s` (strings, nested
/// objects/arrays, or scalars up to a top-level ',' or closing
/// brace/bracket).
fn json_value_len(s: &str) -> Result<usize> {
    let bytes = s.as_bytes();
    let mut depth = 0i64;
    let mut in_str = false;
    let mut esc = false;
    for (i, &b) in bytes.iter().enumerate() {
        let c = b as char;
        if in_str {
            if esc {
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else if c == '"' {
                in_str = false;
                if depth == 0 && i > 0 && bytes[0] == b'"' {
                    return Ok(i + 1);
                }
            }
            continue;
        }
        match c {
            '"' => in_str = true,
            '{' | '[' => depth += 1,
            '}' | ']' => {
                if depth == 0 {
                    return Ok(i); // closing brace of the enclosing object
                }
                depth -= 1;
                if depth == 0 && matches!(bytes[0], b'{' | b'[') {
                    return Ok(i + 1);
                }
            }
            ',' if depth == 0 => return Ok(i),
            _ => {}
        }
    }
    anyhow::ensure!(depth == 0 && !in_str, "unbalanced JSON value");
    Ok(s.len())
}

/// Weight-ring replica training demo: run the same workload at each
/// requested replica count and check the deterministic all-reduce
/// contract — final weights bitwise identical regardless of how many
/// threads the fixed shard lanes are spread over.
fn cmd_train_ring(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    apply_dtype(args, &mut cfg)?;
    cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
    cfg.pipeline.stages = args.usize_or("stages", cfg.pipeline.stages)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    cfg.validate()?;
    let kind = match args.get("strategy") {
        Some(s) => StrategyKind::parse(s)?,
        None => StrategyKind::PipelineAwareEma,
    };
    // Default shard count: the largest divisor of the batch ≤ 8, so the
    // ring always validates out of the box.
    let default_shards =
        (1..=8.min(cfg.model.batch)).rev().find(|d| cfg.model.batch % d == 0).unwrap_or(1);
    let shards = args.usize_or("shards", default_shards)?;
    let replica_counts = match args.get("replicas") {
        Some(_) => args.usize_list("replicas", &[])?,
        None => {
            // LAYERPIPE2_REPLICAS (clamped to a divisor of the shard
            // count) picks the contender; 1 is always the oracle.
            let n = replica::default_replicas(shards);
            if n == 1 { vec![1] } else { vec![1, n] }
        }
    };
    if replica_counts.is_empty() {
        bail!("--replicas needs at least one count");
    }

    let backend = backend::from_env(&cfg.artifacts_dir)?;
    let data = teacher_dataset(&cfg.model, &cfg.data);
    println!(
        "weight ring: backend {}  strategy {}  shards {}  batch {}  epochs {}  dtype {}",
        backend.name(),
        kind.name(),
        shards,
        cfg.model.batch,
        cfg.epochs,
        cfg.dtype
    );
    println!(
        "{:<10} {:>8} {:>12} {:>14} {:>10} {:>12} {:>10}",
        "replicas", "shards", "iterations", "samples/s", "speedup", "train loss", "test acc"
    );
    let mut oracle: Option<replica::RingReport> = None;
    for &n in &replica_counts {
        let ring = replica::RingConfig::new(n, shards);
        let report = replica::train_ring(&backend, &cfg, None, kind, &ring, &data)?;
        let base = oracle.as_ref().map_or(report.samples_per_sec, |o| o.samples_per_sec);
        println!(
            "{:<10} {:>8} {:>12} {:>14.1} {:>9.2}x {:>12.4} {:>10.4}",
            report.replicas,
            report.shards,
            report.iterations,
            report.samples_per_sec,
            report.samples_per_sec / base,
            report.train_loss,
            report.test_accuracy
        );
        match &oracle {
            None => oracle = Some(report),
            Some(o) => {
                let same = report.final_weights.len() == o.final_weights.len()
                    && report
                        .final_weights
                        .data()
                        .iter()
                        .zip(o.final_weights.data())
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                if !same {
                    bail!(
                        "final weights at {} replicas differ from {} replicas (determinism broken)",
                        report.replicas,
                        o.replicas
                    );
                }
            }
        }
    }
    if replica_counts.len() > 1 {
        println!("final weights bitwise identical across all replica counts");
    }
    Ok(())
}

/// Telemetry demo: run a short pipelined training with the span gate
/// forced on, then print the full registry table (the same `[stats]`
/// lines the trainers emit at epoch boundaries) plus the per-stage
/// bubble breakdown, and optionally the JSON export.
fn cmd_stats(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    apply_dtype(args, &mut cfg)?;
    cfg.epochs = args.usize_or("epochs", cfg.epochs.min(2))?;
    cfg.pipeline.stages = args.usize_or("stages", cfg.pipeline.stages)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    cfg.validate()?;
    let kind = match args.get("strategy") {
        Some(s) => StrategyKind::parse(s)?,
        None => StrategyKind::PipelineAwareEma,
    };
    obs::set_enabled(true);

    let backend = backend::from_env(&cfg.artifacts_dir)?;
    let data = teacher_dataset(&cfg.model, &cfg.data);
    println!(
        "telemetry run: backend {}  strategy {}  stages {}  epochs {}",
        backend.name(),
        kind.name(),
        cfg.pipeline.stages,
        cfg.epochs
    );
    let before = obs::TelemetrySnapshot::capture();
    let mut rng = Rng::new(cfg.seed);
    let mut trainer = pipeline::PipelinedTrainer::new(backend, &cfg, kind, &mut rng)?;
    let curve = trainer.train(&data, &mut rng)?;
    let window = obs::TelemetrySnapshot::capture().diff(&before);

    println!("final test accuracy: {:.4}", curve.final_accuracy());
    println!("--- telemetry window (this run only) ---");
    print!("{window}");
    for b in trainer.bubble_report(&window) {
        println!(
            "[stats] bubble stage {}: compute {:.0}% (predicted {:.0}%)  bubble {:.1}%",
            b.stage,
            b.measured_share * 100.0,
            b.predicted_share * 100.0,
            b.bubble_fraction * 100.0
        );
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, window.to_json().to_string())
            .with_context(|| format!("writing telemetry json to {path}"))?;
        println!("telemetry json written to {path}");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let a = args(&["--epochs", "5", "--strategy", "stashing", "--strategy", "latest"]);
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 5);
        assert_eq!(a.usize_or("stages", 8).unwrap(), 8);
        assert_eq!(a.get_all("strategy"), vec!["stashing", "latest"]);
        assert_eq!(a.get("missing"), None);
    }

    #[test]
    fn last_value_wins_for_get() {
        let a = args(&["--seed", "1", "--seed", "2"]);
        assert_eq!(a.get("seed"), Some("2"));
    }

    #[test]
    fn lists_parse() {
        let a = args(&["--delays", "0, 4,16"]);
        assert_eq!(a.usize_list("delays", &[]).unwrap(), vec![0, 4, 16]);
        assert_eq!(a.usize_list("other", &[7]).unwrap(), vec![7]);
    }

    #[test]
    fn errors_are_helpful() {
        assert!(Args::parse(&["bare".to_string()]).is_err());
        assert!(Args::parse(&["--flag".to_string()]).is_err());
        let a = args(&["--epochs", "many"]);
        assert!(a.usize_or("epochs", 1).is_err());
        assert!(args(&["--mu", "x"]).f64_or("mu", 0.1).is_err());
    }

    #[test]
    fn json_splice_inserts_updates_and_preserves() {
        // Empty/missing file: a fresh one-key object.
        assert_eq!(
            super::splice_json_key("", "soak", "{\"lost\":0}").unwrap(),
            "{\"soak\":{\"lost\":0}}\n"
        );
        // Insert alongside existing sections.
        let merged = super::splice_json_key("{\"gate_ok\":true}", "soak", "{\"lost\":0}").unwrap();
        assert_eq!(merged, "{\"gate_ok\":true,\"soak\":{\"lost\":0}}");
        // Replace in place (idempotent reruns); braces inside strings
        // must not confuse the scan.
        let twice =
            super::splice_json_key(&merged, "soak", "{\"lost\":1,\"s\":\"a}b\"}").unwrap();
        assert_eq!(twice, "{\"gate_ok\":true,\"soak\":{\"lost\":1,\"s\":\"a}b\"}}");
        // Object → scalar and a spaced writer style both splice cleanly.
        let back = super::splice_json_key(&twice, "soak", "7").unwrap();
        assert_eq!(back, "{\"gate_ok\":true,\"soak\":7}");
        let spaced =
            super::splice_json_key("{\"soak\": {\"x\": [1,2]}, \"other\": 3}", "soak", "9")
                .unwrap();
        assert_eq!(spaced, "{\"soak\": 9, \"other\": 3}");
        // Only top-level objects are mergeable.
        assert!(super::splice_json_key("[1,2]", "k", "1").is_err());
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    // Manifest inspection works on every build; only execution needs the
    // `pjrt` feature.
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(&Path::new(dir).join("manifest.json"))?;
    println!("preset: {}  fingerprint: {}", m.preset, m.fingerprint);
    println!(
        "model: batch={} input={} hidden={} classes={} layers={}",
        m.model.batch, m.model.input_dim, m.model.hidden_dim, m.model.classes, m.model.layers
    );
    for e in &m.entries {
        println!(
            "  {:<16} {} inputs → {} outputs  ({})",
            e.name,
            e.inputs.len(),
            e.outputs,
            e.file
        );
    }
    Ok(())
}
