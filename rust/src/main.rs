//! `layerpipe2` — CLI launcher for the LayerPipe2 reproduction.
//!
//! Subcommands (clap is unavailable offline; parsing is hand-rolled):
//!
//! ```text
//! layerpipe2 train   [--config F] [--strategy S]... [--epochs N] [--stages K] [--csv PATH]
//! layerpipe2 retime  [--layers L] [--groups a,b,c]
//! layerpipe2 dlms    [--delays 0,1,4,16] [--mu MU] [--taps T]
//! layerpipe2 schedule [--layers L] [--stages K] [--batches B]
//! layerpipe2 throughput [--stages 1,2,4,8] [--batches B] [--artifacts DIR]
//! layerpipe2 info    [--artifacts DIR]
//! ```

use anyhow::{bail, Context, Result};
use layerpipe2::backend::{self, Exec};
use layerpipe2::config::ExperimentConfig;
use layerpipe2::coordinator::{check_fig5_shape, Coordinator, ExecutorKind};
use layerpipe2::dlms;
use layerpipe2::model::Mlp;
use layerpipe2::pipeline;
use layerpipe2::retiming::{Derivation, StagePartition};
use layerpipe2::runtime::Manifest;
use layerpipe2::schedule::{sweep_stages, CostModel, Schedule};
use layerpipe2::strategy::StrategyKind;
use layerpipe2::tensor::Tensor;
use layerpipe2::util::Rng;
use std::path::Path;

/// Minimal flag parser: `--key value` pairs after the subcommand;
/// repeated keys accumulate.
struct Args {
    flags: Vec<(String, String)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let k = &argv[i];
            if !k.starts_with("--") {
                bail!("expected --flag, got '{k}'");
            }
            let v = argv
                .get(i + 1)
                .with_context(|| format!("flag {k} needs a value"))?;
            flags.push((k[2..].to_string(), v.clone()));
            i += 2;
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    fn get_all(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got '{v}'")),
        }
    }

    fn usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| s.trim().parse().with_context(|| format!("bad list item '{s}' in --{key}")))
                .collect(),
        }
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let args = Args::parse(&argv[1..])?;
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "retime" => cmd_retime(&args),
        "dlms" => cmd_dlms(&args),
        "schedule" => cmd_schedule(&args),
        "throughput" => cmd_throughput(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'layerpipe2 help')"),
    }
}

fn print_usage() {
    println!(
        "layerpipe2 — multistage pipelined training with EMA weight recompute

USAGE: layerpipe2 <COMMAND> [--flag value]...

COMMANDS:
  train       run the Fig. 5 strategy sweep (pipelined training)
              --config F --strategy S (repeatable) --epochs N --stages K
              --csv PATH --artifacts DIR --seed N
              --executor iteration|threaded (threaded = one thread/stage)
  retime      derive pipeline delays via retiming (Figs. 3/4)
              --layers L  --groups a,b,c (group sizes)
  dlms        delayed-LMS convergence sweep (Fig. 2)
              --delays 0,1,4,16 --mu 0.01 --taps 16 --samples 20000
  schedule    clock-schedule analysis (utilization/speedup/staleness)
              --layers L --stages K --batches B
  throughput  threaded pipeline throughput on real XLA compute
              --stages 1,2,4,8 --batches B --artifacts DIR
  info        print artifact manifest details  --artifacts DIR"
    );
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(path)?,
        None => ExperimentConfig::default(),
    };
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_dir = dir.to_string();
    }
    cfg.epochs = args.usize_or("epochs", cfg.epochs)?;
    cfg.pipeline.stages = args.usize_or("stages", cfg.pipeline.stages)?;
    cfg.seed = args.usize_or("seed", cfg.seed as usize)? as u64;
    if let Some(csv) = args.get("csv") {
        cfg.csv_out = Some(csv.to_string());
    }
    let requested = args.get_all("strategy");
    if !requested.is_empty() {
        cfg.strategies = requested
            .iter()
            .map(|s| StrategyKind::parse(s))
            .collect::<Result<_>>()?;
    }
    cfg.validate()?;
    let executor = match args.get("executor").unwrap_or("iteration") {
        "iteration" | "oracle" => ExecutorKind::Iteration,
        "threaded" | "pipelined" => ExecutorKind::Threaded,
        other => bail!("unknown --executor '{other}' (expected iteration|threaded)"),
    };

    let coord = Coordinator::new(cfg)?;
    let result = coord.sweep_on(executor)?;
    println!("{}", result.table());
    let problems = check_fig5_shape(&result);
    if problems.is_empty() {
        println!("fig5 shape: REPRODUCED (orderings + memory reduction hold)");
    } else {
        for p in &problems {
            println!("fig5 shape deviation: {p}");
        }
    }
    Ok(())
}

fn cmd_retime(args: &Args) -> Result<()> {
    let layers = args.usize_or("layers", 8)?;
    let partition = match args.get("groups") {
        Some(_) => {
            let sizes = args.usize_list("groups", &[])?;
            StagePartition::from_group_sizes(&sizes)?
        }
        None => StagePartition::even(layers, layers)?,
    };
    let d = Derivation::derive(partition.layers(), partition.stage_of())?;
    d.verify()?;
    println!("layers: {}  stages: {}", partition.layers(), partition.stages());
    println!(
        "{:<8} {:>6} {:>16} {:>12} {:>12}",
        "layer", "stage", "Delay(l)=2S(l)", "act stash", "wt stash"
    );
    for l in 0..partition.layers() {
        println!(
            "{:<8} {:>6} {:>16} {:>12} {:>12}",
            l,
            partition.stage_of()[l],
            d.gradient_delay[l],
            d.act_stash_depth[l],
            d.weight_stash_depth[l]
        );
    }
    println!("verified: retimed graph legal, Eq.1 closed form holds");
    Ok(())
}

fn cmd_dlms(args: &Args) -> Result<()> {
    let delays = args.usize_list("delays", &[0, 1, 4, 16, 64])?;
    let mu = args.f64_or("mu", 0.01)?;
    let taps = args.usize_or("taps", 16)?;
    let samples = args.usize_or("samples", 20_000)?;
    println!(
        "{:<8} {:>12} {:>16} {:>14} {:>10}",
        "delay", "misalign", "steady MSE", "conv@1e-3", "stable"
    );
    for &delay in &delays {
        let cfg = dlms::DlmsConfig { taps, mu, delay, samples, ..Default::default() };
        let r = dlms::run(&cfg);
        let conv = dlms::convergence_time(&r.mse_curve, 1e-3)
            .map(|t| t.to_string())
            .unwrap_or_else(|| "-".to_string());
        println!(
            "{:<8} {:>12.3e} {:>16.3e} {:>14} {:>10}",
            delay, r.misalignment, r.steady_state_mse, conv, r.converged
        );
    }
    println!("μ stability bound (white input): μ < 2/(σ²(T+2M))");
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let layers = args.usize_or("layers", 8)?;
    let stages = args.usize_or("stages", 8)?;
    let batches = args.usize_or("batches", 64)? as u64;
    let p = StagePartition::even(layers, stages)?;
    let s = Schedule::build(&p, batches);
    println!("observed staleness per stage: {:?}", s.observed_staleness());
    println!("stash versions per stage:     {:?}", s.stash_versions());
    println!(
        "utilization per stage:        {:?}",
        s.utilization().iter().map(|u| format!("{u:.3}")).collect::<Vec<_>>()
    );
    let cost = CostModel::uniform(layers);
    for (k, perf) in sweep_stages(layers, &cost, batches, &[1, 2, 4, stages.min(layers)]) {
        println!(
            "stages={k}: speedup {:.2}x  util {:.3}  bottleneck {:.1}",
            perf.speedup, perf.mean_utilization, perf.bottleneck_cost
        );
    }
    Ok(())
}

fn cmd_throughput(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let stage_counts = args.usize_list("stages", &[1, 2, 4, 8])?;
    let batches = args.usize_or("batches", 200)?;
    let depth = args.usize_or("depth", 4)?;
    let backend = backend::from_env(dir)?;
    // Manifest shapes when present (the PJRT backend is locked to them),
    // the default preset otherwise (the host backend takes any shape).
    let cfg = Manifest::model_config_or_default(dir);
    println!("backend: {}", backend.name());
    let mut rng = Rng::new(7);
    let mlp = Mlp::init(&cfg, &mut rng);
    let inputs: Vec<Tensor> =
        (0..8).map(|_| Tensor::randn(&[cfg.batch, cfg.input_dim], 1.0, &mut rng)).collect();
    let seq = pipeline::forward_sequential(&backend, &mlp, &inputs, batches)?;
    println!("sequential: {:.1} batches/s", seq.batches_per_sec);
    for &k in &stage_counts {
        if k < 1 || k > cfg.layers {
            continue;
        }
        let p = StagePartition::even(cfg.layers, k)?;
        let r = pipeline::forward_throughput(&backend, &mlp, &p, inputs.clone(), batches, depth)?;
        println!(
            "stages={k}: {:.1} batches/s  speedup {:.2}x",
            r.batches_per_sec,
            r.batches_per_sec / seq.batches_per_sec
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::Args;

    fn args(v: &[&str]) -> Args {
        Args::parse(&v.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let a = args(&["--epochs", "5", "--strategy", "stashing", "--strategy", "latest"]);
        assert_eq!(a.usize_or("epochs", 1).unwrap(), 5);
        assert_eq!(a.usize_or("stages", 8).unwrap(), 8);
        assert_eq!(a.get_all("strategy"), vec!["stashing", "latest"]);
        assert_eq!(a.get("missing"), None);
    }

    #[test]
    fn last_value_wins_for_get() {
        let a = args(&["--seed", "1", "--seed", "2"]);
        assert_eq!(a.get("seed"), Some("2"));
    }

    #[test]
    fn lists_parse() {
        let a = args(&["--delays", "0, 4,16"]);
        assert_eq!(a.usize_list("delays", &[]).unwrap(), vec![0, 4, 16]);
        assert_eq!(a.usize_list("other", &[7]).unwrap(), vec![7]);
    }

    #[test]
    fn errors_are_helpful() {
        assert!(Args::parse(&["bare".to_string()]).is_err());
        assert!(Args::parse(&["--flag".to_string()]).is_err());
        let a = args(&["--epochs", "many"]);
        assert!(a.usize_or("epochs", 1).is_err());
        assert!(args(&["--mu", "x"]).f64_or("mu", 0.1).is_err());
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    // Manifest inspection works on every build; only execution needs the
    // `pjrt` feature.
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(&Path::new(dir).join("manifest.json"))?;
    println!("preset: {}  fingerprint: {}", m.preset, m.fingerprint);
    println!(
        "model: batch={} input={} hidden={} classes={} layers={}",
        m.model.batch, m.model.input_dim, m.model.hidden_dim, m.model.classes, m.model.layers
    );
    for e in &m.entries {
        println!(
            "  {:<16} {} inputs → {} outputs  ({})",
            e.name,
            e.inputs.len(),
            e.outputs,
            e.file
        );
    }
    Ok(())
}
