//! Pure-Rust execution backend over the host tensor kernels.
//!
//! Mirrors the artifact contract exactly (same math as the lowered HLO:
//! dense + bias + optional fused ReLU forward; `(dx, dw, db)` backward
//! with the ReLU mask applied from the *output* activation; softmax-CE
//! loss/grad over one-hot labels), so the single-threaded trainer, the
//! threaded pipelined executor, every test and every bench run unchanged
//! on machines without PJRT artifacts.

use super::Exec;
use crate::config::ModelConfig;
use crate::model::LayerRole;
use crate::tensor::{self, Tensor};
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// The host backend: stateless except for dispatch bookkeeping.
#[derive(Debug, Default)]
pub struct HostBackend {
    exec_count: AtomicU64,
}

impl HostBackend {
    pub fn new() -> HostBackend {
        HostBackend::default()
    }

    fn count(&self) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }
}

impl Exec for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn check_model(&self, cfg: &ModelConfig) -> Result<()> {
        // Any validated shape is servable: kernels are shape-generic.
        cfg.validate()
    }

    fn forward(&self, role: LayerRole, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
        self.count();
        ensure!(
            x.ndim() == 2 && w.ndim() == 2 && b.ndim() == 1,
            "host forward: x/w must be 2-D and b 1-D, got {:?}/{:?}/{:?}",
            x.shape(),
            w.shape(),
            b.shape()
        );
        ensure!(
            x.shape()[1] == w.shape()[0] && w.shape()[1] == b.shape()[0],
            "host forward shape mismatch: x {:?} @ w {:?} + b {:?}",
            x.shape(),
            w.shape(),
            b.shape()
        );
        let z = tensor::add_bias(&tensor::matmul(x, w), b);
        Ok(if role.has_relu() { tensor::relu(&z) } else { z })
    }

    fn backward(
        &self,
        role: LayerRole,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        self.count();
        // Rank checks first: indexing shape()[1] below must never panic
        // (the backend contract is Err, not UB/panics, on bad shapes).
        ensure!(
            x.ndim() == 2 && y.ndim() == 2 && w.ndim() == 2 && dy.ndim() == 2,
            "host backward: x/y/w/dy must all be 2-D, got {:?}/{:?}/{:?}/{:?}",
            x.shape(),
            y.shape(),
            w.shape(),
            dy.shape()
        );
        ensure!(
            y.shape() == dy.shape(),
            "host backward: y {:?} vs dy {:?}",
            y.shape(),
            dy.shape()
        );
        ensure!(
            x.shape()[1] == w.shape()[0] && w.shape()[1] == dy.shape()[1],
            "host backward shape mismatch: x {:?}, w {:?}, dy {:?}",
            x.shape(),
            w.shape(),
            dy.shape()
        );
        // Pre-activation gradient: mask with the saved output for ReLU
        // layers (y > 0 ⇔ the unit was active), pass-through otherwise.
        let masked;
        let dz = if role.has_relu() {
            masked = tensor::relu_grad(y, dy);
            &masked
        } else {
            dy
        };
        let dx = tensor::matmul_nt(dz, w);
        let dw = tensor::matmul_tn(x, dz);
        let db = tensor::col_sum(dz);
        Ok((dx, dw, db))
    }

    fn loss_grad(&self, logits: &Tensor, onehot: &Tensor) -> Result<(f32, Tensor, f32)> {
        self.count();
        ensure!(
            logits.ndim() == 2 && logits.shape() == onehot.shape(),
            "host loss_grad: logits {:?} vs onehot {:?} (both must be 2-D)",
            logits.shape(),
            onehot.shape()
        );
        Ok(tensor::softmax_xent_onehot(logits, onehot))
    }

    fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{layer_dims, Mlp};
    use crate::util::Rng;

    fn be() -> HostBackend {
        HostBackend::new()
    }

    #[test]
    fn forward_matches_op_composition() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let w = Tensor::randn(&[6, 5], 0.3, &mut rng);
        let b = Tensor::randn(&[5], 0.1, &mut rng);
        let z = tensor::add_bias(&tensor::matmul(&x, &w), &b);
        let hid = be().forward(LayerRole::Hidden, &x, &w, &b).unwrap();
        assert_eq!(hid, tensor::relu(&z));
        // Output layer skips the ReLU: raw affine result comes through.
        let out = be().forward(LayerRole::Output, &x, &w, &b).unwrap();
        assert_eq!(out, z);
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Scalar-project the layer output and check every parameter
        // gradient against central differences.
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let w = Tensor::randn(&[4, 5], 0.5, &mut rng);
        let b = Tensor::randn(&[5], 0.1, &mut rng);
        let proj = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let backend = be();
        let fwd = |w: &Tensor, b: &Tensor, x: &Tensor| -> f32 {
            let y = backend.forward(LayerRole::Hidden, x, w, b).unwrap();
            y.data().iter().zip(proj.data()).map(|(a, p)| a * p).sum()
        };
        let y = backend.forward(LayerRole::Hidden, &x, &w, &b).unwrap();
        let (dx, dw, db) = backend.backward(LayerRole::Hidden, &x, &y, &w, &proj).unwrap();
        let eps = 1e-3;
        let check = |grad: &Tensor, target: &Tensor, which: &str| {
            for idx in 0..target.len() {
                let (mut tp, mut tm) = (target.clone(), target.clone());
                tp.data_mut()[idx] += eps;
                tm.data_mut()[idx] -= eps;
                let (fp, fm) = match which {
                    "w" => (fwd(&tp, &b, &x), fwd(&tm, &b, &x)),
                    "b" => (fwd(&w, &tp, &x), fwd(&w, &tm, &x)),
                    _ => (fwd(&w, &b, &tp), fwd(&w, &b, &tm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - grad.data()[idx]).abs() < 2e-2,
                    "{which}[{idx}]: fd {fd} vs analytic {}",
                    grad.data()[idx]
                );
            }
        };
        check(&dw, &w, "w");
        check(&db, &b, "b");
        check(&dx, &x, "x");
    }

    #[test]
    fn loss_grad_matches_host_oracle() {
        let mut rng = Rng::new(3);
        let logits = Tensor::randn(&[4, 6], 2.0, &mut rng);
        let labels = [1usize, 5, 0, 3];
        let mut onehot = Tensor::zeros(&[4, 6]);
        for (i, &l) in labels.iter().enumerate() {
            onehot.set2(i, l, 1.0);
        }
        let (loss, dl, correct) = be().loss_grad(&logits, &onehot).unwrap();
        let (wl, wdl, wc) = tensor::softmax_xent(&logits, &labels);
        assert_eq!(loss, wl);
        assert_eq!(dl, wdl);
        assert_eq!(correct, wc as f32);
    }

    #[test]
    fn forward_full_chains_layers() {
        let cfg = ModelConfig {
            batch: 4,
            input_dim: 6,
            hidden_dim: 5,
            classes: 3,
            layers: 3,
            init_scale: 1.0,
        };
        let mut rng = Rng::new(4);
        let mlp = Mlp::init(&cfg, &mut rng);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let backend = be();
        let fused = backend.forward_full(&x, &mlp.layers).unwrap();
        let mut h = x;
        for (l, lp) in mlp.layers.iter().enumerate() {
            let (din, _) = layer_dims(&cfg, l);
            assert_eq!(h.shape()[1], din);
            h = backend.forward(lp.role, &h, &lp.w, &lp.b).unwrap();
        }
        assert_eq!(fused, h);
        assert!(backend.exec_count() >= 6);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let x = Tensor::zeros(&[2, 3]);
        let w = Tensor::zeros(&[4, 5]); // 3 != 4
        let b = Tensor::zeros(&[5]);
        let err = be().forward(LayerRole::Hidden, &x, &w, &b);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("shape"));
    }

    #[test]
    fn any_model_shape_is_accepted() {
        let cfg = ModelConfig {
            batch: 3,
            input_dim: 11,
            hidden_dim: 7,
            classes: 2,
            layers: 5,
            init_scale: 1.0,
        };
        be().check_model(&cfg).unwrap();
    }
}
