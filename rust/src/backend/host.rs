//! Pure-Rust execution backend over the host tensor kernels.
//!
//! Mirrors the artifact contract exactly (same math as the lowered HLO:
//! dense + bias + optional fused ReLU forward; `(dx, dw, db)` backward
//! with the ReLU mask applied from the *output* activation; softmax-CE
//! loss/grad over one-hot labels), so the single-threaded trainer, the
//! threaded pipelined executor, every test and every bench run unchanged
//! on machines without PJRT artifacts.
//!
//! Every kernel this backend dispatches to — the packed matmuls, the
//! tree-reduction `dw`, and the fused bias/ReLU epilogues — is
//! worker-pool parallel past its size threshold while staying
//! bit-identical across `LAYERPIPE2_WORKERS` values (`tensor::ops`
//! module docs / DESIGN.md §7), so the backend keeps the `Exec`
//! determinism contract at every pool size.

use super::Exec;
use crate::config::ModelConfig;
use crate::model::LayerRole;
use crate::tensor::{self, Tensor};
use anyhow::{ensure, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// The host backend: stateless except for dispatch bookkeeping.
#[derive(Debug, Default)]
pub struct HostBackend {
    exec_count: AtomicU64,
}

impl HostBackend {
    pub fn new() -> HostBackend {
        HostBackend::default()
    }

    fn count(&self) {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
    }
}

impl Exec for HostBackend {
    fn name(&self) -> &'static str {
        "host"
    }

    fn check_model(&self, cfg: &ModelConfig) -> Result<()> {
        // Any validated shape is servable: kernels are shape-generic.
        cfg.validate()
    }

    /// The host kernel family widens bf16 operands to f32 tiles while
    /// packing (DESIGN.md §11), so both storage dtypes are servable.
    fn supports_dtype(&self, _dtype: crate::tensor::Dtype) -> bool {
        true
    }

    fn forward(&self, role: LayerRole, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::empty();
        self.forward_into(role, x, w, b, &mut out)?;
        Ok(out)
    }

    /// Fused dense forward: matmul into `out`, then one bias(+ReLU)
    /// epilogue pass — bitwise identical to the matmul/add_bias/relu
    /// composition, with zero allocations when `out` is a recycled
    /// buffer.
    fn forward_into(
        &self,
        role: LayerRole,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        self.count();
        ensure!(
            x.ndim() == 2 && w.ndim() == 2 && b.ndim() == 1,
            "host forward: x/w must be 2-D and b 1-D, got {:?}/{:?}/{:?}",
            x.shape(),
            w.shape(),
            b.shape()
        );
        ensure!(
            x.shape()[1] == w.shape()[0] && w.shape()[1] == b.shape()[0],
            "host forward shape mismatch: x {:?} @ w {:?} + b {:?}",
            x.shape(),
            w.shape(),
            b.shape()
        );
        tensor::matmul_into(x, w, out);
        tensor::bias_act_inplace(out, b, role.has_relu());
        Ok(())
    }

    fn backward(
        &self,
        role: LayerRole,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let (mut scratch, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        self.backward_into(role, x, y, w, dy, &mut scratch, &mut dx, &mut dw, &mut db)?;
        Ok((dx, dw, db))
    }

    /// Fused dense backward: the ReLU mask and the bias-grad reduction
    /// run as one streaming epilogue over `dy` (writing `dz` into
    /// `scratch` and `db` together), then the two gradient matmuls fill
    /// `dx`/`dw` — all into caller-owned buffers.
    fn backward_into(
        &self,
        role: LayerRole,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        self.count();
        // Rank checks first: indexing shape()[1] below must never panic
        // (the backend contract is Err, not UB/panics, on bad shapes).
        ensure!(
            x.ndim() == 2 && y.ndim() == 2 && w.ndim() == 2 && dy.ndim() == 2,
            "host backward: x/y/w/dy must all be 2-D, got {:?}/{:?}/{:?}/{:?}",
            x.shape(),
            y.shape(),
            w.shape(),
            dy.shape()
        );
        ensure!(
            y.shape() == dy.shape(),
            "host backward: y {:?} vs dy {:?}",
            y.shape(),
            dy.shape()
        );
        ensure!(
            x.shape()[1] == w.shape()[0] && w.shape()[1] == dy.shape()[1],
            "host backward shape mismatch: x {:?}, w {:?}, dy {:?}",
            x.shape(),
            w.shape(),
            dy.shape()
        );
        // Pre-activation gradient: mask with the saved output for ReLU
        // layers (y > 0 ⇔ the unit was active), pass-through otherwise;
        // db streams out of the same pass.
        let use_mask = role.has_relu();
        if use_mask {
            tensor::relu_grad_col_sum_into(y, dy, scratch, db);
        } else {
            tensor::col_sum_into(dy, db);
        }
        let dz: &Tensor = if use_mask { scratch } else { dy };
        tensor::matmul_nt_into(dz, w, dx);
        tensor::matmul_tn_into(x, dz, dw);
        Ok(())
    }

    fn loss_grad(&self, logits: &Tensor, onehot: &Tensor) -> Result<(f32, Tensor, f32)> {
        let mut dl = Tensor::empty();
        let (loss, correct) = self.loss_grad_into(logits, onehot, &mut dl)?;
        Ok((loss, dl, correct))
    }

    fn loss_grad_into(&self, logits: &Tensor, onehot: &Tensor, dl: &mut Tensor) -> Result<(f32, f32)> {
        self.count();
        ensure!(
            logits.ndim() == 2 && logits.shape() == onehot.shape(),
            "host loss_grad: logits {:?} vs onehot {:?} (both must be 2-D)",
            logits.shape(),
            onehot.shape()
        );
        Ok(tensor::softmax_xent_onehot_into(logits, onehot, dl))
    }

    fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{layer_dims, Mlp};
    use crate::util::Rng;

    fn be() -> HostBackend {
        HostBackend::new()
    }

    #[test]
    fn forward_matches_op_composition() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let w = Tensor::randn(&[6, 5], 0.3, &mut rng);
        let b = Tensor::randn(&[5], 0.1, &mut rng);
        let z = tensor::add_bias(&tensor::matmul(&x, &w), &b);
        let hid = be().forward(LayerRole::Hidden, &x, &w, &b).unwrap();
        assert_eq!(hid, tensor::relu(&z));
        // Output layer skips the ReLU: raw affine result comes through.
        let out = be().forward(LayerRole::Output, &x, &w, &b).unwrap();
        assert_eq!(out, z);
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Scalar-project the layer output and check every parameter
        // gradient against central differences.
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let w = Tensor::randn(&[4, 5], 0.5, &mut rng);
        let b = Tensor::randn(&[5], 0.1, &mut rng);
        let proj = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let backend = be();
        let fwd = |w: &Tensor, b: &Tensor, x: &Tensor| -> f32 {
            let y = backend.forward(LayerRole::Hidden, x, w, b).unwrap();
            y.data().iter().zip(proj.data()).map(|(a, p)| a * p).sum()
        };
        let y = backend.forward(LayerRole::Hidden, &x, &w, &b).unwrap();
        let (dx, dw, db) = backend.backward(LayerRole::Hidden, &x, &y, &w, &proj).unwrap();
        let eps = 1e-3;
        let check = |grad: &Tensor, target: &Tensor, which: &str| {
            for idx in 0..target.len() {
                let (mut tp, mut tm) = (target.clone(), target.clone());
                tp.data_mut()[idx] += eps;
                tm.data_mut()[idx] -= eps;
                let (fp, fm) = match which {
                    "w" => (fwd(&tp, &b, &x), fwd(&tm, &b, &x)),
                    "b" => (fwd(&w, &tp, &x), fwd(&w, &tm, &x)),
                    _ => (fwd(&w, &b, &tp), fwd(&w, &b, &tm)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - grad.data()[idx]).abs() < 2e-2,
                    "{which}[{idx}]: fd {fd} vs analytic {}",
                    grad.data()[idx]
                );
            }
        };
        check(&dw, &w, "w");
        check(&db, &b, "b");
        check(&dx, &x, "x");
    }

    #[test]
    fn into_variants_match_allocating_bitwise() {
        // The allocating Exec methods delegate to the `_into` kernels,
        // and `_into` outputs are fully overwritten — so results must be
        // bit-identical even into dirty recycled buffers.
        let mut rng = Rng::new(9);
        let x = Tensor::randn(&[5, 6], 1.0, &mut rng);
        let w = Tensor::randn(&[6, 4], 0.4, &mut rng);
        let b = Tensor::randn(&[4], 0.1, &mut rng);
        let dy = Tensor::randn(&[5, 4], 1.0, &mut rng);
        let backend = be();
        for role in [LayerRole::Hidden, LayerRole::Output] {
            let y = backend.forward(role, &x, &w, &b).unwrap();
            let mut y2 = Tensor::randn(&[2, 2], 3.0, &mut rng);
            backend.forward_into(role, &x, &w, &b, &mut y2).unwrap();
            assert_eq!(y, y2, "{role:?} forward");
            let (dx, dw, db) = backend.backward(role, &x, &y, &w, &dy).unwrap();
            let (mut scr, mut dx2, mut dw2, mut db2) = (
                Tensor::randn(&[3], 1.0, &mut rng),
                Tensor::randn(&[3], 1.0, &mut rng),
                Tensor::randn(&[3], 1.0, &mut rng),
                Tensor::randn(&[3], 1.0, &mut rng),
            );
            backend
                .backward_into(role, &x, &y, &w, &dy, &mut scr, &mut dx2, &mut dw2, &mut db2)
                .unwrap();
            assert_eq!(dx, dx2, "{role:?} dx");
            assert_eq!(dw, dw2, "{role:?} dw");
            assert_eq!(db, db2, "{role:?} db");
        }
        let onehot = {
            let mut oh = Tensor::zeros(&[5, 4]);
            for i in 0..5 {
                oh.set2(i, i % 4, 1.0);
            }
            oh
        };
        let logits = backend.forward(LayerRole::Output, &x, &w, &b).unwrap();
        let (loss, dl, correct) = backend.loss_grad(&logits, &onehot).unwrap();
        let mut dl2 = Tensor::randn(&[1], 1.0, &mut rng);
        let (loss2, correct2) = backend.loss_grad_into(&logits, &onehot, &mut dl2).unwrap();
        assert_eq!(loss, loss2);
        assert_eq!(dl, dl2);
        assert_eq!(correct, correct2);
    }

    #[test]
    fn loss_grad_matches_host_oracle() {
        let mut rng = Rng::new(3);
        let logits = Tensor::randn(&[4, 6], 2.0, &mut rng);
        let labels = [1usize, 5, 0, 3];
        let mut onehot = Tensor::zeros(&[4, 6]);
        for (i, &l) in labels.iter().enumerate() {
            onehot.set2(i, l, 1.0);
        }
        let (loss, dl, correct) = be().loss_grad(&logits, &onehot).unwrap();
        let (wl, wdl, wc) = tensor::softmax_xent(&logits, &labels);
        assert_eq!(loss, wl);
        assert_eq!(dl, wdl);
        assert_eq!(correct, wc as f32);
    }

    #[test]
    fn forward_full_chains_layers() {
        let cfg = ModelConfig {
            batch: 4,
            input_dim: 6,
            hidden_dim: 5,
            classes: 3,
            layers: 3,
            init_scale: 1.0,
        };
        let mut rng = Rng::new(4);
        let mlp = Mlp::init(&cfg, &mut rng);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let backend = be();
        let fused = backend.forward_full(&x, &mlp.layers).unwrap();
        let mut h = x;
        for (l, lp) in mlp.layers.iter().enumerate() {
            let (din, _) = layer_dims(&cfg, l);
            assert_eq!(h.shape()[1], din);
            h = backend.forward(lp.role, &h, &lp.w, &lp.b).unwrap();
        }
        assert_eq!(fused, h);
        assert!(backend.exec_count() >= 6);
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let x = Tensor::zeros(&[2, 3]);
        let w = Tensor::zeros(&[4, 5]); // 3 != 4
        let b = Tensor::zeros(&[5]);
        let err = be().forward(LayerRole::Hidden, &x, &w, &b);
        assert!(err.is_err());
        assert!(format!("{:#}", err.unwrap_err()).contains("shape"));
    }

    #[test]
    fn bf16_operands_flow_through_exec_bitwise_vs_widened() {
        // bf16 weights/activations must produce exactly the result of
        // the f32 kernels on the (exactly) widened operands — the
        // backend-level restatement of the widening-on-pack contract.
        use crate::tensor::Dtype;
        let mut rng = Rng::new(31);
        let x = Tensor::randn(&[4, 6], 1.0, &mut rng).to_dtype(Dtype::Bf16);
        let w = Tensor::randn(&[6, 5], 0.3, &mut rng).to_dtype(Dtype::Bf16);
        let b = Tensor::randn(&[5], 0.1, &mut rng);
        let (xw, ww) = (x.to_dtype(Dtype::F32), w.to_dtype(Dtype::F32));
        let backend = be();
        assert!(backend.supports_dtype(Dtype::Bf16));
        assert!(backend.supports_dtype(Dtype::F32));
        for role in [LayerRole::Hidden, LayerRole::Output] {
            let y = backend.forward(role, &x, &w, &b).unwrap();
            assert_eq!(y, backend.forward(role, &xw, &ww, &b).unwrap(), "{role:?} forward");
            let dy = Tensor::randn(&[4, 5], 1.0, &mut rng);
            let got = backend.backward(role, &x, &y, &w, &dy).unwrap();
            let want = backend.backward(role, &xw, &y, &ww, &dy).unwrap();
            assert_eq!(got, want, "{role:?} backward");
        }
    }

    #[test]
    fn any_model_shape_is_accepted() {
        let cfg = ModelConfig {
            batch: 3,
            input_dim: 11,
            hidden_dim: 7,
            classes: 2,
            layers: 5,
            init_scale: 1.0,
        };
        be().check_model(&cfg).unwrap();
    }
}
