//! PJRT execution backend (`pjrt` feature): the original artifact hot
//! path, now behind the [`Exec`] seam.
//!
//! Dispatch mapping is the role→artifact table of [`LayerRole`]; the
//! fused `fwd_full` artifact serves [`Exec::forward_full`] in one
//! dispatch instead of `L`.

use super::Exec;
use crate::config::ModelConfig;
use crate::model::{LayerParams, LayerRole};
use crate::runtime::Engine;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Backend over a compiled artifact set.
pub struct PjrtBackend {
    engine: Engine,
}

impl PjrtBackend {
    /// Load `manifest.json` + HLO artifacts from `dir` and compile them.
    pub fn load(dir: &str) -> Result<PjrtBackend> {
        Ok(PjrtBackend { engine: Engine::load(dir)? })
    }

    /// Wrap an already-loaded engine.
    pub fn from_engine(engine: Engine) -> PjrtBackend {
        PjrtBackend { engine }
    }

    /// The underlying engine (manifest inspection, raw dispatch).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Exec for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn check_model(&self, cfg: &ModelConfig) -> Result<()> {
        cfg.validate()?;
        let m = self.engine.manifest();
        ensure!(
            m.model.batch == cfg.batch
                && m.model.input_dim == cfg.input_dim
                && m.model.hidden_dim == cfg.hidden_dim
                && m.model.classes == cfg.classes
                && m.model.layers == cfg.layers,
            "artifact preset {:?} does not match experiment model config {:?} — \
             re-run `make artifacts` with the matching preset",
            m.model,
            cfg
        );
        Ok(())
    }

    fn forward(&self, role: LayerRole, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
        let mut out = self.engine.run(role.fwd_artifact(), &[x, w, b])?;
        ensure!(out.len() == 1, "forward artifact returns one tensor");
        Ok(out.pop().expect("one output"))
    }

    fn backward(
        &self,
        role: LayerRole,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)> {
        let out = if role.has_relu() {
            self.engine.run(role.bwd_artifact(), &[x, y, w, dy])?
        } else {
            self.engine.run(role.bwd_artifact(), &[x, w, dy])?
        };
        ensure!(out.len() == 3, "backward artifact returns (dx, dw, db)");
        let mut it = out.into_iter();
        Ok((
            it.next().expect("dx"),
            it.next().expect("dw"),
            it.next().expect("db"),
        ))
    }

    fn loss_grad(&self, logits: &Tensor, onehot: &Tensor) -> Result<(f32, Tensor, f32)> {
        let out = self.engine.run("loss_grad", &[logits, onehot])?;
        ensure!(out.len() == 3, "loss_grad returns (loss, dlogits, correct)");
        let mut it = out.into_iter();
        let loss = it.next().expect("loss").data()[0];
        let dlogits = it.next().expect("dlogits");
        let correct = it.next().expect("correct").data()[0];
        Ok((loss, dlogits, correct))
    }

    fn forward_full(&self, x: &Tensor, layers: &[LayerParams]) -> Result<Tensor> {
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(1 + 2 * layers.len());
        inputs.push(x);
        for lp in layers {
            inputs.push(&lp.w);
            inputs.push(&lp.b);
        }
        let mut out = self.engine.run("fwd_full", &inputs)?;
        ensure!(out.len() == 1, "fwd_full returns logits");
        Ok(out.pop().expect("logits"))
    }

    fn exec_count(&self) -> u64 {
        self.engine.exec_count()
    }
}
