//! Execution backends: the compute abstraction under model, trainer and
//! pipeline.
//!
//! The seed hard-wired every consumer to the PJRT [`crate::runtime::Engine`],
//! which made all training code unrunnable on machines without AOT
//! artifacts + libpjrt. The [`Exec`] trait is the seam: per-layer forward,
//! per-layer backward, loss/grad, and fused full-network forward — exactly
//! the artifact surface of `manifest.json` — with two implementations:
//!
//! - [`HostBackend`]: pure Rust on [`crate::tensor`] kernels. Always
//!   available; the default for tests, examples and clean checkouts.
//! - [`PjrtBackend`] (`pjrt` feature): wraps the engine and dispatches to
//!   the lowered HLO artifacts, preserving the original hot path.
//!
//! Trainers no longer dispatch on `LayerRole` directly: they drive
//! `Box<dyn crate::layers::Layer>` ops, and the *dense* op routes back
//! through this trait (keeping PJRT artifact dispatch) while conv, pool
//! and spiking ops compute on host kernels — per-op PJRT artifacts are
//! a ROADMAP open item.
//!
//! Selection ([`from_env`]): the `LAYERPIPE2_BACKEND` env var picks
//! `host`, `pjrt` or `auto` (default). `auto` uses PJRT only when the
//! feature is compiled in *and* `manifest.json` exists in the artifacts
//! directory; otherwise it silently falls back to the host backend so
//! `cargo test -q` passes from a clean checkout.

mod host;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use host::HostBackend;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;

use crate::config::ModelConfig;
use crate::model::{LayerParams, LayerRole};
use crate::tensor::{Dtype, Tensor};
use anyhow::{bail, Result};
use std::path::Path;
use std::sync::Arc;

/// Environment variable selecting the execution backend
/// (`host` | `pjrt` | `auto`).
pub const BACKEND_ENV: &str = "LAYERPIPE2_BACKEND";

/// Shared handle to a backend: cheap to clone into stage worker threads.
pub type Backend = Arc<dyn Exec>;

/// The execution contract every backend honors. One method per artifact
/// class; tensors are host-resident on both sides of every call.
pub trait Exec: Send + Sync {
    /// Stable identifier for logs and reports (`"host"`, `"pjrt"`).
    fn name(&self) -> &'static str;

    /// Fail fast if this backend cannot serve the model shape (the PJRT
    /// backend is locked to the shapes its artifacts were lowered at;
    /// the host backend accepts anything).
    fn check_model(&self, cfg: &ModelConfig) -> Result<()>;

    /// Whether this backend can execute on tensors of the given storage
    /// dtype. Defaults to f32-only — the PJRT artifacts were lowered
    /// for f32 literals; the host backend overrides (its kernel family
    /// widens bf16 operands on pack, DESIGN.md §11).
    fn supports_dtype(&self, dtype: Dtype) -> bool {
        dtype == Dtype::F32
    }

    /// One dense layer forward: `y = act(x @ w + b)` with the activation
    /// implied by `role` (`ReLU` except for the output layer).
    fn forward(&self, role: LayerRole, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor>;

    /// One dense layer backward given the saved forward pair `(x, y)` and
    /// the upstream gradient `dy`; returns `(dx, dw, db)`.
    fn backward(
        &self,
        role: LayerRole,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
    ) -> Result<(Tensor, Tensor, Tensor)>;

    /// Mean softmax cross-entropy against one-hot labels:
    /// `(loss, dlogits, argmax-correct row count)`.
    fn loss_grad(&self, logits: &Tensor, onehot: &Tensor) -> Result<(f32, Tensor, f32)>;

    // ---- buffer-aware variants (hot-path memory discipline) -----------
    //
    // The `_into` methods write caller-owned outputs (resized in place)
    // so trainers can run their steady-state loops on recycled
    // workspaces. Default impls delegate to the allocating methods —
    // backends like PJRT, whose outputs materialize device-side anyway,
    // need not implement them; `HostBackend` overrides all three with
    // fused allocation-free kernels.

    /// [`Exec::forward`] into a caller-owned output buffer.
    fn forward_into(
        &self,
        role: LayerRole,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        *out = self.forward(role, x, w, b)?;
        Ok(())
    }

    /// [`Exec::backward`] into caller-owned gradient buffers. `scratch`
    /// is a workspace for the pre-activation gradient `dz` (contents
    /// unspecified on return); backends that don't need it ignore it.
    #[allow(clippy::too_many_arguments)]
    fn backward_into(
        &self,
        role: LayerRole,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = scratch;
        let (gx, gw, gb) = self.backward(role, x, y, w, dy)?;
        *dx = gx;
        *dw = gw;
        *db = gb;
        Ok(())
    }

    /// [`Exec::loss_grad`] with the logits gradient written into `dl`:
    /// returns `(loss, argmax-correct row count)`.
    fn loss_grad_into(&self, logits: &Tensor, onehot: &Tensor, dl: &mut Tensor) -> Result<(f32, f32)> {
        let (loss, dlogits, correct) = self.loss_grad(logits, onehot)?;
        *dl = dlogits;
        Ok((loss, correct))
    }

    /// Full-network forward (eval path). Backends with a fused artifact
    /// override this; the default chains [`Exec::forward`].
    fn forward_full(&self, x: &Tensor, layers: &[LayerParams]) -> Result<Tensor> {
        let mut h = x.clone();
        for lp in layers {
            h = self.forward(lp.role, &h, &lp.w, &lp.b)?;
        }
        Ok(h)
    }

    /// Total kernel/artifact executions served (dispatch bookkeeping).
    fn exec_count(&self) -> u64;
}

/// Whether an artifacts directory holds a loadable manifest.
pub fn artifacts_present(dir: &str) -> bool {
    Path::new(dir).join("manifest.json").is_file()
}

/// Construct the PJRT backend, or a readable error when the crate was
/// built without the `pjrt` feature.
#[cfg(feature = "pjrt")]
pub fn load_pjrt(artifacts_dir: &str) -> Result<Backend> {
    Ok(Arc::new(PjrtBackend::load(artifacts_dir)?))
}

/// Construct the PJRT backend, or a readable error when the crate was
/// built without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
pub fn load_pjrt(artifacts_dir: &str) -> Result<Backend> {
    // Engine::load carries the canonical "rebuild with --features pjrt"
    // message; delegating keeps the two paths' errors identical.
    crate::runtime::Engine::load(artifacts_dir)?;
    unreachable!("stub Engine::load always errors");
}

/// Select a backend from `LAYERPIPE2_BACKEND` (default `auto`): explicit
/// `host`/`pjrt`, or automatic PJRT-when-available with host fallback.
pub fn from_env(artifacts_dir: &str) -> Result<Backend> {
    let choice = std::env::var(BACKEND_ENV).unwrap_or_default();
    match choice.as_str() {
        "host" => Ok(Arc::new(HostBackend::new())),
        "pjrt" => load_pjrt(artifacts_dir),
        "" | "auto" => {
            if cfg!(feature = "pjrt") && artifacts_present(artifacts_dir) {
                load_pjrt(artifacts_dir)
            } else {
                Ok(Arc::new(HostBackend::new()))
            }
        }
        other => bail!(
            "unknown {BACKEND_ENV}='{other}' (expected one of: host, pjrt, auto)"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_without_artifacts_is_host() {
        // No manifest at this path → auto must fall back to the host
        // backend regardless of features.
        let b = from_env("/nonexistent/artifacts").unwrap();
        assert_eq!(b.name(), "host");
    }

    #[test]
    fn artifacts_probe_is_path_based() {
        assert!(!artifacts_present("/nonexistent/artifacts"));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_without_feature_is_a_clear_error() {
        let err = load_pjrt("artifacts").unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"));
    }

    /// Minimal backend that implements only the allocating methods — the
    /// `_into` defaults must delegate so PJRT-style backends stay
    /// correct without overrides.
    struct AllocOnly(HostBackend);

    impl Exec for AllocOnly {
        fn name(&self) -> &'static str {
            "alloc-only"
        }

        fn check_model(&self, cfg: &ModelConfig) -> Result<()> {
            self.0.check_model(cfg)
        }

        fn forward(&self, role: LayerRole, x: &Tensor, w: &Tensor, b: &Tensor) -> Result<Tensor> {
            self.0.forward(role, x, w, b)
        }

        fn backward(
            &self,
            role: LayerRole,
            x: &Tensor,
            y: &Tensor,
            w: &Tensor,
            dy: &Tensor,
        ) -> Result<(Tensor, Tensor, Tensor)> {
            self.0.backward(role, x, y, w, dy)
        }

        fn loss_grad(&self, logits: &Tensor, onehot: &Tensor) -> Result<(f32, Tensor, f32)> {
            self.0.loss_grad(logits, onehot)
        }

        fn exec_count(&self) -> u64 {
            self.0.exec_count()
        }
    }

    #[test]
    fn into_defaults_delegate_to_allocating_methods() {
        use crate::util::Rng;
        let mut rng = Rng::new(6);
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let w = Tensor::randn(&[5, 4], 0.4, &mut rng);
        let b = Tensor::randn(&[4], 0.1, &mut rng);
        let dy = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let be = AllocOnly(HostBackend::new());
        let role = LayerRole::Hidden;
        let mut out = Tensor::empty();
        be.forward_into(role, &x, &w, &b, &mut out).unwrap();
        let y = be.forward(role, &x, &w, &b).unwrap();
        assert_eq!(out, y);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        be.backward_into(role, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        let (dx2, dw2, db2) = be.backward(role, &x, &y, &w, &dy).unwrap();
        assert_eq!((dx, dw, db), (dx2, dw2, db2));
        let mut onehot = Tensor::zeros(&[3, 4]);
        for i in 0..3 {
            onehot.set2(i, i, 1.0);
        }
        let mut dl = Tensor::empty();
        let (loss, correct) = be.loss_grad_into(&y, &onehot, &mut dl).unwrap();
        let (loss2, dl2, correct2) = be.loss_grad(&y, &onehot).unwrap();
        assert_eq!((loss, correct), (loss2, correct2));
        assert_eq!(dl, dl2);
    }
}
