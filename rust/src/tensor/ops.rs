//! Host tensor kernels: the compute substrate of the pure-Rust backend.
//!
//! Originally these were cross-check oracles for the PJRT path; with the
//! [`crate::backend::HostBackend`] they are also a real execution path,
//! so the forward kernels are joined by the backward set (matmul with
//! transposed operands, bias-grad reduction, ReLU mask, softmax-CE
//! loss/grad) and the blocked matmul parallelizes across row blocks with
//! `std::thread::scope` once shapes are large enough to amortize spawns.
//! Results are bit-identical across thread counts: each row of `C` is
//! always accumulated in the same block order by exactly one thread.

use super::Tensor;

/// Cache-block edge for the matmul kernels.
const BLK: usize = 32;

/// Below this many multiply-adds the blocked matmul stays single-threaded
/// (thread spawn + join costs more than the kernel itself).
const PAR_MIN_MADDS: usize = 1 << 20;

/// Worker count for the parallel matmul: the machine's parallelism,
/// clamped so tiny matrices never see degenerate row chunks.
fn matmul_threads(m: usize) -> usize {
    let hw = std::thread::available_parallelism().map_or(1, |n| n.get());
    hw.min(m.div_ceil(BLK)).max(1)
}

/// Blocked kernel over the row range `[i0, i0 + rows)` of `A`, writing the
/// matching rows of `C` (passed as the disjoint slice `cd`).
fn matmul_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], i0: usize, rows: usize, k: usize, n: usize) {
    for ib in (0..rows).step_by(BLK) {
        for k0 in (0..k).step_by(BLK) {
            for j0 in (0..n).step_by(BLK) {
                let i1 = (ib + BLK).min(rows);
                let k1 = (k0 + BLK).min(k);
                let j1 = (j0 + BLK).min(n);
                for i in ib..i1 {
                    for kk in k0..k1 {
                        let aik = ad[(i0 + i) * k + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[kk * n + j0..kk * n + j1];
                        let crow = &mut cd[i * n + j0..i * n + j1];
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = A @ B` for 2-D tensors, blocked for locality and parallelized
/// across row blocks for large shapes (no extra dependencies —
/// `std::thread::scope` only).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    let threads = matmul_threads(m);
    if m * k * n < PAR_MIN_MADDS || threads == 1 {
        matmul_rows(ad, bd, cd, 0, m, k, n);
        return c;
    }
    // Row chunks aligned to the cache block so per-row accumulation order
    // (and thus the fp result) is independent of the thread count.
    let rows_per = m.div_ceil(threads).div_ceil(BLK) * BLK;
    std::thread::scope(|scope| {
        for (chunk_idx, c_chunk) in cd.chunks_mut(rows_per * n).enumerate() {
            let i0 = chunk_idx * rows_per;
            let rows = c_chunk.len() / n;
            scope.spawn(move || matmul_rows(ad, bd, c_chunk, i0, rows, k, n));
        }
    });
    c
}

/// Row-dot kernel over `[i0, i0 + rows)` of `A` for [`matmul_nt`],
/// writing the matching rows of `C` (disjoint slice `cd`).
fn matmul_nt_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], i0: usize, rows: usize, k: usize, n: usize) {
    for i in 0..rows {
        let arow = &ad[(i0 + i) * k..(i0 + i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                s += av * bv;
            }
            cd[i * n + j] = s;
        }
    }
}

/// `C = A @ Bᵀ` with `A: [m, k]`, `B: [n, k]` → `C: [m, n]`.
///
/// The `dx = dy @ Wᵀ` backward kernel. Both operands stream row-major, so
/// no explicit transpose materializes; rows of `C` are independent, so
/// large shapes split across threads exactly like [`matmul`] (bit-stable:
/// each row's dot order never changes).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul_nt lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_nt rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    let threads = matmul_threads(m);
    if m * k * n < PAR_MIN_MADDS || threads == 1 {
        matmul_nt_rows(ad, bd, cd, 0, m, k, n);
        return c;
    }
    let rows_per = m.div_ceil(threads);
    std::thread::scope(|scope| {
        for (chunk_idx, c_chunk) in cd.chunks_mut(rows_per * n).enumerate() {
            let i0 = chunk_idx * rows_per;
            let rows = c_chunk.len() / n;
            scope.spawn(move || matmul_nt_rows(ad, bd, c_chunk, i0, rows, k, n));
        }
    });
    c
}

/// `C = Aᵀ @ B` with `A: [r, m]`, `B: [r, n]` → `C: [m, n]`.
///
/// The `dw = xᵀ @ dy` backward kernel, accumulated as a sum of row outer
/// products so every access stays row-major. Stays single-threaded: `r`
/// is the batch dimension (small at training shapes), and parallelizing
/// the reduction would either need per-thread partials (changing fp
/// summation order → breaking the oracle/executor bit-equivalence) or
/// strided column chunking with poor locality.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul_tn lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_tn rhs must be 2-D");
    let (r, m) = (a.shape()[0], a.shape()[1]);
    let (r2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(r, r2, "matmul_tn outer dims: {r} vs {r2}");
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    for rr in 0..r {
        let brow = &bd[rr * n..(rr + 1) * n];
        for i in 0..m {
            let ari = ad[rr * m + i];
            if ari == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += ari * bv;
            }
        }
    }
    c
}

/// Column sums of a 2-D tensor: `out[j] = Σ_i x[i, j]` — the bias-grad
/// reduction (`db = Σ_rows dz`).
pub fn col_sum(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2, "col_sum needs a 2-D tensor");
    let (m, n) = (x.shape()[0], x.shape()[1]);
    let mut out = Tensor::zeros(&[n]);
    let (xd, od) = (x.data(), out.data_mut());
    for i in 0..m {
        let row = &xd[i * n..(i + 1) * n];
        for (ov, xv) in od.iter_mut().zip(row.iter()) {
            *ov += xv;
        }
    }
    out
}

/// `A^T` for a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.set2(j, i, a.at2(i, j));
        }
    }
    t
}

/// Row-broadcast add: `y[i, j] = x[i, j] + b[j]`.
pub fn add_bias(x: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    assert_eq!(b.ndim(), 1);
    assert_eq!(x.shape()[1], b.shape()[0]);
    let mut y = x.clone();
    let n = b.len();
    for (i, v) in y.data_mut().iter_mut().enumerate() {
        *v += b.data()[i % n];
    }
    y
}

/// Elementwise ReLU.
pub fn relu(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    for v in y.data_mut().iter_mut() {
        *v = v.max(0.0);
    }
    y
}

/// Gradient mask of ReLU given its *output* `y`: `dy * (y > 0)`.
pub fn relu_grad(y: &Tensor, dy: &Tensor) -> Tensor {
    assert_eq!(y.shape(), dy.shape());
    let mut g = dy.clone();
    for (gv, yv) in g.data_mut().iter_mut().zip(y.data().iter()) {
        if *yv <= 0.0 {
            *gv = 0.0;
        }
    }
    g
}

/// Numerically-stable row softmax.
pub fn softmax_rows(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (m, n) = (x.shape()[0], x.shape()[1]);
    let mut y = x.clone();
    for i in 0..m {
        let row = &mut y.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    y
}

/// Mean softmax cross-entropy and its gradient w.r.t. logits, plus the
/// number of argmax-correct rows. Mirrors the `loss_grad` HLO artifact.
pub fn softmax_xent(logits: &Tensor, labels: &[usize]) -> (f32, Tensor, usize) {
    let (m, n) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(m, labels.len());
    let p = softmax_rows(logits);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    let mut dl = p.clone();
    for i in 0..m {
        let li = labels[i];
        assert!(li < n, "label {li} out of range {n}");
        loss -= p.at2(i, li).max(1e-12).ln();
        let row = &p.data()[i * n..(i + 1) * n];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == li {
            correct += 1;
        }
        let d = dl.at2(i, li) - 1.0;
        dl.set2(i, li, d);
    }
    dl.scale(1.0 / m as f32);
    (loss / m as f32, dl, correct)
}

/// [`softmax_xent`] with one-hot labels — the exact input/output contract
/// of the `loss_grad` artifact, so the host backend is a drop-in
/// replacement: `(mean loss, dlogits, argmax-correct row count)`.
pub fn softmax_xent_onehot(logits: &Tensor, onehot: &Tensor) -> (f32, Tensor, f32) {
    assert_eq!(logits.shape(), onehot.shape(), "logits vs onehot shape");
    let (m, n) = (logits.shape()[0], logits.shape()[1]);
    let labels: Vec<usize> = (0..m)
        .map(|i| {
            let row = &onehot.data()[i * n..(i + 1) * n];
            let mut arg = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[arg] {
                    arg = j;
                }
            }
            arg
        })
        .collect();
    let (loss, dl, correct) = softmax_xent(logits, &labels);
    (loss, dl, correct as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_on_random_shapes() {
        let mut rng = Rng::new(77);
        for _ in 0..10 {
            let m = 1 + rng.index(40);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(40);
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let c_ref = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c_ref) < 1e-4);
        }
    }

    #[test]
    fn matmul_is_deterministic_across_parallel_threshold() {
        // Shapes straddling PAR_MIN_MADDS must agree with the naive
        // kernel; the parallel split may not change the fp result.
        let mut rng = Rng::new(11);
        let (m, k, n) = (160, 96, 96); // 160·96·96 ≈ 1.5M madds → parallel
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let par = matmul(&a, &b);
        let mut serial = Tensor::zeros(&[m, n]);
        matmul_rows(a.data(), b.data(), serial.data_mut(), 0, m, k, n);
        assert_eq!(par, serial, "parallel result must be bit-identical");
    }

    #[test]
    fn matmul_nt_matches_transpose_composition() {
        let mut rng = Rng::new(12);
        // Small shapes (serial path) plus one above PAR_MIN_MADDS so the
        // threaded row split is exercised too.
        let mut cases: Vec<(usize, usize, usize)> = (0..8)
            .map(|_| (1 + rng.index(20), 1 + rng.index(20), 1 + rng.index(20)))
            .collect();
        cases.push((160, 96, 96));
        for (m, k, n) in cases {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let got = matmul_nt(&a, &b);
            let mut serial = Tensor::zeros(&[m, n]);
            matmul_nt_rows(a.data(), b.data(), serial.data_mut(), 0, m, k, n);
            assert_eq!(got, serial, "parallel nt must be bit-identical");
            let want = matmul(&a, &transpose(&b));
            assert!(got.max_abs_diff(&want) < 1e-3);
        }
    }

    #[test]
    fn matmul_tn_matches_transpose_composition() {
        let mut rng = Rng::new(13);
        for _ in 0..8 {
            let r = 1 + rng.index(20);
            let m = 1 + rng.index(20);
            let n = 1 + rng.index(20);
            let a = Tensor::randn(&[r, m], 1.0, &mut rng);
            let b = Tensor::randn(&[r, n], 1.0, &mut rng);
            let got = matmul_tn(&a, &b);
            let want = matmul(&transpose(&a), &b);
            assert!(got.max_abs_diff(&want) < 1e-4);
        }
    }

    #[test]
    fn col_sum_reduces_rows() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
        assert_eq!(col_sum(&x).data(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn onehot_xent_matches_label_xent() {
        let mut rng = Rng::new(14);
        let logits = Tensor::randn(&[5, 7], 2.0, &mut rng);
        let labels: Vec<usize> = (0..5).map(|_| rng.index(7)).collect();
        let mut onehot = Tensor::zeros(&[5, 7]);
        for (i, &l) in labels.iter().enumerate() {
            onehot.set2(i, l, 1.0);
        }
        let (l1, g1, c1) = softmax_xent(&logits, &labels);
        let (l2, g2, c2) = softmax_xent_onehot(&logits, &onehot);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        assert_eq!(c1 as f32, c2);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[7, 3], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn relu_and_grad() {
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.0, 2.0, -3.0]);
        let y = relu(&x);
        assert_eq!(y.data(), &[0.0, 0.0, 2.0, 0.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![1.0; 4]);
        let g = relu_grad(&y, &dy);
        assert_eq!(g.data(), &[0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, 9], 3.0, &mut rng);
        let p = softmax_rows(&x);
        for i in 0..5 {
            let s: f32 = (0..9).map(|j| p.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn xent_gradient_matches_finite_difference() {
        let mut rng = Rng::new(21);
        let logits = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let labels = vec![0usize, 3, 5, 2];
        let (_, grad, _) = softmax_xent(&logits, &labels);
        let eps = 1e-3;
        for idx in 0..logits.len() {
            let mut lp = logits.clone();
            lp.data_mut()[idx] += eps;
            let (l_plus, _, _) = softmax_xent(&lp, &labels);
            let mut lm = logits.clone();
            lm.data_mut()[idx] -= eps;
            let (l_minus, _, _) = softmax_xent(&lm, &labels);
            let fd = (l_plus - l_minus) / (2.0 * eps);
            assert!(
                (fd - grad.data()[idx]).abs() < 1e-3,
                "idx {idx}: fd {fd} vs grad {}",
                grad.data()[idx]
            );
        }
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        // Strongly peaked logits at the true label → loss ≈ 0, all correct.
        let mut logits = Tensor::zeros(&[3, 4]);
        for (i, &l) in [1usize, 2, 0].iter().enumerate() {
            logits.set2(i, l, 20.0);
        }
        let (loss, _, correct) = softmax_xent(&logits, &[1, 2, 0]);
        assert!(loss < 1e-3);
        assert_eq!(correct, 3);
    }
}
