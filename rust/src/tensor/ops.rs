//! Reference tensor operations on the host.
//!
//! These are *not* the hot path (XLA executes the lowered HLO for all
//! per-layer compute); they exist to (a) cross-check the PJRT path in
//! integration tests and (b) support pure-Rust components such as the
//! DLMS simulator and the dataset synthesizer. The matmul is cache-blocked
//! so host-side checks stay fast at paper-scale shapes.

use super::Tensor;

/// `C = A @ B` for 2-D tensors, blocked for locality.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut c = Tensor::zeros(&[m, n]);
    const BLK: usize = 32;
    let (ad, bd) = (a.data(), b.data());
    let cd = c.data_mut();
    for i0 in (0..m).step_by(BLK) {
        for k0 in (0..k).step_by(BLK) {
            for j0 in (0..n).step_by(BLK) {
                let i1 = (i0 + BLK).min(m);
                let k1 = (k0 + BLK).min(k);
                let j1 = (j0 + BLK).min(n);
                for i in i0..i1 {
                    for kk in k0..k1 {
                        let aik = ad[i * k + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[kk * n + j0..kk * n + j1];
                        let crow = &mut cd[i * n + j0..i * n + j1];
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
    c
}

/// `A^T` for a 2-D tensor.
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.set2(j, i, a.at2(i, j));
        }
    }
    t
}

/// Row-broadcast add: `y[i, j] = x[i, j] + b[j]`.
pub fn add_bias(x: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    assert_eq!(b.ndim(), 1);
    assert_eq!(x.shape()[1], b.shape()[0]);
    let mut y = x.clone();
    let n = b.len();
    for (i, v) in y.data_mut().iter_mut().enumerate() {
        *v += b.data()[i % n];
    }
    y
}

/// Elementwise ReLU.
pub fn relu(x: &Tensor) -> Tensor {
    let mut y = x.clone();
    for v in y.data_mut().iter_mut() {
        *v = v.max(0.0);
    }
    y
}

/// Gradient mask of ReLU given its *output* `y`: `dy * (y > 0)`.
pub fn relu_grad(y: &Tensor, dy: &Tensor) -> Tensor {
    assert_eq!(y.shape(), dy.shape());
    let mut g = dy.clone();
    for (gv, yv) in g.data_mut().iter_mut().zip(y.data().iter()) {
        if *yv <= 0.0 {
            *gv = 0.0;
        }
    }
    g
}

/// Numerically-stable row softmax.
pub fn softmax_rows(x: &Tensor) -> Tensor {
    assert_eq!(x.ndim(), 2);
    let (m, n) = (x.shape()[0], x.shape()[1]);
    let mut y = x.clone();
    for i in 0..m {
        let row = &mut y.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    y
}

/// Mean softmax cross-entropy and its gradient w.r.t. logits, plus the
/// number of argmax-correct rows. Mirrors the `loss_grad` HLO artifact.
pub fn softmax_xent(logits: &Tensor, labels: &[usize]) -> (f32, Tensor, usize) {
    let (m, n) = (logits.shape()[0], logits.shape()[1]);
    assert_eq!(m, labels.len());
    let p = softmax_rows(logits);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    let mut dl = p.clone();
    for i in 0..m {
        let li = labels[i];
        assert!(li < n, "label {li} out of range {n}");
        loss -= p.at2(i, li).max(1e-12).ln();
        let row = &p.data()[i * n..(i + 1) * n];
        let argmax = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if argmax == li {
            correct += 1;
        }
        let d = dl.at2(i, li) - 1.0;
        dl.set2(i, li, d);
    }
    dl.scale(1.0 / m as f32);
    (loss / m as f32, dl, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_on_random_shapes() {
        let mut rng = Rng::new(77);
        for _ in 0..10 {
            let m = 1 + rng.index(40);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(40);
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let c_ref = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c_ref) < 1e-4);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[7, 3], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn relu_and_grad() {
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.0, 2.0, -3.0]);
        let y = relu(&x);
        assert_eq!(y.data(), &[0.0, 0.0, 2.0, 0.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![1.0; 4]);
        let g = relu_grad(&y, &dy);
        assert_eq!(g.data(), &[0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, 9], 3.0, &mut rng);
        let p = softmax_rows(&x);
        for i in 0..5 {
            let s: f32 = (0..9).map(|j| p.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn xent_gradient_matches_finite_difference() {
        let mut rng = Rng::new(21);
        let logits = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let labels = vec![0usize, 3, 5, 2];
        let (_, grad, _) = softmax_xent(&logits, &labels);
        let eps = 1e-3;
        for idx in 0..logits.len() {
            let mut lp = logits.clone();
            lp.data_mut()[idx] += eps;
            let (l_plus, _, _) = softmax_xent(&lp, &labels);
            let mut lm = logits.clone();
            lm.data_mut()[idx] -= eps;
            let (l_minus, _, _) = softmax_xent(&lm, &labels);
            let fd = (l_plus - l_minus) / (2.0 * eps);
            assert!(
                (fd - grad.data()[idx]).abs() < 1e-3,
                "idx {idx}: fd {fd} vs grad {}",
                grad.data()[idx]
            );
        }
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        // Strongly peaked logits at the true label → loss ≈ 0, all correct.
        let mut logits = Tensor::zeros(&[3, 4]);
        for (i, &l) in [1usize, 2, 0].iter().enumerate() {
            logits.set2(i, l, 20.0);
        }
        let (loss, _, correct) = softmax_xent(&logits, &[1, 2, 0]);
        assert!(loss < 1e-3);
        assert_eq!(correct, 3);
    }
}
