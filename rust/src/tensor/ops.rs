//! Host tensor kernels: the compute substrate of the pure-Rust backend.
//!
//! Every kernel comes in two forms: an `_into` variant that writes a
//! caller-owned output (resizing it in place — combined with
//! [`super::BufferPool`] the hot path allocates nothing), and an
//! allocating wrapper that delegates to it, so the two are bitwise
//! identical by construction.
//!
//! ### Kernel architecture (see DESIGN.md §7)
//!
//! The matmul family is register-tiled and panel-packed: [`matmul_into`]
//! packs `B` into contiguous `BLK`-wide column panels (pooled scratch,
//! pure data movement — bitwise-neutral) and accumulates each output row
//! in a `BLK`-wide register block with an unconditional fused inner loop
//! (no data-dependent branches, so the autovectorizer owns it);
//! [`matmul_nt_into`] runs four independent dot-product chains per pass.
//! The `dw` reduction [`matmul_tn_into`] parallelizes via a
//! **deterministic tree reduction**: the batch dimension splits into
//! fixed [`TN_CHUNK`]-row chunks (geometry a pure function of the shape,
//! never the worker count), per-chunk partials accumulate into pooled
//! scratch, and partials combine in a fixed pairwise order — so results
//! are bit-identical for every `LAYERPIPE2_WORKERS` value, serial or
//! parallel.
//!
//! Large kernels split across the persistent [`super::WorkerPool`] (no
//! per-call thread spawns); every parallel split assigns each output row
//! (or each reduction chunk) to exactly one task, and combination orders
//! are fixed, so worker count can only change speed, never bits.

use super::workers::{self, Task};
use super::{bf16_to_f32, Dtype, Tensor};

/// Cache-block edge / packed-panel width for the matmul kernels.
const BLK: usize = 32;

/// Below this many multiply-adds the blocked matmuls stay single-threaded
/// (the queue handoff costs more than the kernel itself).
const PAR_MIN_MADDS: usize = 1 << 20;

/// Touched-element threshold for the epilogue kernels — shared with the
/// gather/pool passes ([`workers::PAR_MIN_WORK`]). Part of the chunk
/// *geometry* for [`grad_col_sum_rows`] (single-pass vs chunked), so it
/// must stay a pure function of the shape.
const PAR_MIN_ELEMS: usize = workers::PAR_MIN_WORK;

/// Fixed row-chunk length of the [`matmul_tn_into`] tree reduction. The
/// chunk geometry depends only on this constant and the shape — never on
/// the worker count — which is what makes the summation order (and thus
/// the fp result) worker-count independent.
const TN_CHUNK: usize = 64;

/// Fixed row-chunk length of the chunked epilogue reduction in
/// [`grad_col_sum_rows`] (same worker-count-independence argument).
const EPI_CHUNK: usize = 256;

/// Borrow-or-widen view of a kernel operand: f32 tensors borrow their
/// payload directly (zero cost — the historical path, bitwise
/// unchanged); bf16 tensors widen into pooled thread-local scratch
/// (exact — widening is a bit shift per element), recycled on drop.
///
/// This is the mixed-precision entry point of the whole kernel family:
/// widening is pure data movement *ahead of* the multiply/add stream,
/// exactly like `B`-panel packing, so the consuming kernel's summation
/// geometry — and with it the PR 4 worker-count determinism argument —
/// is unchanged by the storage dtype (DESIGN.md §11).
struct Widened<'a> {
    borrowed: Option<&'a [f32]>,
    owned: Option<Vec<f32>>,
}

impl<'a> Widened<'a> {
    fn new(t: &'a Tensor) -> Widened<'a> {
        match t.dtype() {
            Dtype::F32 => Widened { borrowed: Some(t.data()), owned: None },
            Dtype::Bf16 => {
                let mut s = workers::take_scratch(t.len());
                for (o, &b) in s.iter_mut().zip(t.bits().iter()) {
                    *o = bf16_to_f32(b);
                }
                Widened { borrowed: None, owned: Some(s) }
            }
        }
    }

    fn as_slice(&self) -> &[f32] {
        match self.borrowed {
            Some(s) => s,
            None => self.owned.as_deref().expect("widened scratch present"),
        }
    }
}

impl Drop for Widened<'_> {
    fn drop(&mut self) {
        if let Some(v) = self.owned.take() {
            workers::recycle_scratch(v);
        }
    }
}

/// Worker count for a matmul of `m·k·n` multiply-adds: 1 below the
/// parallel threshold — WITHOUT touching the worker pool, so
/// serial-sized matmuls never spawn it — else the pool's parallelism
/// clamped so tiny row counts don't produce degenerate chunks.
fn matmul_threads(m: usize, k: usize, n: usize) -> usize {
    if m * k * n < PAR_MIN_MADDS {
        return 1;
    }
    workers::pool_size().min(m.div_ceil(BLK)).max(1)
}

/// Pack `B: [k, n]` into contiguous `BLK`-wide column panels: panel `p`
/// covers columns `[p·BLK, min((p+1)·BLK, n))`, storing its rows
/// `kk = 0..k` back to back (`pack[p·BLK·k + kk·jw + jj]`). Pure data
/// movement — the consuming kernel's multiply/add order is unchanged, so
/// packed and unpacked kernels are bitwise identical; the win is that
/// the inner loop streams one contiguous, cache-resident panel instead
/// of `k` strided rows of `B`.
fn pack_b_panels(bd: &[f32], k: usize, n: usize, pack: &mut [f32]) {
    debug_assert_eq!(pack.len(), k * n);
    if pack.is_empty() {
        return; // degenerate k == 0 or n == 0: nothing to pack
    }
    for (p, panel) in pack.chunks_mut(BLK * k).enumerate() {
        let j0 = p * BLK;
        let jw = (n - j0).min(BLK);
        for kk in 0..k {
            panel[kk * jw..(kk + 1) * jw]
                .copy_from_slice(&bd[kk * n + j0..kk * n + j0 + jw]);
        }
    }
}

/// [`pack_b_panels`] for bf16 storage bits: identical panel layout, with
/// the (exact) widening fused into the packing copy — the bf16 matmul
/// moves half the `B` bytes through memory and still hands the compute
/// loop the same f32 tiles, so the multiply/add order is untouched.
fn pack_b_panels_bf16(bb: &[u16], k: usize, n: usize, pack: &mut [f32]) {
    debug_assert_eq!(pack.len(), k * n);
    if pack.is_empty() {
        return; // degenerate k == 0 or n == 0: nothing to pack
    }
    for (p, panel) in pack.chunks_mut(BLK * k).enumerate() {
        let j0 = p * BLK;
        let jw = (n - j0).min(BLK);
        for kk in 0..k {
            let dst = &mut panel[kk * jw..(kk + 1) * jw];
            let src = &bb[kk * n + j0..kk * n + j0 + jw];
            for (o, &b) in dst.iter_mut().zip(src.iter()) {
                *o = bf16_to_f32(b);
            }
        }
    }
}

/// Register-tiled row kernel over packed `B` panels: rows
/// `[i0, i0 + rows)` of `A` into the matching rows of `C` (passed as the
/// disjoint slice `cd`, fully overwritten). Each output row accumulates
/// a `BLK`-wide register block per panel with an unconditional fused
/// inner loop — no `aik == 0.0` sparsity skip (the branch defeated
/// autovectorization and cost more than the multiplies it saved on ReLU
/// activations). Per output element the multiply-add order is ascending
/// `kk`, identical to a naive `i, j, k` triple loop, so this kernel is
/// bitwise equal to [`reference::matmul`].
fn matmul_rows(ad: &[f32], pack: &[f32], cd: &mut [f32], i0: usize, rows: usize, k: usize, n: usize) {
    for i in 0..rows {
        let arow = &ad[(i0 + i) * k..(i0 + i) * k + k];
        let crow = &mut cd[i * n..(i + 1) * n];
        let mut j0 = 0;
        while j0 < n {
            let jw = (n - j0).min(BLK);
            let panel = &pack[j0 * k..j0 * k + jw * k];
            if jw == BLK {
                // Full panel: constant-width accumulator block (the
                // compiler unrolls and vectorizes the fixed-size loops).
                let mut acc = [0.0f32; BLK];
                for (kk, &a) in arow.iter().enumerate() {
                    let prow = &panel[kk * BLK..(kk + 1) * BLK];
                    for (av, pv) in acc.iter_mut().zip(prow.iter()) {
                        *av += a * pv;
                    }
                }
                crow[j0..j0 + BLK].copy_from_slice(&acc);
            } else {
                // Edge panel (n % BLK columns): same order, dynamic width.
                let mut acc = [0.0f32; BLK];
                let acc = &mut acc[..jw];
                for (kk, &a) in arow.iter().enumerate() {
                    let prow = &panel[kk * jw..(kk + 1) * jw];
                    for (av, pv) in acc.iter_mut().zip(prow.iter()) {
                        *av += a * pv;
                    }
                }
                crow[j0..j0 + jw].copy_from_slice(acc);
            }
            j0 += jw;
        }
    }
}

/// `C = A @ B` into `out` (resized in place), blocked for locality and
/// parallelized across row chunks on the persistent worker pool for
/// large shapes.
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let threads = matmul_threads(a.shape()[0], a.shape()[1], b.shape()[1]);
    matmul_into_with_threads(a, b, out, threads);
}

/// [`matmul_into`] with an explicit worker count — exposed so tests and
/// benches can prove the fp result is bit-identical for every `threads`
/// value (the row partition depends on `threads`, the per-row
/// accumulation order never does).
pub fn matmul_into_with_threads(a: &Tensor, b: &Tensor, out: &mut Tensor, threads: usize) {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    out.resize(&[m, n]);
    let a_w = Widened::new(a);
    let ad = a_w.as_slice();
    // Pack B once per call (pooled scratch, shared read-only by every row
    // chunk); the kernel then fully overwrites `out` — no zero-fill pass.
    // bf16 `B` widens *during* packing (same panel layout, half the bytes
    // read), so the compute loop always consumes f32 tiles.
    let mut pack = workers::take_scratch(k * n);
    match b.dtype() {
        Dtype::F32 => pack_b_panels(b.data(), k, n, &mut pack),
        Dtype::Bf16 => pack_b_panels_bf16(b.bits(), k, n, &mut pack),
    }
    let cd = out.data_mut();
    if m * k * n < PAR_MIN_MADDS || threads <= 1 {
        matmul_rows(ad, &pack, cd, 0, m, k, n);
    } else {
        // Row chunks aligned to the cache block so chunk boundaries are
        // uniform across the kernel family (rows are independent — any
        // partition is bit-identical).
        let rows_per = m.div_ceil(threads).div_ceil(BLK) * BLK;
        let pk: &[f32] = &pack;
        workers::run_chunked(cd, rows_per * n, &|ci, c_chunk| {
            matmul_rows(ad, pk, c_chunk, ci * rows_per, c_chunk.len() / n, k, n)
        });
    }
    workers::recycle_scratch(pack);
}

/// `C = A @ B` for 2-D tensors (allocating wrapper over [`matmul_into`]).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::empty();
    matmul_into(a, b, &mut c);
    c
}

/// Register-tiled dot kernel over `[i0, i0 + rows)` of `A` for
/// [`matmul_nt`], writing the matching rows of `C` (disjoint slice
/// `cd`). `j` is blocked to `BLK` columns (the corresponding `BLK` rows
/// of `B` stay cache-resident across the chunk's `A` rows — the same
/// k/j blocking discipline as [`matmul_rows`]) and each pass drives four
/// independent accumulator chains for ILP. Every dot still sums in
/// ascending `kk` order, so the tiling is bitwise-neutral.
fn matmul_nt_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], i0: usize, rows: usize, k: usize, n: usize) {
    for j0 in (0..n).step_by(BLK) {
        let j1 = (j0 + BLK).min(n);
        for i in 0..rows {
            let arow = &ad[(i0 + i) * k..(i0 + i + 1) * k];
            let crow = &mut cd[i * n..(i + 1) * n];
            let mut j = j0;
            while j + 4 <= j1 {
                let b0 = &bd[j * k..(j + 1) * k];
                let b1 = &bd[(j + 1) * k..(j + 2) * k];
                let b2 = &bd[(j + 2) * k..(j + 3) * k];
                let b3 = &bd[(j + 3) * k..(j + 4) * k];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for (kk, &a) in arow.iter().enumerate() {
                    s0 += a * b0[kk];
                    s1 += a * b1[kk];
                    s2 += a * b2[kk];
                    s3 += a * b3[kk];
                }
                crow[j] = s0;
                crow[j + 1] = s1;
                crow[j + 2] = s2;
                crow[j + 3] = s3;
                j += 4;
            }
            while j < j1 {
                let brow = &bd[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for (av, bv) in arow.iter().zip(brow.iter()) {
                    s += av * bv;
                }
                crow[j] = s;
                j += 1;
            }
        }
    }
}

/// `C = A @ Bᵀ` into `out`, with `A: [m, k]`, `B: [n, k]` → `C: [m, n]`.
///
/// The `dx = dy @ Wᵀ` backward kernel. Both operands stream row-major, so
/// no explicit transpose materializes; rows of `C` are independent, so
/// large shapes split across pool workers exactly like [`matmul_into`]
/// (bit-stable: each row's dot order never changes).
pub fn matmul_nt_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(a.ndim(), 2, "matmul_nt lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_nt rhs must be 2-D");
    let threads = matmul_threads(a.shape()[0], a.shape()[1], b.shape()[0]);
    matmul_nt_into_with_threads(a, b, out, threads);
}

/// [`matmul_nt_into`] with an explicit worker count (determinism tests).
pub fn matmul_nt_into_with_threads(a: &Tensor, b: &Tensor, out: &mut Tensor, threads: usize) {
    assert_eq!(a.ndim(), 2, "matmul_nt lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_nt rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    out.resize(&[m, n]);
    let (a_w, b_w) = (Widened::new(a), Widened::new(b));
    let (ad, bd) = (a_w.as_slice(), b_w.as_slice());
    let cd = out.data_mut();
    if m * k * n < PAR_MIN_MADDS || threads <= 1 {
        matmul_nt_rows(ad, bd, cd, 0, m, k, n);
        return;
    }
    // BLK-aligned row chunks — uniform chunk-boundary rule across the
    // kernel family (matmul / matmul_nt / matmul_tn epilogues).
    let rows_per = m.div_ceil(threads).div_ceil(BLK) * BLK;
    workers::run_chunked(cd, rows_per * n, &|ci, c_chunk| {
        matmul_nt_rows(ad, bd, c_chunk, ci * rows_per, c_chunk.len() / n, k, n)
    });
}

/// `C = A @ Bᵀ` (allocating wrapper over [`matmul_nt_into`]).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::empty();
    matmul_nt_into(a, b, &mut c);
    c
}

/// Sequential partial of the tree reduction: accumulate rows
/// `[r0, r0 + rr)` of the outer-product sum `Σ_r a[r, ·]ᵀ b[r, ·]` into
/// the `m×n` partial `pd` (which must arrive zero-filled). Unconditional
/// inner loop — the old `ari == 0.0` skip is gone for the same
/// autovectorization reason as [`matmul_rows`].
fn matmul_tn_chunk(ad: &[f32], bd: &[f32], pd: &mut [f32], r0: usize, rr: usize, m: usize, n: usize) {
    for r in r0..r0 + rr {
        let brow = &bd[r * n..(r + 1) * n];
        let arow = &ad[r * m..(r + 1) * m];
        for (i, &ari) in arow.iter().enumerate() {
            let prow = &mut pd[i * n..(i + 1) * n];
            for (pv, bv) in prow.iter_mut().zip(brow.iter()) {
                *pv += ari * bv;
            }
        }
    }
}

/// `dst += src`, elementwise — one combine step of the reduction tree.
fn add_assign(dst: &mut [f32], src: &[f32]) {
    for (dv, sv) in dst.iter_mut().zip(src.iter()) {
        *dv += sv;
    }
}

/// `C = Aᵀ @ B` into `out`, with `A: [r, m]`, `B: [r, n]` → `C: [m, n]`.
///
/// The `dw = xᵀ @ dy` backward kernel (dense and conv-im2col), now a
/// **deterministic tree reduction** over the batch dimension: `r` splits
/// into fixed [`TN_CHUNK`]-row chunks (geometry a pure function of the
/// shape), each chunk accumulates an `m×n` partial sequentially — chunk
/// 0 directly into `out`, the rest into pooled scratch — and the
/// partials combine in a fixed pairwise order (`P[i] += P[i+gap]` for
/// `gap = 1, 2, 4, …`). Worker count decides only *who* computes a
/// chunk, never the chunk boundaries or the combine order, so the fp
/// result is bit-identical across `LAYERPIPE2_WORKERS` values — the
/// property the oracle/executor bit-equivalence rests on. (Relative to
/// the pre-tree sequential kernel the summation order *did* change once
/// `r > TN_CHUNK`; oracle and executor moved together.)
pub fn matmul_tn_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(a.ndim(), 2, "matmul_tn lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_tn rhs must be 2-D");
    let (r, m, n) = (a.shape()[0], a.shape()[1], b.shape()[1]);
    let threads = if r * m * n < PAR_MIN_MADDS {
        1
    } else {
        workers::pool_size().min(r.div_ceil(TN_CHUNK)).max(1)
    };
    matmul_tn_into_with_threads(a, b, out, threads);
}

/// [`matmul_tn_into`] with an explicit worker count (determinism tests
/// and benches; `threads` affects only the task split, never the bits).
pub fn matmul_tn_into_with_threads(a: &Tensor, b: &Tensor, out: &mut Tensor, threads: usize) {
    assert_eq!(a.ndim(), 2, "matmul_tn lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_tn rhs must be 2-D");
    let (r, m) = (a.shape()[0], a.shape()[1]);
    let (r2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(r, r2, "matmul_tn outer dims: {r} vs {r2}");
    out.resize(&[m, n]);
    out.fill(0.0);
    let (a_w, b_w) = (Widened::new(a), Widened::new(b));
    let (ad, bd) = (a_w.as_slice(), b_w.as_slice());
    let cd = out.data_mut();
    let nchunks = r.div_ceil(TN_CHUNK).max(1);
    if nchunks == 1 {
        // Single chunk: plain sequential accumulation (identical to the
        // tree with one leaf) — the common dense case, batch ≤ TN_CHUNK.
        matmul_tn_chunk(ad, bd, cd, 0, r, m, n);
        return;
    }
    let mn = m * n;
    let mut ws = workers::take_scratch((nchunks - 1) * mn);
    let chunk_rows = |ci: usize| TN_CHUNK.min(r - ci * TN_CHUNK);
    if threads > 1 && r * m * n >= PAR_MIN_MADDS {
        // Chunks grouped into at most `threads` tasks (so the parameter
        // genuinely bounds parallelism); partial 0 is `out` (already
        // zeroed), the rest zero their pooled slice before accumulating.
        // Grouping never touches the chunk geometry or combine order, so
        // the bits stay independent of `threads`.
        let mut parts: Vec<(usize, &mut [f32])> = Vec::with_capacity(nchunks);
        parts.push((0, &mut cd[..]));
        for (i, w) in ws.chunks_mut(mn).enumerate() {
            parts.push((i + 1, w));
        }
        let chunks_per_task = nchunks.div_ceil(threads.min(nchunks));
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(nchunks.div_ceil(chunks_per_task));
        while !parts.is_empty() {
            let take = chunks_per_task.min(parts.len());
            let group: Vec<(usize, &mut [f32])> = parts.drain(..take).collect();
            tasks.push(Box::new(move || {
                for (ci, pd) in group {
                    if ci > 0 {
                        pd.fill(0.0);
                    }
                    matmul_tn_chunk(ad, bd, pd, ci * TN_CHUNK, chunk_rows(ci), m, n);
                }
            }) as Task<'_>);
        }
        workers::global().run(tasks);
    } else {
        matmul_tn_chunk(ad, bd, cd, 0, chunk_rows(0), m, n);
        for ci in 1..nchunks {
            let pd = &mut ws[(ci - 1) * mn..ci * mn];
            pd.fill(0.0);
            matmul_tn_chunk(ad, bd, pd, ci * TN_CHUNK, chunk_rows(ci), m, n);
        }
    }
    // Fixed pairwise combine: P[0] = out, P[i>0] = ws chunk i−1. The
    // gap-doubling order depends only on `nchunks` — worker-count
    // independent by construction.
    let mut gap = 1;
    while gap < nchunks {
        let mut i = 0;
        while i + gap < nchunks {
            if i == 0 {
                add_assign(cd, &ws[(gap - 1) * mn..gap * mn]);
            } else {
                let (lo, hi) = ws.split_at_mut((i + gap - 1) * mn);
                add_assign(&mut lo[(i - 1) * mn..i * mn], &hi[..mn]);
            }
            i += 2 * gap;
        }
        gap *= 2;
    }
    workers::recycle_scratch(ws);
}

/// `C = Aᵀ @ B` (allocating wrapper over [`matmul_tn_into`]).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::empty();
    matmul_tn_into(a, b, &mut c);
    c
}

/// Column sums of a 2-D tensor into `out`: `out[j] = Σ_i x[i, j]` — the
/// bias-grad reduction (`db = Σ_rows dz`).
pub fn col_sum_into(x: &Tensor, out: &mut Tensor) {
    assert_eq!(x.ndim(), 2, "col_sum needs a 2-D tensor");
    let (m, n) = (x.shape()[0], x.shape()[1]);
    out.resize(&[n]);
    out.fill(0.0);
    let (xd, od) = (x.data(), out.data_mut());
    for i in 0..m {
        let row = &xd[i * n..(i + 1) * n];
        for (ov, xv) in od.iter_mut().zip(row.iter()) {
            *ov += xv;
        }
    }
}

/// Column sums (allocating wrapper over [`col_sum_into`]).
pub fn col_sum(x: &Tensor) -> Tensor {
    let mut out = Tensor::empty();
    col_sum_into(x, &mut out);
    out
}

/// `A^T` for a 2-D tensor (cold path: checkpointing and tests only).
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.set2(j, i, a.at2(i, j));
        }
    }
    t
}

/// Row body of [`bias_act_inplace`] over a chunk of rows.
fn bias_act_rows(yd: &mut [f32], bd: &[f32], n: usize, relu: bool) {
    for row in yd.chunks_mut(n) {
        if relu {
            for (v, bv) in row.iter_mut().zip(bd.iter()) {
                *v = (*v + bv).max(0.0);
            }
        } else {
            for (v, bv) in row.iter_mut().zip(bd.iter()) {
                *v += bv;
            }
        }
    }
}

/// Fused forward epilogue, in place on `y` (typically a fresh matmul
/// result): `y[i, j] += b[j]`, then `max(0, ·)` when `relu` — one pass
/// instead of the add-bias + relu pair, same per-element op order.
/// Large surfaces split rows across pool workers; rows are independent
/// (no cross-row reduction), so any partition is bit-identical.
pub fn bias_act_inplace(y: &mut Tensor, b: &Tensor, relu: bool) {
    assert_eq!(y.ndim(), 2);
    assert_eq!(b.ndim(), 1);
    let (m, n) = (y.shape()[0], y.shape()[1]);
    assert_eq!(n, b.shape()[0]);
    if n == 0 {
        return; // zero-width rows: nothing to add or activate
    }
    let (yd, bd) = (y.data_mut(), b.data());
    let threads = workers::unit_threads(m * n, m);
    if threads <= 1 {
        bias_act_rows(yd, bd, n, relu);
        return;
    }
    let rows_per = m.div_ceil(threads);
    workers::run_chunked(yd, rows_per * n, &|_, chunk| bias_act_rows(chunk, bd, n, relu));
}

/// Row-broadcast add into `out`: `out[i, j] = x[i, j] + b[j]`.
pub fn add_bias_into(x: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(x.ndim(), 2);
    assert_eq!(b.ndim(), 1);
    assert_eq!(x.shape()[1], b.shape()[0]);
    out.widen_from(x);
    bias_act_inplace(out, b, false);
}

/// Row-broadcast add (allocating wrapper over [`add_bias_into`]).
pub fn add_bias(x: &Tensor, b: &Tensor) -> Tensor {
    let mut y = Tensor::empty();
    add_bias_into(x, b, &mut y);
    y
}

/// Elementwise ReLU into `out` (f32 output; bf16 inputs widen on entry,
/// bitwise `copy_from` for f32 inputs).
pub fn relu_into(x: &Tensor, out: &mut Tensor) {
    out.widen_from(x);
    for v in out.data_mut().iter_mut() {
        *v = v.max(0.0);
    }
}

/// Elementwise ReLU (allocating wrapper over [`relu_into`]).
pub fn relu(x: &Tensor) -> Tensor {
    let mut y = Tensor::empty();
    relu_into(x, &mut y);
    y
}

/// Gradient mask of ReLU given its *output* `y`, into `out`:
/// `dy * (y > 0)`.
pub fn relu_grad_into(y: &Tensor, dy: &Tensor, out: &mut Tensor) {
    assert_eq!(y.shape(), dy.shape());
    out.widen_from(dy);
    let y_w = Widened::new(y);
    for (gv, yv) in out.data_mut().iter_mut().zip(y_w.as_slice().iter()) {
        if *yv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// ReLU gradient mask (allocating wrapper over [`relu_grad_into`]).
pub fn relu_grad(y: &Tensor, dy: &Tensor) -> Tensor {
    let mut g = Tensor::empty();
    relu_grad_into(y, dy, &mut g);
    g
}

/// One chunk of [`grad_col_sum_rows`]: mask + per-column reduction over
/// `rows` rows, accumulating into the chunk's private `db` partial
/// (which must arrive zero-filled).
fn grad_col_sum_chunk(
    yd: &[f32],
    dyd: &[f32],
    zd: &mut [f32],
    db: &mut [f32],
    n: usize,
    relu: bool,
) {
    for (r, zrow) in zd.chunks_mut(n).enumerate() {
        let yrow = &yd[r * n..(r + 1) * n];
        let dyrow = &dyd[r * n..(r + 1) * n];
        for (((zv, &yv), &gv), sv) in
            zrow.iter_mut().zip(yrow.iter()).zip(dyrow.iter()).zip(db.iter_mut())
        {
            let g = if relu && yv <= 0.0 { 0.0 } else { gv };
            *zv = g;
            *sv += g;
        }
    }
}

/// Fused backward epilogue over a row-major `[rows, n]` view, on raw
/// slices so spatial ops can apply it to their channel-major views
/// (conv reads `[batch·oh·ow, out_c]` out of its flat wire tensors):
/// `zd[r, j] = dyd[r, j] · mask` (mask = `yd[r, j] > 0` when `relu`,
/// else pass-through) and `db[j] = Σ_r zd[r, j]`.
///
/// Small surfaces run as one streaming pass (row-major ascending — the
/// pre-PR-4 order). Large surfaces split into fixed [`EPI_CHUNK`]-row
/// chunks — geometry a pure function of `rows` — where each chunk owns
/// its `zd` rows and reduces into a private partial, and partials
/// combine in fixed ascending order: bit-identical across worker counts
/// for the same reason as the [`matmul_tn_into`] tree.
pub fn grad_col_sum_rows(
    yd: &[f32],
    dyd: &[f32],
    zd: &mut [f32],
    db: &mut [f32],
    rows: usize,
    n: usize,
    relu: bool,
) {
    assert_eq!(yd.len(), rows * n, "grad_col_sum_rows: y view length");
    assert_eq!(dyd.len(), rows * n, "grad_col_sum_rows: dy view length");
    assert_eq!(zd.len(), rows * n, "grad_col_sum_rows: dz view length");
    assert_eq!(db.len(), n, "grad_col_sum_rows: db length");
    if n == 0 {
        return; // zero-width rows: no dz elements, no db columns
    }
    db.fill(0.0);
    let nchunks = if rows * n < PAR_MIN_ELEMS { 1 } else { rows.div_ceil(EPI_CHUNK) };
    if nchunks <= 1 {
        grad_col_sum_chunk(yd, dyd, zd, db, n, relu);
        return;
    }
    let mut ws = workers::take_scratch((nchunks - 1) * n);
    let run_chunk = |ci: usize, zchunk: &mut [f32], part: &mut [f32]| {
        part.fill(0.0);
        let r0 = ci * EPI_CHUNK;
        let rr = zchunk.len() / n;
        grad_col_sum_chunk(&yd[r0 * n..(r0 + rr) * n], &dyd[r0 * n..(r0 + rr) * n], zchunk, part, n, relu);
    };
    if workers::pool_size() > 1 {
        let mut parts: Vec<&mut [f32]> = Vec::with_capacity(nchunks);
        parts.push(&mut db[..]);
        parts.extend(ws.chunks_mut(n));
        let tasks: Vec<Task<'_>> = zd
            .chunks_mut(EPI_CHUNK * n)
            .zip(parts)
            .enumerate()
            .map(|(ci, (zchunk, part))| {
                let rc = &run_chunk;
                Box::new(move || rc(ci, zchunk, part)) as Task<'_>
            })
            .collect();
        workers::global().run(tasks);
    } else {
        run_chunk(0, &mut zd[..EPI_CHUNK * n], db);
        for (ci, (zchunk, part)) in
            zd[EPI_CHUNK * n..].chunks_mut(EPI_CHUNK * n).zip(ws.chunks_mut(n)).enumerate()
        {
            run_chunk(ci + 1, zchunk, part);
        }
    }
    // Fixed ascending combine of the db partials (geometry depends only
    // on `rows`, so worker count never changes the summation order).
    for part in ws.chunks(n) {
        add_assign(db, part);
    }
    workers::recycle_scratch(ws);
}

/// Fused backward epilogue: the ReLU mask and the bias-grad reduction in
/// one streaming pass — `dz = dy * (y > 0)` and `db[j] = Σ_i dz[i, j]`,
/// element-for-element equal to [`relu_grad_into`] + [`col_sum_into`]
/// (identical per-element ops; for surfaces past the parallel threshold
/// the `db` summation runs as the fixed-chunk reduction of
/// [`grad_col_sum_rows`]) but touching `dy` and `dz` once instead of
/// twice.
pub fn relu_grad_col_sum_into(y: &Tensor, dy: &Tensor, dz: &mut Tensor, db: &mut Tensor) {
    assert_eq!(y.shape(), dy.shape());
    assert_eq!(y.ndim(), 2, "fused backward epilogue needs 2-D activations");
    let (m, n) = (y.shape()[0], y.shape()[1]);
    dz.resize(&[m, n]);
    db.resize(&[n]);
    // `y` may be a bf16-stored activation (the mask only needs signs;
    // widening is exact); `dy`/`dz`/`db` are gradients — always f32.
    let y_w = Widened::new(y);
    grad_col_sum_rows(y_w.as_slice(), dy.data(), dz.data_mut(), db.data_mut(), m, n, true);
}

/// Numerically-stable row softmax into `out`. Total on every input:
/// a fully-masked row (every entry `-inf`) or a zero-width row yields a
/// deterministic all-zero row instead of the `(-inf) - (-inf) = NaN`
/// and `0/0` cascade. Rows with at least one finite entry are
/// bitwise-unchanged from the historical kernel.
pub fn softmax_rows_into(x: &Tensor, out: &mut Tensor) {
    masked_softmax_rows_into(x, None, out);
}

/// Row softmax with an optional additive mask: mask entries are `0.0`
/// to keep a position or `f32::NEG_INFINITY` to exclude it, added to
/// the logits before the stable-softmax pass. The mask is 2-D with the
/// same row width as `x` and broadcasts cyclically over rows — score
/// row `i` uses mask row `i % mask_rows` — so a single `[seq, seq]`
/// causal mask serves every sample of a flattened `[batch·seq, seq]`
/// score matrix. Fully-masked rows produce all-zero rows (no NaN);
/// `mask == None` is bitwise-identical to [`softmax_rows_into`].
pub fn masked_softmax_rows_into(x: &Tensor, mask: Option<&Tensor>, out: &mut Tensor) {
    assert_eq!(x.ndim(), 2);
    let (m, n) = (x.shape()[0], x.shape()[1]);
    out.widen_from(x);
    let mask_w = mask.map(|mk| {
        assert_eq!(mk.ndim(), 2, "softmax mask must be 2-D");
        assert_eq!(mk.shape()[1], n, "softmax mask width {} vs row width {n}", mk.shape()[1]);
        assert!(mk.shape()[0] > 0, "softmax mask needs at least one row");
        (mk.shape()[0], Widened::new(mk))
    });
    for i in 0..m {
        let row = &mut out.data_mut()[i * n..(i + 1) * n];
        if let Some((mrows, ref mw)) = mask_w {
            let mrow = &mw.as_slice()[(i % mrows) * n..(i % mrows + 1) * n];
            for (v, &mv) in row.iter_mut().zip(mrow) {
                *v += mv;
            }
        }
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        if mx == f32::NEG_INFINITY {
            // No finite support (fully masked or n == 0): the limit
            // distribution is undefined, so emit zeros deterministically
            // rather than letting -inf - -inf poison the row with NaN.
            row.fill(0.0);
            continue;
        }
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Numerically-stable row softmax (allocating wrapper).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let mut y = Tensor::empty();
    softmax_rows_into(x, &mut y);
    y
}

/// Shared cross-entropy core: `p` holds row-softmax probabilities on
/// entry and the mean loss gradient w.r.t. logits on exit. `label_of(i)`
/// supplies row `i`'s class. Returns `(mean loss, argmax-correct rows)`.
fn xent_from_probs(p: &mut Tensor, label_of: impl Fn(usize) -> usize) -> (f32, usize) {
    let (m, n) = (p.shape()[0], p.shape()[1]);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    let pd = p.data_mut();
    for i in 0..m {
        let row = &mut pd[i * n..(i + 1) * n];
        let li = label_of(i);
        assert!(li < n, "label {li} out of range {n}");
        loss -= row[li].max(1e-12).ln();
        let mut argmax = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[argmax] {
                argmax = j;
            }
        }
        if argmax == li {
            correct += 1;
        }
        row[li] -= 1.0;
    }
    p.scale(1.0 / m as f32);
    (loss / m as f32, correct)
}

/// Mean softmax cross-entropy into `dl` (the gradient w.r.t. logits),
/// returning `(mean loss, argmax-correct rows)`.
pub fn softmax_xent_into(logits: &Tensor, labels: &[usize], dl: &mut Tensor) -> (f32, usize) {
    assert_eq!(logits.shape()[0], labels.len());
    softmax_rows_into(logits, dl);
    xent_from_probs(dl, |i| labels[i])
}

/// Mean softmax cross-entropy and its gradient w.r.t. logits, plus the
/// number of argmax-correct rows. Mirrors the `loss_grad` HLO artifact.
pub fn softmax_xent(logits: &Tensor, labels: &[usize]) -> (f32, Tensor, usize) {
    let mut dl = Tensor::empty();
    let (loss, correct) = softmax_xent_into(logits, labels, &mut dl);
    (loss, dl, correct)
}

/// [`softmax_xent_into`] with one-hot labels (row argmax, no intermediate
/// label vector — the hot path allocates nothing): `(loss, correct)`,
/// gradient in `dl`.
pub fn softmax_xent_onehot_into(logits: &Tensor, onehot: &Tensor, dl: &mut Tensor) -> (f32, f32) {
    assert_eq!(logits.shape(), onehot.shape(), "logits vs onehot shape");
    let n = logits.shape()[1];
    softmax_rows_into(logits, dl);
    let od = onehot.data();
    let (loss, correct) = xent_from_probs(dl, |i| {
        let row = &od[i * n..(i + 1) * n];
        let mut arg = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = j;
            }
        }
        arg
    });
    (loss, correct as f32)
}

/// [`softmax_xent`] with one-hot labels — the exact input/output contract
/// of the `loss_grad` artifact, so the host backend is a drop-in
/// replacement: `(mean loss, dlogits, argmax-correct row count)`.
pub fn softmax_xent_onehot(logits: &Tensor, onehot: &Tensor) -> (f32, Tensor, f32) {
    let mut dl = Tensor::empty();
    let (loss, correct) = softmax_xent_onehot_into(logits, onehot, &mut dl);
    (loss, dl, correct)
}

/// Scalar reference kernels — the pre-packing/pre-tree serial paths,
/// kept **only** as oracles for tests and the kernel bench (never called
/// from the hot path; the trainers and backends use the tiled kernels
/// above).
///
/// [`reference::matmul`] and [`reference::matmul_nt`] sum each output
/// element in ascending `kk` order — the exact order the tiled kernels
/// preserve — so the production kernels must match them **bitwise**.
/// [`reference::matmul_tn`] is the old purely sequential `dw` reduction
/// (rows ascending, no chunking): once `r > TN_CHUNK` the tree reduction
/// legitimately reassociates the sum, so comparisons against it are
/// tolerance-based, not bitwise.
pub mod reference {
    use super::Tensor;

    /// Naive `C = A @ B`, ascending-`k` dots.
    pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        assert_eq!(k, b.shape()[0]);
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    /// Naive `C = A @ Bᵀ`, ascending-`k` dots.
    pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[0];
        assert_eq!(k, b.shape()[1]);
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(j, kk);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    /// The pre-tree sequential `C = Aᵀ @ B`: one outer-product row at a
    /// time, rows ascending — the summation order the trainers used
    /// before the deterministic tree reduction.
    pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
        let (r, m) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        assert_eq!(r, b.shape()[0]);
        let mut c = Tensor::zeros(&[m, n]);
        let (ad, bd, cd) = (a.data(), b.data(), c.data_mut());
        for rr in 0..r {
            let brow = &bd[rr * n..(rr + 1) * n];
            for i in 0..m {
                let ari = ad[rr * m + i];
                let crow = &mut cd[i * n..(i + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += ari * bv;
                }
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        reference::matmul(a, b)
    }

    #[test]
    fn matmul_matches_naive_on_random_shapes() {
        let mut rng = Rng::new(77);
        for _ in 0..10 {
            let m = 1 + rng.index(40);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(40);
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let c_ref = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c_ref) < 1e-4);
        }
    }

    #[test]
    fn matmul_is_deterministic_across_parallel_threshold() {
        // Shapes straddling PAR_MIN_MADDS: the parallel split may not
        // change the fp result, and the packed kernel must stay bitwise
        // equal to the naive ascending-k reference.
        let mut rng = Rng::new(11);
        let (m, k, n) = (160, 96, 96); // 160·96·96 ≈ 1.5M madds → parallel
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let par = matmul(&a, &b);
        let mut serial = Tensor::empty();
        matmul_into_with_threads(&a, &b, &mut serial, 1);
        assert_eq!(par, serial, "parallel result must be bit-identical");
        assert_eq!(par, reference::matmul(&a, &b), "packed kernel vs naive reference");
    }

    #[test]
    fn matmul_nt_matches_transpose_composition() {
        let mut rng = Rng::new(12);
        // Small shapes (serial path) plus one above PAR_MIN_MADDS so the
        // pooled row split is exercised too.
        let mut cases: Vec<(usize, usize, usize)> = (0..8)
            .map(|_| (1 + rng.index(20), 1 + rng.index(20), 1 + rng.index(20)))
            .collect();
        cases.push((160, 96, 96));
        for (m, k, n) in cases {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let got = matmul_nt(&a, &b);
            let mut serial = Tensor::empty();
            matmul_nt_into_with_threads(&a, &b, &mut serial, 1);
            assert_eq!(got, serial, "parallel nt must be bit-identical");
            assert_eq!(got, reference::matmul_nt(&a, &b), "tiled nt vs naive reference");
            let want = matmul(&a, &transpose(&b));
            assert!(got.max_abs_diff(&want) < 1e-3);
        }
    }

    #[test]
    fn matmul_tn_matches_transpose_composition() {
        let mut rng = Rng::new(13);
        for _ in 0..8 {
            let r = 1 + rng.index(20);
            let m = 1 + rng.index(20);
            let n = 1 + rng.index(20);
            let a = Tensor::randn(&[r, m], 1.0, &mut rng);
            let b = Tensor::randn(&[r, n], 1.0, &mut rng);
            let got = matmul_tn(&a, &b);
            let want = matmul(&transpose(&a), &b);
            assert!(got.max_abs_diff(&want) < 1e-4);
            // Single-chunk shapes (r ≤ TN_CHUNK): the tree degenerates to
            // the old sequential order — bitwise vs the reference.
            assert_eq!(got, reference::matmul_tn(&a, &b));
        }
    }

    #[test]
    fn matmul_tn_tree_reduction_is_chunk_deterministic() {
        // r spanning several TN_CHUNK chunks but below the parallel
        // threshold: serial execution must already use the tree order, so
        // explicit thread counts can't change the bits.
        let mut rng = Rng::new(29);
        let (r, m, n) = (3 * TN_CHUNK + 7, 18, 13);
        let a = Tensor::randn(&[r, m], 0.25, &mut rng);
        let b = Tensor::randn(&[r, n], 0.25, &mut rng);
        let got = matmul_tn(&a, &b);
        for threads in [1usize, 2, 5, 8] {
            let mut out = Tensor::empty();
            matmul_tn_into_with_threads(&a, &b, &mut out, threads);
            assert_eq!(got, out, "tree reduction diverged at threads={threads}");
        }
        // Tolerance (not bitwise) vs the pre-tree sequential order.
        assert!(got.max_abs_diff(&reference::matmul_tn(&a, &b)) < 1e-5);
    }

    #[test]
    fn col_sum_reduces_rows() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
        assert_eq!(col_sum(&x).data(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn onehot_xent_matches_label_xent() {
        let mut rng = Rng::new(14);
        let logits = Tensor::randn(&[5, 7], 2.0, &mut rng);
        let labels: Vec<usize> = (0..5).map(|_| rng.index(7)).collect();
        let mut onehot = Tensor::zeros(&[5, 7]);
        for (i, &l) in labels.iter().enumerate() {
            onehot.set2(i, l, 1.0);
        }
        let (l1, g1, c1) = softmax_xent(&logits, &labels);
        let (l2, g2, c2) = softmax_xent_onehot(&logits, &onehot);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        assert_eq!(c1 as f32, c2);
    }

    #[test]
    fn fused_bias_act_matches_composition() {
        let mut rng = Rng::new(15);
        let x = Tensor::randn(&[6, 9], 1.0, &mut rng);
        let b = Tensor::randn(&[9], 0.5, &mut rng);
        let mut fused = x.clone();
        bias_act_inplace(&mut fused, &b, true);
        assert_eq!(fused, relu(&add_bias(&x, &b)), "relu epilogue");
        let mut affine = x.clone();
        bias_act_inplace(&mut affine, &b, false);
        assert_eq!(affine, add_bias(&x, &b), "linear epilogue");
    }

    #[test]
    fn fused_backward_epilogue_matches_composition() {
        let mut rng = Rng::new(16);
        let y = relu(&Tensor::randn(&[7, 5], 1.0, &mut rng));
        let dy = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let (mut dz, mut db) = (Tensor::empty(), Tensor::empty());
        relu_grad_col_sum_into(&y, &dy, &mut dz, &mut db);
        let dz_ref = relu_grad(&y, &dy);
        assert_eq!(dz, dz_ref);
        assert_eq!(db, col_sum(&dz_ref));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[7, 3], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn relu_and_grad() {
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.0, 2.0, -3.0]);
        let y = relu(&x);
        assert_eq!(y.data(), &[0.0, 0.0, 2.0, 0.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![1.0; 4]);
        let g = relu_grad(&y, &dy);
        assert_eq!(g.data(), &[0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, 9], 3.0, &mut rng);
        let p = softmax_rows(&x);
        for i in 0..5 {
            let s: f32 = (0..9).map(|j| p.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn softmax_fully_masked_rows_are_finite_zeros() {
        // Every row pattern the padding/causal masks can produce: fully
        // -inf, partially -inf, a single -inf survivor, and empty width.
        let x = Tensor::from_vec(
            &[3, 4],
            vec![
                f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY,
                1.0, f32::NEG_INFINITY, 2.0, f32::NEG_INFINITY,
                f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY, 0.5,
            ],
        );
        let p = softmax_rows(&x);
        assert!(p.data().iter().all(|v| v.is_finite()), "softmax emitted non-finite values");
        assert_eq!(&p.data()[0..4], &[0.0; 4], "fully-masked row must be all zeros");
        let s1: f32 = p.data()[4..8].iter().sum();
        assert!((s1 - 1.0).abs() < 1e-6);
        assert_eq!(p.at2(1, 1), 0.0);
        assert_eq!(p.at2(1, 3), 0.0);
        // Single survivor gets the whole mass.
        assert_eq!(&p.data()[8..12], &[0.0, 0.0, 0.0, 1.0]);
        // Zero-width rows: nothing to write, nothing to NaN.
        let empty = Tensor::zeros(&[3, 0]);
        let pe = softmax_rows(&empty);
        assert_eq!(pe.shape(), &[3, 0]);
    }

    #[test]
    fn softmax_unmasked_rows_bitwise_unchanged_by_fix() {
        // The guard only fires on rows with no finite entry; ordinary
        // inputs must reproduce the historical kernel bit-for-bit.
        let mut rng = Rng::new(41);
        let x = Tensor::randn(&[7, 11], 3.0, &mut rng);
        let p = softmax_rows(&x);
        let (m, n) = (x.shape()[0], x.shape()[1]);
        let mut want = x.clone();
        for i in 0..m {
            let row = &mut want.data_mut()[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        for (g, e) in p.data().iter().zip(want.data()) {
            assert_eq!(g.to_bits(), e.to_bits(), "unmasked softmax drifted from legacy kernel");
        }
    }

    #[test]
    fn masked_softmax_matches_premasked_input_and_broadcasts_rows() {
        let mut rng = Rng::new(42);
        let (b, seq) = (3usize, 5usize);
        let x = Tensor::randn(&[b * seq, seq], 1.5, &mut rng);
        // Causal mask: strictly-upper triangle excluded.
        let mut mask = Tensor::zeros(&[seq, seq]);
        for i in 0..seq {
            for j in (i + 1)..seq {
                mask.set2(i, j, f32::NEG_INFINITY);
            }
        }
        let mut got = Tensor::empty();
        masked_softmax_rows_into(&x, Some(&mask), &mut got);
        // Reference: add the mask row (cyclic over samples) by hand, then
        // run the unmasked kernel.
        let mut xm = x.clone();
        for i in 0..b * seq {
            for j in 0..seq {
                let mv = mask.at2(i % seq, j);
                let v = xm.at2(i, j) + mv;
                xm.set2(i, j, v);
            }
        }
        let want = softmax_rows(&xm);
        assert_eq!(got, want, "masked kernel vs pre-masked composition");
        // Masked positions carry exactly zero probability; rows sum to 1.
        for i in 0..b * seq {
            for j in ((i % seq) + 1)..seq {
                assert_eq!(got.at2(i, j), 0.0);
            }
            let s: f32 = (0..seq).map(|j| got.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
        // None-mask path is bitwise the plain kernel.
        let mut none_path = Tensor::empty();
        masked_softmax_rows_into(&x, None, &mut none_path);
        assert_eq!(none_path, softmax_rows(&x));
    }

    #[test]
    fn xent_gradient_matches_finite_difference() {
        let mut rng = Rng::new(21);
        let logits = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let labels = vec![0usize, 3, 5, 2];
        let (_, grad, _) = softmax_xent(&logits, &labels);
        let eps = 1e-3;
        for idx in 0..logits.len() {
            let mut lp = logits.clone();
            lp.data_mut()[idx] += eps;
            let (l_plus, _, _) = softmax_xent(&lp, &labels);
            let mut lm = logits.clone();
            lm.data_mut()[idx] -= eps;
            let (l_minus, _, _) = softmax_xent(&lm, &labels);
            let fd = (l_plus - l_minus) / (2.0 * eps);
            assert!(
                (fd - grad.data()[idx]).abs() < 1e-3,
                "idx {idx}: fd {fd} vs grad {}",
                grad.data()[idx]
            );
        }
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        // Strongly peaked logits at the true label → loss ≈ 0, all correct.
        let mut logits = Tensor::zeros(&[3, 4]);
        for (i, &l) in [1usize, 2, 0].iter().enumerate() {
            logits.set2(i, l, 20.0);
        }
        let (loss, _, correct) = softmax_xent(&logits, &[1, 2, 0]);
        assert!(loss < 1e-3);
        assert_eq!(correct, 3);
    }

    #[test]
    fn bf16_operands_equal_widened_f32_kernels_bitwise() {
        // Widening-on-pack is pure data movement: a matmul over bf16
        // operands must be BITWISE equal to the f32 kernel applied to the
        // (exactly) widened operands — for every kernel in the family,
        // serial and parallel shapes alike.
        let mut rng = Rng::new(41);
        for (m, k, n) in [(5, 7, 9), (33, 40, 37), (160, 96, 96)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng).to_dtype(Dtype::Bf16);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng).to_dtype(Dtype::Bf16);
            let (aw, bw) = (a.to_dtype(Dtype::F32), b.to_dtype(Dtype::F32));
            assert_eq!(matmul(&a, &b), matmul(&aw, &bw), "matmul {m}x{k}x{n}");
            let bt = Tensor::randn(&[n, k], 1.0, &mut rng).to_dtype(Dtype::Bf16);
            let btw = bt.to_dtype(Dtype::F32);
            assert_eq!(matmul_nt(&a, &bt), matmul_nt(&aw, &btw), "matmul_nt {m}x{k}x{n}");
            // Mixed dtypes (bf16 weights, f32 gradients) widen per operand.
            assert_eq!(matmul_nt(&aw, &bt), matmul_nt(&aw, &btw), "mixed nt {m}x{k}x{n}");
        }
        let (r, m, n) = (3 * TN_CHUNK + 7, 18, 13);
        let a = Tensor::randn(&[r, m], 0.25, &mut rng).to_dtype(Dtype::Bf16);
        let b = Tensor::randn(&[r, n], 0.25, &mut rng).to_dtype(Dtype::Bf16);
        let (aw, bw) = (a.to_dtype(Dtype::F32), b.to_dtype(Dtype::F32));
        assert_eq!(matmul_tn(&a, &b), matmul_tn(&aw, &bw), "tn tree");
    }

    #[test]
    fn bf16_matmul_family_is_bit_stable_across_worker_counts() {
        // The PR 4 determinism contract must hold WITHIN the bf16
        // configuration: thread count changes placement, never bits.
        let mut rng = Rng::new(42);
        let a = Tensor::randn(&[160, 96], 1.0, &mut rng).to_dtype(Dtype::Bf16);
        let b = Tensor::randn(&[96, 96], 1.0, &mut rng).to_dtype(Dtype::Bf16);
        let bt = Tensor::randn(&[96, 96], 1.0, &mut rng).to_dtype(Dtype::Bf16);
        let tn_a = Tensor::randn(&[3 * TN_CHUNK + 5, 24], 0.5, &mut rng).to_dtype(Dtype::Bf16);
        let tn_b = Tensor::randn(&[3 * TN_CHUNK + 5, 17], 0.5, &mut rng).to_dtype(Dtype::Bf16);
        let (mm, nt, tn) = (matmul(&a, &b), matmul_nt(&a, &bt), matmul_tn(&tn_a, &tn_b));
        for threads in [1usize, 2, 3, 8] {
            let mut out = Tensor::empty();
            matmul_into_with_threads(&a, &b, &mut out, threads);
            assert_eq!(mm, out, "bf16 matmul diverged at threads={threads}");
            matmul_nt_into_with_threads(&a, &bt, &mut out, threads);
            assert_eq!(nt, out, "bf16 matmul_nt diverged at threads={threads}");
            matmul_tn_into_with_threads(&tn_a, &tn_b, &mut out, threads);
            assert_eq!(tn, out, "bf16 matmul_tn diverged at threads={threads}");
        }
    }

    #[test]
    fn bf16_elementwise_kernels_widen_on_entry() {
        let mut rng = Rng::new(43);
        let x = Tensor::randn(&[6, 9], 1.0, &mut rng).to_dtype(Dtype::Bf16);
        let xw = x.to_dtype(Dtype::F32);
        let b = Tensor::randn(&[9], 0.5, &mut rng);
        assert_eq!(relu(&x), relu(&xw));
        assert_eq!(add_bias(&x, &b), add_bias(&xw, &b));
        assert_eq!(softmax_rows(&x), softmax_rows(&xw));
        let y = relu(&xw).to_dtype(Dtype::Bf16);
        let yw = y.to_dtype(Dtype::F32);
        let dy = Tensor::randn(&[6, 9], 1.0, &mut rng);
        assert_eq!(relu_grad(&y, &dy), relu_grad(&yw, &dy));
        let (mut dz1, mut db1) = (Tensor::empty(), Tensor::empty());
        let (mut dz2, mut db2) = (Tensor::empty(), Tensor::empty());
        relu_grad_col_sum_into(&y, &dy, &mut dz1, &mut db1);
        relu_grad_col_sum_into(&yw, &dy, &mut dz2, &mut db2);
        assert_eq!((dz1, db1), (dz2, db2));
        // The loss kernel accepts bf16 logits (widened before softmax).
        let onehot = {
            let mut oh = Tensor::zeros(&[6, 9]);
            for i in 0..6 {
                oh.set2(i, i % 9, 1.0);
            }
            oh
        };
        let mut dl1 = Tensor::empty();
        let mut dl2 = Tensor::empty();
        let r1 = softmax_xent_onehot_into(&x, &onehot, &mut dl1);
        let r2 = softmax_xent_onehot_into(&xw, &onehot, &mut dl2);
        assert_eq!(r1, r2);
        assert_eq!(dl1, dl2);
    }
}
