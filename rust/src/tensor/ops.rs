//! Host tensor kernels: the compute substrate of the pure-Rust backend.
//!
//! Every kernel comes in two forms: an `_into` variant that writes a
//! caller-owned output (resizing it in place — combined with
//! [`super::BufferPool`] the hot path allocates nothing), and an
//! allocating wrapper that delegates to it, so the two are bitwise
//! identical by construction. The blocked matmuls run i-k-j inside fixed
//! `BLK`-edge cache blocks with tight, autovectorizer-friendly inner
//! loops, and parallelize across row chunks on the persistent
//! [`super::WorkerPool`] (no per-call thread spawns) once shapes are
//! large enough to amortize the queue handoff. Results are bit-identical
//! across worker counts: each row of `C` is always accumulated in the
//! same block order by exactly one task.

use super::workers::{self, Task};
use super::Tensor;

/// Cache-block edge for the matmul kernels.
const BLK: usize = 32;

/// Below this many multiply-adds the blocked matmul stays single-threaded
/// (the queue handoff costs more than the kernel itself).
const PAR_MIN_MADDS: usize = 1 << 20;

/// Worker count for a matmul of `m·k·n` multiply-adds: 1 below the
/// parallel threshold — WITHOUT touching the worker pool, so
/// serial-sized matmuls never spawn it — else the pool's parallelism
/// clamped so tiny row counts don't produce degenerate chunks.
fn matmul_threads(m: usize, k: usize, n: usize) -> usize {
    if m * k * n < PAR_MIN_MADDS {
        return 1;
    }
    workers::pool_size().min(m.div_ceil(BLK)).max(1)
}

/// Blocked i-k-j kernel over the row range `[i0, i0 + rows)` of `A`,
/// writing the matching rows of `C` (passed as the disjoint slice `cd`,
/// which must be zero-initialized — the kernel accumulates).
fn matmul_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], i0: usize, rows: usize, k: usize, n: usize) {
    for ib in (0..rows).step_by(BLK) {
        let i1 = (ib + BLK).min(rows);
        for k0 in (0..k).step_by(BLK) {
            let k1 = (k0 + BLK).min(k);
            for j0 in (0..n).step_by(BLK) {
                let j1 = (j0 + BLK).min(n);
                for i in ib..i1 {
                    let arow = &ad[(i0 + i) * k..(i0 + i) * k + k];
                    let crow = &mut cd[i * n + j0..i * n + j1];
                    for kk in k0..k1 {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bd[kk * n + j0..kk * n + j1];
                        for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                }
            }
        }
    }
}

/// `C = A @ B` into `out` (resized in place), blocked for locality and
/// parallelized across row chunks on the persistent worker pool for
/// large shapes.
pub fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let threads = matmul_threads(a.shape()[0], a.shape()[1], b.shape()[1]);
    matmul_into_with_threads(a, b, out, threads);
}

/// [`matmul_into`] with an explicit worker count — exposed so tests and
/// benches can prove the fp result is bit-identical for every `threads`
/// value (the row partition depends on `threads`, the per-row
/// accumulation order never does).
pub fn matmul_into_with_threads(a: &Tensor, b: &Tensor, out: &mut Tensor, threads: usize) {
    assert_eq!(a.ndim(), 2, "matmul lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    out.resize(&[m, n]);
    out.fill(0.0);
    let (ad, bd) = (a.data(), b.data());
    let cd = out.data_mut();
    if m * k * n < PAR_MIN_MADDS || threads <= 1 {
        matmul_rows(ad, bd, cd, 0, m, k, n);
        return;
    }
    // Row chunks aligned to the cache block so per-row accumulation order
    // (and thus the fp result) is independent of the worker count.
    let rows_per = m.div_ceil(threads).div_ceil(BLK) * BLK;
    let tasks: Vec<Task<'_>> = cd
        .chunks_mut(rows_per * n)
        .enumerate()
        .map(|(chunk_idx, c_chunk)| {
            let i0 = chunk_idx * rows_per;
            let rows = c_chunk.len() / n;
            Box::new(move || matmul_rows(ad, bd, c_chunk, i0, rows, k, n)) as Task<'_>
        })
        .collect();
    workers::global().run(tasks);
}

/// `C = A @ B` for 2-D tensors (allocating wrapper over [`matmul_into`]).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::empty();
    matmul_into(a, b, &mut c);
    c
}

/// Row-dot kernel over `[i0, i0 + rows)` of `A` for [`matmul_nt`],
/// writing the matching rows of `C` (disjoint slice `cd`).
fn matmul_nt_rows(ad: &[f32], bd: &[f32], cd: &mut [f32], i0: usize, rows: usize, k: usize, n: usize) {
    for i in 0..rows {
        let arow = &ad[(i0 + i) * k..(i0 + i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut s = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                s += av * bv;
            }
            cd[i * n + j] = s;
        }
    }
}

/// `C = A @ Bᵀ` into `out`, with `A: [m, k]`, `B: [n, k]` → `C: [m, n]`.
///
/// The `dx = dy @ Wᵀ` backward kernel. Both operands stream row-major, so
/// no explicit transpose materializes; rows of `C` are independent, so
/// large shapes split across pool workers exactly like [`matmul_into`]
/// (bit-stable: each row's dot order never changes).
pub fn matmul_nt_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(a.ndim(), 2, "matmul_nt lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_nt rhs must be 2-D");
    let threads = matmul_threads(a.shape()[0], a.shape()[1], b.shape()[0]);
    matmul_nt_into_with_threads(a, b, out, threads);
}

/// [`matmul_nt_into`] with an explicit worker count (determinism tests).
pub fn matmul_nt_into_with_threads(a: &Tensor, b: &Tensor, out: &mut Tensor, threads: usize) {
    assert_eq!(a.ndim(), 2, "matmul_nt lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_nt rhs must be 2-D");
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    out.resize(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let cd = out.data_mut();
    if m * k * n < PAR_MIN_MADDS || threads <= 1 {
        matmul_nt_rows(ad, bd, cd, 0, m, k, n);
        return;
    }
    let rows_per = m.div_ceil(threads);
    let tasks: Vec<Task<'_>> = cd
        .chunks_mut(rows_per * n)
        .enumerate()
        .map(|(chunk_idx, c_chunk)| {
            let i0 = chunk_idx * rows_per;
            let rows = c_chunk.len() / n;
            Box::new(move || matmul_nt_rows(ad, bd, c_chunk, i0, rows, k, n)) as Task<'_>
        })
        .collect();
    workers::global().run(tasks);
}

/// `C = A @ Bᵀ` (allocating wrapper over [`matmul_nt_into`]).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::empty();
    matmul_nt_into(a, b, &mut c);
    c
}

/// `C = Aᵀ @ B` into `out`, with `A: [r, m]`, `B: [r, n]` → `C: [m, n]`.
///
/// The `dw = xᵀ @ dy` backward kernel, accumulated as a sum of row outer
/// products so every access stays row-major. Stays single-threaded: `r`
/// is the batch dimension (small at training shapes), and parallelizing
/// the reduction would either need per-thread partials (changing fp
/// summation order → breaking the oracle/executor bit-equivalence) or
/// strided column chunking with poor locality.
pub fn matmul_tn_into(a: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(a.ndim(), 2, "matmul_tn lhs must be 2-D");
    assert_eq!(b.ndim(), 2, "matmul_tn rhs must be 2-D");
    let (r, m) = (a.shape()[0], a.shape()[1]);
    let (r2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(r, r2, "matmul_tn outer dims: {r} vs {r2}");
    out.resize(&[m, n]);
    out.fill(0.0);
    let (ad, bd) = (a.data(), b.data());
    let cd = out.data_mut();
    for rr in 0..r {
        let brow = &bd[rr * n..(rr + 1) * n];
        for i in 0..m {
            let ari = ad[rr * m + i];
            if ari == 0.0 {
                continue;
            }
            let crow = &mut cd[i * n..(i + 1) * n];
            for (cv, bv) in crow.iter_mut().zip(brow.iter()) {
                *cv += ari * bv;
            }
        }
    }
}

/// `C = Aᵀ @ B` (allocating wrapper over [`matmul_tn_into`]).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let mut c = Tensor::empty();
    matmul_tn_into(a, b, &mut c);
    c
}

/// Column sums of a 2-D tensor into `out`: `out[j] = Σ_i x[i, j]` — the
/// bias-grad reduction (`db = Σ_rows dz`).
pub fn col_sum_into(x: &Tensor, out: &mut Tensor) {
    assert_eq!(x.ndim(), 2, "col_sum needs a 2-D tensor");
    let (m, n) = (x.shape()[0], x.shape()[1]);
    out.resize(&[n]);
    out.fill(0.0);
    let (xd, od) = (x.data(), out.data_mut());
    for i in 0..m {
        let row = &xd[i * n..(i + 1) * n];
        for (ov, xv) in od.iter_mut().zip(row.iter()) {
            *ov += xv;
        }
    }
}

/// Column sums (allocating wrapper over [`col_sum_into`]).
pub fn col_sum(x: &Tensor) -> Tensor {
    let mut out = Tensor::empty();
    col_sum_into(x, &mut out);
    out
}

/// `A^T` for a 2-D tensor (cold path: checkpointing and tests only).
pub fn transpose(a: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut t = Tensor::zeros(&[n, m]);
    for i in 0..m {
        for j in 0..n {
            t.set2(j, i, a.at2(i, j));
        }
    }
    t
}

/// Fused forward epilogue, in place on `y` (typically a fresh matmul
/// result): `y[i, j] += b[j]`, then `max(0, ·)` when `relu` — one pass
/// instead of the add-bias + relu pair, same per-element op order.
pub fn bias_act_inplace(y: &mut Tensor, b: &Tensor, relu: bool) {
    assert_eq!(y.ndim(), 2);
    assert_eq!(b.ndim(), 1);
    let (m, n) = (y.shape()[0], y.shape()[1]);
    assert_eq!(n, b.shape()[0]);
    let (yd, bd) = (y.data_mut(), b.data());
    for i in 0..m {
        let row = &mut yd[i * n..(i + 1) * n];
        if relu {
            for (v, bv) in row.iter_mut().zip(bd.iter()) {
                *v = (*v + bv).max(0.0);
            }
        } else {
            for (v, bv) in row.iter_mut().zip(bd.iter()) {
                *v += bv;
            }
        }
    }
}

/// Row-broadcast add into `out`: `out[i, j] = x[i, j] + b[j]`.
pub fn add_bias_into(x: &Tensor, b: &Tensor, out: &mut Tensor) {
    assert_eq!(x.ndim(), 2);
    assert_eq!(b.ndim(), 1);
    assert_eq!(x.shape()[1], b.shape()[0]);
    out.copy_from(x);
    bias_act_inplace(out, b, false);
}

/// Row-broadcast add (allocating wrapper over [`add_bias_into`]).
pub fn add_bias(x: &Tensor, b: &Tensor) -> Tensor {
    let mut y = Tensor::empty();
    add_bias_into(x, b, &mut y);
    y
}

/// Elementwise ReLU into `out`.
pub fn relu_into(x: &Tensor, out: &mut Tensor) {
    out.copy_from(x);
    for v in out.data_mut().iter_mut() {
        *v = v.max(0.0);
    }
}

/// Elementwise ReLU (allocating wrapper over [`relu_into`]).
pub fn relu(x: &Tensor) -> Tensor {
    let mut y = Tensor::empty();
    relu_into(x, &mut y);
    y
}

/// Gradient mask of ReLU given its *output* `y`, into `out`:
/// `dy * (y > 0)`.
pub fn relu_grad_into(y: &Tensor, dy: &Tensor, out: &mut Tensor) {
    assert_eq!(y.shape(), dy.shape());
    out.copy_from(dy);
    for (gv, yv) in out.data_mut().iter_mut().zip(y.data().iter()) {
        if *yv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// ReLU gradient mask (allocating wrapper over [`relu_grad_into`]).
pub fn relu_grad(y: &Tensor, dy: &Tensor) -> Tensor {
    let mut g = Tensor::empty();
    relu_grad_into(y, dy, &mut g);
    g
}

/// Fused backward epilogue: the ReLU mask and the bias-grad reduction in
/// one streaming pass — `dz = dy * (y > 0)` and `db[j] = Σ_i dz[i, j]`,
/// bit-identical to [`relu_grad_into`] + [`col_sum_into`] (same
/// per-element ops, same row-major accumulation order) but touching `dy`
/// and `dz` once instead of twice.
pub fn relu_grad_col_sum_into(y: &Tensor, dy: &Tensor, dz: &mut Tensor, db: &mut Tensor) {
    assert_eq!(y.shape(), dy.shape());
    assert_eq!(y.ndim(), 2, "fused backward epilogue needs 2-D activations");
    let (m, n) = (y.shape()[0], y.shape()[1]);
    dz.copy_from(dy);
    db.resize(&[n]);
    db.fill(0.0);
    let (zd, yd, sd) = (dz.data_mut(), y.data(), db.data_mut());
    for i in 0..m {
        let zrow = &mut zd[i * n..(i + 1) * n];
        let yrow = &yd[i * n..(i + 1) * n];
        for ((zv, yv), sv) in zrow.iter_mut().zip(yrow.iter()).zip(sd.iter_mut()) {
            if *yv <= 0.0 {
                *zv = 0.0;
            }
            *sv += *zv;
        }
    }
}

/// Numerically-stable row softmax into `out`.
pub fn softmax_rows_into(x: &Tensor, out: &mut Tensor) {
    assert_eq!(x.ndim(), 2);
    let (m, n) = (x.shape()[0], x.shape()[1]);
    out.copy_from(x);
    for i in 0..m {
        let row = &mut out.data_mut()[i * n..(i + 1) * n];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// Numerically-stable row softmax (allocating wrapper).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let mut y = Tensor::empty();
    softmax_rows_into(x, &mut y);
    y
}

/// Shared cross-entropy core: `p` holds row-softmax probabilities on
/// entry and the mean loss gradient w.r.t. logits on exit. `label_of(i)`
/// supplies row `i`'s class. Returns `(mean loss, argmax-correct rows)`.
fn xent_from_probs(p: &mut Tensor, label_of: impl Fn(usize) -> usize) -> (f32, usize) {
    let (m, n) = (p.shape()[0], p.shape()[1]);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    let pd = p.data_mut();
    for i in 0..m {
        let row = &mut pd[i * n..(i + 1) * n];
        let li = label_of(i);
        assert!(li < n, "label {li} out of range {n}");
        loss -= row[li].max(1e-12).ln();
        let mut argmax = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[argmax] {
                argmax = j;
            }
        }
        if argmax == li {
            correct += 1;
        }
        row[li] -= 1.0;
    }
    p.scale(1.0 / m as f32);
    (loss / m as f32, correct)
}

/// Mean softmax cross-entropy into `dl` (the gradient w.r.t. logits),
/// returning `(mean loss, argmax-correct rows)`.
pub fn softmax_xent_into(logits: &Tensor, labels: &[usize], dl: &mut Tensor) -> (f32, usize) {
    assert_eq!(logits.shape()[0], labels.len());
    softmax_rows_into(logits, dl);
    xent_from_probs(dl, |i| labels[i])
}

/// Mean softmax cross-entropy and its gradient w.r.t. logits, plus the
/// number of argmax-correct rows. Mirrors the `loss_grad` HLO artifact.
pub fn softmax_xent(logits: &Tensor, labels: &[usize]) -> (f32, Tensor, usize) {
    let mut dl = Tensor::empty();
    let (loss, correct) = softmax_xent_into(logits, labels, &mut dl);
    (loss, dl, correct)
}

/// [`softmax_xent_into`] with one-hot labels (row argmax, no intermediate
/// label vector — the hot path allocates nothing): `(loss, correct)`,
/// gradient in `dl`.
pub fn softmax_xent_onehot_into(logits: &Tensor, onehot: &Tensor, dl: &mut Tensor) -> (f32, f32) {
    assert_eq!(logits.shape(), onehot.shape(), "logits vs onehot shape");
    let n = logits.shape()[1];
    softmax_rows_into(logits, dl);
    let od = onehot.data();
    let (loss, correct) = xent_from_probs(dl, |i| {
        let row = &od[i * n..(i + 1) * n];
        let mut arg = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = j;
            }
        }
        arg
    });
    (loss, correct as f32)
}

/// [`softmax_xent`] with one-hot labels — the exact input/output contract
/// of the `loss_grad` artifact, so the host backend is a drop-in
/// replacement: `(mean loss, dlogits, argmax-correct row count)`.
pub fn softmax_xent_onehot(logits: &Tensor, onehot: &Tensor) -> (f32, Tensor, f32) {
    let mut dl = Tensor::empty();
    let (loss, correct) = softmax_xent_onehot_into(logits, onehot, &mut dl);
    (loss, dl, correct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for kk in 0..k {
                    s += a.at2(i, kk) * b.at2(kk, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_on_random_shapes() {
        let mut rng = Rng::new(77);
        for _ in 0..10 {
            let m = 1 + rng.index(40);
            let k = 1 + rng.index(40);
            let n = 1 + rng.index(40);
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            let c_ref = naive_matmul(&a, &b);
            assert!(c.max_abs_diff(&c_ref) < 1e-4);
        }
    }

    #[test]
    fn matmul_is_deterministic_across_parallel_threshold() {
        // Shapes straddling PAR_MIN_MADDS must agree with the naive
        // kernel; the parallel split may not change the fp result.
        let mut rng = Rng::new(11);
        let (m, k, n) = (160, 96, 96); // 160·96·96 ≈ 1.5M madds → parallel
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let par = matmul(&a, &b);
        let mut serial = Tensor::zeros(&[m, n]);
        matmul_rows(a.data(), b.data(), serial.data_mut(), 0, m, k, n);
        assert_eq!(par, serial, "parallel result must be bit-identical");
    }

    #[test]
    fn matmul_nt_matches_transpose_composition() {
        let mut rng = Rng::new(12);
        // Small shapes (serial path) plus one above PAR_MIN_MADDS so the
        // pooled row split is exercised too.
        let mut cases: Vec<(usize, usize, usize)> = (0..8)
            .map(|_| (1 + rng.index(20), 1 + rng.index(20), 1 + rng.index(20)))
            .collect();
        cases.push((160, 96, 96));
        for (m, k, n) in cases {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[n, k], 1.0, &mut rng);
            let got = matmul_nt(&a, &b);
            let mut serial = Tensor::zeros(&[m, n]);
            matmul_nt_rows(a.data(), b.data(), serial.data_mut(), 0, m, k, n);
            assert_eq!(got, serial, "parallel nt must be bit-identical");
            let want = matmul(&a, &transpose(&b));
            assert!(got.max_abs_diff(&want) < 1e-3);
        }
    }

    #[test]
    fn matmul_tn_matches_transpose_composition() {
        let mut rng = Rng::new(13);
        for _ in 0..8 {
            let r = 1 + rng.index(20);
            let m = 1 + rng.index(20);
            let n = 1 + rng.index(20);
            let a = Tensor::randn(&[r, m], 1.0, &mut rng);
            let b = Tensor::randn(&[r, n], 1.0, &mut rng);
            let got = matmul_tn(&a, &b);
            let want = matmul(&transpose(&a), &b);
            assert!(got.max_abs_diff(&want) < 1e-4);
        }
    }

    #[test]
    fn col_sum_reduces_rows() {
        let x = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]);
        assert_eq!(col_sum(&x).data(), &[11.0, 22.0, 33.0]);
    }

    #[test]
    fn onehot_xent_matches_label_xent() {
        let mut rng = Rng::new(14);
        let logits = Tensor::randn(&[5, 7], 2.0, &mut rng);
        let labels: Vec<usize> = (0..5).map(|_| rng.index(7)).collect();
        let mut onehot = Tensor::zeros(&[5, 7]);
        for (i, &l) in labels.iter().enumerate() {
            onehot.set2(i, l, 1.0);
        }
        let (l1, g1, c1) = softmax_xent(&logits, &labels);
        let (l2, g2, c2) = softmax_xent_onehot(&logits, &onehot);
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        assert_eq!(c1 as f32, c2);
    }

    #[test]
    fn fused_bias_act_matches_composition() {
        let mut rng = Rng::new(15);
        let x = Tensor::randn(&[6, 9], 1.0, &mut rng);
        let b = Tensor::randn(&[9], 0.5, &mut rng);
        let mut fused = x.clone();
        bias_act_inplace(&mut fused, &b, true);
        assert_eq!(fused, relu(&add_bias(&x, &b)), "relu epilogue");
        let mut affine = x.clone();
        bias_act_inplace(&mut affine, &b, false);
        assert_eq!(affine, add_bias(&x, &b), "linear epilogue");
    }

    #[test]
    fn fused_backward_epilogue_matches_composition() {
        let mut rng = Rng::new(16);
        let y = relu(&Tensor::randn(&[7, 5], 1.0, &mut rng));
        let dy = Tensor::randn(&[7, 5], 1.0, &mut rng);
        let (mut dz, mut db) = (Tensor::empty(), Tensor::empty());
        relu_grad_col_sum_into(&y, &dy, &mut dz, &mut db);
        let dz_ref = relu_grad(&y, &dy);
        assert_eq!(dz, dz_ref);
        assert_eq!(db, col_sum(&dz_ref));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[7, 3], 1.0, &mut rng);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn relu_and_grad() {
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.0, 2.0, -3.0]);
        let y = relu(&x);
        assert_eq!(y.data(), &[0.0, 0.0, 2.0, 0.0]);
        let dy = Tensor::from_vec(&[1, 4], vec![1.0; 4]);
        let g = relu_grad(&y, &dy);
        assert_eq!(g.data(), &[0.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(3);
        let x = Tensor::randn(&[5, 9], 3.0, &mut rng);
        let p = softmax_rows(&x);
        for i in 0..5 {
            let s: f32 = (0..9).map(|j| p.at2(i, j)).sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn xent_gradient_matches_finite_difference() {
        let mut rng = Rng::new(21);
        let logits = Tensor::randn(&[4, 6], 1.0, &mut rng);
        let labels = vec![0usize, 3, 5, 2];
        let (_, grad, _) = softmax_xent(&logits, &labels);
        let eps = 1e-3;
        for idx in 0..logits.len() {
            let mut lp = logits.clone();
            lp.data_mut()[idx] += eps;
            let (l_plus, _, _) = softmax_xent(&lp, &labels);
            let mut lm = logits.clone();
            lm.data_mut()[idx] -= eps;
            let (l_minus, _, _) = softmax_xent(&lm, &labels);
            let fd = (l_plus - l_minus) / (2.0 * eps);
            assert!(
                (fd - grad.data()[idx]).abs() < 1e-3,
                "idx {idx}: fd {fd} vs grad {}",
                grad.data()[idx]
            );
        }
    }

    #[test]
    fn xent_perfect_prediction_low_loss() {
        // Strongly peaked logits at the true label → loss ≈ 0, all correct.
        let mut logits = Tensor::zeros(&[3, 4]);
        for (i, &l) in [1usize, 2, 0].iter().enumerate() {
            logits.set2(i, l, 20.0);
        }
        let (loss, _, correct) = softmax_xent(&logits, &[1, 2, 0]);
        assert!(loss < 1e-3);
        assert_eq!(correct, 3);
    }
}
