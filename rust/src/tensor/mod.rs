//! Host-side dense `f32` tensors.
//!
//! Weights, optimizer state, stashes and EMA accumulators live on the host
//! in Rust; XLA executables only see them as input literals. This module
//! provides the small set of operations those components need, plus a
//! reference matmul used by tests to cross-check the PJRT path.

mod ops;
pub mod pool;
pub mod workers;

pub use ops::*;
pub use pool::BufferPool;
pub use workers::WorkerPool;

/// Dense row-major `f32` tensor with explicit shape.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor from existing data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} needs {n} elems, got {}", data.len());
        Tensor { shape: shape.to_vec(), data }
    }

    /// Allocation-free placeholder (no shape, no data). Used as the
    /// `mem::replace` filler for consumed stash slots and as the initial
    /// value of `_into`-kernel outputs, which resize it on first write.
    /// Only `len()`/`is_empty()`/`nbytes()` are meaningful on it.
    pub fn empty() -> Self {
        Tensor { shape: Vec::new(), data: Vec::new() }
    }

    /// Reshape in place, reusing the backing store when the element count
    /// matches (the `_into`-kernel output contract). Grown elements are
    /// zero-initialized; existing elements keep their (stale) values —
    /// callers must overwrite or [`Tensor::fill`].
    pub fn resize(&mut self, shape: &[usize]) {
        let n: usize = shape.iter().product();
        if self.shape.as_slice() != shape {
            self.shape.clear();
            self.shape.extend_from_slice(shape);
        }
        if self.data.len() != n {
            self.data.resize(n, 0.0);
        }
    }

    /// `self = src`, reusing the existing allocation when sizes match.
    pub fn copy_from(&mut self, src: &Tensor) {
        self.resize(&src.shape);
        self.data.copy_from_slice(&src.data);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    /// i.i.d. normal entries with standard deviation `std`.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal_f32(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size in bytes of the payload (for stash memory accounting).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 2-D accessor (row-major); debug-asserts bounds.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.data[i * cols + j] = v;
    }

    /// `self += alpha * other` (axpy). Shapes must match.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// `self *= s`.
    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// In-place convex blend `self = beta*self + (1-beta)*other` — the EMA
    /// update primitive (paper Eq. 7).
    pub fn ema_update(&mut self, beta: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "ema shape mismatch");
        let omb = 1.0 - beta;
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a = beta * *a + omb * b;
        }
    }

    /// Euclidean norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Max |a-b| against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.nbytes(), 48);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 24.0]);
    }

    #[test]
    fn ema_update_blends() {
        let mut a = Tensor::from_vec(&[2], vec![0.0, 4.0]);
        let b = Tensor::from_vec(&[2], vec![2.0, 0.0]);
        a.ema_update(0.5, &b);
        assert_eq!(a.data(), &[1.0, 2.0]);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean: f32 = t.data().iter().sum::<f32>() / t.len() as f32;
        let var: f32 =
            t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 0.25).abs() < 0.02, "var {var}");
    }

    #[test]
    fn resize_copy_fill_reuse_storage() {
        let mut t = Tensor::empty();
        assert!(t.is_empty());
        t.resize(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.data().iter().all(|&x| x == 0.0));
        t.fill(7.0);
        let src = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        t.copy_from(&src);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data(), src.data());
        // Shrinking then regrowing keeps contents well-defined.
        t.resize(&[2]);
        assert_eq!(t.data(), &[1.0, 2.0]);
    }

    #[test]
    fn at2_is_row_major() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }
}
