//! Host-side dense tensors, storage-dtype parameterized.
//!
//! Weights, optimizer state, stashes and EMA accumulators live on the host
//! in Rust; XLA executables only see them as input literals. This module
//! provides the small set of operations those components need, plus a
//! reference matmul used by tests to cross-check the PJRT path.
//!
//! A [`Tensor`] stores its payload in one of two dtypes ([`Dtype`]):
//! `F32` (the default — bitwise-identical to the historical all-f32
//! tensor) or `Bf16` (`u16` storage bits, round-to-nearest-even on
//! store, exact widening on load — see [`bf16`]). The mixed-precision
//! contract (DESIGN.md §11): *storage* may be bf16, *arithmetic* is
//! always f32 — every multi-element reduction accumulates in f32, and
//! the elementwise update primitives below read through f32 and
//! re-quantize on write. `data()`/`data_mut()` keep their `&[f32]`
//! signatures and panic on bf16 tensors, so every legacy call site is a
//! checked assertion that the f32-only path never silently receives
//! quantized storage.

pub mod bf16;
mod ops;
pub mod pool;
pub mod workers;

pub use bf16::{bf16_round, bf16_to_f32, f32_to_bf16, EPS_BF16};
pub use ops::*;
pub use pool::BufferPool;
pub use workers::WorkerPool;

/// Env var selecting the training storage dtype (`f32` | `bf16`);
/// mirrors `LAYERPIPE2_WORKERS` / `LAYERPIPE2_REPLICAS`. CLI `--dtype`
/// overrides it.
pub const DTYPE_ENV: &str = "LAYERPIPE2_DTYPE";

/// Storage dtype of a [`Tensor`]'s payload.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// 4-byte IEEE-754 single precision (the default; bitwise-identical
    /// behavior to the historical all-f32 tensor).
    #[default]
    F32,
    /// 2-byte brain float: top half of an f32, RTNE on store, exact
    /// widening on load.
    Bf16,
}

impl Dtype {
    /// Payload bytes per element.
    pub fn size_of(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }

    /// Machine epsilon of the format — the unit of the dtype-derived
    /// oracle tolerance `k * eps * scale` for length-`k` reductions.
    pub fn eps(self) -> f32 {
        match self {
            Dtype::F32 => f32::EPSILON,
            Dtype::Bf16 => EPS_BF16,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Parse a CLI/env/TOML spelling. `None` for anything unknown.
    pub fn parse(s: &str) -> Option<Dtype> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "float32" => Some(Dtype::F32),
            "bf16" | "bfloat16" => Some(Dtype::Bf16),
            _ => None,
        }
    }

    /// Dtype requested via [`DTYPE_ENV`], if set and valid.
    pub fn from_env() -> Option<Dtype> {
        std::env::var(DTYPE_ENV).ok().as_deref().and_then(Dtype::parse)
    }
}

impl std::fmt::Display for Dtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Dense row-major tensor with explicit shape and storage dtype.
///
/// Exactly one backing store is active (`data` for `F32`, `bits` for
/// `Bf16`); the other is always empty but keeps its capacity, so a
/// pooled buffer that flips dtype does not leak its old allocation.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
    bits: Vec<u16>,
    dtype: Dtype,
}

impl Tensor {
    /// Zero-filled f32 tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n], bits: Vec::new(), dtype: Dtype::F32 }
    }

    /// Zero-filled tensor of the given storage dtype.
    pub fn zeros_dtype(shape: &[usize], dtype: Dtype) -> Self {
        let mut t = Tensor::empty();
        t.resize_dtype(shape, dtype);
        t
    }

    /// Tensor from existing data; panics if the element count mismatches.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} needs {n} elems, got {}", data.len());
        Tensor { shape: shape.to_vec(), data, bits: Vec::new(), dtype: Dtype::F32 }
    }

    /// Allocation-free placeholder (no shape, no data). Used as the
    /// `mem::replace` filler for consumed stash slots and as the initial
    /// value of `_into`-kernel outputs, which resize it on first write.
    /// Only `len()`/`is_empty()`/`nbytes()` are meaningful on it.
    pub fn empty() -> Self {
        Tensor { shape: Vec::new(), data: Vec::new(), bits: Vec::new(), dtype: Dtype::F32 }
    }

    /// Storage dtype of the payload.
    #[inline]
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Reshape in place **as f32**, reusing the backing store when the
    /// element count matches (the `_into`-kernel output contract: kernel
    /// outputs are always f32, so a recycled bf16 buffer handed to a
    /// kernel converts here rather than corrupting the result). Grown
    /// elements are zero-initialized; existing elements keep their
    /// (stale) values — callers must overwrite or [`Tensor::fill`].
    pub fn resize(&mut self, shape: &[usize]) {
        self.resize_dtype(shape, Dtype::F32);
    }

    /// Reshape in place to the given storage dtype. Switching dtype
    /// empties the other backing store but keeps both capacities.
    pub fn resize_dtype(&mut self, shape: &[usize], dtype: Dtype) {
        if self.shape.as_slice() != shape {
            self.shape.clear();
            self.shape.extend_from_slice(shape);
        }
        if self.dtype != dtype {
            self.dtype = dtype;
            match dtype {
                Dtype::F32 => self.bits.clear(),
                Dtype::Bf16 => self.data.clear(),
            }
        }
        let n: usize = self.shape.iter().product();
        match dtype {
            Dtype::F32 => {
                if self.data.len() != n {
                    self.data.resize(n, 0.0);
                }
            }
            Dtype::Bf16 => {
                if self.bits.len() != n {
                    self.bits.resize(n, 0);
                }
            }
        }
    }

    /// `self = src` (shape, dtype and payload), reusing the existing
    /// allocation when sizes match.
    pub fn copy_from(&mut self, src: &Tensor) {
        self.resize_dtype(&src.shape, src.dtype);
        match src.dtype {
            Dtype::F32 => self.data.copy_from_slice(&src.data),
            Dtype::Bf16 => self.bits.copy_from_slice(&src.bits),
        }
    }

    /// `self = f32(src)`: widen `src` into this tensor as f32. Exact for
    /// bf16 sources (widening is a bit shift); for f32 sources this is
    /// bitwise `copy_from`.
    pub fn widen_from(&mut self, src: &Tensor) {
        self.resize_dtype(&src.shape, Dtype::F32);
        match src.dtype {
            Dtype::F32 => self.data.copy_from_slice(&src.data),
            Dtype::Bf16 => {
                for (o, &b) in self.data.iter_mut().zip(src.bits.iter()) {
                    *o = bf16_to_f32(b);
                }
            }
        }
    }

    /// `self = bf16(src)`: quantize an f32 tensor into this tensor's
    /// bf16 storage (RTNE per element).
    pub fn quantize_from(&mut self, src: &Tensor) {
        self.resize_dtype(&src.shape, Dtype::Bf16);
        match src.dtype {
            Dtype::F32 => {
                for (o, &v) in self.bits.iter_mut().zip(src.data.iter()) {
                    *o = f32_to_bf16(v);
                }
            }
            Dtype::Bf16 => self.bits.copy_from_slice(&src.bits),
        }
    }

    /// Converted copy. `to_dtype(self.dtype())` is a plain clone.
    pub fn to_dtype(&self, dtype: Dtype) -> Tensor {
        let mut t = Tensor::empty();
        match dtype {
            Dtype::F32 => t.widen_from(self),
            Dtype::Bf16 => t.quantize_from(self),
        }
        t
    }

    /// Set every element to `v` (quantized on bf16 tensors).
    pub fn fill(&mut self, v: f32) {
        match self.dtype {
            Dtype::F32 => self.data.fill(v),
            Dtype::Bf16 => self.bits.fill(f32_to_bf16(v)),
        }
    }

    /// i.i.d. normal entries with standard deviation `std`.
    pub fn randn(shape: &[usize], std: f32, rng: &mut crate::util::Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal_f32(&mut t.data, std);
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        match self.dtype {
            Dtype::F32 => self.data.len(),
            Dtype::Bf16 => self.bits.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Size in bytes of the payload (for stash memory accounting —
    /// halves when the stash stores bf16).
    pub fn nbytes(&self) -> usize {
        self.len() * self.dtype.size_of()
    }

    /// f32 payload view; panics on bf16 tensors (use [`Tensor::get`] or
    /// widen first — see the module contract).
    #[inline]
    pub fn data(&self) -> &[f32] {
        assert!(self.dtype == Dtype::F32, "data() on a {} tensor — widen first", self.dtype);
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        assert!(self.dtype == Dtype::F32, "data_mut() on a {} tensor — widen first", self.dtype);
        &mut self.data
    }

    /// bf16 storage-bit view; panics on f32 tensors.
    #[inline]
    pub fn bits(&self) -> &[u16] {
        assert!(self.dtype == Dtype::Bf16, "bits() on a {} tensor", self.dtype);
        &self.bits
    }

    #[inline]
    pub fn bits_mut(&mut self) -> &mut [u16] {
        assert!(self.dtype == Dtype::Bf16, "bits_mut() on a {} tensor", self.dtype);
        &mut self.bits
    }

    pub fn into_vec(self) -> Vec<f32> {
        assert!(self.dtype == Dtype::F32, "into_vec() on a {} tensor", self.dtype);
        self.data
    }

    /// Read element `i` (flat index) as f32; exact on bf16 storage.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self.dtype {
            Dtype::F32 => self.data[i],
            Dtype::Bf16 => bf16_to_f32(self.bits[i]),
        }
    }

    /// Write element `i` (flat index), quantizing on bf16 storage.
    #[inline]
    pub fn set(&mut self, i: usize, v: f32) {
        match self.dtype {
            Dtype::F32 => self.data[i] = v,
            Dtype::Bf16 => self.bits[i] = f32_to_bf16(v),
        }
    }

    /// 2-D accessor (row-major); debug-asserts bounds.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.get(i * cols + j)
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        self.set(i * cols + j, v);
    }

    /// `self += alpha * other` (axpy). Shapes must match. On the all-f32
    /// path this is the historical bitwise loop; any bf16 operand reads
    /// and accumulates through f32 and re-quantizes on store.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "axpy shape mismatch");
        if self.dtype == Dtype::F32 && other.dtype == Dtype::F32 {
            for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
                *a += alpha * b;
            }
        } else {
            for i in 0..self.len() {
                let v = self.get(i) + alpha * other.get(i);
                self.set(i, v);
            }
        }
    }

    /// `self *= s`.
    pub fn scale(&mut self, s: f32) {
        match self.dtype {
            Dtype::F32 => {
                for a in self.data.iter_mut() {
                    *a *= s;
                }
            }
            Dtype::Bf16 => {
                for b in self.bits.iter_mut() {
                    *b = f32_to_bf16(bf16_to_f32(*b) * s);
                }
            }
        }
    }

    /// In-place convex blend `self = beta*self + (1-beta)*other` — the EMA
    /// update primitive (paper Eq. 7). The blend itself is always f32;
    /// bf16 EMA state quantizes only the stored result (the "store bf16
    /// history, reconstruct through f32" rule).
    pub fn ema_update(&mut self, beta: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape, "ema shape mismatch");
        let omb = 1.0 - beta;
        if self.dtype == Dtype::F32 && other.dtype == Dtype::F32 {
            for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
                *a = beta * *a + omb * b;
            }
        } else {
            for i in 0..self.len() {
                let v = beta * self.get(i) + omb * other.get(i);
                self.set(i, v);
            }
        }
    }

    /// Euclidean norm (f32 accumulation regardless of storage dtype).
    pub fn norm(&self) -> f32 {
        match self.dtype {
            Dtype::F32 => self.data.iter().map(|x| x * x).sum::<f32>().sqrt(),
            Dtype::Bf16 => {
                self.bits.iter().map(|&b| bf16_to_f32(b)).map(|x| x * x).sum::<f32>().sqrt()
            }
        }
    }

    /// Max |a-b| against another tensor (either dtype; reads are exact).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        if self.dtype == Dtype::F32 && other.dtype == Dtype::F32 {
            self.data
                .iter()
                .zip(other.data.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f32::max)
        } else {
            let mut m = 0.0f32;
            for i in 0..self.len() {
                m = m.max((self.get(i) - other.get(i)).abs());
            }
            m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.len(), 12);
        assert_eq!(t.nbytes(), 48);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.data().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn from_vec_checks_len() {
        Tensor::from_vec(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![10.0, 20.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6.0, 12.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 24.0]);
    }

    #[test]
    fn ema_update_blends() {
        let mut a = Tensor::from_vec(&[2], vec![0.0, 4.0]);
        let b = Tensor::from_vec(&[2], vec![2.0, 0.0]);
        a.ema_update(0.5, &b);
        assert_eq!(a.data(), &[1.0, 2.0]);
    }

    #[test]
    fn randn_statistics() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(&[100, 100], 0.5, &mut rng);
        let mean: f32 = t.data().iter().sum::<f32>() / t.len() as f32;
        let var: f32 =
            t.data().iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 0.25).abs() < 0.02, "var {var}");
    }

    #[test]
    fn resize_copy_fill_reuse_storage() {
        let mut t = Tensor::empty();
        assert!(t.is_empty());
        t.resize(&[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.data().iter().all(|&x| x == 0.0));
        t.fill(7.0);
        let src = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        t.copy_from(&src);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.data(), src.data());
        // Shrinking then regrowing keeps contents well-defined.
        t.resize(&[2]);
        assert_eq!(t.data(), &[1.0, 2.0]);
    }

    #[test]
    fn at2_is_row_major() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 1., 2., 3., 4., 5.]);
        assert_eq!(t.at2(0, 2), 2.0);
        assert_eq!(t.at2(1, 0), 3.0);
    }

    #[test]
    fn dtype_parse_and_sizes() {
        assert_eq!(Dtype::parse("f32"), Some(Dtype::F32));
        assert_eq!(Dtype::parse("BF16"), Some(Dtype::Bf16));
        assert_eq!(Dtype::parse("bfloat16"), Some(Dtype::Bf16));
        assert_eq!(Dtype::parse("f16"), None);
        assert_eq!(Dtype::F32.size_of(), 4);
        assert_eq!(Dtype::Bf16.size_of(), 2);
        assert_eq!(Dtype::Bf16.eps(), EPS_BF16);
    }

    #[test]
    fn bf16_tensor_basics() {
        let mut t = Tensor::zeros_dtype(&[2, 2], Dtype::Bf16);
        assert_eq!(t.dtype(), Dtype::Bf16);
        assert_eq!(t.len(), 4);
        assert_eq!(t.nbytes(), 8, "bf16 payload is 2 bytes/elem");
        t.set(0, 1.5);
        t.set2(1, 1, -0.25);
        assert_eq!(t.get(0), 1.5, "exactly representable values store exactly");
        assert_eq!(t.at2(1, 1), -0.25);
        t.fill(3.0);
        assert!((0..4).all(|i| t.get(i) == 3.0));
    }

    #[test]
    #[should_panic(expected = "data() on a bf16 tensor")]
    fn data_on_bf16_panics() {
        let t = Tensor::zeros_dtype(&[2], Dtype::Bf16);
        let _ = t.data();
    }

    #[test]
    fn quantize_widen_roundtrip() {
        let mut rng = Rng::new(9);
        let src = Tensor::randn(&[5, 7], 1.0, &mut rng);
        let mut q = Tensor::empty();
        q.quantize_from(&src);
        assert_eq!(q.dtype(), Dtype::Bf16);
        assert_eq!(q.shape(), src.shape());
        let mut w = Tensor::empty();
        w.widen_from(&q);
        assert_eq!(w.dtype(), Dtype::F32);
        // Widening is exact, so q and w agree bitwise elementwise...
        for i in 0..q.len() {
            assert_eq!(w.get(i).to_bits(), q.get(i).to_bits());
        }
        // ...and the quantization error vs the f32 source is within eps/2
        // relative (RTNE bound).
        for i in 0..src.len() {
            let (x, y) = (src.get(i), w.get(i));
            assert!((x - y).abs() <= x.abs() * EPS_BF16 * 0.5 + f32::MIN_POSITIVE);
        }
        // Re-quantizing the widened copy is exact (idempotent).
        let q2 = w.to_dtype(Dtype::Bf16);
        assert_eq!(q2, q);
    }

    #[test]
    fn resize_converts_recycled_bf16_buffers_to_f32() {
        // The `_into`-kernel output contract: outputs are f32, so a
        // pooled bf16 buffer handed to a kernel must flip dtype here.
        let mut t = Tensor::zeros_dtype(&[4], Dtype::Bf16);
        t.fill(2.0);
        t.resize(&[3]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.len(), 3);
        let _ = t.data(); // must not panic
    }

    #[test]
    fn copy_from_preserves_dtype() {
        let src = Tensor::zeros_dtype(&[3], Dtype::Bf16);
        let mut dst = Tensor::zeros(&[8]);
        dst.copy_from(&src);
        assert_eq!(dst.dtype(), Dtype::Bf16);
        assert_eq!(dst, src);
    }

    #[test]
    fn mixed_dtype_axpy_and_ema_accumulate_in_f32() {
        let mut acc = Tensor::zeros(&[2]); // f32 accumulator
        let mut g = Tensor::empty();
        g.quantize_from(&Tensor::from_vec(&[2], vec![1.0, -2.0]));
        acc.axpy(0.5, &g);
        assert_eq!(acc.data(), &[0.5, -1.0]);

        let mut m = Tensor::zeros_dtype(&[2], Dtype::Bf16); // bf16 EMA state
        let upd = Tensor::from_vec(&[2], vec![4.0, 8.0]);
        m.ema_update(0.5, &upd);
        assert_eq!(m.get(0), 2.0, "exactly representable blend stores exactly");
        assert_eq!(m.get(1), 4.0);
    }
}
