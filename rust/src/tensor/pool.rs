//! Size-keyed recycling pool for tensor backing stores.
//!
//! The training hot path produces and retires same-shaped tensors every
//! iteration (activations, gradients, loss buffers). A [`BufferPool`]
//! keeps retired tensors bucketed by **(dtype, payload bytes)** and
//! hands them back out via [`BufferPool::take`] /
//! [`BufferPool::take_dtype`], so the steady-state loop performs no
//! heap allocation: `take` pops a spare and [`Tensor::resize_dtype`]s
//! it in place (a no-op when the shape repeats, which it always does in
//! steady state).
//!
//! Keying by bytes *and* dtype (not element count) keeps the f32 and
//! bf16 worlds from cross-contaminating: a 16-element f32 spare (64 B)
//! and a 32-element bf16 spare (also 64 B) have equal byte footprints
//! but different backing vectors — handing one out for the other would
//! force a fresh allocation inside `resize_dtype` and silently break
//! the ≤4-allocs/iter steady-state guarantee (`alloc_steady_state.rs`).
//!
//! Pools are owner-local (one per trainer / per pipeline stage) — no
//! locks, no sharing. Tensors may be recycled into a *different* pool
//! than they were taken from (gradients crossing stage boundaries do
//! this); per-size-class caps keep any imbalance bounded.

use super::{Dtype, Tensor};
use crate::obs;
use std::collections::HashMap;

/// Process-wide take mirrors on the shared `obs` registry (DESIGN.md
/// §12): pools stay owner-local and lock-free — `hits()`/`misses()`
/// keep their per-instance semantics — while the registry accumulates
/// the cross-pool totals for `layerpipe2 stats` and snapshot diffs.
static POOL_HITS: obs::LazyCounter = obs::LazyCounter::new("pool/hits");
static POOL_MISSES: obs::LazyCounter = obs::LazyCounter::new("pool/misses");

/// Spare buffers retained per size class; recycles beyond this are
/// dropped, bounding pool memory when a size class has unbalanced
/// producers/consumers (e.g. per-epoch input batches).
const MAX_SPARES_PER_SIZE: usize = 8;

/// A recycling allocator for [`Tensor`] backing stores.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: HashMap<(Dtype, usize), Vec<Tensor>>,
    hits: u64,
    misses: u64,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Hand out an f32 tensor of `shape`. **Contents are unspecified** —
    /// recycled buffers keep stale values — so pooled tensors must only
    /// be used as `_into`-kernel outputs (which fully overwrite or
    /// zero-initialize) or be explicitly [`Tensor::fill`]ed.
    pub fn take(&mut self, shape: &[usize]) -> Tensor {
        self.take_dtype(shape, Dtype::F32)
    }

    /// Hand out a tensor of `shape` in the given storage dtype (same
    /// unspecified-contents contract as [`BufferPool::take`]).
    pub fn take_dtype(&mut self, shape: &[usize], dtype: Dtype) -> Tensor {
        let n: usize = shape.iter().product();
        match self.free.get_mut(&(dtype, n * dtype.size_of())).and_then(Vec::pop) {
            Some(mut t) => {
                self.hits += 1;
                POOL_HITS.inc();
                t.resize_dtype(shape, dtype);
                t
            }
            None => {
                self.misses += 1;
                POOL_MISSES.inc();
                Tensor::zeros_dtype(shape, dtype)
            }
        }
    }

    /// Pooled deep copy of `src` (same shape, dtype and payload).
    pub fn take_copy(&mut self, src: &Tensor) -> Tensor {
        let mut t = self.take_dtype(src.shape(), src.dtype());
        t.copy_from(src);
        t
    }

    /// Return a tensor's backing store to the pool. Empty placeholders
    /// are dropped, as are spares beyond the per-size cap.
    pub fn recycle(&mut self, t: Tensor) {
        if t.is_empty() {
            return;
        }
        let bucket = self.free.entry((t.dtype(), t.nbytes())).or_default();
        if bucket.len() < MAX_SPARES_PER_SIZE {
            bucket.push(t);
        }
    }

    /// Takes served from a spare buffer (no allocation).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Takes that had to allocate fresh storage.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Spare buffers currently held.
    pub fn spares(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }

    /// Bytes parked in spare buffers (memory accounting).
    pub fn spare_nbytes(&self) -> usize {
        self.free.values().flatten().map(Tensor::nbytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_take_reuses_storage() {
        let mut pool = BufferPool::new();
        let mut t = pool.take(&[4, 3]);
        assert_eq!(pool.misses(), 1);
        t.fill(5.0);
        pool.recycle(t);
        assert_eq!(pool.spares(), 1);
        // Same element count, different shape: the spare is reused and
        // reshaped; contents are unspecified (stale 5s prove reuse).
        let t2 = pool.take(&[6, 2]);
        assert_eq!(pool.hits(), 1);
        assert_eq!(t2.shape(), &[6, 2]);
        assert!(t2.data().iter().all(|&v| v == 5.0), "storage was not reused");
        assert_eq!(pool.spares(), 0);
    }

    #[test]
    fn mismatched_sizes_allocate_fresh() {
        let mut pool = BufferPool::new();
        pool.recycle(Tensor::zeros(&[8]));
        let t = pool.take(&[9]);
        assert_eq!(t.len(), 9);
        assert_eq!(pool.misses(), 1);
        assert_eq!(pool.spares(), 1, "the size-8 spare stays parked");
    }

    #[test]
    fn per_size_cap_bounds_spares() {
        let mut pool = BufferPool::new();
        for _ in 0..(MAX_SPARES_PER_SIZE + 5) {
            pool.recycle(Tensor::zeros(&[16]));
        }
        assert_eq!(pool.spares(), MAX_SPARES_PER_SIZE);
        assert_eq!(pool.spare_nbytes(), MAX_SPARES_PER_SIZE * 16 * 4);
    }

    #[test]
    fn empty_placeholders_are_dropped() {
        let mut pool = BufferPool::new();
        pool.recycle(Tensor::empty());
        assert_eq!(pool.spares(), 0);
    }

    #[test]
    fn take_copy_matches_source() {
        let mut pool = BufferPool::new();
        let src = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let cp = pool.take_copy(&src);
        assert_eq!(cp, src);
        // Dtype-preserving: a bf16 source takes a bf16 copy.
        let qsrc = src.to_dtype(Dtype::Bf16);
        let qcp = pool.take_copy(&qsrc);
        assert_eq!(qcp.dtype(), Dtype::Bf16);
        assert_eq!(qcp, qsrc);
    }

    #[test]
    fn dtypes_never_cross_contaminate_size_classes() {
        // A 32-elem bf16 tensor and a 16-elem f32 tensor both occupy
        // 64 B, but must live in different buckets: a take of one dtype
        // can never be served by a spare of the other.
        let mut pool = BufferPool::new();
        pool.recycle(Tensor::zeros_dtype(&[32], Dtype::Bf16));
        let t = pool.take(&[16]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(pool.misses(), 1, "f32 take must not hit the bf16 spare");
        assert_eq!(pool.spares(), 1, "bf16 spare stays parked");
        let q = pool.take_dtype(&[32], Dtype::Bf16);
        assert_eq!(q.dtype(), Dtype::Bf16);
        assert_eq!(pool.hits(), 1, "bf16 take reuses the bf16 spare");
        // bf16 spares report half the bytes of equal-element f32 spares.
        pool.recycle(q);
        assert_eq!(pool.spare_nbytes(), 32 * 2);
    }
}
