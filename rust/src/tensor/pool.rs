//! Size-keyed recycling pool for tensor backing stores.
//!
//! The training hot path produces and retires same-shaped tensors every
//! iteration (activations, gradients, loss buffers). A [`BufferPool`]
//! keeps retired tensors bucketed by element count and hands them back
//! out via [`BufferPool::take`], so the steady-state loop performs no
//! heap allocation: `take` pops a spare and [`Tensor::resize`]s it in
//! place (a no-op when the shape repeats, which it always does in steady
//! state).
//!
//! Pools are owner-local (one per trainer / per pipeline stage) — no
//! locks, no sharing. Tensors may be recycled into a *different* pool
//! than they were taken from (gradients crossing stage boundaries do
//! this); per-size-class caps keep any imbalance bounded.

use super::Tensor;
use std::collections::HashMap;

/// Spare buffers retained per size class; recycles beyond this are
/// dropped, bounding pool memory when a size class has unbalanced
/// producers/consumers (e.g. per-epoch input batches).
const MAX_SPARES_PER_SIZE: usize = 8;

/// A recycling allocator for [`Tensor`] backing stores.
#[derive(Debug, Default)]
pub struct BufferPool {
    free: HashMap<usize, Vec<Tensor>>,
    hits: u64,
    misses: u64,
}

impl BufferPool {
    pub fn new() -> BufferPool {
        BufferPool::default()
    }

    /// Hand out a tensor of `shape`. **Contents are unspecified** —
    /// recycled buffers keep stale values — so pooled tensors must only
    /// be used as `_into`-kernel outputs (which fully overwrite or
    /// zero-initialize) or be explicitly [`Tensor::fill`]ed.
    pub fn take(&mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        match self.free.get_mut(&n).and_then(Vec::pop) {
            Some(mut t) => {
                self.hits += 1;
                t.resize(shape);
                t
            }
            None => {
                self.misses += 1;
                Tensor::zeros(shape)
            }
        }
    }

    /// Pooled deep copy of `src`.
    pub fn take_copy(&mut self, src: &Tensor) -> Tensor {
        let mut t = self.take(src.shape());
        t.data_mut().copy_from_slice(src.data());
        t
    }

    /// Return a tensor's backing store to the pool. Empty placeholders
    /// are dropped, as are spares beyond the per-size cap.
    pub fn recycle(&mut self, t: Tensor) {
        if t.is_empty() {
            return;
        }
        let bucket = self.free.entry(t.len()).or_default();
        if bucket.len() < MAX_SPARES_PER_SIZE {
            bucket.push(t);
        }
    }

    /// Takes served from a spare buffer (no allocation).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Takes that had to allocate fresh storage.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Spare buffers currently held.
    pub fn spares(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }

    /// Bytes parked in spare buffers (memory accounting).
    pub fn spare_nbytes(&self) -> usize {
        self.free.values().flatten().map(Tensor::nbytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_take_reuses_storage() {
        let mut pool = BufferPool::new();
        let mut t = pool.take(&[4, 3]);
        assert_eq!(pool.misses(), 1);
        t.fill(5.0);
        pool.recycle(t);
        assert_eq!(pool.spares(), 1);
        // Same element count, different shape: the spare is reused and
        // reshaped; contents are unspecified (stale 5s prove reuse).
        let t2 = pool.take(&[6, 2]);
        assert_eq!(pool.hits(), 1);
        assert_eq!(t2.shape(), &[6, 2]);
        assert!(t2.data().iter().all(|&v| v == 5.0), "storage was not reused");
        assert_eq!(pool.spares(), 0);
    }

    #[test]
    fn mismatched_sizes_allocate_fresh() {
        let mut pool = BufferPool::new();
        pool.recycle(Tensor::zeros(&[8]));
        let t = pool.take(&[9]);
        assert_eq!(t.len(), 9);
        assert_eq!(pool.misses(), 1);
        assert_eq!(pool.spares(), 1, "the size-8 spare stays parked");
    }

    #[test]
    fn per_size_cap_bounds_spares() {
        let mut pool = BufferPool::new();
        for _ in 0..(MAX_SPARES_PER_SIZE + 5) {
            pool.recycle(Tensor::zeros(&[16]));
        }
        assert_eq!(pool.spares(), MAX_SPARES_PER_SIZE);
        assert_eq!(pool.spare_nbytes(), MAX_SPARES_PER_SIZE * 16 * 4);
    }

    #[test]
    fn empty_placeholders_are_dropped() {
        let mut pool = BufferPool::new();
        pool.recycle(Tensor::empty());
        assert_eq!(pool.spares(), 0);
    }

    #[test]
    fn take_copy_matches_source() {
        let mut pool = BufferPool::new();
        let src = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let cp = pool.take_copy(&src);
        assert_eq!(cp, src);
    }
}
