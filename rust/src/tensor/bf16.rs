//! Scalar bf16 <-> f32 conversions.
//!
//! bf16 is the top 16 bits of an IEEE-754 f32: 1 sign bit, the full 8
//! exponent bits, 7 mantissa bits. That makes widening (`bf16 -> f32`)
//! *exact* — a pure bit shift — and narrowing a pure mantissa rounding
//! with the same dynamic range as f32 (no overflow-to-inf surprises
//! below f32's own limits, subnormals fall out of the same bit
//! arithmetic). We round to nearest, ties to even (RTNE), the rounding
//! every hardware bf16 unit implements, so stored weights match what an
//! accelerator would hold.
//!
//! These are the *only* conversion routines in the crate: kernels widen
//! through [`bf16_to_f32`] when packing panels, and every store of a
//! bf16 tensor funnels through [`f32_to_bf16`]. Keeping them scalar and
//! branch-light matters — they sit inside the packing loops of the
//! matmul family.

/// Machine epsilon of the bf16 format (8 bits of precision incl. the
/// implicit leading one): `2^-8`. The dtype-derived tolerance rule for
/// comparing bf16 results against the f32 oracle is `k * EPS_BF16 *
/// scale` for a length-`k` reduction (DESIGN.md §11).
pub const EPS_BF16: f32 = 0.003_906_25;

/// Narrow an f32 to bf16 storage bits, round to nearest, ties to even.
///
/// NaNs are quieted (the quiet bit is forced on) so that a signalling
/// NaN whose payload lives entirely in the discarded low mantissa bits
/// cannot round to an infinity bit pattern. Infinities and subnormals
/// need no special casing: the carry arithmetic below is exact
/// sign-magnitude rounding for every finite input, and +/-inf have an
/// all-zero low half so the round increment never fires.
#[inline]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    // Round-to-nearest-even on the low 16 bits: add 0x7FFF plus the
    // current LSB of the retained half, then truncate. A half-way value
    // (low half == 0x8000) bumps only when the retained LSB is odd —
    // ties go to even. The carry can ripple from mantissa into exponent
    // (that is correct rounding: 1.111..1 * 2^e rounds to 1.0 * 2^(e+1),
    // and the largest finite magnitudes round to infinity) but can never
    // reach the sign bit.
    let lsb = (bits >> 16) & 1;
    ((bits.wrapping_add(0x7FFF + lsb)) >> 16) as u16
}

/// Widen bf16 storage bits to f32. Exact for every bit pattern.
#[inline]
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Quantize through bf16 and back: the value a bf16-stored tensor
/// actually holds for `x`.
#[inline]
pub fn bf16_round(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widening_is_a_pure_shift() {
        for b in [0u16, 1, 0x3F80, 0x7F80, 0xFF80, 0x8000, 0xABCD, 0xFFFF] {
            assert_eq!(bf16_to_f32(b).to_bits(), (b as u32) << 16, "bits {b:#06x}");
        }
    }

    #[test]
    fn representable_values_round_trip_exactly() {
        // Anything whose low 16 f32 bits are zero is exactly representable.
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, -0.0078125, 3.140625] {
            assert_eq!(v.to_bits() & 0xFFFF, 0, "test value {v} not representable");
            assert_eq!(bf16_round(v).to_bits(), v.to_bits(), "{v} did not round-trip");
        }
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_round(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn rounds_to_nearest() {
        // 1.0 = 0x3F800000; next bf16 up is 0x3F81 = 1.0078125.
        let up = bf16_to_f32(0x3F81);
        assert_eq!(bf16_round(1.001), 1.0, "below midpoint rounds down");
        assert_eq!(bf16_round(1.007), up, "above midpoint rounds up");
    }

    #[test]
    fn ties_go_to_even() {
        // Exact midpoint between 0x3F80 (even) and 0x3F81 (odd): low
        // half exactly 0x8000.
        let tie_low = f32::from_bits(0x3F80_8000);
        assert_eq!(f32_to_bf16(tie_low), 0x3F80, "tie must pick the even LSB");
        // Midpoint between 0x3F81 (odd) and 0x3F82 (even).
        let tie_high = f32::from_bits(0x3F81_8000);
        assert_eq!(f32_to_bf16(tie_high), 0x3F82, "tie must pick the even LSB");
    }

    #[test]
    fn mantissa_carry_ripples_into_exponent() {
        // Largest f32 below 2.0 rounds up to exactly 2.0.
        let just_below_two = f32::from_bits(0x3FFF_FFFF);
        assert_eq!(bf16_to_f32(f32_to_bf16(just_below_two)), 2.0);
        // Largest finite f32 rounds to +inf (bf16's top finite value is
        // 0x7F7F; MAX is past its rounding midpoint).
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MAX)), f32::INFINITY);
        assert_eq!(bf16_to_f32(f32_to_bf16(f32::MIN)), f32::NEG_INFINITY);
    }

    #[test]
    fn nan_stays_nan_and_is_quieted() {
        let quiet = f32_to_bf16(f32::NAN);
        assert!(bf16_to_f32(quiet).is_nan());
        // Signalling NaN with payload only in the discarded low half:
        // exponent all ones, mantissa 0x0000_0001.
        let snan = f32::from_bits(0x7F80_0001);
        assert!(snan.is_nan());
        let b = f32_to_bf16(snan);
        assert!(bf16_to_f32(b).is_nan(), "sNaN must not collapse to inf");
        assert_ne!(b & 0x0040, 0, "quiet bit must be forced on");
    }

    #[test]
    fn subnormals_round_by_the_same_bit_arithmetic() {
        // f32 subnormals are far below bf16's subnormal range only in
        // mantissa; the shared exponent field means small f32 subnormals
        // round to (signed) zero, large ones to bf16 subnormals.
        let tiny = f32::from_bits(0x0000_0001); // smallest positive subnormal
        assert_eq!(f32_to_bf16(tiny), 0x0000, "rounds to +0");
        let neg_tiny = f32::from_bits(0x8000_0001);
        assert_eq!(f32_to_bf16(neg_tiny), 0x8000, "rounds to -0");
        let big_sub = f32::from_bits(0x007F_8000); // midpoint ties to even
        assert_eq!(f32_to_bf16(big_sub), 0x0080);
    }

    #[test]
    fn quantization_error_is_within_eps() {
        // Relative error of RTNE is bounded by eps/2 for normal values.
        let mut x = 1.0e-30f32;
        while x < 1.0e30 {
            let q = bf16_round(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= EPS_BF16 * 0.5 + 1e-9, "x={x} q={q} rel={rel}");
            x *= 3.7;
        }
    }
}
