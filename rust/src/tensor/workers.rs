//! Persistent worker pool for the parallel tensor kernels.
//!
//! The seed's parallel matmul spawned OS threads via `std::thread::scope`
//! on *every* call, so each pipelined layer paid a spawn+join per batch.
//! This pool spawns its workers once (lazily, on first use) and then
//! parks them on a condvar; a kernel submits a batch of borrowed-closure
//! tasks with [`WorkerPool::run`], which blocks until all of them have
//! executed. Steady-state cost per batch is a queue lock + wakeup instead
//! of thread creation.
//!
//! Determinism contract: the pool executes whatever row partition the
//! caller built — it never re-partitions work — so kernel results remain
//! bit-identical across pool sizes (see the matmul chunking in `ops.rs`).
//!
//! Tasks must not submit nested batches to the pool (a worker blocking in
//! `run` would starve the queue it is supposed to drain).

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// A borrowed unit of work: executed exactly once, strictly before the
/// submitting [`WorkerPool::run`] call returns.
pub type Task<'scope> = Box<dyn FnOnce() + Send + 'scope>;

type StaticTask = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Env var overriding the worker count (default: the machine's available
/// parallelism). Affects throughput only, never results.
pub const WORKERS_ENV: &str = "LAYERPIPE2_WORKERS";

/// Completion latch for one `run` batch: counts outstanding tasks and
/// carries the first panic payload back to the submitter.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<PanicPayload>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new(LatchState { remaining: n, panic: None }), cv: Condvar::new() }
    }

    fn complete(&self, panic: Option<PanicPayload>) {
        let mut st = self.state.lock().expect("latch lock");
        if let Some(p) = panic {
            st.panic.get_or_insert(p);
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<PanicPayload> {
        let mut st = self.state.lock().expect("latch lock");
        while st.remaining > 0 {
            st = self.cv.wait(st).expect("latch wait");
        }
        st.panic.take()
    }
}

struct Job {
    task: StaticTask,
    latch: Arc<Latch>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

/// A fixed-size pool of parked worker threads (spawned once, reused for
/// every kernel invocation in the process).
pub struct WorkerPool {
    shared: Arc<Shared>,
    size: usize,
}

/// Extend a borrowed task's lifetime so it can cross the queue.
///
/// # Safety
/// The caller must not return until the task has finished executing
/// ([`WorkerPool::run`] blocks on the completion latch in all paths,
/// including task panics), so every borrow captured by the task strictly
/// outlives its execution.
unsafe fn erase_lifetime(task: Task<'_>) -> StaticTask {
    std::mem::transmute::<Task<'_>, StaticTask>(task)
}

impl WorkerPool {
    fn start(size: usize) -> WorkerPool {
        let shared = Arc::new(Shared { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() });
        for i in 0..size {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("lp2-worker-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        WorkerPool { shared, size }
    }

    /// Number of worker threads (the kernels' parallelism bound).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Execute every task, blocking until all have completed. A panic in
    /// any task is re-raised here (after the whole batch has finished, so
    /// borrowed data never escapes). Single-task batches and size-1 pools
    /// run inline, skipping the queue entirely.
    pub fn run(&self, tasks: Vec<Task<'_>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        if n == 1 || self.size <= 1 {
            for task in tasks {
                task();
            }
            return;
        }
        let latch = Arc::new(Latch::new(n));
        {
            let mut q = self.shared.queue.lock().expect("pool queue lock");
            for task in tasks {
                // SAFETY: `latch.wait()` below blocks until every task in
                // this batch has executed, so the borrows captured by
                // `task` outlive its execution (see `erase_lifetime`).
                let task = unsafe { erase_lifetime(task) };
                q.push_back(Job { task, latch: Arc::clone(&latch) });
            }
        }
        self.shared.cv.notify_all();
        if let Some(payload) = latch.wait() {
            resume_unwind(payload);
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue lock");
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = shared.cv.wait(q).expect("pool queue wait");
            }
        };
        // Catch panics so the worker survives and the submitter (not the
        // pool) decides how to unwind.
        let task = job.task;
        let result = catch_unwind(AssertUnwindSafe(move || task()));
        job.latch.complete(result.err());
    }
}

fn default_size() -> usize {
    std::env::var(WORKERS_ENV)
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, spawned on first use.
pub fn global() -> &'static WorkerPool {
    POOL.get_or_init(|| WorkerPool::start(default_size()))
}

/// Worker count of the global pool (kernel partition sizing).
pub fn pool_size() -> usize {
    global().size()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_with_borrowed_state() {
        let pool = global();
        let mut outs = vec![0usize; 16];
        let tasks: Vec<Task<'_>> = outs
            .chunks_mut(1)
            .enumerate()
            .map(|(i, c)| Box::new(move || c[0] = i + 1) as Task<'_>)
            .collect();
        pool.run(tasks);
        assert_eq!(outs, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_batches_are_inline() {
        global().run(Vec::new());
        let mut hit = false;
        global().run(vec![Box::new(|| hit = true) as Task<'_>]);
        assert!(hit);
    }

    #[test]
    fn reuses_workers_across_batches() {
        // Many batches back-to-back: the whole point is that this does
        // not spawn threads per call, and every batch still completes.
        let pool = global();
        for round in 0..50 {
            let mut acc = vec![0u64; 4];
            let tasks: Vec<Task<'_>> = acc
                .chunks_mut(1)
                .map(|c| Box::new(move || c[0] = round) as Task<'_>)
                .collect();
            pool.run(tasks);
            assert!(acc.iter().all(|&v| v == round), "round {round}");
        }
    }

    #[test]
    fn panics_propagate_after_the_batch_completes() {
        let done = std::sync::atomic::AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            global().run(vec![
                Box::new(|| panic!("boom")) as Task<'_>,
                Box::new(|| {
                    done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }) as Task<'_>,
            ]);
        }));
        assert!(result.is_err(), "task panic must reach the submitter");
        if global().size() > 1 {
            // Queued path: the rest of the batch still ran to completion
            // before the panic was re-raised (borrow-safety contract).
            assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 1);
        }
    }
}
