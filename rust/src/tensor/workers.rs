//! Persistent worker pool for the parallel tensor kernels.
//!
//! The seed's parallel matmul spawned OS threads via `std::thread::scope`
//! on *every* call, so each pipelined layer paid a spawn+join per batch.
//! This pool spawns its workers once (lazily, on first use) and then
//! parks them on a condvar; a kernel submits a batch of borrowed-closure
//! tasks with [`WorkerPool::run`], which blocks until all of them have
//! executed. Steady-state cost per batch is a queue lock + wakeup instead
//! of thread creation.
//!
//! Determinism contract: the pool executes whatever row partition the
//! caller built — it never re-partitions work — so kernel results remain
//! bit-identical across pool sizes (see the matmul chunking in `ops.rs`).
//!
//! Tasks must not submit nested batches to the pool (a worker blocking in
//! `run` would starve the queue it is supposed to drain).
//!
//! The module also owns the kernel scratch free lists
//! ([`take_scratch`] / [`recycle_scratch`]): pooled `Vec<f32>` workspaces
//! for tree-reduction partials and packed matmul panels, kept
//! *per-thread* so the kernel hot path takes no shared lock. They live
//! here — next to the pool the parallel kernels submit to — but are
//! independent of the worker threads, so taking scratch never spawns
//! them.

use crate::obs;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Registry instruments (DESIGN.md §12): batches submitted through
/// [`WorkerPool::run`] (inline or queued) and the tasks they carried.
static POOL_DISPATCHES: obs::LazyCounter = obs::LazyCounter::new("workers/dispatches");
static POOL_TASKS: obs::LazyCounter = obs::LazyCounter::new("workers/tasks");

/// A borrowed unit of work: executed exactly once, strictly before the
/// submitting [`WorkerPool::run`] call returns.
pub type Task<'scope> = Box<dyn FnOnce() + Send + 'scope>;

type StaticTask = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// Env var overriding the worker count (default: the machine's available
/// parallelism). Affects throughput only, never results.
pub const WORKERS_ENV: &str = "LAYERPIPE2_WORKERS";

/// Completion latch for one `run` batch: counts outstanding tasks and
/// carries the first panic payload back to the submitter.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panic: Option<PanicPayload>,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch { state: Mutex::new(LatchState { remaining: n, panic: None }), cv: Condvar::new() }
    }

    fn complete(&self, panic: Option<PanicPayload>) {
        let mut st = self.state.lock().expect("latch lock");
        if let Some(p) = panic {
            st.panic.get_or_insert(p);
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) -> Option<PanicPayload> {
        let mut st = self.state.lock().expect("latch lock");
        while st.remaining > 0 {
            st = self.cv.wait(st).expect("latch wait");
        }
        st.panic.take()
    }
}

struct Job {
    task: StaticTask,
    latch: Arc<Latch>,
}

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    cv: Condvar,
}

/// A fixed-size pool of parked worker threads (spawned once, reused for
/// every kernel invocation in the process).
pub struct WorkerPool {
    shared: Arc<Shared>,
    size: usize,
}

/// Extend a borrowed task's lifetime so it can cross the queue.
///
/// # Safety
/// The caller must not return until the task has finished executing
/// ([`WorkerPool::run`] blocks on the completion latch in all paths,
/// including task panics), so every borrow captured by the task strictly
/// outlives its execution.
unsafe fn erase_lifetime(task: Task<'_>) -> StaticTask {
    std::mem::transmute::<Task<'_>, StaticTask>(task)
}

impl WorkerPool {
    fn start(size: usize) -> WorkerPool {
        let shared = Arc::new(Shared { queue: Mutex::new(VecDeque::new()), cv: Condvar::new() });
        for i in 0..size {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("lp2-worker-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        WorkerPool { shared, size }
    }

    /// Number of worker threads (the kernels' parallelism bound).
    pub fn size(&self) -> usize {
        self.size
    }

    /// Execute every task, blocking until all have completed. A panic in
    /// any task is re-raised here (after the whole batch has finished, so
    /// borrowed data never escapes). Single-task batches and size-1 pools
    /// run inline, skipping the queue entirely.
    pub fn run(&self, tasks: Vec<Task<'_>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        POOL_DISPATCHES.inc();
        POOL_TASKS.add(n as u64);
        if n == 1 || self.size <= 1 {
            for task in tasks {
                task();
            }
            return;
        }
        let latch = Arc::new(Latch::new(n));
        {
            let mut q = self.shared.queue.lock().expect("pool queue lock");
            for task in tasks {
                // SAFETY: `latch.wait()` below blocks until every task in
                // this batch has executed, so the borrows captured by
                // `task` outlive its execution (see `erase_lifetime`).
                let task = unsafe { erase_lifetime(task) };
                q.push_back(Job { task, latch: Arc::clone(&latch) });
            }
        }
        self.shared.cv.notify_all();
        if let Some(payload) = latch.wait() {
            resume_unwind(payload);
        }
    }
}

/// Touched-element threshold below which the memory-bound parallel
/// passes (elementwise epilogues, gathers, pooling scans) stay
/// single-threaded: the queue handoff costs more than the scan. One
/// shared constant so the kernels can't drift apart.
pub const PAR_MIN_WORK: usize = 1 << 20;

/// Worker count for a memory-bound pass of `work` touched elements over
/// `units` independently-ownable units (rows, samples, patch rows): 1
/// below [`PAR_MIN_WORK`] — WITHOUT touching the pool, so small passes
/// never spawn it — else the pool's parallelism clamped to the unit
/// count.
pub fn unit_threads(work: usize, units: usize) -> usize {
    if work < PAR_MIN_WORK {
        1
    } else {
        pool_size().min(units).max(1)
    }
}

/// Shared fan-out scaffold for the row/sample-parallel kernels: split
/// `data` into `chunk_elems`-sized mutable chunks and run
/// `body(chunk_index, chunk)` for each across the global pool. The
/// caller picks the chunk size (and with it the parallelism); chunks
/// are disjoint, so any kernel whose writes stay inside its chunk is
/// bit-identical for every split. One chunk (or less) runs inline.
pub fn run_chunked(data: &mut [f32], chunk_elems: usize, body: &(impl Fn(usize, &mut [f32]) + Sync)) {
    if data.is_empty() {
        return;
    }
    if chunk_elems == 0 || chunk_elems >= data.len() {
        body(0, data);
        return;
    }
    let tasks: Vec<Task<'_>> = data
        .chunks_mut(chunk_elems)
        .enumerate()
        .map(|(ci, chunk)| Box::new(move || body(ci, chunk)) as Task<'_>)
        .collect();
    global().run(tasks);
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("pool queue lock");
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = shared.cv.wait(q).expect("pool queue wait");
            }
        };
        // Catch panics so the worker survives and the submitter (not the
        // pool) decides how to unwind.
        let task = job.task;
        let result = catch_unwind(AssertUnwindSafe(move || task()));
        job.latch.complete(result.err());
    }
}

fn default_size() -> usize {
    std::env::var(WORKERS_ENV)
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(1, |n| n.get()))
}

// ---------------------------------------------------------------------------
// Kernel scratch workspaces.
// ---------------------------------------------------------------------------

/// Spare scratch buffers retained per thread; recycles beyond this are
/// dropped (bounds parked memory if many distinct sizes churn).
const MAX_SCRATCH_SPARES: usize = 8;

thread_local! {
    /// Per-thread free list of kernel scratch buffers (tree-reduction
    /// partials, packed matmul panels). Thread-local on purpose:
    /// take/recycle sit on the kernel hot path of every stage thread,
    /// and a process-global list would put a shared lock under every
    /// matmul — the pipeline's "no locks on the hot path" contract.
    /// Also deliberately *not* tied to the worker threads: taking
    /// scratch must never spawn the pool, so serial-sized kernels keep
    /// their no-thread guarantee. (Scoped stage threads re-spawned per
    /// epoch start with an empty list — a few amortized allocations per
    /// epoch, not per iteration.)
    static SCRATCH: std::cell::RefCell<Vec<Vec<f32>>> =
        const { std::cell::RefCell::new(Vec::new()) };
}

/// Aggregate take/recycle counters across all threads, kept on the
/// shared `obs` registry (the free lists themselves are thread-local).
/// [`scratch_stats`] is a thin view over these.
static SCRATCH_HITS: obs::LazyCounter = obs::LazyCounter::new("workers/scratch_hits");
static SCRATCH_MISSES: obs::LazyCounter = obs::LazyCounter::new("workers/scratch_misses");

/// Hand out a scratch buffer of `len` f32s from the calling thread's
/// free list. **Contents are unspecified** (recycled buffers keep stale
/// values): callers must fully overwrite or zero-fill before reading.
/// Steady-state cost is a lock-free pop + in-place `resize` (which
/// reallocates only while capacities are still growing), so kernels
/// that take/recycle every call allocate nothing once warm.
pub fn take_scratch(len: usize) -> Vec<f32> {
    let popped = SCRATCH.with(|s| s.borrow_mut().pop());
    match popped {
        Some(mut v) => {
            SCRATCH_HITS.inc();
            v.resize(len, 0.0);
            v
        }
        None => {
            SCRATCH_MISSES.inc();
            vec![0.0; len]
        }
    }
}

/// Return a scratch buffer to the calling thread's free list (capacity
/// retained).
pub fn recycle_scratch(v: Vec<f32>) {
    if v.capacity() == 0 {
        return;
    }
    SCRATCH.with(|s| {
        let mut free = s.borrow_mut();
        if free.len() < MAX_SCRATCH_SPARES {
            free.push(v);
        }
    });
}

/// `(hits, misses)` summed over every thread's scratch free list —
/// takes served from a recycled buffer vs fresh allocations. On a
/// single-threaded trainer, misses must stop growing once the kernel
/// working set is warm (asserted by `alloc_steady_state.rs`). A thin
/// view over the `workers/scratch_hits` / `workers/scratch_misses`
/// registry counters.
pub fn scratch_stats() -> (u64, u64) {
    (SCRATCH_HITS.value(), SCRATCH_MISSES.value())
}

static POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide pool, spawned on first use.
pub fn global() -> &'static WorkerPool {
    POOL.get_or_init(|| WorkerPool::start(default_size()))
}

/// Worker count of the global pool (kernel partition sizing).
pub fn pool_size() -> usize {
    global().size()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_every_task_with_borrowed_state() {
        let pool = global();
        let mut outs = vec![0usize; 16];
        let tasks: Vec<Task<'_>> = outs
            .chunks_mut(1)
            .enumerate()
            .map(|(i, c)| Box::new(move || c[0] = i + 1) as Task<'_>)
            .collect();
        pool.run(tasks);
        assert_eq!(outs, (1..=16).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single_batches_are_inline() {
        global().run(Vec::new());
        let mut hit = false;
        global().run(vec![Box::new(|| hit = true) as Task<'_>]);
        assert!(hit);
    }

    #[test]
    fn reuses_workers_across_batches() {
        // Many batches back-to-back: the whole point is that this does
        // not spawn threads per call, and every batch still completes.
        let pool = global();
        for round in 0..50 {
            let mut acc = vec![0u64; 4];
            let tasks: Vec<Task<'_>> = acc
                .chunks_mut(1)
                .map(|c| Box::new(move || c[0] = round) as Task<'_>)
                .collect();
            pool.run(tasks);
            assert!(acc.iter().all(|&v| v == round), "round {round}");
        }
    }

    #[test]
    fn scratch_recycles_capacity() {
        // The free list is thread-local, so this thread's take/recycle
        // sequence is fully deterministic (the stats counters are
        // process-global, hence the before/after delta).
        let (h0, _) = scratch_stats();
        let mut a = take_scratch(16);
        assert_eq!(a.len(), 16);
        a.fill(7.0);
        recycle_scratch(a);
        let b = take_scratch(8);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&v| v == 7.0), "storage was not reused");
        let (h1, _) = scratch_stats();
        assert!(h1 > h0, "recycled scratch was never reused");
        recycle_scratch(b);
        // Growing past the recycled capacity still yields a valid buffer.
        let c = take_scratch(64);
        assert_eq!(c.len(), 64);
        recycle_scratch(c);
    }

    #[test]
    fn panics_propagate_after_the_batch_completes() {
        let done = std::sync::atomic::AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            global().run(vec![
                Box::new(|| panic!("boom")) as Task<'_>,
                Box::new(|| {
                    done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }) as Task<'_>,
            ]);
        }));
        assert!(result.is_err(), "task panic must reach the submitter");
        if global().size() > 1 {
            // Queued path: the rest of the batch still ran to completion
            // before the panic was re-raised (borrow-safety contract).
            assert_eq!(done.load(std::sync::atomic::Ordering::SeqCst), 1);
        }
    }
}
