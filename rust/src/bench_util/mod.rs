//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with mean/median/stddev, and a
//! fixed-width table printer used by every `rust/benches/*.rs` target
//! (all declared `harness = false`). Output format is stable so
//! `bench_output.txt` diffs cleanly across runs.

use crate::util::timer::fmt_duration;
use std::time::Instant;

/// Summary statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub stddev_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn throughput(&self, items_per_run: f64) -> f64 {
        items_per_run / self.mean_s
    }
}

/// Time `f` for `samples` runs after `warmup` unmeasured runs.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(samples >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / samples as f64;
    let median = times[samples / 2];
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / samples as f64;
    BenchStats {
        name: name.to_string(),
        samples,
        mean_s: mean,
        median_s: median,
        stddev_s: var.sqrt(),
        min_s: times[0],
        max_s: times[samples - 1],
    }
}

/// Print a stats row (pair with [`print_header`]).
pub fn print_row(s: &BenchStats) {
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10} {:>6}",
        s.name,
        fmt_duration(s.mean_s),
        fmt_duration(s.median_s),
        fmt_duration(s.min_s),
        fmt_duration(s.max_s),
        s.samples
    );
}

pub fn print_header(title: &str) {
    println!("\n=== {title} ===");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10} {:>6}",
        "case", "mean", "median", "min", "max", "n"
    );
}

/// Print an arbitrary table: header + rows of equal arity, auto-width.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n=== {title} ===");
    let cols = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        assert_eq!(row.len(), cols, "row arity mismatch");
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: Vec<String>| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths[i] + 2))
            .collect::<String>()
    };
    println!("{}", fmt_row(header.iter().map(|s| s.to_string()).collect()));
    for row in rows {
        println!("{}", fmt_row(row.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.samples, 5);
        assert!(s.mean_s >= 0.0 && s.min_s <= s.median_s && s.median_s <= s.max_s);
    }

    #[test]
    fn throughput_inverts_mean() {
        let s = BenchStats {
            name: "x".into(),
            samples: 1,
            mean_s: 0.5,
            median_s: 0.5,
            stddev_s: 0.0,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert_eq!(s.throughput(10.0), 20.0);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_checks_arity() {
        print_table("t", &["a", "b"], &[vec!["1".into()]]);
    }
}
