//! Small self-contained substrates: RNG, JSON, logging, timing.
//!
//! The build environment is offline with a minimal crate cache, so these
//! are written from scratch rather than pulled from crates.io (see
//! DESIGN.md §Reproduction bands & substitutions).

pub mod rng;
pub mod json;
pub mod log;
pub mod timer;

pub use rng::Rng;
pub use timer::Stopwatch;
