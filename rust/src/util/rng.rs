//! Deterministic pseudo-random number generation.
//!
//! Implements splitmix64 (for seeding) and xoshiro256** (for the stream),
//! the standard pairing recommended by Blackman & Vigna. Every stochastic
//! component in the crate (parameter init, data synthesis, shuffling,
//! property-test generators) draws from [`Rng`], so experiments are fully
//! reproducible from a single `u64` seed.

/// splitmix64 step: used to expand a single seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator with convenience samplers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box-Muller pair.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (splitmix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-layer / per-worker use).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA076_1D64_78BD_642F)
    }

    /// Next raw 64-bit output (xoshiro256**).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Unbiased integer in `[0, n)` via Lemire's rejection method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Fill a slice with i.i.d. `N(0, std^2)` samples (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = (self.gauss() as f32) * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn differs_for_different_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.below(7) as usize;
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_is_roughly_uniform() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mut counts = [0usize; 10];
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 10.0;
            assert!((c as f64 - expect).abs() < 5.0 * expect.sqrt(), "count {c}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gauss();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Rng::new(13);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
