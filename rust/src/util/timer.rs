//! Wall-clock timing helpers used by the bench harness and perf logs.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// The process-wide time origin: first call pins it, every later call
/// returns the same `Instant`. Shared by `obs` trace timestamps and
/// `util::log`'s opt-in elapsed-time prefix, so both clocks agree (and
/// so neither module has to depend on the other).
pub fn process_anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// A resettable stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn reset(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.elapsed_secs())
}

/// Human-readable duration (ns/µs/ms/s autoscale).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_duration(2.5e-9).ends_with("ns"));
        assert!(fmt_duration(2.5e-6).ends_with("µs"));
        assert!(fmt_duration(2.5e-3).ends_with("ms"));
        assert!(fmt_duration(2.5).ends_with('s'));
    }
}
