//! Minimal JSON parser and writer.
//!
//! Used for the `artifacts/manifest.json` interchange with the Python AOT
//! compiler and for metrics dumps. Implements the full JSON grammar
//! (RFC 8259) minus `\u` surrogate-pair edge finesse beyond the BMP —
//! sufficient for machine-generated manifests, and covered by tests.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Object keys are kept in a `BTreeMap` for deterministic
/// serialization (useful for golden tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from a string.
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` convenience: `None` for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- writer ----------------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization (via `Display`, so `.to_string()` works too).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad \\u escape"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            cp = cp * 16 + d;
        }
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// Convenience constructors used by metrics/manifest writers.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"dense_fwd","shapes":[[32,64],[64,64]],"ok":true,"eps":0.5}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""Aé λ""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé λ"));
        let rt = Json::parse(&v.to_string()).unwrap();
        assert_eq!(rt, v);
    }

    #[test]
    fn usize_accessor() {
        assert_eq!(Json::parse("32").unwrap().as_usize(), Some(32));
        assert_eq!(Json::parse("2.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
    }
}
