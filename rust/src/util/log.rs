//! Leveled stderr logging with an env-controlled threshold.
//!
//! `LAYERPIPE2_LOG` ∈ {error, warn, info, debug, trace}; default `info`.
//! Deliberately tiny: no timestamps by default (keeps test output stable),
//! atomics for the level, zero allocation when filtered out.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn init_from_env() -> u8 {
    let lvl = match std::env::var("LAYERPIPE2_LOG").ok().as_deref() {
        Some("error") => Level::Error,
        Some("warn") => Level::Warn,
        Some("debug") => Level::Debug,
        Some("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Current threshold, lazily read from the environment.
pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_from_env() } else { raw };
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// Override the threshold programmatically (tests, CLI `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// `true` if a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

#[doc(hidden)]
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_filters() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
