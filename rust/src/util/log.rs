//! Leveled stderr logging with an env-controlled threshold.
//!
//! `LAYERPIPE2_LOG` ∈ {error, warn, info, debug, trace, off}; default
//! `info`. `off` (also `0`/`none`) silences *everything* including
//! `error` — for bit-stability test runs that diff stderr.
//! `LAYERPIPE2_LOG_TS=1` opts into an elapsed-since-start prefix on
//! every line (via [`crate::util::timer::process_anchor`]); the default
//! output stays byte-identical to the historical format.
//! Deliberately tiny: atomics for the level, zero allocation when
//! filtered out.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

/// Raw threshold value meaning "emit nothing, not even errors". Kept
/// outside the [`Level`] enum so `l <= level()` ordering stays intact.
const OFF: u8 = 5;

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised
static TIMESTAMPS: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn init_from_env() -> u8 {
    let lvl = match std::env::var("LAYERPIPE2_LOG").ok().as_deref() {
        Some("error") => Level::Error as u8,
        Some("warn") => Level::Warn as u8,
        Some("debug") => Level::Debug as u8,
        Some("trace") => Level::Trace as u8,
        Some("off" | "0" | "none") => OFF,
        _ => Level::Info as u8,
    };
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

fn raw_level() -> u8 {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        init_from_env()
    } else {
        raw
    }
}

/// Current threshold, lazily read from the environment. When logging is
/// fully off this reports `Error` (the most restrictive named level) —
/// use [`enabled`] for emission decisions.
pub fn level() -> Level {
    match raw_level() {
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        4 => Level::Trace,
        _ => Level::Error, // 0 and OFF
    }
}

/// Override the threshold programmatically (tests, CLI `--verbose`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Silence every level, `error` included (programmatic `LAYERPIPE2_LOG=off`).
pub fn set_off() {
    LEVEL.store(OFF, Ordering::Relaxed);
}

/// `true` if a message at `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    let raw = raw_level();
    raw != OFF && (l as u8) <= raw
}

fn timestamps_enabled() -> bool {
    let raw = TIMESTAMPS.load(Ordering::Relaxed);
    if raw != 255 {
        return raw == 1;
    }
    let on = std::env::var("LAYERPIPE2_LOG_TS").ok().as_deref() == Some("1");
    TIMESTAMPS.store(u8::from(on), Ordering::Relaxed);
    on
}

#[doc(hidden)]
pub fn emit(l: Level, args: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        if timestamps_enabled() {
            let elapsed = super::timer::process_anchor().elapsed().as_secs_f64();
            eprintln!("[{tag} +{}] {args}", super::timer::fmt_duration(elapsed));
        } else {
            eprintln!("[{tag}] {args}");
        }
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    /// One sequential test: the threshold is process-global, so the
    /// Warn and Off phases must not run as parallel sibling tests.
    #[test]
    fn threshold_filters_and_off_silences_error_too() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_off();
        assert!(!enabled(Level::Error));
        assert!(!enabled(Level::Trace));
        // level() stays a valid named level even while off.
        assert_eq!(level(), Level::Error);
        set_level(Level::Info);
        assert!(enabled(Level::Error));
    }
}
