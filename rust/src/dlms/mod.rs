//! Delayed LMS adaptive filtering (paper §III-A, Fig. 2).
//!
//! The feasibility of pipelined training rests on DLMS theory
//! (Long, Ling & Proakis [20]): an LMS filter whose coefficient update is
//! delayed by `M` samples still converges for slowly-varying processes
//! under a tightened step-size bound. This module is a from-scratch
//! system-identification substrate that reproduces the Fig. 2 behaviour:
//! convergence curves vs. delay `M` and the μ stability boundary.
//!
//! Model: unknown FIR `h*` of order `T`, white input `x(t) ~ N(0,σ²)`,
//! observation `d(t) = h*ᵀx(t) + v(t)`. DLMS update:
//! `w(t+1) = w(t) + μ·e(t−M)·x(t−M)` with `e(t) = d(t) − w(t)ᵀx(t)`.

use crate::util::Rng;

/// Configuration of one DLMS system-identification run.
#[derive(Clone, Debug)]
pub struct DlmsConfig {
    /// Filter order (number of taps).
    pub taps: usize,
    /// Adaptation step size μ.
    pub mu: f64,
    /// Update delay M in samples (M = 0 is classical LMS).
    pub delay: usize,
    /// Input signal power σ².
    pub input_power: f64,
    /// Observation noise standard deviation.
    pub noise_std: f64,
    /// Samples to run.
    pub samples: usize,
    pub seed: u64,
}

impl Default for DlmsConfig {
    fn default() -> Self {
        DlmsConfig {
            taps: 16,
            mu: 0.01,
            delay: 0,
            input_power: 1.0,
            noise_std: 1e-3,
            samples: 20_000,
            seed: 99,
        }
    }
}

/// Outcome of a run.
#[derive(Clone, Debug)]
pub struct DlmsResult {
    /// Squared error `e(t)²` per sample (the learning curve).
    pub mse_curve: Vec<f64>,
    /// Final coefficient misalignment `‖w − h*‖² / ‖h*‖²`.
    pub misalignment: f64,
    /// Steady-state MSE (mean over the last 10 % of samples).
    pub steady_state_mse: f64,
    /// Whether the run stayed numerically bounded.
    pub converged: bool,
}

/// Classical stability heuristics. LMS requires `μ < 2/(T·σ²)` (input
/// power bound); delayed adaptation tightens it by the delay term — the
/// standard small-μ result is `μ·λ_max·M < π/2`-style; we expose the
/// practical white-input form `μ < 2 / (σ²·(T + 2M))` used for sweeps.
pub fn stable_mu_bound(taps: usize, delay: usize, input_power: f64) -> f64 {
    2.0 / (input_power * (taps as f64 + 2.0 * delay as f64))
}

/// Run DLMS system identification.
pub fn run(cfg: &DlmsConfig) -> DlmsResult {
    assert!(cfg.taps > 0 && cfg.samples > 0);
    let mut rng = Rng::new(cfg.seed);

    // Unknown system: random unit-norm FIR.
    let mut h: Vec<f64> = (0..cfg.taps).map(|_| rng.gauss()).collect();
    let hn = h.iter().map(|x| x * x).sum::<f64>().sqrt();
    for x in &mut h {
        *x /= hn;
    }

    let sigma = cfg.input_power.sqrt();
    let mut w = vec![0.0f64; cfg.taps];
    // Input delay line (most recent first) and the M-deep FIFO of
    // (error, input-vector) pairs awaiting application — the M-sample
    // delay of Fig. 2.
    let mut x = vec![0.0f64; cfg.taps];
    let mut pending: std::collections::VecDeque<(f64, Vec<f64>)> =
        std::collections::VecDeque::with_capacity(cfg.delay + 1);

    let mut mse_curve = Vec::with_capacity(cfg.samples);
    let mut converged = true;

    for _ in 0..cfg.samples {
        // Shift in a new sample.
        x.rotate_right(1);
        x[0] = sigma * rng.gauss();
        let d: f64 = h.iter().zip(&x).map(|(a, b)| a * b).sum::<f64>()
            + cfg.noise_std * rng.gauss();
        let y: f64 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
        let e = d - y;
        mse_curve.push(e * e);
        if !e.is_finite() || e.abs() > 1e6 {
            converged = false;
            break;
        }

        pending.push_back((e, x.clone()));
        if pending.len() > cfg.delay {
            // Apply the (possibly stale) gradient e(t−M)·x(t−M).
            let (e_old, x_old) = pending.pop_front().expect("pending nonempty");
            for (wi, xi) in w.iter_mut().zip(&x_old) {
                *wi += cfg.mu * e_old * xi;
            }
        }
    }

    let mis_num: f64 = w.iter().zip(&h).map(|(a, b)| (a - b) * (a - b)).sum();
    let tail = (mse_curve.len() / 10).max(1);
    let steady: f64 =
        mse_curve.iter().rev().take(tail).sum::<f64>() / tail as f64;
    DlmsResult {
        misalignment: mis_num, // ‖h*‖ = 1 by construction
        steady_state_mse: steady,
        converged: converged && mse_curve.len() == cfg.samples,
        mse_curve,
    }
}

/// Convergence-time summary: first sample index where a running mean of
/// the squared error drops below `threshold` (window 200), or `None`.
pub fn convergence_time(curve: &[f64], threshold: f64) -> Option<usize> {
    let w = 200.min(curve.len().max(1));
    let mut sum: f64 = curve.iter().take(w).sum();
    if sum / w as f64 <= threshold {
        return Some(w);
    }
    for i in w..curve.len() {
        sum += curve[i] - curve[i - w];
        if sum / w as f64 <= threshold {
            return Some(i);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classical_lms_converges() {
        let r = run(&DlmsConfig::default());
        assert!(r.converged);
        assert!(r.misalignment < 1e-3, "misalignment {}", r.misalignment);
        assert!(r.steady_state_mse < 1e-4, "ss mse {}", r.steady_state_mse);
    }

    #[test]
    fn delayed_lms_still_converges_with_safe_mu() {
        // The §III-A claim: controlled delay is tolerated.
        for delay in [1usize, 4, 16] {
            let cfg = DlmsConfig { delay, mu: 0.005, ..DlmsConfig::default() };
            let r = run(&cfg);
            assert!(r.converged, "delay {delay}");
            assert!(r.misalignment < 1e-2, "delay {delay}: {}", r.misalignment);
        }
    }

    #[test]
    fn delay_slows_convergence() {
        // More delay ⇒ slower convergence (Fig. 2's qualitative content).
        // Averaged over seeds: a single run's convergence-time estimate
        // is noisy, but near the delayed stability edge the gap is large.
        let mut mis0 = 0.0;
        let mut mis48 = 0.0;
        for seed in 0..8u64 {
            let base = DlmsConfig {
                mu: 0.015,
                noise_std: 1e-3,
                // Short horizon: probe mid-convergence where the delayed
                // filter lags (by 4k samples both reach the noise floor).
                samples: 500,
                seed: 1000 + seed,
                ..DlmsConfig::default()
            };
            mis0 += run(&DlmsConfig { delay: 0, ..base.clone() }).misalignment;
            mis48 += run(&DlmsConfig { delay: 48, ..base }).misalignment;
        }
        assert!(
            mis48 > 2.0 * mis0,
            "delay-48 misalignment {mis48} not clearly worse than classical {mis0}"
        );
    }

    #[test]
    fn large_mu_with_large_delay_diverges() {
        // Above the delay-tightened bound the filter blows up — the
        // "suitable step-size constraints" of the paper.
        let cfg = DlmsConfig {
            delay: 64,
            mu: 0.12, // way past 2/(σ²(T+2M)) ≈ 0.014
            samples: 50_000,
            ..DlmsConfig::default()
        };
        let r = run(&cfg);
        assert!(
            !r.converged || r.steady_state_mse > 1e-2,
            "expected instability: ss {}",
            r.steady_state_mse
        );
    }

    #[test]
    fn mu_bound_decreases_with_delay() {
        let b0 = stable_mu_bound(16, 0, 1.0);
        let b8 = stable_mu_bound(16, 8, 1.0);
        let b32 = stable_mu_bound(16, 32, 1.0);
        assert!(b0 > b8 && b8 > b32);
    }

    #[test]
    fn convergence_time_finds_drop() {
        let mut curve = vec![1.0; 500];
        curve.extend(vec![0.0; 500]);
        let t = convergence_time(&curve, 0.5).unwrap();
        assert!((500..900).contains(&t), "t={t}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(&DlmsConfig::default());
        let b = run(&DlmsConfig::default());
        assert_eq!(a.mse_curve, b.mse_curve);
    }
}
