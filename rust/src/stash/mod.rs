//! Weight and activation stashing with byte-level memory accounting.
//!
//! The paper's §III-B derivation shows stashing is the *structural*
//! consequence of retiming: states displaced by delay motion must remain
//! available until the delayed gradients return. A direct implementation
//! stores one weight version per in-flight iteration — `O(L·S)` memory —
//! which the pipeline-aware EMA of [`crate::ema`] replaces with `O(L)`.
//! This module is that direct implementation (the PipeDream-style
//! baseline) plus the activation stash every pipelined strategy needs.

use crate::tensor::Tensor;
use std::collections::VecDeque;

/// Ring buffer of historical weight versions for one layer.
///
/// `push(t, w)` stores version `t`; `get(t)` retrieves it while it is
/// still within the retention window (`capacity` versions).
#[derive(Clone, Debug)]
pub struct WeightStash {
    capacity: usize,
    entries: VecDeque<(u64, Tensor)>,
    peak_nbytes: usize,
}

impl WeightStash {
    /// `capacity` = number of versions retained = the layer's gradient
    /// delay + 1 (a gradient delayed by `d` needs the version from `d`
    /// iterations ago while the current version also exists).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "stash capacity must be positive");
        WeightStash { capacity, entries: VecDeque::new(), peak_nbytes: 0 }
    }

    /// Store the weight version at iteration `t`. Versions must be pushed
    /// in increasing `t` order; the oldest is evicted beyond capacity.
    pub fn push(&mut self, t: u64, w: &Tensor) {
        if let Some(&(last, _)) = self.entries.back() {
            assert!(t > last, "stash pushes must be in increasing order ({t} after {last})");
        }
        if self.entries.len() == self.capacity {
            // At capacity, recycle the evicted version's allocation
            // instead of cloning (hot-path memory discipline: steady-
            // state stashing pushes are a copy, not an allocation).
            let (_, mut slot) = self.entries.pop_front().expect("nonempty at capacity");
            slot.copy_from(w);
            self.entries.push_back((t, slot));
        } else {
            self.entries.push_back((t, w.clone()));
        }
        self.peak_nbytes = self.peak_nbytes.max(self.nbytes());
    }

    /// Retrieve the stashed version from iteration `t`, if still retained.
    pub fn get(&self, t: u64) -> Option<&Tensor> {
        self.entries.iter().find(|(vt, _)| *vt == t).map(|(_, w)| w)
    }

    /// Oldest retained version index.
    pub fn oldest(&self) -> Option<u64> {
        self.entries.front().map(|(t, _)| *t)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Current bytes held.
    pub fn nbytes(&self) -> usize {
        self.entries.iter().map(|(_, w)| w.nbytes()).sum()
    }

    /// High-water mark of bytes held (the memory-footprint metric).
    pub fn peak_nbytes(&self) -> usize {
        self.peak_nbytes
    }
}

/// FIFO stash of per-iteration activations (and any per-batch state) for
/// one layer: pushed at forward time, popped when the matching backward
/// arrives. All pipelined strategies require this — only *weight* state is
/// optimized away by the EMA recompute.
#[derive(Clone, Debug, Default)]
pub struct ActivationStash {
    entries: VecDeque<(u64, Vec<Tensor>)>,
    peak_nbytes: usize,
}

impl ActivationStash {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, t: u64, tensors: Vec<Tensor>) {
        if let Some(&(last, _)) = self.entries.back() {
            assert!(t > last, "activation pushes must be in increasing order");
        }
        self.entries.push_back((t, tensors));
        self.peak_nbytes = self.peak_nbytes.max(self.nbytes());
    }

    /// Pop the activations for iteration `t`. Entries are expected to be
    /// consumed in FIFO order (the pipeline guarantees this); popping out
    /// of order is an error that signals a scheduler bug.
    pub fn pop(&mut self, t: u64) -> Option<Vec<Tensor>> {
        match self.entries.front() {
            Some(&(ft, _)) if ft == t => self.entries.pop_front().map(|(_, v)| v),
            Some(&(ft, _)) => panic!("activation stash out-of-order pop: want {t}, front {ft}"),
            None => None,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn nbytes(&self) -> usize {
        self.entries
            .iter()
            .map(|(_, v)| v.iter().map(Tensor::nbytes).sum::<usize>())
            .sum()
    }

    pub fn peak_nbytes(&self) -> usize {
        self.peak_nbytes
    }
}

/// Aggregate memory report across a model's layers (bytes).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemoryReport {
    pub weight_stash: usize,
    pub activation_stash: usize,
    pub ema_state: usize,
    pub optimizer_state: usize,
    pub weights: usize,
}

impl MemoryReport {
    pub fn total(&self) -> usize {
        self.weight_stash
            + self.activation_stash
            + self.ema_state
            + self.optimizer_state
            + self.weights
    }

    /// Extra state beyond the live weights + optimizer (what the paper's
    /// O(LS)→O(L) claim is about).
    pub fn staleness_overhead(&self) -> usize {
        self.weight_stash + self.ema_state
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(v: f32) -> Tensor {
        Tensor::from_vec(&[2], vec![v, v])
    }

    #[test]
    fn stash_retrieves_within_window() {
        let mut s = WeightStash::new(3);
        for t in 0..5u64 {
            s.push(t, &w(t as f32));
        }
        assert_eq!(s.len(), 3);
        assert!(s.get(1).is_none(), "evicted");
        assert_eq!(s.get(2).unwrap().data()[0], 2.0);
        assert_eq!(s.get(4).unwrap().data()[0], 4.0);
        assert_eq!(s.oldest(), Some(2));
    }

    #[test]
    #[should_panic(expected = "increasing order")]
    fn stash_rejects_out_of_order() {
        let mut s = WeightStash::new(2);
        s.push(3, &w(0.0));
        s.push(2, &w(0.0));
    }

    #[test]
    fn stash_memory_scales_with_capacity() {
        let mut small = WeightStash::new(2);
        let mut large = WeightStash::new(8);
        for t in 0..10u64 {
            small.push(t, &w(0.0));
            large.push(t, &w(0.0));
        }
        assert_eq!(small.nbytes(), 2 * 8);
        assert_eq!(large.nbytes(), 8 * 8);
        assert_eq!(large.peak_nbytes(), 8 * 8);
    }

    #[test]
    fn stash_inherits_storage_dtype_and_halves_bytes() {
        // The stash clones / copy_froms whatever it is handed, so bf16
        // weight history costs half the bytes of f32 — including through
        // the at-capacity slot-recycling path.
        use crate::tensor::Dtype;
        let mut q = WeightStash::new(3);
        let mut full = WeightStash::new(3);
        for t in 0..6u64 {
            q.push(t, &w(t as f32).to_dtype(Dtype::Bf16));
            full.push(t, &w(t as f32));
        }
        assert_eq!(q.nbytes() * 2, full.nbytes());
        assert_eq!(q.peak_nbytes() * 2, full.peak_nbytes());
        let got = q.get(4).unwrap();
        assert_eq!(got.dtype(), Dtype::Bf16);
        assert_eq!(got, &w(4.0).to_dtype(Dtype::Bf16));
    }

    #[test]
    fn activation_fifo_order() {
        let mut a = ActivationStash::new();
        a.push(0, vec![w(0.0)]);
        a.push(1, vec![w(1.0), w(1.5)]);
        assert_eq!(a.nbytes(), 3 * 8);
        let v0 = a.pop(0).unwrap();
        assert_eq!(v0.len(), 1);
        let v1 = a.pop(1).unwrap();
        assert_eq!(v1.len(), 2);
        assert!(a.pop(2).is_none());
        assert_eq!(a.peak_nbytes(), 3 * 8);
    }

    #[test]
    #[should_panic(expected = "out-of-order pop")]
    fn activation_pop_out_of_order_panics() {
        let mut a = ActivationStash::new();
        a.push(0, vec![w(0.0)]);
        a.push(1, vec![w(1.0)]);
        let _ = a.pop(1);
    }

    #[test]
    fn memory_report_totals() {
        let r = MemoryReport {
            weight_stash: 100,
            activation_stash: 50,
            ema_state: 10,
            optimizer_state: 20,
            weights: 20,
        };
        assert_eq!(r.total(), 200);
        assert_eq!(r.staleness_overhead(), 110);
    }
}
