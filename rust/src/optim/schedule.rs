//! Learning-rate schedules. The paper uses cosine annealing from the
//! initial lr over the full training horizon (§IV-A).

/// A learning-rate schedule over global steps.
pub trait LrSchedule: Send + Sync {
    /// Learning rate at 0-indexed global step `t` of `total` steps.
    fn lr(&self, t: usize) -> f32;
}

/// Constant learning rate.
#[derive(Clone, Debug)]
pub struct ConstantLr(pub f32);

impl LrSchedule for ConstantLr {
    fn lr(&self, _t: usize) -> f32 {
        self.0
    }
}

/// Cosine annealing: `min + (max−min)·(1+cos(π·t/T))/2`, clamped at `T`.
#[derive(Clone, Debug)]
pub struct CosineLr {
    pub max_lr: f32,
    pub min_lr: f32,
    pub total_steps: usize,
}

impl CosineLr {
    pub fn new(max_lr: f32, min_lr: f32, total_steps: usize) -> Self {
        assert!(total_steps > 0);
        CosineLr { max_lr, min_lr, total_steps }
    }
}

impl LrSchedule for CosineLr {
    fn lr(&self, t: usize) -> f32 {
        let t = t.min(self.total_steps) as f32;
        let frac = t / self.total_steps as f32;
        let cos = (std::f32::consts::PI * frac).cos();
        self.min_lr + (self.max_lr - self.min_lr) * 0.5 * (1.0 + cos)
    }
}

/// A schedule plus lazily-grown prefix sums of its learning rates:
/// `prefix[t] = Σ_{τ<t} lr(τ)` in f64, giving the exact `lr_sum` of the
/// paper's Eq. 9 under arbitrary schedules. Shared by the iteration-
/// indexed trainer and the threaded pipelined executor so both compute
/// bit-identical reconstruction sums.
pub struct LrBook {
    sched: Box<dyn LrSchedule>,
    prefix: Vec<f64>,
}

impl LrBook {
    pub fn new(sched: Box<dyn LrSchedule>) -> LrBook {
        LrBook { sched, prefix: vec![0.0] }
    }

    fn grow(&mut self, upto: u64) {
        while self.prefix.len() <= upto as usize {
            let t = self.prefix.len() - 1;
            let last = *self.prefix.last().expect("nonempty prefix");
            self.prefix.push(last + self.sched.lr(t) as f64);
        }
    }

    /// Learning rate at step `t`, growing the prefix through `t`.
    pub fn lr(&mut self, t: u64) -> f32 {
        self.grow(t + 1);
        self.sched.lr(t as usize)
    }

    /// Learning rate at step `t` without touching the prefix (reporting).
    pub fn peek(&self, t: u64) -> f32 {
        self.sched.lr(t as usize)
    }

    /// `Σ lr(τ)` for `τ ∈ [t0, t1)` — the `lr_sum` of Eq. 9.
    pub fn lr_sum(&mut self, t0: u64, t1: u64) -> f32 {
        self.grow(t1);
        (self.prefix[t1 as usize] - self.prefix[t0 as usize]) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_book_sums_match_direct_accumulation() {
        let mut book = LrBook::new(Box::new(CosineLr::new(0.1, 0.001, 50)));
        let direct: f64 = (10..30).map(|t| book.peek(t) as f64).sum();
        assert!((book.lr_sum(10, 30) as f64 - direct).abs() < 1e-6);
        assert_eq!(book.lr_sum(7, 7), 0.0);
        assert_eq!(book.lr(3), book.peek(3));
    }

    #[test]
    fn cosine_endpoints() {
        let s = CosineLr::new(0.1, 0.001, 100);
        assert!((s.lr(0) - 0.1).abs() < 1e-7);
        assert!((s.lr(100) - 0.001).abs() < 1e-7);
        assert!((s.lr(50) - 0.0505).abs() < 1e-4);
    }

    #[test]
    fn cosine_is_monotone_decreasing() {
        let s = CosineLr::new(0.1, 0.0, 200);
        let mut prev = f32::INFINITY;
        for t in 0..=200 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-9, "t={t}");
            prev = lr;
        }
    }

    #[test]
    fn cosine_clamps_past_horizon() {
        let s = CosineLr::new(0.1, 0.01, 10);
        assert_eq!(s.lr(10), s.lr(999));
    }

    #[test]
    fn constant_is_constant() {
        let s = ConstantLr(0.05);
        assert_eq!(s.lr(0), s.lr(12345));
    }
}
