//! First-order optimizers and learning-rate schedules.
//!
//! The paper trains with SGD + momentum + weight decay under cosine
//! annealing (§IV-A). The optimizer exposes the *applied update vector*
//! `U(t) = (W(t) − W(t+1)) / lr(t)` to callers, because the weight
//! recompute rule (paper Eq. 3, generalized in DESIGN.md) averages applied
//! updates rather than raw gradients so it remains exact under momentum
//! and weight decay.

mod schedule;
mod sgd;

pub use schedule::{ConstantLr, CosineLr, LrBook, LrSchedule};
pub use sgd::Sgd;

use crate::tensor::Tensor;

/// A first-order optimizer over one parameter tensor.
pub trait Optimizer {
    /// Apply `grad` to `weights` at the current step with learning rate
    /// `lr`. Returns a borrow of the applied update vector `U` (owned by
    /// the optimizer's state — no per-step clone on the hot path) such
    /// that `W_new = W_old − lr · U`.
    fn step(&mut self, weights: &mut Tensor, grad: &Tensor, lr: f32) -> &Tensor;

    /// Bytes of optimizer state (for the memory-footprint experiment).
    fn state_nbytes(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn update_vector_identity_holds() {
        // W_new must equal W_old − lr·U for whatever U the optimizer
        // reports — the identity the EMA weight recompute relies on.
        let mut rng = crate::util::Rng::new(2);
        let mut sgd = Sgd::new(&[4], 0.9, 5e-4);
        let mut w = Tensor::randn(&[4], 1.0, &mut rng);
        for _ in 0..10 {
            let g = Tensor::randn(&[4], 1.0, &mut rng);
            let w_old = w.clone();
            let u = sgd.step(&mut w, &g, 0.1).clone();
            let mut recon = w.clone();
            recon.axpy(0.1, &u);
            assert!(recon.max_abs_diff(&w_old) < 1e-6);
        }
    }
}
