//! SGD with momentum and decoupled-style weight decay (PyTorch semantics:
//! weight decay is added to the gradient before the momentum buffer).

use super::Optimizer;
use crate::tensor::Tensor;

/// `v ← μ·v + (g + wd·w)`; `w ← w − lr·v`.
#[derive(Clone, Debug)]
pub struct Sgd {
    velocity: Tensor,
    momentum: f32,
    weight_decay: f32,
    steps: u64,
}

impl Sgd {
    pub fn new(shape: &[usize], momentum: f32, weight_decay: f32) -> Self {
        Sgd { velocity: Tensor::zeros(shape), momentum, weight_decay, steps: 0 }
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// The momentum buffer — equal to the last applied update `U`.
    /// Mixed-precision trainers step the f32 *master* weights and then
    /// re-quantize, so they need the update by accessor rather than via
    /// [`Optimizer::step`]'s return borrow (which the master step holds).
    pub fn velocity(&self) -> &Tensor {
        &self.velocity
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, weights: &mut Tensor, grad: &Tensor, lr: f32) -> &Tensor {
        assert_eq!(weights.shape(), grad.shape(), "sgd shape mismatch");
        let mu = self.momentum;
        let wd = self.weight_decay;
        for ((v, g), w) in self
            .velocity
            .data_mut()
            .iter_mut()
            .zip(grad.data().iter())
            .zip(weights.data().iter())
        {
            *v = mu * *v + (g + wd * w);
        }
        // Applied update U = velocity; W ← W − lr·U. Returned by borrow:
        // the EMA accumulators copy what they need, so the hot path pays
        // no per-step clone.
        for (w, v) in weights.data_mut().iter_mut().zip(self.velocity.data().iter()) {
            *w -= lr * v;
        }
        self.steps += 1;
        &self.velocity
    }

    fn state_nbytes(&self) -> usize {
        self.velocity.nbytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_matches_hand_calc() {
        let mut sgd = Sgd::new(&[2], 0.0, 0.0);
        let mut w = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let g = Tensor::from_vec(&[2], vec![0.5, -0.5]);
        sgd.step(&mut w, &g, 0.1);
        assert_eq!(w.data(), &[0.95, 2.05]);
    }

    #[test]
    fn momentum_accumulates() {
        let mut sgd = Sgd::new(&[1], 0.5, 0.0);
        let mut w = Tensor::from_vec(&[1], vec![0.0]);
        let g = Tensor::from_vec(&[1], vec![1.0]);
        sgd.step(&mut w, &g, 1.0); // v=1, w=-1
        sgd.step(&mut w, &g, 1.0); // v=1.5, w=-2.5
        assert!((w.data()[0] + 2.5).abs() < 1e-6);
    }

    #[test]
    fn weight_decay_shrinks_weights() {
        let mut sgd = Sgd::new(&[1], 0.0, 0.1);
        let mut w = Tensor::from_vec(&[1], vec![10.0]);
        let g = Tensor::zeros(&[1]);
        sgd.step(&mut w, &g, 0.1);
        // v = 0.1*10 = 1; w = 10 - 0.1*1 = 9.9
        assert!((w.data()[0] - 9.9).abs() < 1e-6);
    }

    #[test]
    fn state_accounting() {
        let sgd = Sgd::new(&[8, 8], 0.9, 0.0);
        assert_eq!(sgd.state_nbytes(), 256);
    }

    #[test]
    fn master_step_then_requantize_is_the_mixed_precision_update() {
        // The bf16 training step: the optimizer touches only the f32
        // master copy; the bf16 storage weights are re-quantized from it.
        // velocity() must expose the same update step() returned.
        use crate::tensor::Dtype;
        let mut sgd = Sgd::new(&[2], 0.9, 0.0);
        let mut master = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let mut stored = master.to_dtype(Dtype::Bf16);
        let g = Tensor::from_vec(&[2], vec![0.5, -0.5]);
        let upd = sgd.step(&mut master, &g, 0.1).clone();
        assert_eq!(&upd, sgd.velocity());
        stored.quantize_from(&master);
        assert_eq!(stored.dtype(), Dtype::Bf16);
        for i in 0..2 {
            assert_eq!(stored.get(i), crate::tensor::bf16_round(master.get(i)));
        }
    }
}
