//! Process-wide runtime telemetry (DESIGN.md §12).
//!
//! A single registry of lock-free, allocation-free instruments shared by
//! every runtime in the crate:
//!
//! - **Counters** — monotonic relaxed `AtomicU64`s ([`counter`],
//!   [`LazyCounter`] for static call sites).
//! - **Gauges** — signed levels ([`gauge`]): queue depths, in-flight
//!   request counts.
//! - **Histograms** — fixed 256-bucket log-scale (`2` sub-bucket bits,
//!   ≤25% relative bucket error) nanosecond distributions ([`hist`]):
//!   p50/p90/p99 derivable from the buckets, no sample storage.
//! - **Spans** — scoped timers ([`span!`]) aggregating into
//!   per-(thread, label) duration sums. Thread slots are interned by
//!   *logical* thread name ([`set_thread_name`]) so per-epoch respawned
//!   pipeline stage threads keep accumulating into the same slot.
//!
//! Cost discipline: a counter bump is one relaxed `fetch_add`; a span is
//! two `Instant::now()` reads plus two relaxed `fetch_add`s when
//! enabled, and a **single relaxed load** when disabled
//! (`LAYERPIPE2_OBS=off`, or [`set_enabled`]). Counters, gauges and
//! histogram records are *always* on — they are pure atomics with no
//! clock reads, and the stat-struct views over the registry
//! (`scratch_stats`, `Server::stats`, …) must stay correct regardless
//! of the span gate. Instruments allocate only at registration (leaked
//! `'static` inners); the steady-state hot path allocates nothing
//! (asserted by `alloc_steady_state.rs`).
//!
//! Determinism contract: observability **reads clocks, never branches
//! on them** — no measurement feeds back into scheduling, batching or
//! kernel dispatch, so all numeric results are bitwise-identical with
//! obs on, off, or compiled out.
//!
//! Export surfaces: [`TelemetrySnapshot`] (typed, diffable between two
//! points), its `Display` table (`[stats] …` lines for the CLI), JSON
//! via [`crate::util::json`] for `BENCH_*.json` ride-alongs, and an
//! optional Chrome-trace-format span dump ([`trace_begin`] /
//! [`trace_end_to_json`], wired to `LAYERPIPE2_TRACE=<path>` by the
//! CLI) for flame-style inspection in `chrome://tracing` / Perfetto.

use crate::util::json::Json;
use crate::util::timer::{fmt_duration, process_anchor};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Env var gating span timing (`off`/`0`/`false` disable; default on).
/// Counters/gauges/histograms are not gated — they never read clocks.
pub const OBS_ENV: &str = "LAYERPIPE2_OBS";

/// Env var naming a file path for the Chrome-trace span dump (read by
/// the CLI entry point, not by this module).
pub const TRACE_ENV: &str = "LAYERPIPE2_TRACE";

/// Distinct span labels the process can register; labels past the cap
/// are counted in the `obs/labels_dropped` counter and not timed.
pub const MAX_SPAN_LABELS: usize = 64;

const HIST_BUCKETS: usize = 256;

/// Trace events retained per [`trace_begin`]/[`trace_end_to_json`]
/// window (preallocated; overflow is dropped and counted, never grows).
const TRACE_CAP: usize = 1 << 16;

/// Sentinel label id for spans past [`MAX_SPAN_LABELS`].
const DROPPED_LABEL: u32 = u32::MAX;

// ---------------------------------------------------------------------------
// Enable gate.
// ---------------------------------------------------------------------------

/// 255 = uninitialised; 0 = off; 1 = on (same lazy-init idiom as
/// `util::log::LEVEL`).
static ENABLED: AtomicU8 = AtomicU8::new(255);

/// Whether span timing is enabled. The hot-path fast gate: a single
/// relaxed load after the first (lazy, env-reading) call.
#[inline]
pub fn enabled() -> bool {
    match ENABLED.load(Ordering::Relaxed) {
        255 => init_enabled(),
        v => v == 1,
    }
}

#[cold]
fn init_enabled() -> bool {
    let on = !matches!(
        std::env::var(OBS_ENV).ok().as_deref(),
        Some("off" | "0" | "false")
    );
    ENABLED.store(u8::from(on), Ordering::Relaxed);
    on
}

/// Programmatic override of the span gate (tests and benches toggle
/// this instead of the environment, which is unsafe to mutate with
/// threads running).
pub fn set_enabled(on: bool) {
    ENABLED.store(u8::from(on), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Registry.
// ---------------------------------------------------------------------------

struct HistInner {
    count: AtomicU64,
    sum_ns: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

/// Per-(thread, label) span aggregation slot. Interned by logical
/// thread name — `'static`, shared by every OS thread claiming the name.
struct ThreadSlot {
    name: String,
    /// 1-based trace thread id (0 is never used; Chrome treats tid 0 as
    /// the process row).
    tid: u32,
    sums_ns: [AtomicU64; MAX_SPAN_LABELS],
    counts: [AtomicU64; MAX_SPAN_LABELS],
}

struct Registry {
    counters: Mutex<BTreeMap<String, &'static AtomicU64>>,
    gauges: Mutex<BTreeMap<String, &'static AtomicI64>>,
    hists: Mutex<BTreeMap<String, &'static HistInner>>,
    /// Registered span label names, indexed by label id.
    labels: Mutex<Vec<&'static str>>,
    slots: Mutex<Vec<&'static ThreadSlot>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        hists: Mutex::new(BTreeMap::new()),
        labels: Mutex::new(Vec::new()),
        slots: Mutex::new(Vec::new()),
    })
}

/// A monotonic counter handle: `Copy`, bump is one relaxed `fetch_add`.
#[derive(Clone, Copy)]
pub struct Counter(&'static AtomicU64);

impl Counter {
    #[inline]
    pub fn inc(self) {
        self.add(1);
    }

    #[inline]
    pub fn add(self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn value(self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A signed level gauge handle (queue depths, in-flight counts).
#[derive(Clone, Copy)]
pub struct Gauge(&'static AtomicI64);

impl Gauge {
    #[inline]
    pub fn add(self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn set(self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn value(self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A log-scale nanosecond histogram handle.
#[derive(Clone, Copy)]
pub struct Hist(&'static HistInner);

impl Hist {
    #[inline]
    pub fn record_ns(self, ns: u64) {
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.0.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn record_secs(self, secs: f64) {
        self.record_ns((secs * 1e9) as u64);
    }

    pub fn count(self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of this histogram (for quantiles without going
    /// through a full [`TelemetrySnapshot`]).
    pub fn snapshot(self) -> HistSnapshot {
        HistSnapshot {
            count: self.0.count.load(Ordering::Relaxed),
            sum_ns: self.0.sum_ns.load(Ordering::Relaxed),
            buckets: self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
        }
    }
}

/// Register (or fetch) the counter named `name`. Same name ⇒ same
/// instrument, process-wide; the inner is leaked once at registration.
pub fn counter(name: &str) -> Counter {
    let mut map = registry().counters.lock().expect("obs counters lock");
    if let Some(c) = map.get(name) {
        return Counter(c);
    }
    let inner: &'static AtomicU64 = Box::leak(Box::new(AtomicU64::new(0)));
    map.insert(name.to_string(), inner);
    Counter(inner)
}

/// Register (or fetch) the gauge named `name`.
pub fn gauge(name: &str) -> Gauge {
    let mut map = registry().gauges.lock().expect("obs gauges lock");
    if let Some(g) = map.get(name) {
        return Gauge(g);
    }
    let inner: &'static AtomicI64 = Box::leak(Box::new(AtomicI64::new(0)));
    map.insert(name.to_string(), inner);
    Gauge(inner)
}

/// Register (or fetch) the histogram named `name`.
pub fn hist(name: &str) -> Hist {
    let mut map = registry().hists.lock().expect("obs hists lock");
    if let Some(h) = map.get(name) {
        return Hist(h);
    }
    let inner: &'static HistInner = Box::leak(Box::new(HistInner {
        count: AtomicU64::new(0),
        sum_ns: AtomicU64::new(0),
        buckets: std::array::from_fn(|_| AtomicU64::new(0)),
    }));
    map.insert(name.to_string(), inner);
    Hist(inner)
}

/// Current value of the counter named `name` (0 if never registered) —
/// the accessor behind the thin stat-struct views.
pub fn counter_value(name: &str) -> u64 {
    registry()
        .counters
        .lock()
        .expect("obs counters lock")
        .get(name)
        .map_or(0, |c| c.load(Ordering::Relaxed))
}

/// A counter with a `const`-constructible static call site: the name
/// resolves to its registry entry once (`OnceLock`), after which every
/// bump is a load + relaxed `fetch_add`.
pub struct LazyCounter {
    name: &'static str,
    cell: OnceLock<Counter>,
}

impl LazyCounter {
    pub const fn new(name: &'static str) -> LazyCounter {
        LazyCounter { name, cell: OnceLock::new() }
    }

    #[inline]
    pub fn get(&self) -> Counter {
        *self.cell.get_or_init(|| counter(self.name))
    }

    #[inline]
    pub fn inc(&self) {
        self.get().inc();
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.get().add(n);
    }

    pub fn value(&self) -> u64 {
        self.get().value()
    }
}

// ---------------------------------------------------------------------------
// Histogram bucket geometry: log-scale with 2 sub-bucket bits
// (HdrHistogram-lite). Values 0..=3 map directly; larger values index
// by (exponent, top-2 mantissa bits), so each power of two splits into
// 4 sub-buckets — worst-case relative bucket width 25%.
// ---------------------------------------------------------------------------

fn bucket_index(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let exp = 63 - u64::from(v.leading_zeros());
    (((exp << 2) | ((v >> (exp - 2)) & 3)) as usize).min(HIST_BUCKETS - 1)
}

/// Lower bound of bucket `idx` — the deterministic quantile
/// representative (reported quantiles round *down* to a bucket floor).
fn bucket_floor(idx: usize) -> u64 {
    if idx < 4 {
        return idx as u64;
    }
    let exp = (idx >> 2) as u64;
    let sub = (idx & 3) as u64;
    (1u64 << exp) | (sub << (exp - 2))
}

// ---------------------------------------------------------------------------
// Spans.
// ---------------------------------------------------------------------------

/// A span label's static call-site registration: the name resolves to a
/// small integer id once; after that entering the span is id load +
/// thread-slot load + `Instant::now()`.
pub struct SpanLabel {
    name: &'static str,
    id: OnceLock<u32>,
}

static LABELS_DROPPED: LazyCounter = LazyCounter::new("obs/labels_dropped");

impl SpanLabel {
    pub const fn new(name: &'static str) -> SpanLabel {
        SpanLabel { name, id: OnceLock::new() }
    }

    fn resolve(&self) -> u32 {
        *self.id.get_or_init(|| {
            let mut tbl = registry().labels.lock().expect("obs labels lock");
            if let Some(pos) = tbl.iter().position(|&n| n == self.name) {
                return pos as u32;
            }
            if tbl.len() >= MAX_SPAN_LABELS {
                LABELS_DROPPED.inc();
                return DROPPED_LABEL;
            }
            tbl.push(self.name);
            (tbl.len() - 1) as u32
        })
    }
}

fn intern_slot(name: &str) -> &'static ThreadSlot {
    let mut slots = registry().slots.lock().expect("obs slots lock");
    if let Some(s) = slots.iter().find(|s| s.name == name) {
        return s;
    }
    let slot: &'static ThreadSlot = Box::leak(Box::new(ThreadSlot {
        name: name.to_string(),
        tid: slots.len() as u32 + 1,
        sums_ns: std::array::from_fn(|_| AtomicU64::new(0)),
        counts: std::array::from_fn(|_| AtomicU64::new(0)),
    }));
    slots.push(slot);
    slot
}

thread_local! {
    static SLOT: std::cell::Cell<Option<&'static ThreadSlot>> =
        const { std::cell::Cell::new(None) };
}

static ANON_SEQ: AtomicU32 = AtomicU32::new(0);

/// Bind the calling OS thread to the logical slot `name`. Spans entered
/// on this thread aggregate there; threads respawned per epoch under
/// the same name keep accumulating into the same slot. Unbound threads
/// default to their OS thread name, or `thread-N`.
pub fn set_thread_name(name: &str) {
    let slot = intern_slot(name);
    SLOT.with(|c| c.set(Some(slot)));
}

fn current_slot() -> &'static ThreadSlot {
    SLOT.with(|c| match c.get() {
        Some(s) => s,
        None => {
            let t = std::thread::current();
            let slot = match t.name() {
                Some(n) => intern_slot(n),
                None => {
                    let n = ANON_SEQ.fetch_add(1, Ordering::Relaxed);
                    intern_slot(&format!("thread-{n}"))
                }
            };
            c.set(Some(slot));
            slot
        }
    })
}

struct Armed {
    slot: &'static ThreadSlot,
    id: u32,
    start: Instant,
}

/// RAII span timer: created by [`span!`], records on drop. Nested spans
/// each record their *own* full duration (self + children) — the
/// breakdown reports pick non-overlapping labels, and the Chrome trace
/// shows the nesting directly.
pub struct SpanGuard {
    armed: Option<Armed>,
}

impl SpanGuard {
    #[inline]
    pub fn enter(label: &SpanLabel) -> SpanGuard {
        if !enabled() {
            return SpanGuard { armed: None };
        }
        let id = label.resolve();
        let slot = current_slot();
        // Clock read last: registration/interning cost stays outside the
        // measured window.
        SpanGuard { armed: Some(Armed { slot, id, start: Instant::now() }) }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if let Some(a) = self.armed.take() {
            let ns = a.start.elapsed().as_nanos() as u64;
            if a.id != DROPPED_LABEL {
                a.slot.sums_ns[a.id as usize].fetch_add(ns, Ordering::Relaxed);
                a.slot.counts[a.id as usize].fetch_add(1, Ordering::Relaxed);
                if TRACE_ON.load(Ordering::Relaxed) {
                    push_trace_event(a.id, a.slot.tid, a.start, ns);
                }
            }
        }
    }
}

/// Scoped span timer: `obs::span!("stage3/backward");` times from the
/// statement to the end of the enclosing block. Statically registers
/// the label at the call site; when the gate is off the whole statement
/// is a single relaxed load.
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        let _obs_span_guard = {
            static __OBS_SPAN_LABEL: $crate::obs::SpanLabel = $crate::obs::SpanLabel::new($name);
            $crate::obs::SpanGuard::enter(&__OBS_SPAN_LABEL)
        };
    };
}

pub use crate::obs_span as span;

// ---------------------------------------------------------------------------
// Chrome trace dump.
// ---------------------------------------------------------------------------

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE_DROPPED: AtomicU64 = AtomicU64::new(0);

#[derive(Clone, Copy)]
struct TraceEvent {
    label: u32,
    tid: u32,
    /// Nanoseconds since [`process_anchor`].
    start_ns: u64,
    dur_ns: u64,
}

fn trace_buf() -> &'static Mutex<Vec<TraceEvent>> {
    static BUF: OnceLock<Mutex<Vec<TraceEvent>>> = OnceLock::new();
    BUF.get_or_init(|| Mutex::new(Vec::new()))
}

fn push_trace_event(label: u32, tid: u32, start: Instant, dur_ns: u64) {
    let start_ns = start
        .checked_duration_since(process_anchor())
        .unwrap_or_default()
        .as_nanos() as u64;
    let mut buf = trace_buf().lock().expect("obs trace lock");
    if buf.len() < TRACE_CAP {
        buf.push(TraceEvent { label, tid, start_ns, dur_ns });
    } else {
        TRACE_DROPPED.fetch_add(1, Ordering::Relaxed);
    }
}

/// Start capturing span events for a Chrome-trace dump: clears any prior
/// window, preallocates the buffer (span recording stays
/// allocation-free), and arms the trace gate. Timestamps are relative to
/// [`process_anchor`], initialised here if not earlier.
pub fn trace_begin() {
    process_anchor();
    let mut buf = trace_buf().lock().expect("obs trace lock");
    buf.clear();
    buf.reserve(TRACE_CAP);
    TRACE_DROPPED.store(0, Ordering::Relaxed);
    TRACE_ON.store(true, Ordering::Relaxed);
}

/// Whether a trace window is currently armed.
pub fn trace_active() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Disarm the trace gate and drain the captured window into a
/// Chrome-trace-format (`trace_events`) JSON document: complete (`"X"`)
/// events sorted by `(tid, start)` — per-thread timestamps are
/// monotonically nondecreasing, with enclosing spans first at ties —
/// plus thread-name metadata (`"M"`) events. `ts`/`dur` are
/// microseconds (the format's unit), as exact ns/1000 fractions.
pub fn trace_end_to_json() -> Json {
    TRACE_ON.store(false, Ordering::Relaxed);
    let mut events = {
        let mut buf = trace_buf().lock().expect("obs trace lock");
        std::mem::take(&mut *buf)
    };
    // Enclosing spans sort before their children at equal start.
    events.sort_by_key(|e| (e.tid, e.start_ns, u64::MAX - e.dur_ns));
    let labels: Vec<&'static str> = registry().labels.lock().expect("obs labels lock").clone();
    let slot_names: BTreeMap<u32, String> = registry()
        .slots
        .lock()
        .expect("obs slots lock")
        .iter()
        .map(|s| (s.tid, s.name.clone()))
        .collect();

    let mut arr: Vec<Json> = Vec::with_capacity(events.len() + slot_names.len());
    let mut seen_tids: Vec<u32> = events.iter().map(|e| e.tid).collect();
    seen_tids.sort_unstable();
    seen_tids.dedup();
    for tid in &seen_tids {
        let mut args = BTreeMap::new();
        args.insert(
            "name".to_string(),
            Json::Str(slot_names.get(tid).cloned().unwrap_or_default()),
        );
        let mut m = BTreeMap::new();
        m.insert("ph".to_string(), Json::Str("M".to_string()));
        m.insert("pid".to_string(), Json::Num(1.0));
        m.insert("tid".to_string(), Json::Num(f64::from(*tid)));
        m.insert("name".to_string(), Json::Str("thread_name".to_string()));
        m.insert("args".to_string(), Json::Obj(args));
        arr.push(Json::Obj(m));
    }
    for e in &events {
        let mut m = BTreeMap::new();
        m.insert("ph".to_string(), Json::Str("X".to_string()));
        m.insert("pid".to_string(), Json::Num(1.0));
        m.insert("tid".to_string(), Json::Num(f64::from(e.tid)));
        m.insert(
            "name".to_string(),
            Json::Str(labels.get(e.label as usize).copied().unwrap_or("?").to_string()),
        );
        m.insert("ts".to_string(), Json::Num(e.start_ns as f64 / 1000.0));
        m.insert("dur".to_string(), Json::Num(e.dur_ns as f64 / 1000.0));
        arr.push(Json::Obj(m));
    }
    let mut top = BTreeMap::new();
    top.insert("traceEvents".to_string(), Json::Arr(arr));
    top.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    top.insert(
        "spansDropped".to_string(),
        Json::Num(TRACE_DROPPED.load(Ordering::Relaxed) as f64),
    );
    Json::Obj(top)
}

// ---------------------------------------------------------------------------
// Snapshots.
// ---------------------------------------------------------------------------

/// One histogram's state at a point in time (diffable bucket-wise).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum_ns: u64,
    buckets: Vec<u64>,
}

impl HistSnapshot {
    /// The quantile-`q` value in ns (bucket floor; 0 when empty).
    ///
    /// The rank is computed from the *bucket sum*, not `count`: captures
    /// read relaxed atomics one by one, so a concurrent `record` can be
    /// visible in `count` before its bucket increment is — and diffing
    /// two such torn captures (`since`) makes the shortfall routine. A
    /// rank derived from `count` can then exceed the bucket sum, fall
    /// through the scan, and report the top-bucket floor — spiking
    /// windowed p99 by orders of magnitude and spuriously triggering the
    /// serving AIMD multiplicative decrease. Ranking over the bucket sum
    /// keeps the quantile a statement about the records actually visible
    /// in the buckets; consistent snapshots (sum == count) are
    /// unchanged.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_floor(i);
            }
        }
        bucket_floor(HIST_BUCKETS - 1)
    }

    /// Test-only constructor for deliberately inconsistent snapshots —
    /// `count` disagreeing with the bucket sum, the shape a torn
    /// relaxed-atomic capture produces. `entries` is `(value_ns, n)`
    /// pairs routed through the real bucket mapping.
    #[cfg(test)]
    pub(crate) fn synthetic(count: u64, sum_ns: u64, entries: &[(u64, u64)]) -> HistSnapshot {
        let mut buckets = vec![0u64; HIST_BUCKETS];
        for &(v, n) in entries {
            buckets[bucket_index(v)] += n;
        }
        HistSnapshot { count, sum_ns, buckets }
    }

    pub fn mean_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.sum_ns / self.count
        }
    }

    /// The change since `earlier` (saturating, bucket-wise): windowed
    /// quantiles for consumers that sample a live histogram
    /// periodically — the serving AIMD batch controller diffs
    /// consecutive snapshots so its p99 reflects *recent* requests, not
    /// the full history.
    pub fn since(&self, earlier: &HistSnapshot) -> HistSnapshot {
        let mut d = self.clone();
        d.count = d.count.saturating_sub(earlier.count);
        d.sum_ns = d.sum_ns.saturating_sub(earlier.sum_ns);
        for (b, eb) in d.buckets.iter_mut().zip(&earlier.buckets) {
            *b = b.saturating_sub(*eb);
        }
        d
    }
}

/// One (thread, label) span aggregate at a point in time.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanSnapshot {
    pub count: u64,
    pub total_ns: u64,
}

/// A typed capture of every registered instrument. Diffable
/// ([`TelemetrySnapshot::diff`]) to scope measurements to an epoch, a
/// bench section, or a serve window; printable as a `[stats]` table;
/// exportable as JSON.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, i64>,
    pub hists: BTreeMap<String, HistSnapshot>,
    /// thread name → span label → aggregate.
    pub spans: BTreeMap<String, BTreeMap<String, SpanSnapshot>>,
}

impl TelemetrySnapshot {
    /// Capture the current value of every registered instrument.
    pub fn capture() -> TelemetrySnapshot {
        let reg = registry();
        let counters = reg
            .counters
            .lock()
            .expect("obs counters lock")
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = reg
            .gauges
            .lock()
            .expect("obs gauges lock")
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let hists = reg
            .hists
            .lock()
            .expect("obs hists lock")
            .iter()
            .map(|(k, h)| {
                (
                    k.clone(),
                    HistSnapshot {
                        count: h.count.load(Ordering::Relaxed),
                        sum_ns: h.sum_ns.load(Ordering::Relaxed),
                        buckets: h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
                    },
                )
            })
            .collect();
        let labels: Vec<&'static str> = reg.labels.lock().expect("obs labels lock").clone();
        let mut spans: BTreeMap<String, BTreeMap<String, SpanSnapshot>> = BTreeMap::new();
        for slot in reg.slots.lock().expect("obs slots lock").iter() {
            let mut per = BTreeMap::new();
            for (i, label) in labels.iter().enumerate() {
                let count = slot.counts[i].load(Ordering::Relaxed);
                if count > 0 {
                    per.insert(
                        (*label).to_string(),
                        SpanSnapshot { count, total_ns: slot.sums_ns[i].load(Ordering::Relaxed) },
                    );
                }
            }
            if !per.is_empty() {
                spans.insert(slot.name.clone(), per);
            }
        }
        TelemetrySnapshot { counters, gauges, hists, spans }
    }

    /// The change since `earlier`: counters/histograms/spans subtract
    /// (saturating; instruments registered since appear as-is), gauges
    /// keep the later level (a gauge is a state, not a rate).
    pub fn diff(&self, earlier: &TelemetrySnapshot) -> TelemetrySnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| (k.clone(), v.saturating_sub(earlier.counters.get(k).copied().unwrap_or(0))))
            .collect();
        let hists = self
            .hists
            .iter()
            .map(|(k, h)| {
                let d = match earlier.hists.get(k) {
                    Some(e) => h.since(e),
                    None => h.clone(),
                };
                (k.clone(), d)
            })
            .collect();
        let mut spans: BTreeMap<String, BTreeMap<String, SpanSnapshot>> = BTreeMap::new();
        for (thread, per) in &self.spans {
            let eper = earlier.spans.get(thread);
            let mut out = BTreeMap::new();
            for (label, s) in per {
                let e = eper.and_then(|p| p.get(label)).copied().unwrap_or_default();
                let d = SpanSnapshot {
                    count: s.count.saturating_sub(e.count),
                    total_ns: s.total_ns.saturating_sub(e.total_ns),
                };
                if d.count > 0 || d.total_ns > 0 {
                    out.insert(label.clone(), d);
                }
            }
            if !out.is_empty() {
                spans.insert(thread.clone(), out);
            }
        }
        TelemetrySnapshot { counters, gauges: self.gauges.clone(), hists, spans }
    }

    /// The aggregate for span `label` on logical thread `thread`.
    pub fn span(&self, thread: &str, label: &str) -> SpanSnapshot {
        self.spans
            .get(thread)
            .and_then(|p| p.get(label))
            .copied()
            .unwrap_or_default()
    }

    /// JSON export for `BENCH_*.json` ride-alongs: counters and gauges
    /// verbatim, histograms as count/sum/p50/p90/p99, spans nested by
    /// thread. Deterministic key order (`BTreeMap` throughout).
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .iter()
            .filter(|(_, &v)| v != 0)
            .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .iter()
            .map(|(k, &v)| (k.clone(), Json::Num(v as f64)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .hists
            .iter()
            .filter(|(_, h)| h.count > 0)
            .map(|(k, h)| {
                let mut m = BTreeMap::new();
                m.insert("count".to_string(), Json::Num(h.count as f64));
                m.insert("sum_ns".to_string(), Json::Num(h.sum_ns as f64));
                m.insert("p50_ns".to_string(), Json::Num(h.quantile_ns(0.50) as f64));
                m.insert("p90_ns".to_string(), Json::Num(h.quantile_ns(0.90) as f64));
                m.insert("p99_ns".to_string(), Json::Num(h.quantile_ns(0.99) as f64));
                (k.clone(), Json::Obj(m))
            })
            .collect();
        let spans: BTreeMap<String, Json> = self
            .spans
            .iter()
            .map(|(thread, per)| {
                let inner: BTreeMap<String, Json> = per
                    .iter()
                    .map(|(label, s)| {
                        let mut m = BTreeMap::new();
                        m.insert("count".to_string(), Json::Num(s.count as f64));
                        m.insert("total_ns".to_string(), Json::Num(s.total_ns as f64));
                        (label.clone(), Json::Obj(m))
                    })
                    .collect();
                (thread.clone(), Json::Obj(inner))
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("counters".to_string(), Json::Obj(counters));
        top.insert("gauges".to_string(), Json::Obj(gauges));
        top.insert("hists".to_string(), Json::Obj(hists));
        top.insert("spans".to_string(), Json::Obj(spans));
        Json::Obj(top)
    }
}

impl fmt::Display for TelemetrySnapshot {
    /// The CLI `[stats]` table: one greppable line per live instrument
    /// (zero counters and empty histograms are elided; gauges always
    /// print — a zero queue depth is information).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (name, v) in &self.counters {
            if *v != 0 {
                writeln!(f, "[stats] counter {name} = {v}")?;
            }
        }
        for (name, v) in &self.gauges {
            writeln!(f, "[stats] gauge   {name} = {v}")?;
        }
        for (name, h) in &self.hists {
            if h.count > 0 {
                writeln!(
                    f,
                    "[stats] hist    {name}: n={} mean={} p50={} p90={} p99={}",
                    h.count,
                    fmt_duration(h.mean_ns() as f64 * 1e-9),
                    fmt_duration(h.quantile_ns(0.50) as f64 * 1e-9),
                    fmt_duration(h.quantile_ns(0.90) as f64 * 1e-9),
                    fmt_duration(h.quantile_ns(0.99) as f64 * 1e-9),
                )?;
            }
        }
        for (thread, per) in &self.spans {
            for (label, s) in per {
                writeln!(
                    f,
                    "[stats] span    {thread} {label}: n={} total={} mean={}",
                    s.count,
                    fmt_duration(s.total_ns as f64 * 1e-9),
                    fmt_duration(s.total_ns as f64 * 1e-9 / s.count.max(1) as f64),
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_views_share_the_registry() {
        let c = counter("test/obs_counter");
        c.add(3);
        c.inc();
        // Same name ⇒ same instrument.
        assert_eq!(counter("test/obs_counter").value(), 4);
        assert_eq!(counter_value("test/obs_counter"), 4);
        assert_eq!(counter_value("test/never_registered"), 0);
        let g = gauge("test/obs_gauge");
        g.add(5);
        g.sub(2);
        assert_eq!(gauge("test/obs_gauge").value(), 3);
        g.set(-1);
        assert_eq!(g.value(), -1);
        static LAZY: LazyCounter = LazyCounter::new("test/obs_lazy");
        LAZY.inc();
        LAZY.add(9);
        assert_eq!(counter_value("test/obs_lazy"), 10);
    }

    #[test]
    fn histogram_buckets_are_log_scale_and_quantiles_round_down() {
        // Bucket geometry: floors are reachable and ordered.
        for v in [0u64, 1, 2, 3, 4, 5, 7, 8, 1000, 123_456, u64::MAX / 2] {
            let idx = bucket_index(v);
            assert!(bucket_floor(idx) <= v, "floor({idx}) > {v}");
            if idx + 1 < HIST_BUCKETS && bucket_floor(idx + 1) > bucket_floor(idx) {
                // Within one sub-bucket: ≤25% relative width.
                assert!(bucket_floor(idx + 1) > v || bucket_floor(idx + 1) >= v);
            }
        }
        let h = hist("test/obs_hist");
        for ms in 1..=100u64 {
            h.record_ns(ms * 1_000_000);
        }
        let snap = TelemetrySnapshot::capture();
        let hs = snap.hists.get("test/obs_hist").expect("registered hist");
        assert_eq!(hs.count, 100);
        let p50 = hs.quantile_ns(0.50);
        let p99 = hs.quantile_ns(0.99);
        // 50ms and 99ms, within one log-bucket (≤25%) below.
        assert!((37_500_000..=50_000_000).contains(&p50), "p50 = {p50}");
        assert!((74_250_000..=99_000_000).contains(&p99), "p99 = {p99}");
        assert!(p50 <= p99);
        assert_eq!(hs.mean_ns(), hs.sum_ns / 100);
    }

    #[test]
    fn windowed_quantile_ranks_over_bucket_sum_not_count() {
        // Torn windowed diff: 4 records landed in `count` whose bucket
        // increments were not yet visible at capture time. Every record
        // the buckets *do* show sits near 1 µs — p99 must report that
        // bucket's floor, not fall through to the top-bucket floor.
        let torn = HistSnapshot::synthetic(14, 14_000, &[(1_000, 10)]);
        let p99 = torn.quantile_ns(0.99);
        assert_eq!(p99, bucket_floor(bucket_index(1_000)), "p99 = {p99}");
        assert!(p99 < bucket_floor(HIST_BUCKETS - 1));
        // Fully torn window (count > 0, no visible buckets) reads empty.
        let all_torn = HistSnapshot::synthetic(3, 999, &[]);
        assert_eq!(all_torn.quantile_ns(0.99), 0);
        // Consistent snapshots (sum == count) are unchanged by the fix:
        // `histogram_buckets_are_log_scale_and_quantiles_round_down`
        // pins the absolute values; here pin equality with a count-ranked
        // scan on a two-bucket layout.
        let consistent = HistSnapshot::synthetic(10, 10_000, &[(1_000, 9), (1_000_000, 1)]);
        assert_eq!(consistent.quantile_ns(0.50), bucket_floor(bucket_index(1_000)));
        assert_eq!(consistent.quantile_ns(0.99), bucket_floor(bucket_index(1_000_000)));
        // A `since` of two live captures with records in between stays
        // consistent end-to-end through the public path.
        let h = hist("test/obs_windowed_rank");
        h.record_ns(2_000);
        let s0 = TelemetrySnapshot::capture();
        for _ in 0..5 {
            h.record_ns(2_000);
        }
        let s1 = TelemetrySnapshot::capture();
        let hs0 = s0.hists.get("test/obs_windowed_rank").expect("hist registered");
        let hs1 = s1.hists.get("test/obs_windowed_rank").expect("hist registered");
        let win = hs1.since(hs0);
        assert_eq!(win.count, 5);
        assert_eq!(win.quantile_ns(0.99), bucket_floor(bucket_index(2_000)));
    }

    #[test]
    fn snapshot_diff_scopes_a_window() {
        let c = counter("test/obs_diff");
        c.add(7);
        let before = TelemetrySnapshot::capture();
        c.add(5);
        let h = hist("test/obs_diff_hist");
        h.record_ns(1_000);
        let after = TelemetrySnapshot::capture();
        let d = after.diff(&before);
        assert_eq!(d.counters.get("test/obs_diff"), Some(&5));
        assert_eq!(d.hists.get("test/obs_diff_hist").map(|h| h.count), Some(1));
        // JSON export parses back through util::json.
        let js = d.to_json().to_string();
        let parsed = Json::parse(&js).expect("snapshot json parses");
        assert_eq!(
            parsed.get("counters").and_then(|c| c.get("test/obs_diff")).and_then(Json::as_f64),
            Some(5.0)
        );
    }

    /// The span gate, aggregation, and Chrome-trace dump in one
    /// sequential test: the gate is process-global, so toggling it must
    /// not race sibling tests that rely on spans.
    #[test]
    fn spans_aggregate_and_trace_round_trips() {
        static OUTER: SpanLabel = SpanLabel::new("test/outer");
        static INNER: SpanLabel = SpanLabel::new("test/inner");

        // Disabled gate: no aggregation, guard is a no-op.
        set_enabled(false);
        assert!(!enabled());
        {
            let _g = SpanGuard::enter(&OUTER);
        }
        set_enabled(true);
        assert!(enabled());

        set_thread_name("obs-test");
        let before = TelemetrySnapshot::capture();
        trace_begin();
        assert!(trace_active());
        for _ in 0..3 {
            let _outer = SpanGuard::enter(&OUTER);
            std::thread::sleep(std::time::Duration::from_millis(1));
            {
                let _inner = SpanGuard::enter(&INNER);
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }
        // The macro form registers and aggregates the same way.
        {
            crate::obs::span!("test/outer");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let trace = trace_end_to_json();
        assert!(!trace_active());
        let after = TelemetrySnapshot::capture();
        let d = after.diff(&before);
        let outer = d.span("obs-test", "test/outer");
        let inner = d.span("obs-test", "test/inner");
        assert_eq!(outer.count, 4);
        assert_eq!(inner.count, 3);
        // Nested spans record self + children: outer ≥ inner.
        assert!(outer.total_ns >= inner.total_ns);

        // Satellite: the emitted trace parses back through util::json,
        // same-thread spans are properly nested (never partially
        // overlapping), and per-thread timestamps are monotonic.
        let parsed = Json::parse(&trace.to_string()).expect("trace json parses");
        let events = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        let mut last_ts: BTreeMap<i64, f64> = BTreeMap::new();
        let mut open: BTreeMap<i64, Vec<f64>> = BTreeMap::new(); // tid → stack of end timestamps
        let mut xs = 0usize;
        for ev in events {
            let ph = ev.get("ph").and_then(Json::as_str).expect("ph");
            let tid = ev.get("tid").and_then(Json::as_f64).expect("tid") as i64;
            if ph == "M" {
                assert_eq!(ev.get("name").and_then(Json::as_str), Some("thread_name"));
                continue;
            }
            assert_eq!(ph, "X");
            xs += 1;
            let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
            let dur = ev.get("dur").and_then(Json::as_f64).expect("dur");
            assert!(dur >= 0.0);
            if let Some(&prev) = last_ts.get(&tid) {
                assert!(ts >= prev, "timestamps regress on tid {tid}: {ts} < {prev}");
            }
            last_ts.insert(tid, ts);
            let stack = open.entry(tid).or_default();
            while let Some(&end) = stack.last() {
                if ts >= end {
                    stack.pop(); // sibling: the previous span closed first
                } else {
                    // Nested: must end within the enclosing span.
                    assert!(
                        ts + dur <= end + 1e-9,
                        "partial overlap on tid {tid}: [{ts}, {}] vs enclosing end {end}",
                        ts + dur
                    );
                    break;
                }
            }
            stack.push(ts + dur);
        }
        // At least this test's 7 spans made it in (other obs-enabled
        // tests running concurrently may add more).
        assert!(xs >= 7, "expected ≥7 X events, got {xs}");
        // A second window starts clean.
        trace_begin();
        let t2 = trace_end_to_json();
        let n2 = t2.get("traceEvents").and_then(Json::as_arr).map_or(0, Vec::len);
        assert!(n2 <= xs, "trace window did not reset");
        // Display table is greppable and covers the span rows.
        let table = format!("{d}");
        assert!(table.contains("[stats] span    obs-test test/outer"));
    }
}
