//! Batched inference serving: multi-client forward-only traffic with
//! checkpoint hot-reload.
//!
//! The ROADMAP north star includes heavy inference traffic on top of the
//! training pipeline. This module is that serving path — the
//! generalization of [`crate::pipeline::forward_throughput`]'s stage
//! loop to heterogeneous [`Network`]s, live clients and live weights:
//!
//! ```text
//!  clients ──submit──▶ bounded MPSC ──▶ batcher ──▶ [stage 0] ─▶ … ─▶ [stage K−1] ──▶ collector ──▶ per-client
//!     ▲                (per-client FIFO) │  ▲           packets (epoch-versioned weights)   │        responses
//!     └──────── recycled buffers ────────┘  └───────────── free-packet return ─────────────┘
//! ```
//!
//! - **Request queue.** One bounded MPSC channel (`std::sync::mpsc`
//!   `sync_channel`; array-based, allocation-free sends): every client
//!   holds a sender clone, so per-client submission order is the
//!   channel's per-producer FIFO guarantee. Backpressure is structural —
//!   a full queue blocks `submit`, a full pipeline blocks the batcher.
//!   Response queues are *bounded* ([`ServerConfig::client_queue_cap`]
//!   payload-bearing responses per client, shed-oldest-with-notice), so
//!   a slow (or stalled) client can neither wedge the collector nor
//!   grow memory without limit — and never stalls other clients.
//!   Shutdown closes a submit gate and pushes a close marker through
//!   the queue: every request whose `submit` returned `Ok` before
//!   `shutdown` began is ordered ahead of the marker and gets a
//!   terminal response (served, or an explicit shed notice — never a
//!   silent drop).
//! - **Survival layer** (admission, deadlines, shedding, adaptive
//!   batching — DESIGN.md §13). `submit_with` runs per-client
//!   token-bucket admission ([`ServerConfig::admit_rate`]) and a global
//!   in-flight budget ([`ServerConfig::inflight_cap`]) *synchronously*:
//!   overload answers with [`SubmitVerdict::Rejected`] immediately
//!   instead of queue growth. Each request may carry a deadline in
//!   batcher ticks; the batcher sheds expired requests *before* batch
//!   formation, decided purely by the [`Coalescer`]'s tick clock (wall
//!   time is never consulted — reproducible), and the collector tags
//!   responses that were served past their deadline [`Status::Late`].
//!   An optional AIMD controller ([`ServerConfig::adaptive`]) adapts
//!   `max_batch`/`max_wait_ticks` to the observed p99 within configured
//!   clamps. All knobs default off: the PR-5 behavior is bit-for-bit
//!   unchanged.
//! - **Batcher.** A [`Coalescer`] (pure, property-fuzzed) greedily packs
//!   whole requests — never splitting one — into batches of at most
//!   `max_batch` rows, flushing a partial batch after `max_wait_ticks`
//!   idle ticks (one tick = [`BATCH_TICK`] without traffic), and — with
//!   `shrink_under > 0` — emitting a queue-emptying small batch
//!   immediately (low-occupancy shrink: idle-traffic requests skip the
//!   coalescing wait). Batches materialize into pooled, zero-padded
//!   `[max_batch, in_dim]` tensors riding recycled [`Packet`]s, so
//!   steady-state batching allocates nothing.
//! - **Stage workers.** `stages` OS threads, layers split by
//!   *forward-cost*-balanced [`StagePartition`] (serving has no backward
//!   lane, so boundaries balance `fwd_flops` alone). Each stage owns its
//!   ops' persistent workspaces and ping-pongs a packet's `data`/`spare`
//!   buffers through its layers — the kernels underneath run on the
//!   shared PR 2/4 `WorkerPool`.
//! - **Hot-reload.** Weights live in an epoch-versioned
//!   `Arc<ModelVersion>` swapped atomically under a mutex by
//!   [`Server::reload`]. The batcher pins the *current* version into
//!   each packet at batch-formation time, so an in-flight batch finishes
//!   on the version it started with — a response can never observe a
//!   torn mix of two versions, and every [`Response`] carries the epoch
//!   that produced it.
//!
//! **Determinism / oracle equivalence.** Every forward op is row-wise
//! independent (per output element the madd order is ascending-`k`, and
//! conv/pool/LIF never mix samples — DESIGN.md §7), so row `i` of a
//! padded `[max_batch, d]` batch is bitwise identical to the same row
//! forwarded alone: concurrent batched serving reproduces the
//! single-threaded `Network::forward_full` oracle *bitwise*, for any
//! batch composition and any `LAYERPIPE2_WORKERS` value
//! (`tests/integration_serving.rs`).

use crate::backend::{Backend, Exec};
use crate::layers::{build_op, Layer, Network, NetworkSpec};
use crate::model::checkpoint;
use crate::obs;
use crate::retiming::StagePartition;
use crate::tensor::{BufferPool, Tensor};
use crate::util::Rng;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

pub mod chaos;

/// One batcher tick: how long the batcher waits for more traffic before
/// counting an idle tick against `max_wait_ticks`. A partial batch
/// therefore waits at most `max_wait_ticks · BATCH_TICK` after the last
/// arrival before flushing.
pub const BATCH_TICK: Duration = Duration::from_micros(200);

/// Serving engine knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Row capacity of one coalesced batch (requests are never split, so
    /// a single request may hold at most this many rows).
    pub max_batch: usize,
    /// Idle ticks ([`BATCH_TICK`] each) a partial batch waits before
    /// flushing; `0` flushes on every batcher poll (lowest latency).
    pub max_wait_ticks: u64,
    /// Low-occupancy batch shrink: when the queue would be *emptied* by
    /// the next batch and that batch holds at most this many rows, emit
    /// it immediately instead of waiting out `max_wait_ticks` — under
    /// idle traffic a lone request stops paying the coalescing wait
    /// (p99 relief), while any backlog (more pending than the prefix)
    /// still coalesces normally. `0` disables shrinking (the default:
    /// bit-for-bit the pre-knob behavior).
    pub shrink_under: usize,
    /// Bound of the request queue and each inter-stage channel
    /// (per-client response queues are bounded separately by
    /// `client_queue_cap`).
    pub queue_depth: usize,
    /// Forward pipeline stages (1 ≤ stages ≤ layers).
    pub stages: usize,
    /// Per-client token-bucket admission: rows admitted per batcher tick
    /// (refill rate). `0` disables admission control (the default).
    pub admit_rate: u64,
    /// Token-bucket capacity in rows (burst allowance). `0` means
    /// `max_batch` rows.
    pub admit_burst: u64,
    /// Global in-flight budget: `submit_with` rejects
    /// ([`RejectReason::Saturated`]) while this many accepted requests
    /// are still unanswered. `0` disables the budget (the default).
    /// Racing clients can overshoot by at most one request each — the
    /// check and the enqueue are not atomic — so the real bound is
    /// `inflight_cap + clients`.
    pub inflight_cap: usize,
    /// Default per-request deadline in batcher ticks, applied by
    /// [`ServingClient::submit`]; `submit_with` overrides per request.
    /// A request older than its deadline (measured on the coalescer's
    /// tick clock, never wall time) is shed *before* batch formation
    /// with an explicit [`ShedReason::Deadline`] notice. `0` = no
    /// deadline (the default).
    pub deadline_ticks: u64,
    /// Payload-bearing responses buffered per client before the oldest
    /// is stripped to a [`ShedReason::Backpressure`] notice (notices
    /// keep per-seq continuity and never count toward the cap).
    pub client_queue_cap: usize,
    /// p99-driven AIMD adaptation of `max_batch`/`max_wait_ticks`
    /// (clamped to `adapt_min_batch..=max_batch` and
    /// `adapt_min_wait_ticks..=max_wait_ticks`). Off by default: the
    /// configured limits are immutable and behavior is byte-identical
    /// to previous releases.
    pub adaptive: bool,
    /// AIMD latency target: windowed p99 above this shrinks the batch
    /// limits (multiplicative), below grows them (additive).
    pub adapt_target_p99_ms: f64,
    /// Floor for the adapted batch size (≥ 1).
    pub adapt_min_batch: usize,
    /// Floor for the adapted wait budget.
    pub adapt_min_wait_ticks: u64,
    /// Chaos hook: when non-zero, every stage worker injects short
    /// seeded sleeps between packets (time-only faults — data, order
    /// and accounting are untouched; `faults_injected` counts them).
    pub fault_stall_seed: u64,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_batch: 32,
            max_wait_ticks: 4,
            shrink_under: 0,
            queue_depth: 64,
            stages: 2,
            admit_rate: 0,
            admit_burst: 0,
            inflight_cap: 0,
            deadline_ticks: 0,
            client_queue_cap: 1024,
            adaptive: false,
            adapt_target_p99_ms: 2.0,
            adapt_min_batch: 1,
            adapt_min_wait_ticks: 0,
            fault_stall_seed: 0,
        }
    }
}

impl ServerConfig {
    fn validate(&self, layers: usize) -> Result<()> {
        ensure!(self.max_batch >= 1, "max_batch must be positive");
        ensure!(self.queue_depth >= 1, "queue_depth must be positive");
        ensure!(
            self.shrink_under <= self.max_batch,
            "shrink_under {} exceeds max_batch {}",
            self.shrink_under,
            self.max_batch
        );
        ensure!(
            self.stages >= 1 && self.stages <= layers,
            "stages {} outside 1..={layers}",
            self.stages
        );
        ensure!(self.client_queue_cap >= 1, "client_queue_cap must be positive");
        if self.adaptive {
            ensure!(
                self.adapt_min_batch >= 1 && self.adapt_min_batch <= self.max_batch,
                "adapt_min_batch {} outside 1..={}",
                self.adapt_min_batch,
                self.max_batch
            );
            ensure!(
                self.adapt_min_wait_ticks <= self.max_wait_ticks,
                "adapt_min_wait_ticks {} exceeds max_wait_ticks {}",
                self.adapt_min_wait_ticks,
                self.max_wait_ticks
            );
            ensure!(
                self.adapt_target_p99_ms > 0.0,
                "adaptive mode needs a positive adapt_target_p99_ms"
            );
        }
        Ok(())
    }
}

/// One in-flight inference request: `data` is `[rows, in_dim]` with
/// `1 ≤ rows ≤ max_batch`. Public so the batching core is
/// property-testable from `tests/property_fuzz.rs`.
pub struct Request {
    pub client: u32,
    /// Per-client submission sequence number (assigned by the handle).
    pub seq: u64,
    pub data: Tensor,
    /// Submission time — the start of the submit→respond latency the
    /// collector records into the server's `obs` histogram. Never read
    /// by the batching logic itself (determinism: clocks are observed,
    /// not branched on).
    pub born: Instant,
    /// Batcher tick at submission (the client samples the shared tick
    /// clock) — the deadline's epoch. Unlike `born` this *is* read by
    /// the shed logic: tick counts are reproducible, wall time is not.
    pub born_tick: u64,
    /// Deadline in batcher ticks past `born_tick`; `0` = none.
    pub deadline_ticks: u64,
}

impl Request {
    pub fn rows(&self) -> usize {
        self.data.shape()[0]
    }
}

/// What flows through the request channel: traffic, or the shutdown
/// marker. The marker rides the same FIFO queue, so everything enqueued
/// before it is guaranteed to reach the batcher first.
enum Inbound {
    Req(Request),
    Close,
}

/// One served result: `data` is `[rows, out_dim]` for the request's
/// rows, `version` the weight epoch that computed it. A shed response
/// is a payload-free *notice* (`data` empty) that keeps the per-client
/// seq stream gapless — every accepted request gets exactly one
/// terminal response.
pub struct Response {
    pub client: u32,
    pub seq: u64,
    pub version: u64,
    pub data: Tensor,
    pub status: Status,
}

impl Response {
    /// `Some(reason)` when this is a payload-free shed notice.
    pub fn shed(&self) -> Option<ShedReason> {
        match self.status {
            Status::Shed(r) => Some(r),
            _ => None,
        }
    }
}

/// Terminal disposition of an accepted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Served within its deadline (or it had none).
    Ok,
    /// Served *past* its deadline (tick-measured). Observational only:
    /// the payload is still delivered and still bitwise-exact.
    Late,
    /// Not served — a payload-free notice explaining why.
    Shed(ShedReason),
}

/// Why a request was shed (terminal, no payload was computed — except
/// `Backpressure`, which strips an already-computed payload).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// Expired in the queue: older than its `deadline_ticks` before a
    /// batch could form.
    Deadline,
    /// The client's bounded response queue was full of unread payloads;
    /// this (oldest) one was stripped to make room.
    Backpressure,
    /// The pipeline went away (stage failure / teardown) before the
    /// request could be served.
    Shutdown,
}

/// Why `submit_with` refused a request outright (no seq consumed, the
/// input buffer is handed back).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The per-client token bucket is empty (`admit_rate`).
    RateLimited,
    /// The global in-flight budget is spent (`inflight_cap`).
    Saturated,
}

/// Outcome of [`ServingClient::submit_with`]: admission is synchronous,
/// so overload is a fast observable signal instead of queue growth.
#[derive(Debug)]
pub enum SubmitVerdict {
    /// Accepted; the per-client sequence number a terminal [`Response`]
    /// will carry.
    Accepted(u64),
    /// Rejected before enqueue; `data` is the caller's input back.
    Rejected { reason: RejectReason, data: Tensor },
}

// ---------------------------------------------------------------------------
// Admission control + adaptive batch control: pure, unit-testable cores.
// ---------------------------------------------------------------------------

/// A token bucket over the batcher's tick clock: `capacity` tokens of
/// burst, `refill_per_tick` tokens back per elapsed tick, one token per
/// request row. Pure (no clocks of its own) so it property-fuzzes: an
/// admitted-cost total can never exceed `capacity + refill · elapsed`.
#[derive(Clone, Debug)]
pub struct TokenBucket {
    capacity: u64,
    refill_per_tick: u64,
    tokens: u64,
    last_tick: u64,
}

impl TokenBucket {
    /// A full bucket at tick 0.
    pub fn new(capacity: u64, refill_per_tick: u64) -> TokenBucket {
        TokenBucket { capacity, refill_per_tick, tokens: capacity, last_tick: 0 }
    }

    /// Refill for the ticks elapsed since the last call (the clock is
    /// treated as monotonic — a stale `now_tick` refills nothing), then
    /// admit iff `cost` tokens are available, spending them.
    pub fn admit(&mut self, now_tick: u64, cost: u64) -> bool {
        let elapsed = now_tick.saturating_sub(self.last_tick);
        self.last_tick = self.last_tick.max(now_tick);
        self.tokens = self
            .tokens
            .saturating_add(elapsed.saturating_mul(self.refill_per_tick))
            .min(self.capacity);
        if cost <= self.tokens {
            self.tokens -= cost;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (after the last refill).
    pub fn tokens(&self) -> u64 {
        self.tokens
    }
}

/// AIMD controller over the serving batch limits, fed by the windowed
/// p99 of the obs latency histogram: over target → multiplicative
/// decrease (halve the wait, shrink the batch to ¾), under target →
/// additive increase (+1 each), always clamped to the configured
/// bounds. Pure — the batcher owns the sampling cadence.
#[derive(Clone, Debug)]
pub struct AimdBatchControl {
    min_batch: usize,
    max_batch: usize,
    min_wait: u64,
    max_wait: u64,
    target_p99_ns: u64,
    batch: usize,
    wait: u64,
}

impl AimdBatchControl {
    /// Starts at the configured ceiling (`max_batch`, `max_wait`): with
    /// no pressure observed yet, behave exactly as configured.
    pub fn new(
        min_batch: usize,
        max_batch: usize,
        min_wait: u64,
        max_wait: u64,
        target_p99_ns: u64,
    ) -> AimdBatchControl {
        assert!(min_batch >= 1 && min_batch <= max_batch, "batch clamp order");
        assert!(min_wait <= max_wait, "wait clamp order");
        AimdBatchControl {
            min_batch,
            max_batch,
            min_wait,
            max_wait,
            target_p99_ns,
            batch: max_batch,
            wait: max_wait,
        }
    }

    /// Feed one windowed p99 observation; returns the new
    /// `(max_batch, max_wait_ticks)` limits (always within the clamps).
    pub fn observe(&mut self, p99_ns: u64) -> (usize, u64) {
        if p99_ns > self.target_p99_ns {
            // Multiplicative decrease: back off fast under pressure.
            self.wait = (self.wait / 2).max(self.min_wait);
            self.batch = (self.batch * 3 / 4).max(self.min_batch);
        } else {
            // Additive increase: creep back toward the ceiling.
            self.wait = (self.wait + 1).min(self.max_wait);
            self.batch = (self.batch + 1).min(self.max_batch);
        }
        (self.batch, self.wait)
    }

    /// Current `(max_batch, max_wait_ticks)` limits.
    pub fn limits(&self) -> (usize, u64) {
        (self.batch, self.wait)
    }
}

// ---------------------------------------------------------------------------
// Coalescer: the pure batching core.
// ---------------------------------------------------------------------------

/// Greedy request coalescing, decoupled from threads and clocks so its
/// invariants are fuzzable: requests leave in exactly the order they
/// arrived (global FIFO ⇒ per-client FIFO), none is ever dropped,
/// duplicated or split, and no batch exceeds `max_batch` rows.
pub struct Coalescer {
    max_batch: usize,
    max_wait_ticks: u64,
    /// Low-occupancy shrink threshold (`0` = off) — see
    /// [`ServerConfig::shrink_under`].
    shrink_under: usize,
    queue: VecDeque<Request>,
    waited: u64,
    /// Absolute tick clock: advances by one on every idle tick *and*
    /// every emitted batch, so request age is measured in units of
    /// batcher progress whether the server is idle or saturated — and
    /// deadline shedding is a pure function of the push/tick/emit
    /// sequence, never of wall time.
    now: u64,
}

impl Coalescer {
    pub fn new(max_batch: usize, max_wait_ticks: u64) -> Coalescer {
        Self::with_shrink(max_batch, max_wait_ticks, 0)
    }

    /// [`Coalescer::new`] with the low-occupancy shrink rule enabled:
    /// a queue-emptying prefix of ≤ `shrink_under` rows is emitted
    /// immediately, skipping the idle-tick wait.
    pub fn with_shrink(max_batch: usize, max_wait_ticks: u64, shrink_under: usize) -> Coalescer {
        debug_assert!(shrink_under <= max_batch);
        Coalescer { max_batch, max_wait_ticks, shrink_under, queue: VecDeque::new(), waited: 0, now: 0 }
    }

    /// Enqueue a request. Rows are validated against the *configured*
    /// cap by the server edge; the adaptive controller may have lowered
    /// this coalescer's cap below a request's size, in which case it is
    /// emitted as a singleton batch (see `take_ready_into_reason`).
    pub fn push(&mut self, req: Request) {
        debug_assert!(req.rows() >= 1);
        self.queue.push_back(req);
    }

    /// Register one idle tick (no traffic for [`BATCH_TICK`]).
    pub fn tick(&mut self) {
        self.now += 1;
        if !self.queue.is_empty() {
            self.waited += 1;
        }
    }

    /// The absolute tick clock (idle ticks + emitted batches).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Replace the batch limits (the AIMD controller's knob). The shrink
    /// threshold is left alone — it only ever fires on queue-emptying
    /// prefixes, so a cap below it just means small batches flush early.
    pub fn set_limits(&mut self, max_batch: usize, max_wait_ticks: u64) {
        debug_assert!(max_batch >= 1);
        self.max_batch = max_batch;
        self.max_wait_ticks = max_wait_ticks;
    }

    /// Extract every request older than its deadline (`now − born_tick ≥
    /// deadline_ticks`, deadline 0 = never), preserving the arrival
    /// order of both survivors and the shed. Appends to `out` and
    /// returns how many were shed. Called *before* batch formation so an
    /// expired request never consumes pipeline capacity; the decision
    /// reads only the tick clock — rerunning the same push/tick/emit
    /// sequence sheds exactly the same requests.
    pub fn shed_expired(&mut self, out: &mut Vec<Request>) -> usize {
        let before = out.len();
        let now = self.now;
        let mut i = 0;
        while i < self.queue.len() {
            let r = &self.queue[i];
            if r.deadline_ticks > 0 && now.saturating_sub(r.born_tick) >= r.deadline_ticks {
                let r = self.queue.remove(i).expect("index in bounds");
                out.push(r);
            } else {
                i += 1;
            }
        }
        if self.queue.is_empty() {
            self.waited = 0;
        }
        out.len() - before
    }

    /// Drain every queued request (shutdown teardown: the caller turns
    /// them into terminal shed notices).
    pub fn drain_all(&mut self, out: &mut Vec<Request>) {
        out.extend(self.queue.drain(..));
        self.waited = 0;
    }

    /// Rows currently pending (not yet emitted in a batch).
    pub fn pending_rows(&self) -> usize {
        self.queue.iter().map(Request::rows).sum()
    }

    /// Take the next batch if one is due: the greedy front prefix is
    /// emitted when it is *full* (exactly `max_batch` rows, or the next
    /// request would not fit), when the wait budget is spent, or when
    /// `force` is set (shutdown drain). Returns at least one request or
    /// `None`.
    pub fn take_ready(&mut self, force: bool) -> Option<Vec<Request>> {
        let mut out = Vec::new();
        if self.take_ready_into(force, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// [`Coalescer::take_ready`] into a caller-owned (empty) Vec — the
    /// batcher reuses one scratch Vec so steady-state batching performs
    /// no heap allocation. Returns whether a batch was emitted.
    pub fn take_ready_into(&mut self, force: bool, out: &mut Vec<Request>) -> bool {
        self.take_ready_into_reason(force, out).is_some()
    }

    /// [`Coalescer::take_ready_into`], additionally reporting *why* the
    /// batch flushed (the batcher feeds these into the per-server
    /// `flush_*` counters). Reasons are ranked full > shrank > force >
    /// waited when several hold at once.
    pub fn take_ready_into_reason(
        &mut self,
        force: bool,
        out: &mut Vec<Request>,
    ) -> Option<FlushReason> {
        debug_assert!(out.is_empty(), "scratch must be drained before reuse");
        if self.queue.is_empty() {
            self.waited = 0;
            return None;
        }
        let mut rows = 0usize;
        let mut n = 0usize;
        for r in &self.queue {
            // `n > 0`: a request larger than an *adapted* cap still goes
            // out as a singleton batch (the packet buffer is sized to
            // the configured cap, which every request fits).
            if n > 0 && rows + r.rows() > self.max_batch {
                break;
            }
            rows += r.rows();
            n += 1;
        }
        debug_assert!(n >= 1, "a non-empty queue always yields a prefix");
        let full = rows >= self.max_batch || n < self.queue.len();
        // Low-occupancy shrink: the prefix drains the whole queue and is
        // small — nothing is coming that it could coalesce with, so
        // waiting only adds latency. Never splits/drops/reorders (same
        // greedy prefix, emitted earlier).
        let shrank = self.shrink_under > 0 && n == self.queue.len() && rows <= self.shrink_under;
        let reason = if full {
            FlushReason::Full
        } else if shrank {
            FlushReason::Shrank
        } else if force {
            FlushReason::Force
        } else if self.waited >= self.max_wait_ticks {
            FlushReason::Waited
        } else {
            return None;
        };
        self.waited = 0;
        // An emitted batch is one step of batcher progress: advance the
        // deadline clock so queued requests age under saturation too
        // (idle ticks alone would freeze time under sustained traffic).
        self.now += 1;
        out.extend(self.queue.drain(..n));
        Some(reason)
    }
}

/// Why a coalesced batch left the queue — see
/// [`Coalescer::take_ready_into_reason`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushReason {
    /// The prefix hit `max_batch` rows (or the next request overflowed).
    Full,
    /// Low-occupancy shrink: a queue-emptying small prefix went early.
    Shrank,
    /// Forced drain (shutdown).
    Force,
    /// The idle-tick wait budget was spent.
    Waited,
}

// ---------------------------------------------------------------------------
// Versioned weights + circulating packets.
// ---------------------------------------------------------------------------

/// One immutable weight snapshot. Stages read only through the `Arc`
/// pinned into their packet, so a version is observable either fully or
/// not at all.
struct ModelVersion {
    epoch: u64,
    /// `(w, b)` per global layer, in stack order.
    params: Vec<(Tensor, Tensor)>,
}

/// Routing slice of one request inside a batch (rows are contiguous).
struct Route {
    client: u32,
    seq: u64,
    rows: usize,
    /// Carried over from the request: submit→respond latency endpoint.
    born: Instant,
    /// Carried over from the request: the collector tags the response
    /// `Late` when it lands past `born_tick + deadline_ticks` on the
    /// shared tick clock (observational — the payload still ships).
    born_tick: u64,
    deadline_ticks: u64,
}

/// A batch moving down the stage pipeline. Packets circulate: the
/// collector returns spent ones to the batcher, whose `data`/`spare`
/// backing stores and `routes` Vec are reused in place — the
/// steady-state pipeline allocates nothing.
struct Packet {
    version: Arc<ModelVersion>,
    occupied: usize,
    routes: Vec<Route>,
    /// Current activation, `[max_batch, dim]` (padding rows zeroed at
    /// batch formation; their outputs are computed and discarded).
    data: Tensor,
    /// Ping-pong output buffer (capacity grows to the widest layer once,
    /// then every resize is in place).
    spare: Tensor,
}

impl Packet {
    fn fresh(version: Arc<ModelVersion>) -> Packet {
        Packet {
            version,
            occupied: 0,
            routes: Vec::new(),
            data: Tensor::empty(),
            spare: Tensor::empty(),
        }
    }
}

// ---------------------------------------------------------------------------
// Bounded per-client response queues.
// ---------------------------------------------------------------------------

/// A client's bounded response queue (Mutex + Condvar): at most `cap`
/// payload-bearing responses buffered; pushing past the cap strips the
/// *oldest* payload in place to a [`ShedReason::Backpressure`] notice
/// (its buffer returns to the pool), so a stalled client costs O(cap)
/// memory while its seq stream stays gapless. Notices never count
/// toward the cap and are never dropped. Lock order elsewhere is
/// pool → client table → chan; nothing is ever locked while holding a
/// chan, so the hierarchy is cycle-free.
#[derive(Clone)]
struct RespChan(Arc<(Mutex<RespState>, Condvar)>);

struct RespState {
    q: VecDeque<Response>,
    /// Payload-bearing (non-notice) responses currently queued.
    payloads: usize,
    cap: usize,
    /// Client handle still alive (false after `ServingClient` drop).
    open: bool,
    /// Server side finished — `recv` errors once the queue is drained.
    done: bool,
}

/// What `RespChan::push` did with a response.
enum PushOutcome {
    /// Queued. When the cap forced the oldest payload out, its buffer
    /// comes back for recycling (the stripped response itself stays
    /// queued as a `Shed(Backpressure)` notice).
    Delivered { shed_payload: Option<Tensor> },
    /// The client handle is gone; the response comes back untouched.
    Gone(Response),
}

impl RespChan {
    fn new(cap: usize) -> RespChan {
        debug_assert!(cap >= 1);
        RespChan(Arc::new((
            Mutex::new(RespState { q: VecDeque::new(), payloads: 0, cap, open: true, done: false }),
            Condvar::new(),
        )))
    }

    fn push(&self, resp: Response) -> PushOutcome {
        let (m, cv) = &*self.0;
        let mut st = m.lock().expect("resp chan lock");
        if !st.open {
            return PushOutcome::Gone(resp);
        }
        let mut shed_payload = None;
        if resp.shed().is_none() {
            if st.payloads >= st.cap {
                // Shed-oldest-with-notice: keep the victim's identity
                // (client/seq/version) so the receiver still sees every
                // seq exactly once, in order.
                if let Some(victim) = st.q.iter_mut().find(|r| r.shed().is_none()) {
                    victim.status = Status::Shed(ShedReason::Backpressure);
                    shed_payload = Some(std::mem::replace(&mut victim.data, Tensor::empty()));
                    st.payloads -= 1;
                }
            }
            st.payloads += 1;
        }
        st.q.push_back(resp);
        cv.notify_one();
        PushOutcome::Delivered { shed_payload }
    }

    fn pop(st: &mut RespState) -> Option<Response> {
        let r = st.q.pop_front()?;
        if r.shed().is_none() {
            st.payloads -= 1;
        }
        Some(r)
    }

    fn try_recv(&self) -> Option<Response> {
        let (m, _) = &*self.0;
        Self::pop(&mut m.lock().expect("resp chan lock"))
    }

    /// Blocking receive; `None` once the server is done *and* the queue
    /// is drained (responses queued before teardown still deliver).
    fn recv(&self) -> Option<Response> {
        let (m, cv) = &*self.0;
        let mut st = m.lock().expect("resp chan lock");
        loop {
            if let Some(r) = Self::pop(&mut st) {
                return Some(r);
            }
            if st.done {
                return None;
            }
            st = cv.wait(st).expect("resp chan wait");
        }
    }

    /// [`RespChan::recv`] with a wall-clock cap (chaos harness: turns a
    /// would-be hang into a counted loss instead of wedging the suite).
    fn recv_timeout(&self, timeout: Duration) -> Option<Response> {
        let deadline = Instant::now() + timeout;
        let (m, cv) = &*self.0;
        let mut st = m.lock().expect("resp chan lock");
        loop {
            if let Some(r) = Self::pop(&mut st) {
                return Some(r);
            }
            if st.done {
                return None;
            }
            let left = deadline.saturating_duration_since(Instant::now());
            if left.is_zero() {
                return None;
            }
            let (guard, _) = cv.wait_timeout(st, left).expect("resp chan wait");
            st = guard;
        }
    }

    /// Server side: no further responses will ever be pushed — wake
    /// every blocked receiver so it can drain and return.
    fn mark_done(&self) {
        let (m, cv) = &*self.0;
        m.lock().expect("resp chan lock").done = true;
        cv.notify_all();
    }

    /// Client side (handle drop): refuse future pushes and surrender the
    /// queued payload buffers (the caller recycles them *outside* the
    /// chan lock, respecting the pool → table → chan order).
    fn close(&self) -> Vec<Tensor> {
        let (m, cv) = &*self.0;
        let mut st = m.lock().expect("resp chan lock");
        st.open = false;
        st.payloads = 0;
        let out = st.q.drain(..).filter(|r| r.shed().is_none()).map(|r| r.data).collect();
        cv.notify_all();
        out
    }
}

// ---------------------------------------------------------------------------
// Shared counters — per-server views over the `obs` registry.
// ---------------------------------------------------------------------------

/// Server instance sequence: each [`Server::start`] claims the next id,
/// so its instrument names (`serving#N/…`) are process-unique and every
/// instance's counters start a fresh window at zero.
static SERVER_SEQ: AtomicU64 = AtomicU64::new(0);

/// One server's instrument handles on the shared [`crate::obs`]
/// registry (DESIGN.md §12). `Copy` — every worker context carries the
/// handles by value; [`Server::stats`] is a thin read-side view.
#[derive(Clone, Copy)]
struct Counters {
    submitted: obs::Counter,
    completed: obs::Counter,
    dropped: obs::Counter,
    batches: obs::Counter,
    rows: obs::Counter,
    reloads: obs::Counter,
    packets_created: obs::Counter,
    flush_full: obs::Counter,
    flush_shrank: obs::Counter,
    flush_force: obs::Counter,
    flush_wait: obs::Counter,
    /// Synchronous admission rejections (no seq consumed).
    rejected_rate: obs::Counter,
    rejected_budget: obs::Counter,
    /// Terminal sheds (each retires an accepted request)…
    shed_deadline: obs::Counter,
    shed_shutdown: obs::Counter,
    /// …and post-completion payload strips (orthogonal: the request was
    /// already counted `completed`).
    shed_backpressure: obs::Counter,
    /// Payload responses delivered past their deadline (`Status::Late`).
    late: obs::Counter,
    /// Chaos stalls injected by stage workers (`fault_stall_seed`).
    faults: obs::Counter,
    /// Requests accepted by `submit` and not yet routed to a response —
    /// the live queue depth across queue + coalescer + pipeline.
    queue_depth: obs::Gauge,
    /// Submit→respond latency per request.
    latency: obs::Hist,
}

impl Counters {
    fn register(id: u64) -> Counters {
        let c = |k: &str| obs::counter(&format!("serving#{id}/{k}"));
        Counters {
            submitted: c("submitted"),
            completed: c("completed"),
            dropped: c("dropped"),
            batches: c("batches"),
            rows: c("rows"),
            reloads: c("reloads"),
            packets_created: c("packets_created"),
            flush_full: c("flush_full"),
            flush_shrank: c("flush_shrank"),
            flush_force: c("flush_force"),
            flush_wait: c("flush_wait"),
            rejected_rate: c("rejected_rate"),
            rejected_budget: c("rejected_budget"),
            shed_deadline: c("shed_deadline"),
            shed_shutdown: c("shed_shutdown"),
            shed_backpressure: c("shed_backpressure"),
            late: c("late"),
            faults: c("faults_injected"),
            queue_depth: obs::gauge(&format!("serving#{id}/queue_depth")),
            latency: obs::hist(&format!("serving#{id}/latency")),
        }
    }

    fn mark_flush(&self, reason: FlushReason) {
        match reason {
            FlushReason::Full => self.flush_full.inc(),
            FlushReason::Shrank => self.flush_shrank.inc(),
            FlushReason::Force => self.flush_force.inc(),
            FlushReason::Waited => self.flush_wait.inc(),
        }
    }
}

/// A point-in-time snapshot of the serving counters.
#[derive(Clone, Copy, Debug)]
pub struct ServingStats {
    /// Requests accepted by `submit`.
    pub submitted: u64,
    /// Responses delivered to a live client handle.
    pub completed: u64,
    /// Responses whose client handle was gone (buffer recycled).
    pub dropped: u64,
    /// Batches formed.
    pub batches: u64,
    /// Occupied (non-padding) rows served.
    pub rows: u64,
    /// Weight swaps performed.
    pub reloads: u64,
    /// Packets ever allocated (freezes once the ring is warm).
    pub packets_created: u64,
    /// Batches flushed because the greedy prefix was full.
    pub flush_full: u64,
    /// Batches flushed by the low-occupancy shrink rule.
    pub flush_shrank: u64,
    /// Batches flushed by the shutdown drain.
    pub flush_force: u64,
    /// Batches flushed after the idle-tick wait budget.
    pub flush_wait: u64,
    /// Submits rejected by the per-client token bucket.
    pub rejected_rate: u64,
    /// Submits rejected by the global in-flight budget.
    pub rejected_budget: u64,
    /// Accepted requests shed on deadline expiry (terminal notice, no
    /// payload computed).
    pub shed_deadline: u64,
    /// Accepted requests shed because the pipeline went away before
    /// serving them (terminal notice).
    pub shed_shutdown: u64,
    /// Completed payloads later stripped by a full client queue —
    /// orthogonal to the terminal accounting (they stay `completed`).
    pub shed_backpressure: u64,
    /// Payload responses delivered past their deadline.
    pub late: u64,
    /// Chaos stalls injected by stage workers.
    pub faults_injected: u64,
    /// Requests accepted but not yet routed to a response (0 after a
    /// clean shutdown: `submitted == completed + dropped + shed_deadline
    /// + shed_shutdown` — every accepted request got exactly one
    /// terminal event).
    pub queue_depth: i64,
    /// Edge-pool takes served from recycled storage / fresh allocations.
    pub pool_hits: u64,
    pub pool_misses: u64,
    /// Current weight epoch.
    pub epoch: u64,
    /// Mean occupied fraction of formed batches (0 when none formed).
    pub occupancy: f64,
}

// ---------------------------------------------------------------------------
// The server.
// ---------------------------------------------------------------------------

/// A running batched-inference server. Construct with [`Server::start`],
/// mint client handles with [`Server::client`], swap weights live with
/// [`Server::reload`], and stop with [`Server::shutdown`] (which drains
/// outstanding requests before joining the workers).
pub struct Server {
    req_tx: SyncSender<Inbound>,
    resp_txs: Arc<Mutex<Vec<Option<RespChan>>>>,
    version: Arc<Mutex<Arc<ModelVersion>>>,
    pool: Arc<Mutex<BufferPool>>,
    stats: Counters,
    fail: Arc<Mutex<Option<String>>>,
    /// Submit gate: held shared for the duration of every `submit`'s
    /// enqueue, taken exclusively (and set) by `shutdown` — so a submit
    /// that returned `Ok` is strictly ordered before the close marker.
    gate: Arc<RwLock<bool>>,
    closing: Arc<AtomicBool>,
    /// The batcher's published tick clock (mirrors `Coalescer::now`):
    /// clients stamp `born_tick` off it, token buckets refill on it,
    /// the collector reads it to tag late responses.
    clock: Arc<AtomicU64>,
    /// Latest `(max_batch, max_wait_ticks)` chosen by the AIMD
    /// controller (= the configured limits while adaptation is off).
    adapt_state: Arc<Mutex<(usize, u64)>>,
    threads: Vec<JoinHandle<()>>,
    // Immutable architecture metadata (reload validation, rebuilds).
    spec: NetworkSpec,
    cfg: ServerConfig,
    in_dim: usize,
    out_dim: usize,
    partition: StagePartition,
}

impl Server {
    /// Spin up the batcher, stage workers and collector around a weight
    /// snapshot of `net` (epoch 0). The network itself is not consumed —
    /// ops are rebuilt per stage with fresh workspaces, weights cloned
    /// into the version table.
    pub fn start(backend: Backend, net: &Network, cfg: &ServerConfig) -> Result<Server> {
        cfg.validate(net.num_layers())?;
        // Serving is host-kernel-only today: padded `[max_batch, in_dim]`
        // batches and the row-wise bitwise-determinism argument are
        // host-kernel properties, while PJRT artifacts are lowered for
        // fixed training shapes (PJRT serving stages: ROADMAP item).
        ensure!(
            backend.name() != "pjrt",
            "the serving path runs on host kernels — use the host backend \
             (LAYERPIPE2_BACKEND=host); PJRT-backed serving stages need per-op \
             artifacts (see ROADMAP)"
        );
        // Forward-only traffic: balance stage boundaries on fwd FLOPs.
        let fwd: Vec<u64> = net.costs(cfg.max_batch).iter().map(|c| c.fwd_flops).collect();
        let partition = StagePartition::balanced(&fwd, cfg.stages)?;

        // Per-stage ops, rebuilt from the specs (same geometry as the
        // network, private workspaces per stage thread).
        let mut stage_ops: Vec<Vec<(usize, Box<dyn Layer>)>> =
            (0..cfg.stages).map(|_| Vec::new()).collect();
        let mut cur = net.input.clone();
        for (l, nl) in net.layers.iter().enumerate() {
            let (op, next) = build_op(&nl.spec, &cur, l)?;
            stage_ops[partition.stage_of()[l]].push((l, op));
            cur = next;
        }

        let version0 = Arc::new(ModelVersion {
            epoch: 0,
            params: net.layers.iter().map(|nl| (nl.w.clone(), nl.b.clone())).collect(),
        });
        let version = Arc::new(Mutex::new(version0));
        let pool = Arc::new(Mutex::new(BufferPool::new()));
        let stats = Counters::register(SERVER_SEQ.fetch_add(1, Ordering::Relaxed));
        let fail: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let gate = Arc::new(RwLock::new(false));
        let closing = Arc::new(AtomicBool::new(false));
        let clock = Arc::new(AtomicU64::new(0));
        let adapt_state = Arc::new(Mutex::new((cfg.max_batch, cfg.max_wait_ticks)));
        let resp_txs: Arc<Mutex<Vec<Option<RespChan>>>> = Arc::new(Mutex::new(Vec::new()));

        // Boundary channels: batcher → stage 0 → … → stage K−1 → collector.
        let mut txs = Vec::with_capacity(cfg.stages + 1);
        let mut rxs = VecDeque::with_capacity(cfg.stages + 1);
        for _ in 0..=cfg.stages {
            let (tx, rx) = sync_channel::<Packet>(cfg.queue_depth);
            txs.push(tx);
            rxs.push_back(rx);
        }
        // Free-packet return: sized so the full circulating set fits and
        // `try_send` never has to drop a warm packet.
        let free_cap = cfg.queue_depth * (cfg.stages + 2) + 4;
        let (free_tx, free_rx) = sync_channel::<Packet>(free_cap);
        let (req_tx, req_rx) = sync_channel::<Inbound>(cfg.queue_depth);

        let mut threads = Vec::with_capacity(cfg.stages + 2);
        let ctx = BatcherCtx {
            tx0: txs.remove(0),
            free_rx,
            version: Arc::clone(&version),
            pool: Arc::clone(&pool),
            resp_txs: Arc::clone(&resp_txs),
            clock: Arc::clone(&clock),
            adapt_state: Arc::clone(&adapt_state),
            stats,
            max_batch: cfg.max_batch,
            in_dim: net.input_dim(),
        };
        let tune = BatcherTuning {
            max_wait_ticks: cfg.max_wait_ticks,
            shrink_under: cfg.shrink_under,
            adaptive: cfg.adaptive.then(|| {
                AimdBatchControl::new(
                    cfg.adapt_min_batch,
                    cfg.max_batch,
                    cfg.adapt_min_wait_ticks,
                    cfg.max_wait_ticks,
                    (cfg.adapt_target_p99_ms * 1e6) as u64,
                )
            }),
        };
        let closing_b = Arc::clone(&closing);
        threads.push(
            std::thread::Builder::new()
                .name("serve-batcher".into())
                .spawn(move || batcher_loop(req_rx, ctx, tune, closing_b))
                .expect("spawn batcher"),
        );
        for (s, ops) in stage_ops.into_iter().enumerate() {
            let rx = rxs.pop_front().expect("stage rx");
            let tx = txs.remove(0);
            let exec = Arc::clone(&backend);
            let fail_s = Arc::clone(&fail);
            // Chaos: per-stage seeded fault source (time-only stalls).
            let fault = (cfg.fault_stall_seed != 0)
                .then(|| Rng::new(cfg.fault_stall_seed.wrapping_add(s as u64)));
            threads.push(
                std::thread::Builder::new()
                    .name(format!("serve-stage-{s}"))
                    .spawn(move || stage_loop(exec, ops, rx, tx, fail_s, fault, stats))
                    .expect("spawn stage"),
            );
        }
        let ctx = CollectorCtx {
            free_tx,
            resp_txs: Arc::clone(&resp_txs),
            pool: Arc::clone(&pool),
            clock: Arc::clone(&clock),
            stats,
            out_dim: net.out_dim(),
        };
        let last_rx = rxs.pop_front().expect("collector rx");
        threads.push(
            std::thread::Builder::new()
                .name("serve-collector".into())
                .spawn(move || collector_loop(last_rx, ctx))
                .expect("spawn collector"),
        );

        Ok(Server {
            req_tx,
            resp_txs,
            version,
            pool,
            stats,
            fail,
            gate,
            closing,
            clock,
            adapt_state,
            threads,
            spec: NetworkSpec {
                input: net.input.clone(),
                layers: net.layers.iter().map(|nl| nl.spec.clone()).collect(),
                init_scale: net.init_scale,
            },
            cfg: cfg.clone(),
            in_dim: net.input_dim(),
            out_dim: net.out_dim(),
            partition,
        })
    }

    /// Mint a client handle: its own bounded response queue
    /// ([`ServerConfig::client_queue_cap`] payloads, shed-oldest) plus a
    /// clone of the request sender (per-client FIFO rides the channel's
    /// per-producer ordering) and — when admission is configured — a
    /// private token bucket over the shared tick clock. Client ids are
    /// never reused; a dropped client's table slot is tombstoned the
    /// first time a response fails to deliver.
    pub fn client(&self) -> ServingClient {
        let chan = RespChan::new(self.cfg.client_queue_cap);
        let mut v = self.resp_txs.lock().expect("client table lock");
        let id = v.len() as u32;
        v.push(Some(chan.clone()));
        let burst =
            if self.cfg.admit_burst == 0 { self.cfg.max_batch as u64 } else { self.cfg.admit_burst };
        ServingClient {
            id,
            seq: 0,
            req_tx: self.req_tx.clone(),
            chan,
            pool: Arc::clone(&self.pool),
            stats: self.stats,
            gate: Arc::clone(&self.gate),
            clock: Arc::clone(&self.clock),
            bucket: (self.cfg.admit_rate > 0).then(|| TokenBucket::new(burst, self.cfg.admit_rate)),
            inflight_cap: self.cfg.inflight_cap,
            default_deadline: self.cfg.deadline_ticks,
            in_dim: self.in_dim,
            max_batch: self.cfg.max_batch,
        }
    }

    /// Atomically swap in `net`'s weights as a new epoch. The
    /// architecture must match layer-for-layer; in-flight batches finish
    /// on the version pinned at their formation. Returns the new epoch.
    pub fn reload(&self, net: &Network) -> Result<u64> {
        ensure!(
            net.input == self.spec.input,
            "reload architecture mismatch: input {:?} vs served {:?}",
            net.input,
            self.spec.input
        );
        ensure!(
            net.layers.len() == self.spec.layers.len(),
            "reload has {} layers, server serves {}",
            net.layers.len(),
            self.spec.layers.len()
        );
        for (l, (nl, spec)) in net.layers.iter().zip(&self.spec.layers).enumerate() {
            ensure!(
                nl.spec == *spec,
                "reload layer {l}: spec {:?} vs served {:?}",
                nl.spec,
                spec
            );
        }
        let params = net.layers.iter().map(|nl| (nl.w.clone(), nl.b.clone())).collect();
        let mut cur = self.version.lock().expect("version lock");
        let epoch = cur.epoch + 1;
        *cur = Arc::new(ModelVersion { epoch, params });
        self.stats.reloads.inc();
        Ok(epoch)
    }

    /// [`Server::reload`] from a network checkpoint on disk — v2
    /// (all-f32) or v3 (dtype-tagged, bf16 payloads) — the
    /// restore-from-disk serving path: the file must hold an
    /// architecture-matching checkpoint. Restored tensors keep the
    /// file's storage dtype; the kernels widen bf16 weights per
    /// operand, so a bf16 checkpoint serves without any conversion
    /// pass.
    pub fn reload_from_file(&self, path: &str) -> Result<u64> {
        // Scratch params are fully overwritten by the restore; the rng
        // seed is irrelevant.
        let mut scratch = Network::build(&self.spec, &mut Rng::new(0))?;
        checkpoint::load_network(&mut scratch, path)?;
        self.reload(&scratch)
    }

    /// Current weight epoch.
    pub fn epoch(&self) -> u64 {
        self.version.lock().expect("version lock").epoch
    }

    /// The forward-cost-balanced stage boundaries this server runs.
    pub fn partition(&self) -> &StagePartition {
        &self.partition
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    /// The batcher's published tick clock (idle ticks + emitted
    /// batches) — the time base for deadlines and token buckets.
    pub fn tick_now(&self) -> u64 {
        self.clock.load(Ordering::Acquire)
    }

    /// The AIMD controller's current `(max_batch, max_wait_ticks)`, or
    /// `None` when `ServerConfig::adaptive` is off (the limits are then
    /// immutable by construction).
    pub fn adaptive_limits(&self) -> Option<(usize, u64)> {
        self.cfg
            .adaptive
            .then(|| *self.adapt_state.lock().expect("adapt state lock"))
    }

    /// Counter snapshot — a thin view over this server's `obs` registry
    /// instruments (cheap; relaxed loads + one pool lock).
    pub fn stats(&self) -> ServingStats {
        let (pool_hits, pool_misses) = {
            let p = self.pool.lock().expect("edge pool lock");
            (p.hits(), p.misses())
        };
        let batches = self.stats.batches.value();
        let rows = self.stats.rows.value();
        ServingStats {
            submitted: self.stats.submitted.value(),
            completed: self.stats.completed.value(),
            dropped: self.stats.dropped.value(),
            batches,
            rows,
            reloads: self.stats.reloads.value(),
            packets_created: self.stats.packets_created.value(),
            flush_full: self.stats.flush_full.value(),
            flush_shrank: self.stats.flush_shrank.value(),
            flush_force: self.stats.flush_force.value(),
            flush_wait: self.stats.flush_wait.value(),
            rejected_rate: self.stats.rejected_rate.value(),
            rejected_budget: self.stats.rejected_budget.value(),
            shed_deadline: self.stats.shed_deadline.value(),
            shed_shutdown: self.stats.shed_shutdown.value(),
            shed_backpressure: self.stats.shed_backpressure.value(),
            late: self.stats.late.value(),
            faults_injected: self.stats.faults.value(),
            queue_depth: self.stats.queue_depth.value(),
            pool_hits,
            pool_misses,
            epoch: self.epoch(),
            occupancy: if batches == 0 {
                0.0
            } else {
                rows as f64 / (batches * self.cfg.max_batch as u64) as f64
            },
        }
    }

    /// Submit→respond latency histogram (per request, full lifetime:
    /// queue + coalescing wait + pipeline). Quantiles come from the
    /// log-scale buckets — p50/p90/p99 each round down to a bucket floor
    /// (≤25 % relative error).
    pub fn latency_hist(&self) -> obs::HistSnapshot {
        self.stats.latency.snapshot()
    }

    /// `(p50, p99)` submit→respond latency in milliseconds, or `None`
    /// before any response. Bucket-floor quantiles over the full request
    /// history (the pre-registry ring kept only a sliding window).
    pub fn latency_ms(&self) -> Option<(f64, f64)> {
        let h = self.latency_hist();
        if h.count == 0 {
            return None;
        }
        Some((h.quantile_ns(0.50) as f64 / 1e6, h.quantile_ns(0.99) as f64 / 1e6))
    }

    /// Drain outstanding requests, stop every worker and return the
    /// final counters (or the first worker error). Every request whose
    /// `submit` returned `Ok` before this call began is guaranteed to
    /// have been served (its response sits in the client's channel).
    pub fn shutdown(mut self) -> Result<ServingStats> {
        // Close the submit gate: after this write completes, every
        // in-flight submit has fully enqueued (and is therefore ordered
        // ahead of the close marker below) and every later submit errors.
        *self.gate.write().expect("gate lock") = true;
        self.closing.store(true, Ordering::Release);
        // Deliver the close marker. A full queue means the batcher is
        // still draining — keep trying; a finished batcher means a
        // worker error already tore the pipeline down — stop.
        let mut msg = Inbound::Close;
        loop {
            match self.req_tx.try_send(msg) {
                Ok(()) => break,
                Err(TrySendError::Full(m)) => {
                    if self.threads[0].is_finished() {
                        break;
                    }
                    msg = m;
                    std::thread::sleep(BATCH_TICK);
                }
                Err(TrySendError::Disconnected(_)) => break,
            }
        }
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
        if let Some(msg) = self.fail.lock().expect("fail lock").take() {
            bail!("{msg}");
        }
        Ok(self.stats())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Un-shutdown drops still stop the workers: the batcher observes
        // `closing` on its next loop iteration (even under sustained
        // traffic) and drains best-effort. Closing the gate — best-effort
        // only, drop must never block — makes later submits error instead
        // of feeding a dying server. Use `shutdown` for the full
        // served-before-join guarantee.
        self.closing.store(true, Ordering::Release);
        if let Ok(mut g) = self.gate.try_write() {
            *g = true;
        }
    }
}

/// A client's connection: submit requests, poll/await responses, and
/// borrow/return buffers from the server's edge pool so a
/// submit→respond loop is allocation-free in steady state.
pub struct ServingClient {
    id: u32,
    seq: u64,
    req_tx: SyncSender<Inbound>,
    chan: RespChan,
    pool: Arc<Mutex<BufferPool>>,
    stats: Counters,
    gate: Arc<RwLock<bool>>,
    clock: Arc<AtomicU64>,
    /// Per-client admission bucket (`None`: admission off).
    bucket: Option<TokenBucket>,
    /// Global in-flight budget (`0`: off).
    inflight_cap: usize,
    /// Deadline `submit` applies (ticks; `0`: none).
    default_deadline: u64,
    in_dim: usize,
    max_batch: usize,
}

impl ServingClient {
    pub fn id(&self) -> u32 {
        self.id
    }

    /// Pooled buffer (contents unspecified — fully overwrite it).
    pub fn take(&self, shape: &[usize]) -> Tensor {
        self.pool.lock().expect("edge pool lock").take(shape)
    }

    /// Return a consumed buffer (request input or response output) to
    /// the edge pool.
    pub fn recycle(&self, t: Tensor) {
        self.pool.lock().expect("edge pool lock").recycle(t);
    }

    /// Enqueue `[rows, in_dim]` input rows (`1 ≤ rows ≤ max_batch`);
    /// blocks when the request queue is full. Applies the configured
    /// default deadline; an admission rejection surfaces as an `Err`
    /// (the input buffer is recycled back into the edge pool). Returns
    /// this request's per-client sequence number; responses arrive in
    /// sequence order.
    pub fn submit(&mut self, data: Tensor) -> Result<u64> {
        match self.submit_with(data, self.default_deadline)? {
            SubmitVerdict::Accepted(seq) => Ok(seq),
            SubmitVerdict::Rejected { reason, data } => {
                self.recycle(data);
                Err(anyhow!("request rejected: {reason:?}"))
            }
        }
    }

    /// [`ServingClient::submit`] with an explicit per-request deadline
    /// (ticks; `0` = none) and a non-panicking overload signal: a
    /// rejected request consumes no sequence number and hands the input
    /// buffer back, so callers under load can retry, downsample or
    /// recycle — overload is a fast verdict, never queue growth.
    pub fn submit_with(&mut self, data: Tensor, deadline_ticks: u64) -> Result<SubmitVerdict> {
        ensure!(
            data.ndim() == 2 && data.shape()[1] == self.in_dim,
            "request shape {:?} (expected [rows, {}])",
            data.shape(),
            self.in_dim
        );
        let rows = data.shape()[0];
        ensure!(
            rows >= 1 && rows <= self.max_batch,
            "request rows {rows} outside 1..={}",
            self.max_batch
        );
        let born_tick = self.clock.load(Ordering::Acquire);
        // Global in-flight budget first (a budget reject must not spend
        // bucket tokens), then the per-client token bucket.
        if self.inflight_cap > 0 && self.stats.queue_depth.value() >= self.inflight_cap as i64 {
            self.stats.rejected_budget.inc();
            return Ok(SubmitVerdict::Rejected { reason: RejectReason::Saturated, data });
        }
        if let Some(bucket) = self.bucket.as_mut() {
            if !bucket.admit(born_tick, rows as u64) {
                self.stats.rejected_rate.inc();
                return Ok(SubmitVerdict::Rejected { reason: RejectReason::RateLimited, data });
            }
        }
        let seq = self.seq;
        // Hold the gate shared across the enqueue: shutdown's exclusive
        // acquire then strictly orders this request ahead of the close
        // marker, so an `Ok` here guarantees a terminal response.
        let gate = self.gate.read().expect("gate lock");
        ensure!(!*gate, "server is shut down");
        self.req_tx
            .send(Inbound::Req(Request {
                client: self.id,
                seq,
                data,
                born: Instant::now(),
                born_tick,
                deadline_ticks,
            }))
            .map_err(|_| anyhow!("server is shut down"))?;
        drop(gate);
        self.seq += 1;
        self.stats.submitted.inc();
        self.stats.queue_depth.add(1);
        Ok(SubmitVerdict::Accepted(seq))
    }

    /// Next response if one is ready (non-blocking).
    pub fn poll(&mut self) -> Option<Response> {
        self.chan.try_recv()
    }

    /// Next response, blocking until served (or the server is gone and
    /// the queue is drained).
    pub fn recv(&mut self) -> Result<Response> {
        self.chan
            .recv()
            .ok_or_else(|| anyhow!("server closed before responding"))
    }

    /// [`ServingClient::recv`] with a wall-clock cap: `None` on timeout
    /// or a drained, closed queue (the chaos harness counts either as a
    /// loss instead of hanging the suite).
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<Response> {
        self.chan.recv_timeout(timeout)
    }
}

impl Drop for ServingClient {
    fn drop(&mut self) {
        // Close our end (future pushes get `Gone` and tombstone the
        // table slot) and reclaim the queued payload buffers. The chan
        // lock is released before touching the pool — the push path
        // locks pool → table → chan, so taking pool while holding chan
        // would invert the order.
        let drained = self.chan.close();
        if !drained.is_empty() {
            let mut pool = self.pool.lock().expect("edge pool lock");
            for t in drained {
                pool.recycle(t);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Verification harness.
// ---------------------------------------------------------------------------

/// Drive one client end to end and verify every response — the shared
/// harness behind the `serve` subcommand, `examples/serve_pipeline.rs`,
/// `tests/integration_serving.rs` and the serving bench section (one
/// implementation, so the Response contract is checked the same way
/// everywhere).
///
/// Submits `count` requests (request `i` carries a pooled copy of
/// `inputs[pick(i)]`), keeps at most `window` responses outstanding
/// (`0` = strict submit→receive lockstep), and checks each response in
/// order: per-client FIFO (`seq == i`), a known weight epoch, epochs
/// non-decreasing, and the payload **bitwise equal** to
/// `expected[epoch][pick(i)]` — the sequential oracle of exactly the
/// version that served it (a torn read across a hot-reload would match
/// none). Returns the per-epoch response counts; consumed response
/// buffers are recycled into the edge pool.
pub fn drive_and_verify(
    cl: &mut ServingClient,
    inputs: &[Tensor],
    expected: &[Vec<Tensor>],
    pick: impl Fn(usize) -> usize,
    count: usize,
    window: usize,
) -> Result<Vec<u64>> {
    let report = drive_and_verify_shed(cl, inputs, expected, pick, count, window, |_| false)?;
    Ok(report.per_version)
}

/// What [`drive_and_verify_shed`] observed (every entry verified).
#[derive(Clone, Debug, Default)]
pub struct DriveReport {
    /// Payload responses per weight epoch (each bitwise-verified).
    pub per_version: Vec<u64>,
    /// Seqs that came back as shed notices, in receive order (each
    /// permitted by the caller's `may_shed` policy).
    pub shed: Vec<u64>,
    /// Payload responses tagged [`Status::Late`] (still bitwise-exact).
    pub late: u64,
}

/// [`drive_and_verify`] under a shedding policy — the chaos/soak
/// scenarios reuse this instead of forking a fifth harness. `may_shed`
/// says which seqs are *allowed* to come back as shed notices (`|_|
/// false` reproduces the strict harness exactly); the report records
/// which actually did. Shed or not, every response must arrive in
/// per-client FIFO order with a gapless seq stream, and every payload
/// must be bitwise equal to its pinned epoch's oracle — `Late` tags are
/// observational and change neither ordering nor payload checks.
pub fn drive_and_verify_shed(
    cl: &mut ServingClient,
    inputs: &[Tensor],
    expected: &[Vec<Tensor>],
    pick: impl Fn(usize) -> usize,
    count: usize,
    window: usize,
    may_shed: impl Fn(u64) -> bool,
) -> Result<DriveReport> {
    let mut report =
        DriveReport { per_version: vec![0u64; expected.len()], shed: Vec::new(), late: 0 };
    let mut last_version = 0u64;
    let mut next_recv = 0usize;
    for i in 0..count {
        let j = pick(i);
        let mut x = cl.take(inputs[j].shape());
        x.copy_from(&inputs[j]);
        cl.submit(x)?;
        while i + 1 - next_recv > window {
            verify_next(cl, expected, next_recv, pick(next_recv), &may_shed, &mut report, &mut last_version)?;
            next_recv += 1;
        }
    }
    while next_recv < count {
        verify_next(cl, expected, next_recv, pick(next_recv), &may_shed, &mut report, &mut last_version)?;
        next_recv += 1;
    }
    Ok(report)
}

/// One in-order receive + full response validation for
/// [`drive_and_verify_shed`].
fn verify_next(
    cl: &mut ServingClient,
    expected: &[Vec<Tensor>],
    i: usize,
    j: usize,
    may_shed: &impl Fn(u64) -> bool,
    report: &mut DriveReport,
    last_version: &mut u64,
) -> Result<()> {
    let r = cl.recv()?;
    ensure!(
        r.seq == i as u64,
        "client {}: response out of order (expected seq {i}, got {})",
        cl.id(),
        r.seq
    );
    if let Some(reason) = r.shed() {
        ensure!(
            may_shed(r.seq),
            "client {}: request {i} was shed ({reason:?}) but the policy expected it served",
            cl.id()
        );
        report.shed.push(r.seq);
        return Ok(());
    }
    let v = r.version as usize;
    ensure!(v < expected.len(), "client {}: unknown weight epoch {v}", cl.id());
    ensure!(
        r.version >= *last_version,
        "client {}: weight epoch went backwards ({} -> {})",
        cl.id(),
        last_version,
        r.version
    );
    *last_version = r.version;
    ensure!(
        r.data == expected[v][j],
        "client {} request {i}: response is not bitwise equal to the epoch-{v} \
         sequential oracle (torn or wrong weights)",
        cl.id()
    );
    if r.status == Status::Late {
        report.late += 1;
    }
    report.per_version[v] += 1;
    cl.recycle(r.data);
    Ok(())
}

// ---------------------------------------------------------------------------
// Worker loops.
// ---------------------------------------------------------------------------

struct BatcherCtx {
    tx0: SyncSender<Packet>,
    free_rx: Receiver<Packet>,
    version: Arc<Mutex<Arc<ModelVersion>>>,
    pool: Arc<Mutex<BufferPool>>,
    resp_txs: Arc<Mutex<Vec<Option<RespChan>>>>,
    clock: Arc<AtomicU64>,
    adapt_state: Arc<Mutex<(usize, u64)>>,
    stats: Counters,
    max_batch: usize,
    in_dim: usize,
}

/// Best-effort delivery of a payload-free terminal notice; a gone
/// client tombstones its slot. The shed counters — not `dropped` —
/// account for the request either way (a notice carries no buffer).
fn deliver_notice(txs: &mut [Option<RespChan>], notice: Response) {
    let idx = notice.client as usize;
    if let Some(slot) = txs.get_mut(idx) {
        let gone = match slot {
            Some(chan) => matches!(chan.push(notice), PushOutcome::Gone(_)),
            None => false,
        };
        if gone {
            *slot = None;
        }
    }
}

impl BatcherCtx {
    /// Terminate every request in `reqs` with a shed notice: count it,
    /// retire its queue-depth slot, recycle its input buffer, deliver a
    /// payload-free terminal response. Drains `reqs`.
    fn shed_all(&self, reqs: &mut Vec<Request>, reason: ShedReason) {
        if reqs.is_empty() {
            return;
        }
        let epoch = self.version.lock().expect("version lock").epoch;
        let mut pool = self.pool.lock().expect("edge pool lock");
        let mut txs = self.resp_txs.lock().expect("client table lock");
        for req in reqs.drain(..) {
            match reason {
                ShedReason::Deadline => self.stats.shed_deadline.inc(),
                ShedReason::Shutdown => self.stats.shed_shutdown.inc(),
                ShedReason::Backpressure => self.stats.shed_backpressure.inc(),
            }
            self.stats.queue_depth.sub(1);
            let notice = Response {
                client: req.client,
                seq: req.seq,
                version: epoch,
                data: Tensor::empty(),
                status: Status::Shed(reason),
            };
            pool.recycle(req.data);
            deliver_notice(&mut txs, notice);
        }
    }

    /// Materialize one coalesced batch into a (recycled) packet and send
    /// it downstream, draining `reqs` (the batcher's reused scratch).
    /// `false` when the pipeline is gone — in which case every routed
    /// request was already terminated with an explicit `Shutdown` shed
    /// notice (no accepted request ever silently vanishes with a
    /// packet).
    fn emit(&self, reqs: &mut Vec<Request>) -> bool {
        let version = self.version.lock().expect("version lock").clone();
        let mut p = match self.free_rx.try_recv() {
            Ok(mut p) => {
                p.version = version;
                p
            }
            Err(_) => {
                self.stats.packets_created.inc();
                Packet::fresh(version)
            }
        };
        p.routes.clear();
        p.data.resize(&[self.max_batch, self.in_dim]);
        let mut offset = 0usize;
        {
            let mut pool = self.pool.lock().expect("edge pool lock");
            for req in reqs.drain(..) {
                let rows = req.rows();
                let n = rows * self.in_dim;
                p.data.data_mut()[offset * self.in_dim..offset * self.in_dim + n]
                    .copy_from_slice(&req.data.data()[..n]);
                p.routes.push(Route {
                    client: req.client,
                    seq: req.seq,
                    rows,
                    born: req.born,
                    born_tick: req.born_tick,
                    deadline_ticks: req.deadline_ticks,
                });
                offset += rows;
                pool.recycle(req.data);
            }
        }
        // Deterministic padding: the occupied rows were just fully
        // overwritten, so only the tail needs zeroing — a batch's bits
        // depend only on its requests (and row independence makes even
        // that irrelevant to occupied rows).
        p.data.data_mut()[offset * self.in_dim..].fill(0.0);
        p.occupied = offset;
        self.stats.batches.inc();
        self.stats.rows.add(offset as u64);
        match self.tx0.send(p) {
            Ok(()) => true,
            Err(std::sync::mpsc::SendError(mut p)) => {
                // A stage died and tore the channel down: the packet (and
                // its routed requests) came back to us. Convert every
                // route into an explicit Shutdown shed notice — this was
                // the PR-5 silent-drop path.
                let epoch = p.version.epoch;
                let mut txs = self.resp_txs.lock().expect("client table lock");
                for route in p.routes.drain(..) {
                    self.stats.shed_shutdown.inc();
                    self.stats.queue_depth.sub(1);
                    deliver_notice(
                        &mut txs,
                        Response {
                            client: route.client,
                            seq: route.seq,
                            version: epoch,
                            data: Tensor::empty(),
                            status: Status::Shed(ShedReason::Shutdown),
                        },
                    );
                }
                false
            }
        }
    }
}

/// Immutable batcher knobs bundled at spawn time.
struct BatcherTuning {
    max_wait_ticks: u64,
    shrink_under: usize,
    /// `Some` iff `ServerConfig::adaptive` (the controller lives on the
    /// batcher thread — no shared mutable state on the hot path).
    adaptive: Option<AimdBatchControl>,
}

/// How many batcher iterations between AIMD observations: long enough
/// to see a latency window, short enough to react within milliseconds.
const ADAPT_EVERY: u64 = 32;

fn batcher_loop(rx: Receiver<Inbound>, ctx: BatcherCtx, tune: BatcherTuning, closing: Arc<AtomicBool>) {
    let mut co = Coalescer::with_shrink(ctx.max_batch, tune.max_wait_ticks, tune.shrink_under);
    let mut scratch: Vec<Request> = Vec::new();
    let mut expired: Vec<Request> = Vec::new();
    let mut ctl = tune.adaptive;
    let mut last_hist = ctx.stats.latency.snapshot();
    let mut iters: u64 = 0;
    // Set on pipeline teardown (a stage died): everything still in hand
    // must be shed, not emitted.
    let mut torn = false;
    'serve: loop {
        // Fallback exit for drop-without-shutdown (no marker was sent):
        // checked every iteration, so even sustained traffic — where
        // recv never times out — cannot keep a dropped server alive.
        if closing.load(Ordering::Acquire) {
            break 'serve;
        }
        match rx.recv_timeout(BATCH_TICK) {
            Ok(Inbound::Req(req)) => co.push(req),
            Ok(Inbound::Close) | Err(RecvTimeoutError::Disconnected) => break 'serve,
            Err(RecvTimeoutError::Timeout) => co.tick(),
        }
        // Drain whatever else already arrived before forming batches.
        loop {
            match rx.try_recv() {
                Ok(Inbound::Req(req)) => co.push(req),
                Ok(Inbound::Close) => break 'serve,
                Err(_) => break,
            }
        }
        ctx.clock.store(co.now(), Ordering::Release);
        // Deadline shedding happens BEFORE batch formation, decided
        // purely on the coalescer's tick clock (never wall time): an
        // expired request costs a notice, not pipeline capacity.
        if co.shed_expired(&mut expired) > 0 {
            ctx.shed_all(&mut expired, ShedReason::Deadline);
        }
        while let Some(reason) = co.take_ready_into_reason(false, &mut scratch) {
            ctx.stats.mark_flush(reason);
            if !ctx.emit(&mut scratch) {
                torn = true;
                break 'serve;
            }
            ctx.clock.store(co.now(), Ordering::Release);
        }
        // p99-driven AIMD adaptation over the *windowed* latency
        // histogram (consecutive snapshot diffs — recent requests, not
        // full history). Off by default; the controller only ever moves
        // limits within the configured clamps.
        iters += 1;
        if let Some(c) = ctl.as_mut() {
            if iters % ADAPT_EVERY == 0 {
                let hist = ctx.stats.latency.snapshot();
                let window = hist.since(&last_hist);
                if window.count > 0 {
                    let (batch, wait) = c.observe(window.quantile_ns(0.99));
                    co.set_limits(batch, wait);
                    *ctx.adapt_state.lock().expect("adapt state lock") = (batch, wait);
                }
                last_hist = hist;
            }
        }
    }
    // Final drain. In the shutdown path everything enqueued before the
    // close marker has already been popped into the coalescer (single
    // consumer over one FIFO queue); the extra try_recv sweep covers
    // the best-effort drop-without-shutdown path.
    loop {
        match rx.try_recv() {
            Ok(Inbound::Req(req)) => co.push(req),
            _ => break,
        }
    }
    ctx.clock.store(co.now(), Ordering::Release);
    if !torn {
        // Drain-or-shed: expired requests shed, everything else force-
        // emitted through the still-live pipeline.
        if co.shed_expired(&mut expired) > 0 {
            ctx.shed_all(&mut expired, ShedReason::Deadline);
        }
        while let Some(reason) = co.take_ready_into_reason(true, &mut scratch) {
            ctx.stats.mark_flush(reason);
            if !ctx.emit(&mut scratch) {
                torn = true;
                break;
            }
        }
    }
    if torn {
        // The pipeline died under us: no downstream thread will ever
        // answer, so terminate every request still in hand with an
        // explicit Shutdown notice (emit already shed the ones routed
        // into its failed packet). One last channel sweep catches
        // requests that raced in while we were shedding; later submits
        // fail on the disconnected channel once `rx` drops.
        co.drain_all(&mut scratch);
        loop {
            match rx.try_recv() {
                Ok(Inbound::Req(req)) => scratch.push(req),
                _ => break,
            }
        }
        ctx.shed_all(&mut scratch, ShedReason::Shutdown);
    }
}

fn stage_loop(
    exec: Backend,
    mut ops: Vec<(usize, Box<dyn Layer>)>,
    rx: Receiver<Packet>,
    tx: SyncSender<Packet>,
    fail: Arc<Mutex<Option<String>>>,
    mut fault: Option<Rng>,
    stats: Counters,
) {
    while let Ok(mut p) = rx.recv() {
        // Chaos hook (`fault_stall_seed`): a seeded, time-only stall
        // between packets. Reorders nothing, touches no data — the
        // survival invariants must hold under arbitrary stage timing.
        if let Some(rng) = fault.as_mut() {
            if rng.chance(0.25) {
                stats.faults.inc();
                std::thread::sleep(Duration::from_micros(100 + rng.below(900)));
            }
        }
        // Span slot: the OS thread name ("serve-stage-{s}") keys the
        // aggregate, so each stage reports separately without an
        // explicit set_thread_name.
        crate::obs::span!("serving/forward");
        for (l, op) in ops.iter_mut() {
            let (w, b) = &p.version.params[*l];
            if let Err(e) = op.forward_into(exec.as_ref(), &p.data, w, b, &mut p.spare) {
                let mut slot = fail.lock().expect("fail lock");
                if slot.is_none() {
                    *slot = Some(format!("serving forward, layer {l}: {e:#}"));
                }
                // Dropping our endpoints disconnects both neighbors —
                // the shutdown cascades instead of deadlocking.
                return;
            }
            std::mem::swap(&mut p.data, &mut p.spare);
        }
        if tx.send(p).is_err() {
            return;
        }
    }
}

struct CollectorCtx {
    free_tx: SyncSender<Packet>,
    resp_txs: Arc<Mutex<Vec<Option<RespChan>>>>,
    pool: Arc<Mutex<BufferPool>>,
    clock: Arc<AtomicU64>,
    stats: Counters,
    out_dim: usize,
}

fn collector_loop(rx: Receiver<Packet>, ctx: CollectorCtx) {
    while let Ok(mut p) = rx.recv() {
        let now_tick = ctx.clock.load(Ordering::Acquire);
        let mut offset = 0usize;
        // One pool guard and one client-table guard per *packet*, not
        // per route: the bounded-queue pushes never block (shed-oldest,
        // not wait), so holding both across the batch is cheap and
        // halves the hot-path lock traffic contending with client
        // take()/recycle(). Lock order (pool → table → chan) is unique
        // to this path — no other thread locks downward from a chan.
        {
            let mut pool = ctx.pool.lock().expect("edge pool lock");
            let mut txs = ctx.resp_txs.lock().expect("client table lock");
            for route in p.routes.drain(..) {
                // Submit→respond latency, recorded whether or not the
                // client is still listening; the queue-depth gauge
                // retires the request either way.
                ctx.stats.latency.record_secs(route.born.elapsed().as_secs_f64());
                ctx.stats.queue_depth.sub(1);
                let mut out = pool.take(&[route.rows, ctx.out_dim]);
                let n = route.rows * ctx.out_dim;
                out.data_mut()[..n]
                    .copy_from_slice(&p.data.data()[offset * ctx.out_dim..offset * ctx.out_dim + n]);
                offset += route.rows;
                // Tick-measured late tag — observational only: the
                // payload still ships and is still bitwise-exact.
                let status = if route.deadline_ticks > 0
                    && now_tick.saturating_sub(route.born_tick) > route.deadline_ticks
                {
                    Status::Late
                } else {
                    Status::Ok
                };
                let resp = Response {
                    client: route.client,
                    seq: route.seq,
                    version: p.version.epoch,
                    data: out,
                    status,
                };
                let idx = route.client as usize;
                match txs.get(idx).and_then(|slot| slot.clone()) {
                    Some(chan) => match chan.push(resp) {
                        PushOutcome::Delivered { shed_payload } => {
                            ctx.stats.completed.inc();
                            if status == Status::Late {
                                ctx.stats.late.inc();
                            }
                            if let Some(t) = shed_payload {
                                // Bounded-queue backpressure: the oldest
                                // buffered payload was stripped to a
                                // notice; reclaim its buffer.
                                ctx.stats.shed_backpressure.inc();
                                pool.recycle(t);
                            }
                        }
                        PushOutcome::Gone(resp) => {
                            // Client handle dropped: reclaim the buffer
                            // and tombstone the slot.
                            pool.recycle(resp.data);
                            txs[idx] = None;
                            ctx.stats.dropped.inc();
                        }
                    },
                    None => {
                        pool.recycle(resp.data);
                        ctx.stats.dropped.inc();
                    }
                }
            }
        }
        debug_assert_eq!(offset, p.occupied);
        // Return the packet to the batcher; capacity is sized so this
        // never drops a warm packet in practice.
        let _ = ctx.free_tx.try_send(p);
    }
    // No more responses can ever arrive (the batcher sheds rather than
    // sends once the pipeline is torn, and its sheds happen-before our
    // exit in the orderly path): wake every client blocked in recv so
    // it drains its queue and gets a clean disconnect.
    let txs = ctx.resp_txs.lock().expect("client table lock");
    for chan in txs.iter().flatten() {
        chan.mark_done();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;
    use crate::config::ModelConfig;

    fn mcfg() -> ModelConfig {
        ModelConfig { batch: 8, input_dim: 12, hidden_dim: 10, classes: 4, layers: 3, init_scale: 1.0 }
    }

    fn tiny_net(seed: u64) -> Network {
        Network::build(&NetworkSpec::mlp(&mcfg()), &mut Rng::new(seed)).unwrap()
    }

    fn host() -> Backend {
        Arc::new(HostBackend::new())
    }

    fn req(rows: usize, seq: u64) -> Request {
        req_dl(rows, seq, 0, 0)
    }

    fn req_dl(rows: usize, seq: u64, born_tick: u64, deadline_ticks: u64) -> Request {
        Request {
            client: 0,
            seq,
            data: Tensor::zeros(&[rows, 1]),
            born: Instant::now(),
            born_tick,
            deadline_ticks,
        }
    }

    #[test]
    fn coalescer_emits_full_batches_immediately() {
        let mut co = Coalescer::new(4, 10);
        co.push(req(2, 0));
        assert!(co.take_ready(false).is_none(), "partial batch must wait");
        co.push(req(2, 1));
        let b = co.take_ready(false).expect("exactly full");
        assert_eq!(b.len(), 2);
        assert_eq!(co.pending_rows(), 0);
        // A request that does not fit closes the current batch.
        co.push(req(3, 2));
        co.push(req(2, 3));
        let b = co.take_ready(false).expect("overflow closes the batch");
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].seq, 2);
        assert_eq!(co.pending_rows(), 2);
    }

    #[test]
    fn coalescer_flushes_after_wait_budget_and_on_force() {
        let mut co = Coalescer::new(8, 2);
        co.push(req(1, 0));
        co.tick();
        assert!(co.take_ready(false).is_none());
        co.tick();
        let b = co.take_ready(false).expect("wait budget spent");
        assert_eq!(b.len(), 1);
        // Ticks on an empty queue never count.
        co.tick();
        co.tick();
        co.push(req(1, 1));
        assert!(co.take_ready(false).is_none());
        let b = co.take_ready(true).expect("force flush");
        assert_eq!(b[0].seq, 1);
        assert!(co.take_ready(true).is_none());
    }

    #[test]
    fn coalescer_shrinks_queue_emptying_small_batches() {
        // shrink_under 2: a lone small request flushes with zero ticks…
        let mut co = Coalescer::with_shrink(8, 1_000, 2);
        co.push(req(2, 0));
        let b = co.take_ready(false).expect("queue-emptying small batch flushes immediately");
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].seq, 0);
        // …a bigger-than-threshold prefix still waits…
        co.push(req(3, 1));
        assert!(co.take_ready(false).is_none(), "above shrink_under: normal coalescing");
        // …and a backlog behind the prefix disables the shrink (the
        // prefix would not empty the queue), even if the prefix is small.
        let mut co = Coalescer::with_shrink(4, 1_000, 4);
        co.push(req(3, 0));
        co.push(req(3, 1));
        let b = co.take_ready(false).expect("overflow closes the batch as before");
        assert_eq!((b.len(), b[0].seq), (1, 0));
        assert_eq!(co.pending_rows(), 3);
        let b = co.take_ready(false).expect("remainder now empties the queue → shrink");
        assert_eq!((b.len(), b[0].seq), (1, 1));
        // shrink_under 0 is exactly the old behavior.
        let mut co = Coalescer::new(8, 5);
        co.push(req(1, 0));
        assert!(co.take_ready(false).is_none(), "shrink disabled by default");
    }

    #[test]
    fn coalescer_reports_flush_reasons() {
        let mut co = Coalescer::with_shrink(4, 2, 1);
        let mut out = Vec::new();
        // Full: exactly max_batch rows.
        co.push(req(2, 0));
        co.push(req(2, 1));
        assert_eq!(co.take_ready_into_reason(false, &mut out), Some(FlushReason::Full));
        out.clear();
        // Shrank: queue-emptying prefix ≤ shrink_under.
        co.push(req(1, 2));
        assert_eq!(co.take_ready_into_reason(false, &mut out), Some(FlushReason::Shrank));
        out.clear();
        // Waited: idle-tick budget spent.
        co.push(req(2, 3));
        co.tick();
        assert_eq!(co.take_ready_into_reason(false, &mut out), None);
        co.tick();
        assert_eq!(co.take_ready_into_reason(false, &mut out), Some(FlushReason::Waited));
        out.clear();
        // Force: shutdown drain beats the wait budget.
        co.push(req(2, 4));
        assert_eq!(co.take_ready_into_reason(true, &mut out), Some(FlushReason::Force));
        out.clear();
        assert_eq!(co.take_ready_into_reason(true, &mut out), None, "empty queue");
    }

    #[test]
    fn roundtrip_matches_forward_full_bitwise_in_fifo_order() {
        let net = tiny_net(5);
        let mut oracle = net.snapshot().unwrap();
        let be = HostBackend::new();
        let cfg = ServerConfig {
            max_batch: 6,
            max_wait_ticks: 1,
            shrink_under: 0,
            queue_depth: 16,
            stages: 2,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        assert_eq!(server.partition().stages(), 2);
        let mut cl = server.client();
        let mut rng = Rng::new(9);
        let inputs: Vec<Tensor> =
            (0..7).map(|i| Tensor::randn(&[1 + i % 3, 12], 1.0, &mut rng)).collect();
        for x in &inputs {
            cl.submit(x.clone()).unwrap();
        }
        for (i, x) in inputs.iter().enumerate() {
            let r = cl.recv().unwrap();
            assert_eq!(r.seq, i as u64, "per-client FIFO order violated");
            assert_eq!(r.version, 0);
            assert_eq!(r.client, cl.id());
            let want = oracle.forward_full(&be, x).unwrap();
            assert_eq!(r.data, want, "request {i}: batched ≠ sequential oracle");
            cl.recycle(r.data);
        }
        let hist = server.latency_hist();
        assert_eq!(hist.count, 7, "one latency sample per request");
        assert!(server.latency_ms().is_some());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.submitted, 7);
        assert_eq!(stats.completed, 7);
        assert_eq!(stats.dropped, 0);
        assert_eq!(stats.rows, inputs.iter().map(|x| x.shape()[0] as u64).sum::<u64>());
        assert_eq!(stats.queue_depth, 0, "every accepted request was routed");
        assert_eq!(
            stats.flush_full + stats.flush_shrank + stats.flush_force + stats.flush_wait,
            stats.batches,
            "every batch carries exactly one flush reason"
        );
    }

    #[test]
    fn reload_swaps_epoch_and_weights() {
        let net0 = tiny_net(5);
        let net1 = tiny_net(6);
        let mut oracle1 = net1.snapshot().unwrap();
        let be = HostBackend::new();
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait_ticks: 0,
            shrink_under: 0,
            queue_depth: 8,
            stages: 1,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net0, &cfg).unwrap();
        assert_eq!(server.epoch(), 0);
        assert_eq!(server.reload(&net1).unwrap(), 1);
        assert_eq!(server.epoch(), 1);
        let mut cl = server.client();
        let x = Tensor::randn(&[2, 12], 1.0, &mut Rng::new(3));
        cl.submit(x.clone()).unwrap();
        let r = cl.recv().unwrap();
        assert_eq!(r.version, 1, "post-reload batch must carry the new epoch");
        assert_eq!(r.data, oracle1.forward_full(&be, &x).unwrap());
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.reloads, 1);
        assert_eq!(stats.epoch, 1);
    }

    #[test]
    fn reload_from_file_roundtrips_bf16_checkpoints() {
        use crate::model::checkpoint::save_network;
        use crate::tensor::Dtype;
        // A trained-in-bf16 network checkpoints as v3; the serving path
        // must restore it bit-for-bit and serve responses that match
        // the bf16 network's own sequential oracle.
        let net0 = tiny_net(5);
        let mut net1 = tiny_net(6);
        for nl in &mut net1.layers {
            nl.w = nl.w.to_dtype(Dtype::Bf16);
        }
        let mut oracle1 = net1.snapshot().unwrap();
        let be = HostBackend::new();
        let path = std::env::temp_dir().join(format!("lp2_srv_bf16_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save_network(&net1, &path).unwrap();

        let cfg = ServerConfig {
            max_batch: 4,
            max_wait_ticks: 0,
            shrink_under: 0,
            queue_depth: 8,
            stages: 2,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net0, &cfg).unwrap();
        assert_eq!(server.reload_from_file(&path).unwrap(), 1);
        let mut cl = server.client();
        let x = Tensor::randn(&[2, 12], 1.0, &mut Rng::new(3));
        cl.submit(x.clone()).unwrap();
        let r = cl.recv().unwrap();
        assert_eq!(r.version, 1);
        assert_eq!(
            r.data,
            oracle1.forward_full(&be, &x).unwrap(),
            "served bf16 forward must equal the bf16 oracle bitwise"
        );
        server.shutdown().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reload_rejects_architecture_mismatch() {
        let net = tiny_net(5);
        let cfg = ServerConfig {
            max_batch: 2,
            max_wait_ticks: 0,
            shrink_under: 0,
            queue_depth: 4,
            stages: 1,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let other_cfg =
            ModelConfig { batch: 8, input_dim: 12, hidden_dim: 11, classes: 4, layers: 3, init_scale: 1.0 };
        let other = Network::build(&NetworkSpec::mlp(&other_cfg), &mut Rng::new(1)).unwrap();
        let err = server.reload(&other).unwrap_err();
        assert!(format!("{err:#}").contains("spec"));
        server.shutdown().unwrap();
    }

    #[test]
    fn submit_validates_shapes_and_errors_after_shutdown() {
        let net = tiny_net(5);
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait_ticks: 0,
            shrink_under: 0,
            queue_depth: 4,
            stages: 1,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        assert!(cl.submit(Tensor::zeros(&[2, 11])).is_err(), "wrong width");
        assert!(cl.submit(Tensor::zeros(&[5, 12])).is_err(), "rows > max_batch");
        assert!(cl.submit(Tensor::zeros(&[0, 12])).is_err(), "empty request");
        assert!(cl.poll().is_none());
        server.shutdown().unwrap();
        let err = cl.submit(Tensor::zeros(&[1, 12])).unwrap_err();
        assert!(format!("{err:#}").contains("shut down"));
        assert!(cl.recv().is_err(), "recv after shutdown must error");
    }

    #[test]
    fn shutdown_drains_outstanding_requests() {
        let net = tiny_net(5);
        // Large wait budget: without the shutdown drain these would sit
        // in a partial batch forever.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait_ticks: 1_000_000,
            shrink_under: 0,
            queue_depth: 8,
            stages: 2,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        let x = Tensor::randn(&[2, 12], 1.0, &mut Rng::new(4));
        cl.submit(x.clone()).unwrap();
        cl.submit(x).unwrap();
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completed, 2, "shutdown must flush the partial batch");
        assert_eq!(cl.recv().unwrap().seq, 0);
        assert_eq!(cl.recv().unwrap().seq, 1);
    }

    #[test]
    fn token_bucket_enforces_rate_and_burst() {
        let mut tb = TokenBucket::new(4, 2);
        // Starts full: burst of 4 spends down to zero.
        assert!(tb.admit(0, 3));
        assert!(tb.admit(0, 1));
        assert!(!tb.admit(0, 1), "burst exhausted within a tick");
        assert_eq!(tb.tokens(), 0);
        // One elapsed tick refills `refill_per_tick`.
        assert!(tb.admit(1, 2));
        assert!(!tb.admit(1, 1));
        // A long gap refills at most `capacity`.
        assert!(tb.admit(100, 4));
        assert!(!tb.admit(100, 1));
        // A stale (non-monotonic) tick refills nothing.
        assert!(!tb.admit(50, 1));
        // …and does not corrupt the high-water mark.
        assert!(tb.admit(101, 2));
    }

    #[test]
    fn aimd_controller_converges_within_clamps() {
        let mut ctl = AimdBatchControl::new(2, 32, 1, 8, 2_000_000);
        assert_eq!(ctl.limits(), (32, 8), "starts at the configured ceiling");
        // Sustained pressure: multiplicative decrease to the floor.
        for _ in 0..64 {
            let (b, w) = ctl.observe(10_000_000);
            assert!((2..=32).contains(&b) && (1..=8).contains(&w), "clamps hold every step");
        }
        assert_eq!(ctl.limits(), (2, 1), "converges to the floor under pressure");
        // Sustained headroom: additive increase back to the ceiling.
        for _ in 0..64 {
            let (b, w) = ctl.observe(100_000);
            assert!((2..=32).contains(&b) && (1..=8).contains(&w), "clamps hold every step");
        }
        assert_eq!(ctl.limits(), (32, 8), "recovers to the ceiling when idle");
    }

    #[test]
    fn aimd_does_not_shrink_on_torn_snapshot_skew() {
        // Regression for the windowed-quantile inconsistency: the AIMD
        // window diffs two relaxed-atomic captures, so a record can be
        // visible in `count` before its bucket increment is. With the
        // rank derived from `count`, the bucket scan fell short and p99
        // read as the top-bucket floor (hundreds of seconds) even though
        // every visible latency was microseconds — one such window per
        // ADAPT_EVERY was enough to halve the limits spuriously. The
        // fixed rank comes from the bucket sum, so the torn window
        // reports the visible-record quantile and the controller holds.
        let target = 2_000_000u64; // 2 ms
        let torn = crate::obs::HistSnapshot::synthetic(14, 14_000, &[(1_000, 10)]);
        let p99 = torn.quantile_ns(0.99);
        assert!(
            p99 <= target,
            "torn window must report the visible-record p99 ({p99} ns), not the top bucket"
        );
        let mut ctl = AimdBatchControl::new(2, 32, 1, 8, target);
        for _ in 0..8 {
            ctl.observe(torn.quantile_ns(0.99));
        }
        assert_eq!(ctl.limits(), (32, 8), "controller must not shrink on the synthetic skew");
    }

    #[test]
    fn coalescer_sheds_expired_requests_deterministically() {
        let mut co = Coalescer::new(8, 1_000_000);
        let t0 = co.now();
        co.push(req_dl(1, 0, t0, 2));
        co.push(req_dl(1, 1, t0, 0)); // deadline 0: never expires
        co.push(req_dl(1, 2, t0, 5));
        let mut out = Vec::new();
        assert_eq!(co.shed_expired(&mut out), 0);
        co.tick();
        assert_eq!(co.shed_expired(&mut out), 0, "one tick short of the deadline");
        co.tick();
        assert_eq!(co.shed_expired(&mut out), 1, "expires exactly at deadline_ticks");
        assert_eq!(out[0].seq, 0);
        co.tick();
        co.tick();
        co.tick();
        assert_eq!(co.shed_expired(&mut out), 1);
        assert_eq!(out[1].seq, 2);
        for _ in 0..100 {
            co.tick();
        }
        assert_eq!(co.shed_expired(&mut out), 0, "deadline 0 must never expire");
        assert_eq!(co.pending_rows(), 1);
        // The tick clock also advances when a batch is emitted, so
        // deadlines keep maturing under saturation (no idle ticks).
        let mut co = Coalescer::new(2, 1_000_000);
        let t0 = co.now();
        co.push(req(2, 0));
        assert_eq!(co.take_ready_into_reason(false, &mut out), Some(FlushReason::Full));
        assert_eq!(co.now(), t0 + 1, "emitting a batch advances the clock");
    }

    #[test]
    fn shutdown_same_tick_submits_get_terminal_responses() {
        let net = tiny_net(5);
        let mut oracle = net.snapshot().unwrap();
        let be = HostBackend::new();
        // Large wait budget: these requests are still queued in the
        // coalescer when shutdown lands.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait_ticks: 1_000_000,
            shrink_under: 0,
            queue_depth: 8,
            stages: 2,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        let mut rng = Rng::new(11);
        let xs: Vec<Tensor> = (0..3).map(|_| Tensor::randn(&[1, 12], 1.0, &mut rng)).collect();
        for x in &xs {
            cl.submit(x.clone()).unwrap();
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.submitted, 3);
        assert_eq!(
            stats.completed + stats.shed_shutdown + stats.shed_deadline,
            3,
            "every accepted request gets exactly one terminal event"
        );
        assert_eq!(stats.queue_depth, 0, "no request left in limbo");
        for (i, x) in xs.iter().enumerate() {
            let r = cl.recv().expect("terminal response, never a silent drop");
            assert_eq!(r.seq, i as u64, "terminal events stay in FIFO order");
            match r.status {
                Status::Shed(ShedReason::Shutdown) => assert_eq!(r.data, Tensor::empty()),
                _ => {
                    assert_eq!(r.data, oracle.forward_full(&be, x).unwrap());
                    cl.recycle(r.data);
                }
            }
        }
        assert!(cl.recv().is_err(), "exactly one terminal event per request");
    }

    #[test]
    fn deadline_expiry_sheds_before_batch_formation() {
        let net = tiny_net(5);
        // Wait budget far beyond the deadline: without deadline shedding
        // this request would sit in a partial batch until shutdown.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait_ticks: 1_000_000,
            shrink_under: 0,
            queue_depth: 8,
            stages: 1,
            deadline_ticks: 2,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        cl.submit(Tensor::randn(&[1, 12], 1.0, &mut Rng::new(2))).unwrap();
        let r = cl.recv().unwrap();
        assert_eq!(r.seq, 0);
        assert_eq!(r.status, Status::Shed(ShedReason::Deadline));
        assert_eq!(r.data, Tensor::empty(), "no payload was ever computed");
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.shed_deadline, 1);
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.queue_depth, 0);
    }

    #[test]
    fn backpressure_strips_oldest_payload_with_notice() {
        let net = tiny_net(5);
        let mut oracle = net.snapshot().unwrap();
        let be = HostBackend::new();
        let cfg = ServerConfig {
            max_batch: 1, // every submit forms its own batch immediately
            max_wait_ticks: 0,
            shrink_under: 0,
            queue_depth: 8,
            stages: 1,
            client_queue_cap: 2,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        let mut rng = Rng::new(13);
        let xs: Vec<Tensor> = (0..5).map(|_| Tensor::randn(&[1, 12], 1.0, &mut rng)).collect();
        for x in &xs {
            cl.submit(x.clone()).unwrap();
        }
        // Let all five complete while the client reads nothing: the
        // bounded queue must strip the three oldest payloads in place.
        while server.stats().completed < 5 {
            std::thread::sleep(Duration::from_millis(1));
        }
        for (i, x) in xs.iter().enumerate() {
            let r = cl.poll().expect("notice or payload for every request");
            assert_eq!(r.seq, i as u64, "stripping must not reorder the stream");
            if i < 3 {
                assert_eq!(r.status, Status::Shed(ShedReason::Backpressure));
                assert_eq!(r.data, Tensor::empty());
            } else {
                assert_eq!(r.status, Status::Ok);
                assert_eq!(r.data, oracle.forward_full(&be, x).unwrap());
                cl.recycle(r.data);
            }
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.completed, 5, "strips happen after completion");
        assert_eq!(stats.shed_backpressure, 3);
        assert_eq!(stats.queue_depth, 0);
    }

    #[test]
    fn admission_rate_limit_rejects_and_accounts() {
        let net = tiny_net(5);
        let cfg = ServerConfig {
            max_batch: 4,
            max_wait_ticks: 0,
            shrink_under: 0,
            queue_depth: 8,
            stages: 1,
            admit_rate: 1,
            admit_burst: 1,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        let (mut accepted, mut rejected) = (0u64, 0u64);
        for _ in 0..32 {
            match cl.submit_with(Tensor::zeros(&[1, 12]), 0).unwrap() {
                SubmitVerdict::Accepted(seq) => {
                    assert_eq!(seq, accepted, "rejections must not consume seq numbers");
                    accepted += 1;
                }
                SubmitVerdict::Rejected { reason, data } => {
                    assert_eq!(reason, RejectReason::RateLimited);
                    rejected += 1;
                    cl.recycle(data);
                }
            }
        }
        assert!(accepted >= 1, "a full bucket admits the first request");
        assert!(rejected >= 1, "a tight loop must outrun refill at 1 row/tick");
        for i in 0..accepted {
            let r = cl.recv().unwrap();
            assert_eq!(r.seq, i, "accepted stream stays gapless FIFO");
            cl.recycle(r.data);
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.submitted, accepted, "rejected requests never count as submitted");
        assert_eq!(stats.rejected_rate, rejected);
        assert_eq!(stats.rejected_budget, 0);
    }

    #[test]
    fn admission_budget_rejects_when_saturated() {
        let net = tiny_net(5);
        // Wait budget keeps the first request in flight indefinitely, so
        // the second submit deterministically finds the budget spent.
        let cfg = ServerConfig {
            max_batch: 8,
            max_wait_ticks: 1_000_000,
            shrink_under: 0,
            queue_depth: 8,
            stages: 1,
            inflight_cap: 1,
            ..ServerConfig::default()
        };
        let server = Server::start(host(), &net, &cfg).unwrap();
        let mut cl = server.client();
        match cl.submit_with(Tensor::zeros(&[1, 12]), 0).unwrap() {
            SubmitVerdict::Accepted(0) => {}
            v => panic!("first request must be admitted, got {v:?}"),
        }
        match cl.submit_with(Tensor::zeros(&[1, 12]), 0).unwrap() {
            SubmitVerdict::Rejected { reason: RejectReason::Saturated, data } => cl.recycle(data),
            v => panic!("budget must reject the second request, got {v:?}"),
        }
        let stats = server.shutdown().unwrap();
        assert_eq!(stats.submitted, 1);
        assert_eq!(stats.rejected_budget, 1);
        assert_eq!(stats.rejected_rate, 0);
        assert_eq!(stats.completed + stats.shed_shutdown, 1, "the admitted request terminates");
        let r = cl.recv().unwrap();
        assert_eq!(r.seq, 0);
    }
}
