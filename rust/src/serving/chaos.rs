//! Deterministic fault-injection + soak harness for the serving
//! survival layer.
//!
//! Five scripted scenarios — client churn, a slow (backpressured)
//! client, a hot-reload storm, admission-controlled saturation bursts,
//! and injected stage-worker stalls — all driven by one seeded
//! [`Rng`](crate::util::Rng). Every scenario asserts the survival
//! invariants the ISSUE names:
//!
//! * **zero lost**: every accepted request gets exactly one terminal
//!   response (payload or shed notice) — checked both on the wire
//!   (gapless per-client seq streams) and against the obs counters
//!   (`submitted == completed + dropped + shed_deadline +
//!   shed_shutdown`, `queue_depth == 0` after shutdown);
//! * **zero duplicated / reordered**: the per-client seq stream is
//!   strictly `0, 1, 2, …` in receive order;
//! * **bitwise payloads**: every payload equals the sequential oracle
//!   of exactly the weight epoch that served it;
//! * **exact accounting**: rejects and sheds observed by the driver
//!   match the obs counters one for one (where the driver can observe
//!   them synchronously).
//!
//! Faults are *time-only* by construction (stalls reorder wall time,
//! never data; deadlines and sheds are decided on the batcher's tick
//! clock), so the invariants hold on every run — the harness is a soak,
//! not a flake generator. Wall-clock throughput and latency quantiles
//! are measured for the report only; nothing branches on them.

use super::{
    drive_and_verify, drive_and_verify_shed, Response, Server, ServerConfig, ServingClient,
    ServingStats, Status, SubmitVerdict,
};
use crate::backend::{Backend, HostBackend};
use crate::config::ModelConfig;
use crate::layers::{Network, NetworkSpec};
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{ensure, Result};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Soak harness configuration. `smoke` shrinks every scenario to a
/// CI-sized run (sub-second) without changing any invariant checked.
#[derive(Clone, Copy, Debug)]
pub struct SoakConfig {
    /// Seed for every scripted decision (inputs, churn order, stalls).
    pub seed: u64,
    /// Small sizes for CI gates; `false` is the full soak.
    pub smoke: bool,
}

impl Default for SoakConfig {
    fn default() -> SoakConfig {
        SoakConfig { seed: 0xC0FFEE, smoke: false }
    }
}

/// Per-scenario outcome. `lost`/`duplicated`/`reordered` are always 0
/// on success — a violation fails the soak with an error instead of
/// reporting a nonzero count, so a passing report *is* the invariant.
#[derive(Clone, Debug, Default)]
pub struct ScenarioReport {
    pub name: &'static str,
    pub submitted: u64,
    pub completed: u64,
    pub dropped: u64,
    pub rejected: u64,
    pub shed: u64,
    pub late: u64,
    pub faults: u64,
    pub reloads: u64,
    pub lost: u64,
    pub duplicated: u64,
    pub reordered: u64,
}

impl ScenarioReport {
    fn from_stats(name: &'static str, stats: &ServingStats) -> ScenarioReport {
        ScenarioReport {
            name,
            submitted: stats.submitted,
            completed: stats.completed,
            dropped: stats.dropped,
            rejected: stats.rejected_rate + stats.rejected_budget,
            shed: stats.shed_deadline + stats.shed_shutdown + stats.shed_backpressure,
            late: stats.late,
            faults: stats.faults_injected,
            reloads: stats.reloads,
            lost: 0,
            duplicated: 0,
            reordered: 0,
        }
    }

    fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"submitted\":{},\"completed\":{},\"dropped\":{},\
             \"rejected\":{},\"shed\":{},\"late\":{},\"faults\":{},\"reloads\":{},\
             \"lost\":{},\"duplicated\":{},\"reordered\":{}}}",
            self.name,
            self.submitted,
            self.completed,
            self.dropped,
            self.rejected,
            self.shed,
            self.late,
            self.faults,
            self.reloads,
            self.lost,
            self.duplicated,
            self.reordered
        )
    }
}

/// The whole soak: per-scenario reports plus an aggregate steady-state
/// throughput/latency measurement (wall-clock, report-only).
#[derive(Clone, Debug)]
pub struct SoakReport {
    pub seed: u64,
    pub smoke: bool,
    pub scenarios: Vec<ScenarioReport>,
    pub req_per_s: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub lost: u64,
    pub duplicated: u64,
    pub reordered: u64,
}

impl SoakReport {
    /// The `"soak"` section of `BENCH_serving.json` (verify.sh greps
    /// for `"lost":0` and `"duplicated":0` — keys carry no spaces).
    pub fn to_json(&self) -> String {
        let scenarios: Vec<String> = self.scenarios.iter().map(|s| s.to_json()).collect();
        format!(
            "{{\"seed\":{},\"smoke\":{},\"lost\":{},\"duplicated\":{},\"reordered\":{},\
             \"req_per_s\":{:.1},\"p50_ms\":{:.3},\"p99_ms\":{:.3},\"scenarios\":[{}]}}",
            self.seed,
            self.smoke,
            self.lost,
            self.duplicated,
            self.reordered,
            self.req_per_s,
            self.p50_ms,
            self.p99_ms,
            scenarios.join(",")
        )
    }
}

/// Run every scenario plus the steady-state measurement. Any invariant
/// violation (lost, duplicated, reordered, accounting drift, non-bitwise
/// payload) is an `Err` — a returned report always carries zeros.
pub fn run_soak(cfg: &SoakConfig) -> Result<SoakReport> {
    let scenarios = vec![
        scenario_churn(cfg)?,
        scenario_slow_client(cfg)?,
        scenario_reload_storm(cfg)?,
        scenario_saturation(cfg)?,
        scenario_stage_stall(cfg)?,
    ];
    let (req_per_s, p50_ms, p99_ms) = measure_steady_state(cfg)?;
    let lost = scenarios.iter().map(|s| s.lost).sum();
    let duplicated = scenarios.iter().map(|s| s.duplicated).sum();
    let reordered = scenarios.iter().map(|s| s.reordered).sum();
    ensure!(
        lost == 0 && duplicated == 0 && reordered == 0,
        "soak invariants violated: lost={lost} duplicated={duplicated} reordered={reordered}"
    );
    Ok(SoakReport {
        seed: cfg.seed,
        smoke: cfg.smoke,
        scenarios,
        req_per_s,
        p50_ms,
        p99_ms,
        lost,
        duplicated,
        reordered,
    })
}

// ---------------------------------------------------------------------------
// Shared fixtures.
// ---------------------------------------------------------------------------

const IN_DIM: usize = 16;

fn mcfg() -> ModelConfig {
    ModelConfig { batch: 8, input_dim: IN_DIM, hidden_dim: 12, classes: 6, layers: 3, init_scale: 1.0 }
}

fn build_net(seed: u64) -> Result<Network> {
    Network::build(&NetworkSpec::mlp(&mcfg()), &mut Rng::new(seed))
}

fn host() -> Backend {
    Arc::new(HostBackend::new())
}

/// Seeded request inputs, `1..=3` rows each (every scenario config keeps
/// `max_batch >= 4`, so any of them fits any batch).
fn inputs_for(rng: &mut Rng, n: usize) -> Vec<Tensor> {
    (0..n).map(|_| Tensor::randn(&[1 + rng.below(3) as usize, IN_DIM], 1.0, rng)).collect()
}

/// Sequential-oracle outputs of `net` for each input (one epoch).
fn oracle_outputs(net: &Network, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let be = HostBackend::new();
    let mut oracle = net.snapshot()?;
    inputs.iter().map(|x| oracle.forward_full(&be, x)).collect()
}

/// The terminal accounting identity every scenario must end in: no
/// request in limbo, every accepted request exactly one terminal event.
fn check_terminal_identity(name: &str, stats: &ServingStats) -> Result<()> {
    ensure!(stats.queue_depth == 0, "{name}: {} requests left in limbo", stats.queue_depth);
    ensure!(
        stats.submitted
            == stats.completed + stats.dropped + stats.shed_deadline + stats.shed_shutdown,
        "{name}: terminal accounting broken: {stats:?}"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Scenarios.
// ---------------------------------------------------------------------------

/// Client churn: short-lived clients come and go, some submitting and
/// vanishing without ever reading a response (dead clients). Live
/// clients verify FIFO + bitwise payloads; dead clients' responses must
/// be accounted as `dropped`, never leaked or delivered to a stranger.
fn scenario_churn(cfg: &SoakConfig) -> Result<ScenarioReport> {
    let (rounds, reqs) = if cfg.smoke { (3, 6) } else { (10, 16) };
    let net = build_net(cfg.seed ^ 0x01)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0101);
    let inputs = inputs_for(&mut rng, 8);
    let expected = vec![oracle_outputs(&net, &inputs)?];
    let scfg = ServerConfig {
        max_batch: 8,
        max_wait_ticks: 2,
        queue_depth: 32,
        stages: 2,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &scfg)?;
    for round in 0..rounds {
        let mut cl = server.client();
        let skew = rng.below(inputs.len() as u64) as usize;
        drive_and_verify(&mut cl, &inputs, &expected, |i| (i + skew) % inputs.len(), reqs, 4)?;
        drop(cl);
        if round % 2 == 1 {
            // A dead client: submits, then vanishes mid-flight.
            let mut dead = server.client();
            for k in 0..3usize {
                let j = (round + k) % inputs.len();
                let mut x = dead.take(inputs[j].shape());
                x.copy_from(&inputs[j]);
                dead.submit(x)?;
            }
            drop(dead);
        }
    }
    let stats = server.shutdown()?;
    check_terminal_identity("churn", &stats)?;
    Ok(ScenarioReport::from_stats("churn", &stats))
}

/// One slow client: it submits a burst and reads nothing until every
/// response has landed in its bounded queue. The oldest payloads must
/// be stripped to `Backpressure` notices — gapless seq stream, bounded
/// memory, survivors still bitwise.
fn scenario_slow_client(cfg: &SoakConfig) -> Result<ScenarioReport> {
    let n: usize = if cfg.smoke { 10 } else { 48 };
    let cap: usize = 4;
    let net = build_net(cfg.seed ^ 0x02)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0202);
    let inputs = inputs_for(&mut rng, 8);
    let expected = oracle_outputs(&net, &inputs)?;
    let scfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 1,
        queue_depth: 16,
        stages: 2,
        client_queue_cap: cap,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &scfg)?;
    let mut cl = server.client();
    let mut js = Vec::with_capacity(n);
    for _ in 0..n {
        let j = rng.below(inputs.len() as u64) as usize;
        let mut x = cl.take(inputs[j].shape());
        x.copy_from(&inputs[j]);
        cl.submit(x)?;
        js.push(j);
    }
    let deadline = Instant::now() + Duration::from_secs(10);
    while server.stats().completed < n as u64 {
        ensure!(Instant::now() < deadline, "slow_client: server wedged draining the burst");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut notices = 0u64;
    for (i, &j) in js.iter().enumerate() {
        let r = cl
            .poll()
            .ok_or_else(|| anyhow::anyhow!("slow_client: seq {i} missing (lost response)"))?;
        ensure!(r.seq == i as u64, "slow_client: reordered at {i} (got seq {})", r.seq);
        match r.status {
            Status::Shed(super::ShedReason::Backpressure) => notices += 1,
            _ => {
                ensure!(r.data == expected[j], "slow_client: payload {i} not bitwise");
                cl.recycle(r.data);
            }
        }
    }
    ensure!(cl.poll().is_none(), "slow_client: duplicated responses");
    let stats = server.shutdown()?;
    check_terminal_identity("slow_client", &stats)?;
    ensure!(
        stats.shed_backpressure == notices && notices == (n - cap) as u64,
        "slow_client: expected {} strips, saw {notices} (counter {})",
        n - cap,
        stats.shed_backpressure
    );
    Ok(ScenarioReport::from_stats("slow_client", &stats))
}

/// Hot-reload storm: weight swaps race in-flight traffic. Every payload
/// must match the oracle of exactly the epoch that served it, and
/// epochs observed by one client never go backwards.
fn scenario_reload_storm(cfg: &SoakConfig) -> Result<ScenarioReport> {
    let (epochs, inflight) = if cfg.smoke { (3usize, 4usize) } else { (6, 8) };
    let nets: Vec<Network> =
        (0..epochs).map(|e| build_net(cfg.seed ^ 0x03 ^ ((e as u64) << 8))).collect::<Result<_>>()?;
    let mut rng = Rng::new(cfg.seed ^ 0x0303);
    let inputs = inputs_for(&mut rng, 6);
    let expected: Vec<Vec<Tensor>> =
        nets.iter().map(|n| oracle_outputs(n, &inputs)).collect::<Result<_>>()?;
    let scfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 1,
        queue_depth: 16,
        stages: 3,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &nets[0], &scfg)?;
    let mut cl = server.client();
    let mut next_seq = 0u64;
    let mut last_version = 0u64;
    for e in 0..epochs {
        // Submit a window, swap weights *while it is in flight*, then
        // verify each response against the epoch it reports.
        let mut pending = Vec::with_capacity(inflight);
        for k in 0..inflight {
            let j = (e + k) % inputs.len();
            let mut x = cl.take(inputs[j].shape());
            x.copy_from(&inputs[j]);
            cl.submit(x)?;
            pending.push(j);
        }
        if e + 1 < epochs {
            server.reload(&nets[e + 1])?;
        }
        for j in pending {
            let r = cl.recv()?;
            ensure!(r.seq == next_seq, "reload_storm: reordered (want {next_seq}, got {})", r.seq);
            next_seq += 1;
            let v = r.version as usize;
            ensure!(v < expected.len(), "reload_storm: unknown epoch {v}");
            ensure!(r.version >= last_version, "reload_storm: epoch went backwards");
            last_version = r.version;
            ensure!(r.data == expected[v][j], "reload_storm: payload not bitwise for epoch {v}");
            cl.recycle(r.data);
        }
    }
    let stats = server.shutdown()?;
    check_terminal_identity("reload_storm", &stats)?;
    ensure!(stats.reloads == (epochs - 1) as u64, "reload_storm: reload count drifted");
    Ok(ScenarioReport::from_stats("reload_storm", &stats))
}

/// Saturation bursts against full admission control: a token bucket,
/// a global in-flight budget, and short deadlines. Rejections must be
/// synchronous and uncounted as traffic; every *accepted* request must
/// still get exactly one terminal event (payload, `Deadline` shed, or
/// `Shutdown` shed at teardown).
fn scenario_saturation(cfg: &SoakConfig) -> Result<ScenarioReport> {
    let (bursts, burst_len) = if cfg.smoke { (4usize, 8usize) } else { (16, 16) };
    let net = build_net(cfg.seed ^ 0x04)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0404);
    let inputs = inputs_for(&mut rng, 8);
    let expected = oracle_outputs(&net, &inputs)?;
    let scfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 2,
        queue_depth: 8,
        stages: 2,
        admit_rate: 2,
        admit_burst: 8,
        inflight_cap: 12,
        deadline_ticks: 64,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &scfg)?;
    let mut cl = server.client();
    let mut js: Vec<usize> = Vec::new();
    let mut rejected = 0u64;
    let mut st = SatState::default();
    for _ in 0..bursts {
        for _ in 0..burst_len {
            let j = rng.below(inputs.len() as u64) as usize;
            let mut x = cl.take(inputs[j].shape());
            x.copy_from(&inputs[j]);
            match cl.submit_with(x, 64)? {
                SubmitVerdict::Accepted(seq) => {
                    ensure!(seq == js.len() as u64, "saturation: seq skipped on accept");
                    js.push(j);
                }
                SubmitVerdict::Rejected { data, .. } => {
                    rejected += 1;
                    cl.recycle(data);
                }
            }
        }
        while let Some(r) = cl.poll() {
            sat_handle(r, &js, &expected, &mut st, &mut cl)?;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    // Drain: deadlines guarantee queued stragglers terminate without
    // needing the shutdown flush, but cap the wait defensively.
    let wall = Instant::now() + Duration::from_secs(10);
    while st.next_recv < js.len() as u64 {
        ensure!(Instant::now() < wall, "saturation: lost {} responses", js.len() as u64 - st.next_recv);
        if let Some(r) = cl.recv_timeout(Duration::from_millis(100)) {
            sat_handle(r, &js, &expected, &mut st, &mut cl)?;
        }
    }
    let stats = server.shutdown()?;
    check_terminal_identity("saturation", &stats)?;
    ensure!(stats.submitted == js.len() as u64, "saturation: accepted count drifted");
    ensure!(
        stats.rejected_rate + stats.rejected_budget == rejected,
        "saturation: reject accounting drifted (driver {rejected}, obs {})",
        stats.rejected_rate + stats.rejected_budget
    );
    ensure!(
        st.completed + st.shed == js.len() as u64,
        "saturation: terminal events ({} + {}) != accepted {}",
        st.completed,
        st.shed,
        js.len()
    );
    Ok(ScenarioReport::from_stats("saturation", &stats))
}

#[derive(Default)]
struct SatState {
    next_recv: u64,
    completed: u64,
    shed: u64,
}

fn sat_handle(
    r: Response,
    js: &[usize],
    expected: &[Tensor],
    st: &mut SatState,
    cl: &mut ServingClient,
) -> Result<()> {
    ensure!(
        r.seq == st.next_recv,
        "saturation: reordered/duplicated (want {}, got {})",
        st.next_recv,
        r.seq
    );
    st.next_recv += 1;
    if r.shed().is_some() {
        st.shed += 1;
        return Ok(());
    }
    let j = js[r.seq as usize];
    ensure!(r.data == expected[j], "saturation: payload {} not bitwise", r.seq);
    st.completed += 1;
    cl.recycle(r.data);
    Ok(())
}

/// Injected stage-worker stalls (`fault_stall_seed`): seeded time-only
/// sleeps inside every stage. Lockstep traffic (window 0) keeps batch
/// formation deterministic, and every payload must remain bitwise —
/// stalls reorder time, never data.
fn scenario_stage_stall(cfg: &SoakConfig) -> Result<ScenarioReport> {
    let reqs = if cfg.smoke { 16 } else { 64 };
    let net = build_net(cfg.seed ^ 0x05)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0505);
    let inputs = inputs_for(&mut rng, 8);
    let expected = vec![oracle_outputs(&net, &inputs)?];
    let scfg = ServerConfig {
        max_batch: 4,
        max_wait_ticks: 1,
        queue_depth: 8,
        stages: 3,
        fault_stall_seed: cfg.seed | 1,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &scfg)?;
    let mut cl = server.client();
    let report = drive_and_verify_shed(
        &mut cl,
        &inputs,
        &expected,
        |i| i % inputs.len(),
        reqs,
        0, // lockstep: one packet per request, so the stall schedule is seed-determined
        |_| false,
    )?;
    ensure!(report.per_version[0] == reqs as u64, "stage_stall: responses went missing");
    let stats = server.shutdown()?;
    check_terminal_identity("stage_stall", &stats)?;
    ensure!(stats.faults_injected > 0, "stage_stall: the fault hook never fired");
    Ok(ScenarioReport::from_stats("stage_stall", &stats))
}

/// Steady-state throughput + latency for the report (wall-clock;
/// report-only, nothing asserts on it beyond bitwise correctness).
fn measure_steady_state(cfg: &SoakConfig) -> Result<(f64, f64, f64)> {
    let n = if cfg.smoke { 48 } else { 512 };
    let net = build_net(cfg.seed ^ 0x06)?;
    let mut rng = Rng::new(cfg.seed ^ 0x0606);
    let inputs = inputs_for(&mut rng, 8);
    let expected = vec![oracle_outputs(&net, &inputs)?];
    let scfg = ServerConfig {
        max_batch: 8,
        max_wait_ticks: 1,
        queue_depth: 32,
        stages: 2,
        ..ServerConfig::default()
    };
    let server = Server::start(host(), &net, &scfg)?;
    let mut cl = server.client();
    let t0 = Instant::now();
    drive_and_verify(&mut cl, &inputs, &expected, |i| i % inputs.len(), n, 8)?;
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    let h = server.latency_hist();
    let p50_ms = h.quantile_ns(0.5) as f64 / 1e6;
    let p99_ms = h.quantile_ns(0.99) as f64 / 1e6;
    server.shutdown()?;
    Ok((n as f64 / secs, p50_ms, p99_ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soak_smoke_passes_with_exact_accounting() {
        let report = run_soak(&SoakConfig { seed: 42, smoke: true }).unwrap();
        assert_eq!(report.scenarios.len(), 5, "all five scenarios ran");
        assert_eq!((report.lost, report.duplicated, report.reordered), (0, 0, 0));
        for s in &report.scenarios {
            assert!(s.submitted > 0, "{}: scenario did no work", s.name);
        }
        let slow = report.scenarios.iter().find(|s| s.name == "slow_client").unwrap();
        assert!(slow.shed > 0, "slow_client must strip payloads");
        let stall = report.scenarios.iter().find(|s| s.name == "stage_stall").unwrap();
        assert!(stall.faults > 0, "stage_stall must inject faults");
        let json = report.to_json();
        assert!(json.contains("\"lost\":0"), "verify.sh greps this literal: {json}");
        assert!(json.contains("\"duplicated\":0"));
        assert!(json.contains("\"reordered\":0"));
        assert!(json.contains("\"scenarios\":["));
    }

    #[test]
    fn soak_seed_changes_are_still_clean() {
        // Different seed, same invariants: the harness is seed-robust,
        // not tuned to one lucky schedule.
        let report = run_soak(&SoakConfig { seed: 7, smoke: true }).unwrap();
        assert_eq!((report.lost, report.duplicated, report.reordered), (0, 0, 0));
    }
}
