//! Layer→stage partitioning (paper §III-C: arbitrary pipeline partitions).

use anyhow::{ensure, Result};

/// A contiguous partition of `layers` into `stages` pipeline stages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StagePartition {
    stage_of: Vec<usize>,
    stages: usize,
}

impl StagePartition {
    /// Even contiguous split: remainders go to the *earliest* stages so
    /// later (outer) stages — which also carry the least gradient delay —
    /// stay lightest, matching LayerPipe's load-balancing intuition.
    pub fn even(layers: usize, stages: usize) -> Result<Self> {
        ensure!(stages >= 1, "need at least one stage");
        ensure!(stages <= layers, "stages ({stages}) exceed layers ({layers})");
        let base = layers / stages;
        let extra = layers % stages;
        let mut stage_of = Vec::with_capacity(layers);
        for s in 0..stages {
            let size = base + usize::from(s < extra);
            stage_of.extend(std::iter::repeat(s).take(size));
        }
        Ok(StagePartition { stage_of, stages })
    }

    /// Cost-balanced contiguous split (LayerPipe: stage boundaries are
    /// chosen by per-layer compute, not layer count): minimizes the
    /// maximum per-stage cost over all contiguous partitions into
    /// exactly `stages` stages. Deterministic tie-break: at the optimal
    /// capacity, each stage stops once it holds its *fair share* of the
    /// remaining cost ([`pack_fair`]) instead of filling to the cap —
    /// for uniform positive costs the repeated ceil-split reproduces
    /// [`StagePartition::even`] exactly (every shape, not just the ones
    /// where cap-filling happens to coincide), so homogeneous stacks
    /// keep their seed partitions. If the fair-share materialization
    /// cannot place every layer under the cap, the cap-filling greedy
    /// (the feasibility oracle of the binary search) is used instead —
    /// the min-max objective is met either way.
    ///
    /// The variable-delay assignment is untouched: whatever the
    /// boundaries, each layer's delay remains `2·S(l)` with `S(l)` the
    /// number of *downstream stages* (paper Eq. 1) — costs move the
    /// boundaries, never the delay rule.
    pub fn balanced(costs: &[u64], stages: usize) -> Result<Self> {
        ensure!(stages >= 1, "need at least one stage");
        ensure!(
            stages <= costs.len(),
            "stages ({stages}) exceed layers ({})",
            costs.len()
        );
        // Binary-search the smallest per-stage capacity the greedy
        // left-fill can honor, then materialize that packing.
        let lo = costs.iter().copied().max().unwrap_or(0);
        let hi: u64 = costs.iter().sum();
        let (mut lo, mut hi) = (lo, hi.max(lo));
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if pack(costs, stages, mid).is_some() {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        let stage_of = pack_fair(costs, stages, lo)
            .or_else(|| pack(costs, stages, lo))
            .expect("max-cost capacity is always feasible");
        Ok(StagePartition { stage_of, stages })
    }

    /// Maximum per-stage cost sum under this partition (the balancing
    /// objective — what a pipelined iteration's critical stage pays).
    pub fn max_stage_cost(&self, costs: &[u64]) -> u64 {
        assert_eq!(costs.len(), self.layers(), "cost vector length mismatch");
        let mut sums = vec![0u64; self.stages];
        for (l, &c) in costs.iter().enumerate() {
            sums[self.stage_of[l]] += c;
        }
        sums.into_iter().max().unwrap_or(0)
    }

    /// Explicit group sizes, e.g. `[2, 2, 4]` for 8 layers in 3 stages.
    pub fn from_group_sizes(sizes: &[usize]) -> Result<Self> {
        ensure!(!sizes.is_empty(), "need at least one group");
        ensure!(sizes.iter().all(|&s| s > 0), "group sizes must be positive");
        let mut stage_of = Vec::new();
        for (s, &size) in sizes.iter().enumerate() {
            stage_of.extend(std::iter::repeat(s).take(size));
        }
        Ok(StagePartition { stage_of, stages: sizes.len() })
    }

    /// From a raw assignment vector (validated).
    pub fn from_stage_of(stage_of: Vec<usize>) -> Result<Self> {
        ensure!(!stage_of.is_empty(), "empty partition");
        ensure!(stage_of[0] == 0, "first layer must be in stage 0");
        for w in stage_of.windows(2) {
            ensure!(w[1] >= w[0] && w[1] - w[0] <= 1, "stages must be contiguous ascending");
        }
        let stages = stage_of.last().unwrap() + 1;
        Ok(StagePartition { stage_of, stages })
    }

    pub fn layers(&self) -> usize {
        self.stage_of.len()
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    pub fn stage_of(&self) -> &[usize] {
        &self.stage_of
    }

    /// Stages after layer `l`'s stage — the `S(l)` of Eq. 1.
    pub fn downstream_stages(&self, layer: usize) -> usize {
        self.stages - 1 - self.stage_of[layer]
    }

    /// `Delay(l) = 2·S(l)` for every layer.
    pub fn gradient_delays(&self) -> Vec<usize> {
        (0..self.layers()).map(|l| 2 * self.downstream_stages(l)).collect()
    }

    /// Layers in stage `s`.
    pub fn layers_in_stage(&self, s: usize) -> Vec<usize> {
        (0..self.layers()).filter(|&l| self.stage_of[l] == s).collect()
    }

    /// The maximum delay any layer carries (stage-0 layers): `2·(K−1)`.
    pub fn max_delay(&self) -> usize {
        2 * (self.stages - 1)
    }
}

/// Greedy left-fill of `costs` into at most `stages` contiguous groups
/// of per-group cost ≤ `cap`, forced to leave one layer for every
/// not-yet-opened stage. Returns the stage assignment when `cap` is
/// feasible, `None` otherwise. Every feasible packing uses exactly
/// `stages` groups (the forced breaks open trailing stages in time).
fn pack(costs: &[u64], stages: usize, cap: u64) -> Option<Vec<usize>> {
    let n = costs.len();
    let mut stage_of = Vec::with_capacity(n);
    let (mut s, mut load, mut count) = (0usize, 0u64, 0usize);
    for (i, &c) in costs.iter().enumerate() {
        // Keeping layer i in stage s requires the n−i−1 layers after it
        // to cover the stages−s−1 stages after it, i.e. n−i ≥ stages−s.
        let must_open = count > 0 && (load + c > cap || n - i < stages - s);
        if must_open {
            s += 1;
            if s == stages {
                return None; // cap too small: ran out of stages
            }
            load = 0;
            count = 0;
        }
        stage_of.push(s);
        load += c;
        count += 1;
    }
    debug_assert_eq!(s + 1, stages, "forced breaks must open every stage");
    Some(stage_of)
}

/// Fair-share materialization at a known-feasible `cap`: like [`pack`],
/// but a stage also closes once its load reaches the *fair share* of the
/// cost remaining when it opened (`remaining / stages_left`, rounded
/// up), instead of greedily filling to the cap. Never exceeds `cap`
/// (the cap break still applies), so any result it returns meets the
/// min-max objective; it can only differ from [`pack`] in how it breaks
/// ties. For uniform *positive* costs the repeated ceil-split takes
/// exactly `ceil(layers_left / stages_left)` layers per stage — the
/// [`StagePartition::even`] distribution. Returns `None` when stopping
/// early strands more cost than the remaining stages can hold (rare,
/// lumpy tails); the caller then falls back to [`pack`].
fn pack_fair(costs: &[u64], stages: usize, cap: u64) -> Option<Vec<usize>> {
    let n = costs.len();
    let mut stage_of = Vec::with_capacity(n);
    let mut remaining: u64 = costs.iter().sum();
    let (mut s, mut load, mut count) = (0usize, 0u64, 0usize);
    let mut target = remaining.div_ceil(stages as u64);
    for (i, &c) in costs.iter().enumerate() {
        let forced = count > 0 && (load + c > cap || n - i < stages - s);
        let fair = count > 0 && load >= target && s + 1 < stages;
        if forced || fair {
            if s + 1 == stages {
                return None; // `forced` on the last stage: cap busted
            }
            s += 1;
            load = 0;
            count = 0;
            target = remaining.div_ceil((stages - s) as u64);
        }
        stage_of.push(s);
        load += c;
        count += 1;
        remaining -= c;
    }
    (s + 1 == stages).then_some(stage_of)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_balances() {
        let p = StagePartition::even(8, 3).unwrap();
        assert_eq!(p.stage_of(), &[0, 0, 0, 1, 1, 1, 2, 2]);
        assert_eq!(p.stages(), 3);
    }

    #[test]
    fn per_layer_split() {
        let p = StagePartition::even(4, 4).unwrap();
        assert_eq!(p.stage_of(), &[0, 1, 2, 3]);
        assert_eq!(p.gradient_delays(), vec![6, 4, 2, 0]);
        assert_eq!(p.max_delay(), 6);
    }

    #[test]
    fn group_sizes() {
        let p = StagePartition::from_group_sizes(&[2, 2]).unwrap();
        assert_eq!(p.stage_of(), &[0, 0, 1, 1]);
        assert_eq!(p.gradient_delays(), vec![2, 2, 0, 0]);
        assert_eq!(p.layers_in_stage(0), vec![0, 1]);
    }

    #[test]
    fn single_stage_is_sequential() {
        let p = StagePartition::even(5, 1).unwrap();
        assert_eq!(p.gradient_delays(), vec![0; 5]);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(StagePartition::even(2, 3).is_err());
        assert!(StagePartition::even(2, 0).is_err());
        assert!(StagePartition::from_group_sizes(&[]).is_err());
        assert!(StagePartition::from_group_sizes(&[1, 0]).is_err());
        assert!(StagePartition::from_stage_of(vec![1, 2]).is_err());
        assert!(StagePartition::from_stage_of(vec![0, 2]).is_err());
    }

    #[test]
    fn balanced_uniform_costs_reduce_to_even() {
        // Every shape — including the ones (like 7/3 or 10/4) where a
        // cap-filling greedy would front-load [3,3,1]-style partitions,
        // the fair-share tie-break must reproduce `even` exactly.
        for layers in 1usize..=12 {
            for stages in 1..=layers {
                let costs = vec![10u64; layers];
                let b = StagePartition::balanced(&costs, stages).unwrap();
                let e = StagePartition::even(layers, stages).unwrap();
                assert_eq!(b, e, "{layers} layers / {stages} stages");
            }
        }
    }

    #[test]
    fn balanced_moves_boundaries_toward_cheap_layers() {
        // One conv-heavy layer followed by cheap ones: the heavy layer
        // gets a stage to itself, unlike the even split.
        let costs = [100u64, 10, 10, 10];
        let p = StagePartition::balanced(&costs, 2).unwrap();
        assert_eq!(p.stage_of(), &[0, 1, 1, 1]);
        assert_eq!(p.max_stage_cost(&costs), 100);
        // The even split would pay 110.
        let e = StagePartition::even(4, 2).unwrap();
        assert_eq!(e.max_stage_cost(&costs), 110);
    }

    #[test]
    fn balanced_is_minmax_optimal_over_contiguous_partitions() {
        // Brute-force every contiguous 3-way split and compare.
        let costs = [7u64, 3, 9, 1, 1, 6, 2];
        let p = StagePartition::balanced(&costs, 3).unwrap();
        let got = p.max_stage_cost(&costs);
        let mut best = u64::MAX;
        for b1 in 1..costs.len() - 1 {
            for b2 in b1 + 1..costs.len() {
                let s0: u64 = costs[..b1].iter().sum();
                let s1: u64 = costs[b1..b2].iter().sum();
                let s2: u64 = costs[b2..].iter().sum();
                best = best.min(s0.max(s1).max(s2));
            }
        }
        assert_eq!(got, best, "stage_of {:?}", p.stage_of());
    }

    #[test]
    fn balanced_handles_zero_cost_layers() {
        // Flatten-style zero-cost layers: every stage still gets at
        // least one layer, and the fair-share split spreads them like
        // `even` (all shares are zero, so each stage closes after one
        // layer until the last takes the rest).
        let costs = [0u64, 0, 0, 0];
        let p = StagePartition::balanced(&costs, 3).unwrap();
        assert_eq!(p.stages(), 3);
        assert_eq!(p.stage_of(), &[0, 1, 2, 2]);
        assert_eq!(p.max_stage_cost(&costs), 0);
        assert!(StagePartition::balanced(&costs, 5).is_err());
    }

    #[test]
    fn balanced_delays_still_follow_downstream_stage_count() {
        // Cost-driven boundaries never change the delay rule: d = 2·S(l).
        let costs = [50u64, 5, 5, 5, 40, 5];
        let p = StagePartition::balanced(&costs, 3).unwrap();
        let delays = p.gradient_delays();
        for l in 0..costs.len() {
            assert_eq!(delays[l], 2 * p.downstream_stages(l));
        }
    }

    #[test]
    fn downstream_matches_formula() {
        let p = StagePartition::even(6, 3).unwrap();
        for l in 0..6 {
            assert_eq!(
                p.gradient_delays()[l],
                2 * p.downstream_stages(l),
            );
        }
    }
}
