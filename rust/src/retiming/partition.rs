//! Layer→stage partitioning (paper §III-C: arbitrary pipeline partitions).

use anyhow::{ensure, Result};

/// A contiguous partition of `layers` into `stages` pipeline stages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StagePartition {
    stage_of: Vec<usize>,
    stages: usize,
}

impl StagePartition {
    /// Even contiguous split: remainders go to the *earliest* stages so
    /// later (outer) stages — which also carry the least gradient delay —
    /// stay lightest, matching LayerPipe's load-balancing intuition.
    pub fn even(layers: usize, stages: usize) -> Result<Self> {
        ensure!(stages >= 1, "need at least one stage");
        ensure!(stages <= layers, "stages ({stages}) exceed layers ({layers})");
        let base = layers / stages;
        let extra = layers % stages;
        let mut stage_of = Vec::with_capacity(layers);
        for s in 0..stages {
            let size = base + usize::from(s < extra);
            stage_of.extend(std::iter::repeat(s).take(size));
        }
        Ok(StagePartition { stage_of, stages })
    }

    /// Explicit group sizes, e.g. `[2, 2, 4]` for 8 layers in 3 stages.
    pub fn from_group_sizes(sizes: &[usize]) -> Result<Self> {
        ensure!(!sizes.is_empty(), "need at least one group");
        ensure!(sizes.iter().all(|&s| s > 0), "group sizes must be positive");
        let mut stage_of = Vec::new();
        for (s, &size) in sizes.iter().enumerate() {
            stage_of.extend(std::iter::repeat(s).take(size));
        }
        Ok(StagePartition { stage_of, stages: sizes.len() })
    }

    /// From a raw assignment vector (validated).
    pub fn from_stage_of(stage_of: Vec<usize>) -> Result<Self> {
        ensure!(!stage_of.is_empty(), "empty partition");
        ensure!(stage_of[0] == 0, "first layer must be in stage 0");
        for w in stage_of.windows(2) {
            ensure!(w[1] >= w[0] && w[1] - w[0] <= 1, "stages must be contiguous ascending");
        }
        let stages = stage_of.last().unwrap() + 1;
        Ok(StagePartition { stage_of, stages })
    }

    pub fn layers(&self) -> usize {
        self.stage_of.len()
    }

    pub fn stages(&self) -> usize {
        self.stages
    }

    pub fn stage_of(&self) -> &[usize] {
        &self.stage_of
    }

    /// Stages after layer `l`'s stage — the `S(l)` of Eq. 1.
    pub fn downstream_stages(&self, layer: usize) -> usize {
        self.stages - 1 - self.stage_of[layer]
    }

    /// `Delay(l) = 2·S(l)` for every layer.
    pub fn gradient_delays(&self) -> Vec<usize> {
        (0..self.layers()).map(|l| 2 * self.downstream_stages(l)).collect()
    }

    /// Layers in stage `s`.
    pub fn layers_in_stage(&self, s: usize) -> Vec<usize> {
        (0..self.layers()).filter(|&l| self.stage_of[l] == s).collect()
    }

    /// The maximum delay any layer carries (stage-0 layers): `2·(K−1)`.
    pub fn max_delay(&self) -> usize {
        2 * (self.stages - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_balances() {
        let p = StagePartition::even(8, 3).unwrap();
        assert_eq!(p.stage_of(), &[0, 0, 0, 1, 1, 1, 2, 2]);
        assert_eq!(p.stages(), 3);
    }

    #[test]
    fn per_layer_split() {
        let p = StagePartition::even(4, 4).unwrap();
        assert_eq!(p.stage_of(), &[0, 1, 2, 3]);
        assert_eq!(p.gradient_delays(), vec![6, 4, 2, 0]);
        assert_eq!(p.max_delay(), 6);
    }

    #[test]
    fn group_sizes() {
        let p = StagePartition::from_group_sizes(&[2, 2]).unwrap();
        assert_eq!(p.stage_of(), &[0, 0, 1, 1]);
        assert_eq!(p.gradient_delays(), vec![2, 2, 0, 0]);
        assert_eq!(p.layers_in_stage(0), vec![0, 1]);
    }

    #[test]
    fn single_stage_is_sequential() {
        let p = StagePartition::even(5, 1).unwrap();
        assert_eq!(p.gradient_delays(), vec![0; 5]);
    }

    #[test]
    fn rejects_bad_inputs() {
        assert!(StagePartition::even(2, 3).is_err());
        assert!(StagePartition::even(2, 0).is_err());
        assert!(StagePartition::from_group_sizes(&[]).is_err());
        assert!(StagePartition::from_group_sizes(&[1, 0]).is_err());
        assert!(StagePartition::from_stage_of(vec![1, 2]).is_err());
        assert!(StagePartition::from_stage_of(vec![0, 2]).is_err());
    }

    #[test]
    fn downstream_matches_formula() {
        let p = StagePartition::even(6, 3).unwrap();
        for l in 0..6 {
            assert_eq!(
                p.gradient_delays()[l],
                2 * p.downstream_stages(l),
            );
        }
    }
}
