//! Retiming-based derivation of pipelined backpropagation (paper §III-B/C).
//!
//! Starting from the sequential backprop graph ([`crate::graph::Dfg`]),
//! the paper's construction is:
//!
//! 1. **Delay insertion at feedforward cutsets** — `n·D` at the network
//!    input and output (`n` = stage boundaries = stages − 1); legal and
//!    semantics-preserving (only latency).
//! 2. **Delay insertion on gradient feedback edges** — `2·S(l)·D` on each
//!    `G_l → W_l` edge; *not* a retiming (it changes semantics to delayed
//!    gradients) but tolerated by DLMS theory (§III-A).
//! 3. **Retiming** — a lag assignment `r : V → ℤ` relocating the inserted
//!    delays so each stage boundary carries one delay in each direction,
//!    with `w_r(u→v) = w(u→v) + r(v) − r(u) ≥ 0`.
//! 4. **Recursive compaction** — realized here both in closed form
//!    ([`closed_form_lags`]) and as the paper's iterative sequence of
//!    backward/forward cutset moves ([`Derivation::derive_stepwise`]),
//!    which are proven equivalent by tests.
//!
//! The derivation *reads the paper's claims off the final graph*:
//! gradient delay `2·S(l)` (Eq. 1), activation-stash depth `2·S(l)`, and
//! weight-stash depth `2·S(l)` — stashing emerges from delay motion.

pub mod partition;

pub use partition::StagePartition;

use crate::graph::{Dfg, EdgeKind, NodeKind};
use anyhow::{bail, ensure, Result};

/// A retiming: one integer lag per node.
#[derive(Clone, Debug, PartialEq)]
pub struct Retiming {
    pub lags: Vec<i64>,
}

impl Retiming {
    pub fn identity(g: &Dfg) -> Self {
        Retiming { lags: vec![0; g.node_count()] }
    }

    /// Apply to a graph: `w_r(u→v) = w(u→v) + r(v) − r(u)`.
    /// Returns an error if any retimed edge weight would be negative.
    pub fn apply(&self, g: &Dfg) -> Result<Dfg> {
        ensure!(self.lags.len() == g.node_count(), "lag vector length mismatch");
        let mut out = g.clone();
        for e in &mut out.edges {
            let w = e.delay + self.lags[e.to] - self.lags[e.from];
            if w < 0 {
                bail!(
                    "illegal retiming: edge {:?}→{:?} ({:?}) would carry {w} delays",
                    g.nodes[e.from].kind,
                    g.nodes[e.to].kind,
                    e.kind
                );
            }
            e.delay = w;
        }
        Ok(out)
    }

    /// Elementary cutset move: shift every node in `set` by `amount`
    /// (+1 = one delay moves from each outgoing edge to each incoming
    /// edge of the set). Composable: `self` accumulates.
    pub fn shift(&mut self, set: &[usize], amount: i64) {
        for &v in set {
            self.lags[v] += amount;
        }
    }
}

/// The closed-form lag assignment solving the paper's compaction
/// (§III-B step 4) for a graph with `n+1` stages:
/// `r(F_σ) = r(W_σ) = σ − n`, `r(D_σ) = r(G_σ) = n − σ`,
/// `r(Env) = r(Loss) = 0`.
pub fn closed_form_lags(g: &Dfg) -> Retiming {
    let n = num_boundaries(g);
    let mut r = Retiming::identity(g);
    for (i, node) in g.nodes.iter().enumerate() {
        let Some(stage) = node.stage else { continue };
        let s = stage as i64;
        r.lags[i] = match node.kind.is_forward_side() {
            Some(true) => s - n,
            Some(false) => n - s,
            None => 0, // Loss: pinned with the last stage's zero lag
        };
    }
    r
}

/// Number of stage boundaries (`stages − 1`) in a stage-annotated graph.
pub fn num_boundaries(g: &Dfg) -> i64 {
    g.nodes
        .iter()
        .filter_map(|n| n.stage)
        .max()
        .map(|s| s as i64)
        .unwrap_or(0)
}

/// Insert the paper's delays into a sequential backprop graph:
/// `n` at the Env input and output edges, `2·S(l)` on each `G_l → W_l`.
pub fn insert_pipeline_delays(g: &mut Dfg) {
    let n = num_boundaries(g);
    for e in &mut g.edges {
        match e.kind {
            EdgeKind::EnvIn | EdgeKind::EnvOut => e.delay += n,
            EdgeKind::GradToWeight => {
                let stage = g.nodes[e.from].stage.expect("G node has a stage") as i64;
                e.delay += 2 * (n - stage);
            }
            _ => {}
        }
    }
}

/// Closed-form rule of Eq. 1: `Delay(l) = 2·S(l)` with `S(l)` = number of
/// stages after layer `l`'s stage.
pub fn delay_formula(stage_of: &[usize]) -> Vec<usize> {
    let num_stages = stage_of.iter().max().map_or(1, |m| m + 1);
    stage_of.iter().map(|&s| 2 * (num_stages - 1 - s)).collect()
}

/// Result of the full derivation: the retimed graph plus the quantities
/// the paper's claims are about.
#[derive(Clone, Debug)]
pub struct Derivation {
    /// The retimed (pipelined) graph.
    pub graph: Dfg,
    /// Stage of each layer.
    pub stage_of: Vec<usize>,
    /// Gradient delay per layer, read from the weight-update cycle.
    pub gradient_delay: Vec<usize>,
    /// Activation-stash depth per layer (`F_l → G_l` edge delay).
    pub act_stash_depth: Vec<usize>,
    /// Weight-stash depth per layer (`W_l → D_l` edge delay).
    pub weight_stash_depth: Vec<usize>,
}

impl Derivation {
    /// Run the construction with the closed-form retiming.
    pub fn derive(layers: usize, stage_of: &[usize]) -> Result<Derivation> {
        let mut g = Dfg::backprop(layers, stage_of);
        insert_pipeline_delays(&mut g);
        let r = closed_form_lags(&g);
        let retimed = r.apply(&g)?;
        Self::extract(retimed, stage_of)
    }

    /// Run the construction with the paper's iterative procedure: `n`
    /// rounds, each performing the *backward* retiming cutset move then
    /// the *forward* one (§III-B step 3), leaving one delay per boundary
    /// per round (step 4). Each intermediate graph is checked legal.
    pub fn derive_stepwise(layers: usize, stage_of: &[usize]) -> Result<Derivation> {
        let mut g = Dfg::backprop(layers, stage_of);
        insert_pipeline_delays(&mut g);
        let n = num_boundaries(&g);
        for round in 1..=n {
            // Backward cutset: all D/G nodes of stages ≤ n − round move +1
            // (delays shift from their outward edges to inward edges).
            let bwd: Vec<usize> = g
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, nd)| {
                    nd.kind.is_forward_side() == Some(false)
                        && (nd.stage.unwrap() as i64) <= n - round
                })
                .map(|(i, _)| i)
                .collect();
            let mut r = Retiming::identity(&g);
            r.shift(&bwd, 1);
            g = r.apply(&g)?; // errors if an intermediate state is illegal

            // Forward cutset: all F/W nodes of stages ≤ round − 1 move −1.
            let fwd: Vec<usize> = g
                .nodes
                .iter()
                .enumerate()
                .filter(|(_, nd)| {
                    nd.kind.is_forward_side() == Some(true)
                        && (nd.stage.unwrap() as i64) <= round - 1
                })
                .map(|(i, _)| i)
                .collect();
            let mut r = Retiming::identity(&g);
            r.shift(&fwd, -1);
            g = r.apply(&g)?;
        }
        Self::extract(g, stage_of)
    }

    fn extract(graph: Dfg, stage_of: &[usize]) -> Result<Derivation> {
        ensure!(graph.delays_legal(), "derived graph has negative delays");
        let layers = stage_of.len();
        let mut gradient_delay = Vec::with_capacity(layers);
        let mut act_stash_depth = Vec::with_capacity(layers);
        let mut weight_stash_depth = Vec::with_capacity(layers);
        for l in 0..layers {
            let act = graph
                .edge_delay(NodeKind::Forward(l), NodeKind::WeightGrad(l))
                .expect("act-stash edge");
            let wsd = graph
                .edge_delay(NodeKind::Weight(l), NodeKind::ActGrad(l))
                .expect("weight-use-bwd edge");
            // Gradient staleness = total delay around the weight-update
            // cycle W→F→…→G→W, which after compaction equals the number
            // of boundary crossings out and back = the stash depth.
            let cycle = weight_cycle_delay(&graph, l, stage_of)?;
            gradient_delay.push(cycle as usize);
            act_stash_depth.push(act as usize);
            weight_stash_depth.push(wsd as usize);
        }
        Ok(Derivation { graph, stage_of: stage_of.to_vec(), gradient_delay, act_stash_depth, weight_stash_depth })
    }

    /// Check every claim of §III-B/C against this derivation:
    /// Eq. 1 (`Delay(l) = 2·S(l)`), stash depths equal to the delay, one
    /// delay per boundary in each direction, and clean Env edges.
    pub fn verify(&self) -> Result<()> {
        let formula = delay_formula(&self.stage_of);
        ensure!(
            self.gradient_delay == formula,
            "gradient delays {:?} != closed form 2S(l) {:?}",
            self.gradient_delay,
            formula
        );
        ensure!(
            self.act_stash_depth == formula,
            "activation stash depths {:?} != 2S(l) {:?}",
            self.act_stash_depth,
            formula
        );
        ensure!(
            self.weight_stash_depth == formula,
            "weight stash depths {:?} != 2S(l) {:?}",
            self.weight_stash_depth,
            formula
        );
        // Boundary edges carry exactly one delay in each direction;
        // within-stage edges carry none.
        let layers = self.stage_of.len();
        for l in 0..layers.saturating_sub(1) {
            let crossing = self.stage_of[l + 1] > self.stage_of[l];
            let want = if crossing { 1 } else { 0 };
            let f = self
                .graph
                .edge_delay(NodeKind::Forward(l), NodeKind::Forward(l + 1))
                .expect("fwd chain edge");
            ensure!(f == want, "forward edge {l}→{} carries {f}, want {want}", l + 1);
            let b = self
                .graph
                .edge_delay(NodeKind::ActGrad(l + 1), NodeKind::ActGrad(l))
                .expect("bwd chain edge");
            ensure!(b == want, "backward edge {}→{l} carries {b}, want {want}", l + 1);
        }
        for e in &self.graph.edges {
            if matches!(e.kind, EdgeKind::EnvIn | EdgeKind::EnvOut) {
                ensure!(e.delay == 0, "env edge retains {} delays", e.delay);
            }
            if matches!(e.kind, EdgeKind::GradToWeight) {
                ensure!(e.delay == 0, "G→W edge retains {} delays after compaction", e.delay);
            }
        }
        Ok(())
    }
}

/// Total delay around layer `l`'s weight-update cycle
/// `W_l → F_l → … → Loss → … → G_l → W_l` (excluding the weight-state
/// self-loop): the gradient staleness in iterations.
fn weight_cycle_delay(g: &Dfg, l: usize, stage_of: &[usize]) -> Result<i64> {
    let layers = stage_of.len();
    let mut total = 0i64;
    let need = |d: Option<i64>| d.ok_or_else(|| anyhow::anyhow!("missing cycle edge"));
    total += need(g.edge_delay(NodeKind::Weight(l), NodeKind::Forward(l)))?;
    for k in l..layers - 1 {
        total += need(g.edge_delay(NodeKind::Forward(k), NodeKind::Forward(k + 1)))?;
    }
    total += need(g.edge_delay(NodeKind::Forward(layers - 1), NodeKind::Loss))?;
    if l == layers - 1 {
        total += need(g.edge_delay(NodeKind::Loss, NodeKind::WeightGrad(l)))?;
    } else {
        total += need(g.edge_delay(NodeKind::Loss, NodeKind::ActGrad(layers - 1)))?;
        for k in (l + 1..layers - 1).rev() {
            total += need(g.edge_delay(NodeKind::ActGrad(k + 1), NodeKind::ActGrad(k)))?;
        }
        total += need(g.edge_delay(NodeKind::ActGrad(l + 1), NodeKind::WeightGrad(l)))?;
    }
    total += need(g.edge_delay(NodeKind::WeightGrad(l), NodeKind::Weight(l)))?;
    Ok(total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::property;

    #[test]
    fn per_layer_derivation_matches_eq1() {
        // Fig. 3: one stage per layer, L = 4 → delays [6, 4, 2, 0].
        let stage_of: Vec<usize> = (0..4).collect();
        let d = Derivation::derive(4, &stage_of).unwrap();
        assert_eq!(d.gradient_delay, vec![6, 4, 2, 0]);
        d.verify().unwrap();
    }

    #[test]
    fn grouped_derivation_shares_delays() {
        // Fig. 4: two-layer groups. All layers of a group carry the same
        // delay, determined by downstream *stages*, not layers.
        let stage_of = vec![0, 0, 1, 1, 2, 2];
        let d = Derivation::derive(6, &stage_of).unwrap();
        assert_eq!(d.gradient_delay, vec![4, 4, 2, 2, 0, 0]);
        d.verify().unwrap();
    }

    #[test]
    fn stepwise_equals_closed_form() {
        for (layers, stage_of) in [
            (5usize, (0..5).collect::<Vec<_>>()),
            (6, vec![0, 0, 1, 1, 2, 2]),
            (7, vec![0, 0, 0, 1, 1, 2, 3]),
            (3, vec![0, 0, 0]),
        ] {
            let a = Derivation::derive(layers, &stage_of).unwrap();
            let b = Derivation::derive_stepwise(layers, &stage_of).unwrap();
            assert_eq!(a.gradient_delay, b.gradient_delay, "{stage_of:?}");
            for (ea, eb) in a.graph.edges.iter().zip(b.graph.edges.iter()) {
                assert_eq!(ea.delay, eb.delay, "{stage_of:?} edge {:?}", ea.kind);
            }
        }
    }

    #[test]
    fn sequential_single_stage_has_no_delays() {
        let d = Derivation::derive(4, &[0, 0, 0, 0]).unwrap();
        assert_eq!(d.gradient_delay, vec![0; 4]);
        assert_eq!(d.act_stash_depth, vec![0; 4]);
        d.verify().unwrap();
    }

    #[test]
    fn retiming_preserves_cycle_delay() {
        // Retiming invariant: total delay around any cycle is unchanged.
        let stage_of: Vec<usize> = (0..5).collect();
        let mut g = Dfg::backprop(5, &stage_of);
        insert_pipeline_delays(&mut g);
        let w2 = g.find(NodeKind::Weight(2)).unwrap();
        let before = g.cycle_delay(&[w2]).unwrap();
        let retimed = closed_form_lags(&g).apply(&g).unwrap();
        assert_eq!(retimed.cycle_delay(&[w2]).unwrap(), before);
    }

    #[test]
    fn pipelined_graph_has_positive_min_cycle_and_bound() {
        let stage_of: Vec<usize> = (0..6).collect();
        let d = Derivation::derive(6, &stage_of).unwrap();
        // After insertion+retiming every cycle carries delay ≥ 1 except
        // the last layer's zero-delay update loop (S = 0 → computed
        // within the iteration), so min cycle delay is still 0...
        // Exclude the last stage by checking an inner layer's cycle sum.
        assert!(d.gradient_delay[0] > 0);
        // Iteration bound exists for the subgraph excluding layer L−1's
        // zero-delay loop — verified indirectly through gradient delays.
    }

    #[test]
    fn illegal_retiming_is_rejected() {
        let stage_of: Vec<usize> = (0..3).collect();
        let g = Dfg::backprop(3, &stage_of);
        // Move one node arbitrarily: some zero-delay edge goes negative.
        let mut r = Retiming::identity(&g);
        let f1 = g.find(NodeKind::Forward(1)).unwrap();
        r.lags[f1] = -1;
        assert!(r.apply(&g).is_err());
    }

    #[test]
    fn property_eq1_holds_for_random_partitions() {
        property(40, |rng, _case| {
            let layers = 2 + rng.index(10);
            // Random contiguous ascending stage assignment.
            let mut stage_of = vec![0usize];
            for _ in 1..layers {
                let next = stage_of.last().unwrap() + usize::from(rng.chance(0.6));
                stage_of.push(next);
            }
            let d = Derivation::derive(layers, &stage_of)
                .unwrap_or_else(|e| panic!("derive failed for {stage_of:?}: {e}"));
            d.verify()
                .unwrap_or_else(|e| panic!("verify failed for {stage_of:?}: {e}"));
            let s = Derivation::derive_stepwise(layers, &stage_of).unwrap();
            assert_eq!(d.gradient_delay, s.gradient_delay, "{stage_of:?}");
        });
    }

    #[test]
    fn deeper_layers_get_monotonically_smaller_delays() {
        // "inner layers require fewer delays, outer layers longer delays"
        let stage_of: Vec<usize> = (0..8).collect();
        let d = Derivation::derive(8, &stage_of).unwrap();
        for w in d.gradient_delay.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(d.gradient_delay[0], 14); // 2·(8−1)
        assert_eq!(d.gradient_delay[7], 0);
    }
}
