//! Synthetic dataset substrate (the CIFAR-100 substitute, DESIGN.md).
//!
//! The paper's Fig. 5 claim is about optimization dynamics under delayed
//! gradients, so the dataset's job is to provide a classification task
//! with (a) a meaningful generalization gap, (b) deterministic
//! generation, and (c) the artifact shapes. A frozen random *teacher*
//! MLP labels gaussian inputs, plus label noise — a standard
//! teacher-student setup whose test accuracy saturates well below 100 %,
//! giving the accuracy-vs-epoch curves room to separate (as in Fig. 5).

use crate::config::{DataConfig, ModelConfig};
use crate::tensor::{matmul, relu, Tensor};
use crate::util::Rng;

/// An in-memory labelled dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[n, input_dim]` features.
    pub x: Tensor,
    /// Class index per sample.
    pub labels: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn input_dim(&self) -> usize {
        self.x.shape()[1]
    }

    /// Extract a batch by sample indices; returns `(x, onehot)` shaped
    /// for the artifacts.
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let d = self.input_dim();
        let mut xb = Tensor::zeros(&[idx.len(), d]);
        let mut oh = Tensor::zeros(&[idx.len(), self.classes]);
        for (row, &i) in idx.iter().enumerate() {
            let src = &self.x.data()[i * d..(i + 1) * d];
            xb.data_mut()[row * d..(row + 1) * d].copy_from_slice(src);
            oh.set2(row, self.labels[i], 1.0);
        }
        (xb, oh)
    }
}

/// Train/test pair.
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Dataset,
    pub test: Dataset,
}

/// Generate the teacher-labelled dataset for a model config.
pub fn teacher_dataset(model: &ModelConfig, data: &DataConfig) -> Splits {
    let mut rng = Rng::new(data.seed);
    // Frozen two-layer teacher, wider margins via tanh-free argmax head.
    let t_w1 = Tensor::randn(&[model.input_dim, data.teacher_hidden], 1.0, &mut rng);
    let t_w2 = Tensor::randn(&[data.teacher_hidden, model.classes], 1.0, &mut rng);

    let gen = |n: usize, rng: &mut Rng| -> Dataset {
        let x = Tensor::randn(&[n, model.input_dim], 1.0, rng);
        let h = relu(&matmul(&x, &t_w1));
        let logits = matmul(&h, &t_w2);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let row = &logits.data()[i * model.classes..(i + 1) * model.classes];
            let mut arg = 0;
            for (j, &v) in row.iter().enumerate() {
                if v > row[arg] {
                    arg = j;
                }
            }
            // Label noise: resample uniformly with probability `label_noise`.
            if rng.chance(data.label_noise) {
                arg = rng.index(model.classes);
            }
            labels.push(arg);
        }
        Dataset { x, labels, classes: model.classes }
    };

    let train = gen(data.train_samples, &mut rng);
    let test = gen(data.test_samples, &mut rng);
    Splits { train, test }
}

/// Deterministic epoch iterator over shuffled fixed-size batches
/// (drops the trailing partial batch — artifact shapes are static).
pub struct BatchIter<'a> {
    data: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, rng: &mut Rng) -> Self {
        assert!(batch > 0 && batch <= data.len(), "batch {batch} vs {} samples", data.len());
        let order = rng.permutation(data.len());
        BatchIter { data, order, batch, pos: 0 }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.data.len() / self.batch
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (Tensor, Tensor);

    fn next(&mut self) -> Option<Self::Item> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let idx = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        Some(self.data.batch(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfgs() -> (ModelConfig, DataConfig) {
        (
            ModelConfig { batch: 8, input_dim: 16, hidden_dim: 8, classes: 4, layers: 3, init_scale: 1.0 },
            DataConfig { train_samples: 64, test_samples: 32, teacher_hidden: 8, label_noise: 0.0, seed: 5 },
        )
    }

    #[test]
    fn shapes_and_label_range() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        assert_eq!(s.train.len(), 64);
        assert_eq!(s.test.len(), 32);
        assert_eq!(s.train.x.shape(), &[64, 16]);
        assert!(s.train.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn deterministic_given_seed() {
        let (m, d) = cfgs();
        let a = teacher_dataset(&m, &d);
        let b = teacher_dataset(&m, &d);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.labels, b.train.labels);
    }

    #[test]
    fn labels_cover_multiple_classes() {
        let (m, mut d) = cfgs();
        d.train_samples = 256;
        let s = teacher_dataset(&m, &d);
        let mut seen = vec![false; m.classes];
        for &l in &s.train.labels {
            seen[l] = true;
        }
        assert!(seen.iter().filter(|&&x| x).count() >= 3, "teacher too degenerate");
    }

    #[test]
    fn label_noise_changes_labels() {
        let (m, d) = cfgs();
        let clean = teacher_dataset(&m, &d);
        let noisy = teacher_dataset(&m, &DataConfig { label_noise: 0.5, ..d });
        let diffs = clean
            .train
            .labels
            .iter()
            .zip(&noisy.train.labels)
            .filter(|(a, b)| a != b)
            .count();
        assert!(diffs > 5, "noise had no effect ({diffs} diffs)");
    }

    #[test]
    fn batch_extracts_onehot() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        let (xb, oh) = s.train.batch(&[0, 3, 5]);
        assert_eq!(xb.shape(), &[3, 16]);
        assert_eq!(oh.shape(), &[3, 4]);
        for row in 0..3 {
            let sum: f32 = (0..4).map(|c| oh.at2(row, c)).sum();
            assert_eq!(sum, 1.0);
        }
    }

    #[test]
    fn batch_iter_covers_epoch_without_repeats() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        let mut rng = Rng::new(3);
        let it = BatchIter::new(&s.train, 8, &mut rng);
        assert_eq!(it.batches_per_epoch(), 8);
        let n: usize = it.map(|(x, _)| x.shape()[0]).sum();
        assert_eq!(n, 64);
    }
}
