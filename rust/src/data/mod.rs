//! Synthetic dataset substrate (the CIFAR-100 substitute, DESIGN.md).
//!
//! The paper's Fig. 5 claim is about optimization dynamics under delayed
//! gradients, so the dataset's job is to provide a classification task
//! with (a) a meaningful generalization gap, (b) deterministic
//! generation, and (c) the artifact shapes. A frozen random *teacher*
//! MLP labels gaussian inputs, plus label noise — a standard
//! teacher-student setup whose test accuracy saturates well below 100 %,
//! giving the accuracy-vs-epoch curves room to separate (as in Fig. 5).

use crate::config::{DataConfig, ModelConfig};
use crate::tensor::{matmul, relu, Tensor};
use crate::util::Rng;

/// An in-memory labelled dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[n, input_dim]` features.
    pub x: Tensor,
    /// Class index per sample.
    pub labels: Vec<usize>,
    pub classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn input_dim(&self) -> usize {
        self.x.shape()[1]
    }

    /// Extract a batch into caller-owned buffers (resized in place,
    /// contents fully overwritten — dirty recycled pool buffers are
    /// fine). The trainers feed their steady-state loops through this:
    /// combined with a `BufferPool`, batch extraction allocates nothing.
    pub fn batch_into(&self, idx: &[usize], x: &mut Tensor, onehot: &mut Tensor) {
        let d = self.input_dim();
        x.resize(&[idx.len(), d]);
        onehot.resize(&[idx.len(), self.classes]);
        onehot.fill(0.0);
        for (row, &i) in idx.iter().enumerate() {
            let src = &self.x.data()[i * d..(i + 1) * d];
            x.data_mut()[row * d..(row + 1) * d].copy_from_slice(src);
            onehot.set2(row, self.labels[i], 1.0);
        }
    }

    /// Extract a batch by sample indices; returns `(x, onehot)` shaped
    /// for the artifacts (allocating wrapper over [`Dataset::batch_into`],
    /// bitwise identical by construction).
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let (mut xb, mut oh) = (Tensor::empty(), Tensor::empty());
        self.batch_into(idx, &mut xb, &mut oh);
        (xb, oh)
    }
}

/// Train/test pair.
#[derive(Clone, Debug)]
pub struct Splits {
    pub train: Dataset,
    pub test: Dataset,
}

/// The shared labeling recipe of every teacher dataset: argmax of a
/// frozen two-layer ReLU teacher over the rows of `x`, resampled
/// uniformly with probability `label_noise`. Kept in one place so the
/// flat and image dataset families can never label differently (the
/// argmax tie rule here must also match `count_correct` in `train`).
fn teacher_labels(
    x: &Tensor,
    t_w1: &Tensor,
    t_w2: &Tensor,
    classes: usize,
    label_noise: f64,
    rng: &mut Rng,
) -> Vec<usize> {
    let h = relu(&matmul(x, t_w1));
    let logits = matmul(&h, t_w2);
    let n = x.shape()[0];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let row = &logits.data()[i * classes..(i + 1) * classes];
        let mut arg = 0;
        for (j, &v) in row.iter().enumerate() {
            if v > row[arg] {
                arg = j;
            }
        }
        if rng.chance(label_noise) {
            arg = rng.index(classes);
        }
        labels.push(arg);
    }
    labels
}

/// Generate the teacher-labelled dataset for a model config.
pub fn teacher_dataset(model: &ModelConfig, data: &DataConfig) -> Splits {
    let mut rng = Rng::new(data.seed);
    // Frozen two-layer teacher, wider margins via tanh-free argmax head.
    let t_w1 = Tensor::randn(&[model.input_dim, data.teacher_hidden], 1.0, &mut rng);
    let t_w2 = Tensor::randn(&[data.teacher_hidden, model.classes], 1.0, &mut rng);

    let gen = |n: usize, rng: &mut Rng| -> Dataset {
        let x = Tensor::randn(&[n, model.input_dim], 1.0, rng);
        let labels = teacher_labels(&x, &t_w1, &t_w2, model.classes, data.label_noise, rng);
        Dataset { x, labels, classes: model.classes }
    };

    let train = gen(data.train_samples, &mut rng);
    let test = gen(data.test_samples, &mut rng);
    Splits { train, test }
}

/// Deterministic *image-shaped* teacher dataset for convolutional and
/// spiking workloads: NHWC maps of `h·w·c` features per sample (the
/// logical `[B, C, H, W]` batch, stored channel-last and flattened on
/// the wire like every activation in [`crate::layers`]).
///
/// Pixels are gaussian noise passed through one fixed 3×3 box blur per
/// channel, giving the local spatial correlation a conv kernel can
/// exploit; labels come from a frozen random teacher MLP over the
/// flattened image plus optional label noise — the same
/// teacher-student recipe as [`teacher_dataset`], so test accuracy
/// saturates below 100 % and curves have room to separate.
pub fn image_teacher_dataset(
    h: usize,
    w: usize,
    c: usize,
    classes: usize,
    data: &DataConfig,
) -> Splits {
    assert!(h > 0 && w > 0 && c > 0 && classes > 0, "image dims must be positive");
    let dim = h * w * c;
    let mut rng = Rng::new(data.seed);
    let t_w1 = Tensor::randn(&[dim, data.teacher_hidden], 1.0, &mut rng);
    let t_w2 = Tensor::randn(&[data.teacher_hidden, classes], 1.0, &mut rng);

    let gen = |n: usize, rng: &mut Rng| -> Dataset {
        let raw = Tensor::randn(&[n, dim], 1.0, rng);
        // 3×3 box blur per channel (zero-padded borders), NHWC layout.
        let mut x = Tensor::zeros(&[n, dim]);
        for s in 0..n {
            let src = &raw.data()[s * dim..(s + 1) * dim];
            let dst = &mut x.data_mut()[s * dim..(s + 1) * dim];
            for iy in 0..h {
                for ix in 0..w {
                    for ch in 0..c {
                        let mut sum = 0.0f32;
                        for dy in -1i32..=1 {
                            for dx in -1i32..=1 {
                                let (py, px) = (iy as i32 + dy, ix as i32 + dx);
                                if py >= 0 && py < h as i32 && px >= 0 && px < w as i32 {
                                    sum += src[(py as usize * w + px as usize) * c + ch];
                                }
                            }
                        }
                        dst[(iy * w + ix) * c + ch] = sum / 9.0;
                    }
                }
            }
        }
        let labels = teacher_labels(&x, &t_w1, &t_w2, classes, data.label_noise, rng);
        Dataset { x, labels, classes }
    };

    let train = gen(data.train_samples, &mut rng);
    let test = gen(data.test_samples, &mut rng);
    Splits { train, test }
}

/// Deterministic *token-sequence* teacher dataset for transformer
/// workloads: each sample is `seq` f32-encoded integer token ids drawn
/// uniformly from `[0, vocab)` (the wire format
/// [`crate::layers::Embedding`] consumes and validates). Labels come
/// from the same frozen-teacher recipe as [`teacher_dataset`], with the
/// teacher reading the raw id values directly — ids correlate with the
/// label through the teacher, so an embedding + attention stack has
/// real structure to learn while test accuracy still saturates below
/// 100 %.
pub fn token_teacher_dataset(
    seq: usize,
    vocab: usize,
    classes: usize,
    data: &DataConfig,
) -> Splits {
    assert!(seq > 0 && vocab > 0 && classes > 0, "token dims must be positive");
    let mut rng = Rng::new(data.seed);
    let t_w1 = Tensor::randn(&[seq, data.teacher_hidden], 1.0, &mut rng);
    let t_w2 = Tensor::randn(&[data.teacher_hidden, classes], 1.0, &mut rng);

    let gen = |n: usize, rng: &mut Rng| -> Dataset {
        let mut x = Tensor::zeros(&[n, seq]);
        for v in x.data_mut().iter_mut() {
            *v = rng.index(vocab) as f32;
        }
        let labels = teacher_labels(&x, &t_w1, &t_w2, classes, data.label_noise, rng);
        Dataset { x, labels, classes }
    };

    let train = gen(data.train_samples, &mut rng);
    let test = gen(data.test_samples, &mut rng);
    Splits { train, test }
}

/// Deterministic epoch iterator over shuffled fixed-size batches
/// (drops the trailing partial batch — artifact shapes are static).
pub struct BatchIter<'a> {
    data: &'a Dataset,
    order: Vec<usize>,
    batch: usize,
    pos: usize,
}

impl<'a> BatchIter<'a> {
    pub fn new(data: &'a Dataset, batch: usize, rng: &mut Rng) -> Self {
        assert!(batch > 0 && batch <= data.len(), "batch {batch} vs {} samples", data.len());
        let order = rng.permutation(data.len());
        BatchIter { data, order, batch, pos: 0 }
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.data.len() / self.batch
    }

    /// The next batch's sample indices, without materializing tensors —
    /// callers pass them to [`Dataset::batch_into`] with pooled buffers
    /// (the allocation-free feed path). Same traversal as the `Iterator`
    /// impl, so the two produce identical batch sequences.
    pub fn next_indices(&mut self) -> Option<&[usize]> {
        if self.pos + self.batch > self.order.len() {
            return None;
        }
        let idx = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        Some(idx)
    }
}

impl<'a> Iterator for BatchIter<'a> {
    type Item = (Tensor, Tensor);

    fn next(&mut self) -> Option<Self::Item> {
        let data = self.data;
        let idx = self.next_indices()?;
        Some(data.batch(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfgs() -> (ModelConfig, DataConfig) {
        (
            ModelConfig { batch: 8, input_dim: 16, hidden_dim: 8, classes: 4, layers: 3, init_scale: 1.0 },
            DataConfig { train_samples: 64, test_samples: 32, teacher_hidden: 8, label_noise: 0.0, seed: 5 },
        )
    }

    #[test]
    fn shapes_and_label_range() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        assert_eq!(s.train.len(), 64);
        assert_eq!(s.test.len(), 32);
        assert_eq!(s.train.x.shape(), &[64, 16]);
        assert!(s.train.labels.iter().all(|&l| l < 4));
    }

    #[test]
    fn deterministic_given_seed() {
        let (m, d) = cfgs();
        let a = teacher_dataset(&m, &d);
        let b = teacher_dataset(&m, &d);
        assert_eq!(a.train.x, b.train.x);
        assert_eq!(a.train.labels, b.train.labels);
    }

    #[test]
    fn labels_cover_multiple_classes() {
        let (m, mut d) = cfgs();
        d.train_samples = 256;
        let s = teacher_dataset(&m, &d);
        let mut seen = vec![false; m.classes];
        for &l in &s.train.labels {
            seen[l] = true;
        }
        assert!(seen.iter().filter(|&&x| x).count() >= 3, "teacher too degenerate");
    }

    #[test]
    fn label_noise_changes_labels() {
        let (m, d) = cfgs();
        let clean = teacher_dataset(&m, &d);
        let noisy = teacher_dataset(&m, &DataConfig { label_noise: 0.5, ..d });
        let diffs = clean
            .train
            .labels
            .iter()
            .zip(&noisy.train.labels)
            .filter(|(a, b)| a != b)
            .count();
        assert!(diffs > 5, "noise had no effect ({diffs} diffs)");
    }

    #[test]
    fn batch_extracts_onehot() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        let (xb, oh) = s.train.batch(&[0, 3, 5]);
        assert_eq!(xb.shape(), &[3, 16]);
        assert_eq!(oh.shape(), &[3, 4]);
        for row in 0..3 {
            let sum: f32 = (0..4).map(|c| oh.at2(row, c)).sum();
            assert_eq!(sum, 1.0);
        }
    }

    #[test]
    fn batch_into_matches_batch_bitwise_on_dirty_buffers() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        let (xb, oh) = s.train.batch(&[1, 4, 9]);
        let mut rng = Rng::new(44);
        let mut x2 = Tensor::randn(&[7, 2], 5.0, &mut rng);
        let mut oh2 = Tensor::randn(&[3, 3], 5.0, &mut rng);
        s.train.batch_into(&[1, 4, 9], &mut x2, &mut oh2);
        assert_eq!(xb, x2);
        assert_eq!(oh, oh2);
    }

    #[test]
    fn next_indices_matches_iterator_sequence() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        let mut a = BatchIter::new(&s.train, 8, &mut Rng::new(9));
        let mut b = BatchIter::new(&s.train, 8, &mut Rng::new(9));
        loop {
            let via_iter = b.next();
            let Some(idx) = a.next_indices() else {
                assert!(via_iter.is_none());
                break;
            };
            let want = s.train.batch(idx);
            assert_eq!(via_iter.expect("same length"), want);
        }
    }

    #[test]
    fn image_dataset_shapes_and_determinism() {
        let (_, d) = cfgs();
        let s = image_teacher_dataset(6, 5, 2, 4, &d);
        assert_eq!(s.train.x.shape(), &[64, 60]);
        assert_eq!(s.test.len(), 32);
        assert!(s.train.labels.iter().all(|&l| l < 4));
        let s2 = image_teacher_dataset(6, 5, 2, 4, &d);
        assert_eq!(s.train.x, s2.train.x);
        assert_eq!(s.train.labels, s2.train.labels);
    }

    #[test]
    fn image_dataset_is_spatially_smoothed() {
        // The box blur must induce positive correlation between
        // horizontally adjacent pixels (raw gaussian noise has ~none).
        let (_, d) = cfgs();
        let (h, w, c) = (8, 8, 1);
        let s = image_teacher_dataset(h, w, c, 4, &d);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for smp in 0..s.train.len() {
            let img = &s.train.x.data()[smp * h * w..(smp + 1) * h * w];
            for iy in 0..h {
                for ix in 0..w - 1 {
                    let (a, b) = (img[iy * w + ix] as f64, img[iy * w + ix + 1] as f64);
                    num += a * b;
                    den += a * a;
                }
            }
        }
        let corr = num / den;
        assert!(corr > 0.3, "adjacent-pixel correlation {corr} too weak");
    }

    #[test]
    fn image_dataset_covers_multiple_classes() {
        let (_, mut d) = cfgs();
        d.train_samples = 256;
        let s = image_teacher_dataset(6, 6, 1, 4, &d);
        let mut seen = vec![false; 4];
        for &l in &s.train.labels {
            seen[l] = true;
        }
        assert!(seen.iter().filter(|&&x| x).count() >= 3, "teacher too degenerate");
    }

    #[test]
    fn token_dataset_ids_are_integers_in_vocab_and_deterministic() {
        let (_, d) = cfgs();
        let s = token_teacher_dataset(6, 11, 4, &d);
        assert_eq!(s.train.x.shape(), &[64, 6]);
        assert_eq!(s.test.len(), 32);
        assert!(s.train.labels.iter().all(|&l| l < 4));
        for &v in s.train.x.data() {
            assert!(v >= 0.0 && v.fract() == 0.0 && (v as usize) < 11, "bad token id {v}");
        }
        let s2 = token_teacher_dataset(6, 11, 4, &d);
        assert_eq!(s.train.x, s2.train.x);
        assert_eq!(s.train.labels, s2.train.labels);
    }

    #[test]
    fn token_dataset_covers_vocab_and_classes() {
        let (_, mut d) = cfgs();
        d.train_samples = 256;
        let s = token_teacher_dataset(8, 7, 4, &d);
        let mut ids = vec![false; 7];
        for &v in s.train.x.data() {
            ids[v as usize] = true;
        }
        assert!(ids.iter().all(|&x| x), "some token ids never drawn");
        let mut seen = vec![false; 4];
        for &l in &s.train.labels {
            seen[l] = true;
        }
        assert!(seen.iter().filter(|&&x| x).count() >= 3, "teacher too degenerate");
    }

    #[test]
    fn batch_iter_covers_epoch_without_repeats() {
        let (m, d) = cfgs();
        let s = teacher_dataset(&m, &d);
        let mut rng = Rng::new(3);
        let it = BatchIter::new(&s.train, 8, &mut rng);
        assert_eq!(it.batches_per_epoch(), 8);
        let n: usize = it.map(|(x, _)| x.shape()[0]).sum();
        assert_eq!(n, 64);
    }
}
