//! The delayed-gradient trainer: the Fig. 5 experiment engine, and the
//! numerical oracle for the threaded executor in [`crate::pipeline`].
//!
//! Implements pipelined training in *iteration-indexed* form, which the
//! schedule module proves equivalent to the clock-level pipeline: with
//! layer delays `d_l = 2·S(l)` (Eq. 1),
//!
//! - at iteration `t`, batch `t` forwards through all layers using each
//!   layer's **current** weights; per-layer inputs/outputs are stashed
//!   (the activation stashing that §III-B shows is structural);
//! - the backward of batch `t` at layer `l` executes at iteration
//!   `t + d_l`, using the weight version chosen by the
//!   [`crate::strategy::LayerStrategy`] (stashed / latest / EMA-recomputed);
//! - the resulting gradient is applied immediately (SGD + momentum + wd,
//!   cosine lr), so the gradient misses exactly `d_l` updates — the
//!   staleness the paper analyzes.
//!
//! The sequential strategy sets every `d_l = 0`, collapsing to standard
//! backpropagation on the same code path (a true reference curve).
//!
//! Per-stage event order is the contract the multi-threaded executor
//! must reproduce: at iteration `t` a stage sees `forward(t)` first,
//! then `backward(t − d)` — see `DESIGN.md` for the equivalence
//! argument.

use crate::backend::{Backend, Exec};
use crate::config::ExperimentConfig;
use crate::data::{BatchIter, Splits};
use crate::metrics::{EpochMetrics, RunCurve};
use crate::model::{LayerParams, Mlp};
use crate::optim::{ConstantLr, CosineLr, LrBook, LrSchedule, Optimizer, Sgd};
use crate::retiming::StagePartition;
use crate::strategy::{LayerStrategy, StrategyKind};
use crate::tensor::{BufferPool, Tensor};
use crate::util::{Rng, Stopwatch};
use anyhow::{ensure, Context, Result};
use std::collections::VecDeque;

/// The learning-rate schedule a config implies (cosine over the full
/// horizon, or constant). Shared by trainer and executor so both see
/// identical rates.
pub fn lr_schedule_for(cfg: &ExperimentConfig) -> Box<dyn LrSchedule> {
    let steps_per_epoch = cfg.data.train_samples / cfg.model.batch;
    let total_steps = steps_per_epoch * cfg.epochs;
    if cfg.optim.cosine {
        Box::new(CosineLr::new(cfg.optim.lr, cfg.optim.min_lr, total_steps.max(1)))
    } else {
        Box::new(ConstantLr(cfg.optim.lr))
    }
}

/// Batched argmax accuracy of a parameter set over the test split, via
/// the backend's full-network forward. Shared eval path for the trainer
/// and the pipelined executor.
pub fn evaluate_params(
    exec: &dyn Exec,
    layers: &[LayerParams],
    batch: usize,
    data: &Splits,
) -> Result<f32> {
    let n = data.test.len() / batch * batch;
    ensure!(n > 0, "test set smaller than one batch");
    let mut correct = 0usize;
    for start in (0..n).step_by(batch) {
        let idx: Vec<usize> = (start..start + batch).collect();
        let (x, _) = data.test.batch(&idx);
        let logits = exec.forward_full(&x, layers)?;
        let c = logits.shape()[1];
        for row in 0..batch {
            let slice = &logits.data()[row * c..(row + 1) * c];
            let mut arg = 0;
            for (j, &v) in slice.iter().enumerate() {
                if v > slice[arg] {
                    arg = j;
                }
            }
            if arg == data.test.labels[start + row] {
                correct += 1;
            }
        }
    }
    Ok(correct as f32 / n as f32)
}

/// Per-layer training state.
struct LayerState {
    strategy: LayerStrategy,
    opt_w: Sgd,
    opt_b: Sgd,
    /// Gradient delay `d_l = 2·S(l)`.
    delay: usize,
    /// Persistent `_into` workspaces for this layer's weight/bias
    /// gradients (overwritten every backward, never reallocated).
    dw_buf: Tensor,
    db_buf: Tensor,
}

/// One in-flight batch: everything the delayed backward will need.
struct Inflight {
    /// Iteration at which the batch was forwarded.
    t: u64,
    /// Activation chain: `acts[0]` is the batch input, `acts[l + 1]` is
    /// layer `l`'s output (each stored once — a layer's input *is* the
    /// previous layer's output). Entries consumed by retiring backwards
    /// are replaced with empty placeholders and recycled into the pool.
    acts: Vec<Tensor>,
    /// One-hot labels (consumed by `loss_grad` at backward time).
    onehot: Tensor,
    /// Upstream gradient flowing down the backward chain.
    dy: Option<Tensor>,
    /// Next layer whose backward is pending (`layers-1` → 0), or None
    /// when fully retired.
    next_bwd: Option<usize>,
    /// Loss observed when this batch's loss_grad ran.
    loss: Option<f32>,
}

impl Inflight {
    fn nbytes(&self) -> usize {
        self.acts.iter().map(Tensor::nbytes).sum::<usize>()
            + self.onehot.nbytes()
            + self.dy.as_ref().map_or(0, Tensor::nbytes)
    }
}

/// The pipelined trainer for one strategy.
pub struct Trainer {
    backend: Backend,
    pub mlp: Mlp,
    cfg: ExperimentConfig,
    kind: StrategyKind,
    partition: StagePartition,
    layers: Vec<LayerState>,
    lr: LrBook,
    inflight: VecDeque<Inflight>,
    step: u64,
    peak_activation_bytes: usize,
    /// Losses observed this epoch (at backward time).
    epoch_losses: Vec<f32>,
    /// Recycled tensor storage for activations and gradients: the
    /// steady-state loop allocates nothing.
    pool: BufferPool,
    /// Pre-activation-gradient workspace shared across layer backwards.
    bwd_scratch: Tensor,
    /// Emptied activation-chain Vecs from retired batches, reused by the
    /// forward lane.
    spare_chains: Vec<Vec<Tensor>>,
}

impl Trainer {
    pub fn new(
        backend: Backend,
        cfg: &ExperimentConfig,
        kind: StrategyKind,
        rng: &mut Rng,
    ) -> Result<Trainer> {
        cfg.validate()?;
        backend.check_model(&cfg.model)?;
        let mlp = Mlp::init(&cfg.model, rng);
        // Sequential runs as a 1-stage pipeline (all delays zero).
        let stages = if kind.is_pipelined() { cfg.pipeline.stages } else { 1 };
        let partition = StagePartition::even(cfg.model.layers, stages)?;
        let delays = partition.gradient_delays();
        let layers = (0..cfg.model.layers)
            .map(|l| {
                let (din, dout) = crate::model::layer_dims(&cfg.model, l);
                LayerState {
                    strategy: LayerStrategy::new(kind, delays[l]),
                    opt_w: Sgd::new(&[din, dout], cfg.optim.momentum, cfg.optim.weight_decay),
                    opt_b: Sgd::new(&[dout], cfg.optim.momentum, 0.0),
                    delay: delays[l],
                    dw_buf: Tensor::empty(),
                    db_buf: Tensor::empty(),
                }
            })
            .collect();
        let lr = LrBook::new(lr_schedule_for(cfg));
        Ok(Trainer {
            backend,
            mlp,
            cfg: cfg.clone(),
            kind,
            partition,
            layers,
            lr,
            inflight: VecDeque::new(),
            step: 0,
            peak_activation_bytes: 0,
            epoch_losses: Vec::new(),
            pool: BufferPool::new(),
            bwd_scratch: Tensor::empty(),
            spare_chains: Vec::new(),
        })
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    pub fn backend(&self) -> &dyn Exec {
        self.backend.as_ref()
    }

    pub fn partition(&self) -> &StagePartition {
        &self.partition
    }

    pub fn gradient_delays(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.delay).collect()
    }

    /// One pipelined iteration: forward batch `t` (if provided), then run
    /// every backward scheduled for this iteration.
    pub fn iteration(&mut self, batch: Option<(Tensor, Tensor)>) -> Result<()> {
        let t = self.step;

        // ---- forward lane ------------------------------------------------
        if let Some((x, onehot)) = batch {
            let nl = self.mlp.num_layers();
            // Recycled chain Vec + pooled output buffers: the steady-state
            // forward performs zero heap allocation.
            let mut acts = self.spare_chains.pop().unwrap_or_default();
            debug_assert!(acts.is_empty());
            acts.reserve(nl + 1);
            acts.push(x);
            for l in 0..nl {
                self.layers[l].strategy.on_forward(t, &self.mlp.layers[l].w);
                let rows = acts[l].shape()[0];
                let dout = self.mlp.layers[l].w.shape()[1];
                let mut y = self.pool.take(&[rows, dout]);
                self.mlp.forward_layer_into(self.backend.as_ref(), l, &acts[l], &mut y)?;
                acts.push(y);
            }
            self.inflight.push_back(Inflight {
                t,
                acts,
                onehot,
                dy: None,
                next_bwd: Some(nl - 1),
                loss: None,
            });
            let act_bytes: usize = self.inflight.iter().map(Inflight::nbytes).sum();
            self.peak_activation_bytes = self.peak_activation_bytes.max(act_bytes);
        }

        // ---- backward lane -----------------------------------------------
        // Delays are non-increasing in l, so scanning in-flight batches
        // oldest-first and their layers top-down preserves dataflow order.
        let mut retired = 0;
        for idx in 0..self.inflight.len() {
            loop {
                let rec = &self.inflight[idx];
                let Some(l) = rec.next_bwd else { break };
                if rec.t + self.layers[l].delay as u64 != t {
                    break;
                }
                self.backward_layer(idx, l)
                    .with_context(|| format!("backward layer {l} of batch {}", self.inflight[idx].t))?;
            }
            if self.inflight[idx].next_bwd.is_none() {
                retired += 1;
            }
        }
        for _ in 0..retired {
            let mut rec = self.inflight.pop_front().expect("retired record");
            debug_assert!(rec.next_bwd.is_none());
            if let Some(loss) = rec.loss {
                self.epoch_losses.push(loss);
            }
            // Recycle the record's remaining buffers and chain storage.
            if let Some(dy) = rec.dy.take() {
                self.pool.recycle(dy);
            }
            self.pool.recycle(rec.onehot);
            for a in rec.acts.drain(..) {
                self.pool.recycle(a);
            }
            self.spare_chains.push(rec.acts);
        }

        self.step += 1;
        Ok(())
    }

    /// Run one layer's delayed backward for in-flight record `idx`.
    ///
    /// Hot-path memory discipline: the loss gradient and `dx` come from
    /// the pool, `dw`/`db` land in the layer's persistent workspaces, the
    /// ReLU mask uses the shared scratch, and every consumed tensor is
    /// recycled — the steady-state backward allocates nothing.
    fn backward_layer(&mut self, idx: usize, l: usize) -> Result<()> {
        let t_now = self.step;
        let t0 = self.inflight[idx].t;
        let last = l + 1 == self.mlp.num_layers();

        // Initial gradient from the loss kernel (last layer only).
        if last {
            let mut dl = self.pool.take(self.inflight[idx].acts[l + 1].shape());
            let (loss, _correct) = {
                let rec = &self.inflight[idx];
                self.backend
                    .loss_grad_into(&rec.acts[l + 1], &rec.onehot, &mut dl)?
            };
            let rec = &mut self.inflight[idx];
            rec.loss = Some(loss);
            rec.dy = Some(dl);
        }

        // The strategy picks the weight version for this backward.
        // `lr_sum` spans only the iterations where this layer actually
        // updated: updates start at iteration d_l (pipeline fill), so for
        // early batches fewer than d_l updates intervened — and the EMA's
        // cumulative-mean ramp (Eq. 7) holds exactly that many samples,
        // making reconstruction near-exact from the very first backward.
        let first_update = self.layers[l].delay as u64;
        let lr_sum = self.lr.lr_sum(t0.max(first_update), t_now);

        // Move (not clone) layer l's output and the upstream gradient out
        // of the record — this backward is their last consumer. The input
        // `acts[l]` stays: it is layer l−1's output, still needed there.
        let (y, dy) = {
            let rec = &mut self.inflight[idx];
            let y = std::mem::replace(&mut rec.acts[l + 1], Tensor::empty());
            let dy = rec.dy.take().expect("upstream gradient present");
            (y, dy)
        };
        let mut dx = self.pool.take(self.inflight[idx].acts[l].shape());
        {
            let rec = &self.inflight[idx];
            let state = &mut self.layers[l];
            let w_bwd = state
                .strategy
                .backward_weights(t0, &self.mlp.layers[l].w, lr_sum);
            self.backend.backward_into(
                self.mlp.layers[l].role,
                &rec.acts[l],
                &y,
                w_bwd,
                &dy,
                &mut self.bwd_scratch,
                &mut dx,
                &mut state.dw_buf,
                &mut state.db_buf,
            )?;
        }
        self.pool.recycle(y);
        self.pool.recycle(dy);

        // Apply immediately: the gradient lands d_l iterations after
        // launch, exactly the Eq. 1 staleness.
        let lr = self.lr.lr(t_now);
        let state = &mut self.layers[l];
        let upd_w = state.opt_w.step(&mut self.mlp.layers[l].w, &state.dw_buf, lr);
        state.strategy.on_update(upd_w);
        state.opt_b.step(&mut self.mlp.layers[l].b, &state.db_buf, lr);

        let rec = &mut self.inflight[idx];
        rec.dy = Some(dx);
        rec.next_bwd = if l == 0 { None } else { Some(l - 1) };
        Ok(())
    }

    /// Drain: run delay-only iterations until every in-flight batch has
    /// fully retired (end of training).
    pub fn drain(&mut self) -> Result<()> {
        while !self.inflight.is_empty() {
            self.iteration(None)?;
        }
        Ok(())
    }

    /// Test accuracy via the backend's full-network forward.
    pub fn evaluate(&self, data: &Splits) -> Result<f32> {
        evaluate_params(self.backend.as_ref(), &self.mlp.layers, self.cfg.model.batch, data)
    }

    /// Peak staleness-handling bytes across layers (stash + EMA).
    pub fn staleness_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.strategy.peak_staleness_nbytes()).sum()
    }

    pub fn peak_activation_bytes(&self) -> usize {
        self.peak_activation_bytes
    }

    /// Train for the configured epochs, returning the metrics curve.
    pub fn train(&mut self, data: &Splits, rng: &mut Rng) -> Result<RunCurve> {
        let mut curve = RunCurve {
            strategy: self.kind.name().to_string(),
            epochs: Vec::with_capacity(self.cfg.epochs),
        };
        for epoch in 0..self.cfg.epochs {
            let warmup = epoch < self.cfg.pipeline.warmup_epochs;
            for ls in &mut self.layers {
                ls.strategy.set_warmup(warmup);
            }
            let sw = Stopwatch::start();
            self.epoch_losses.clear();
            let iter = BatchIter::new(&data.train, self.cfg.model.batch, rng);
            for (x, onehot) in iter {
                self.iteration(Some((x, onehot)))?;
            }
            let test_accuracy = self.evaluate(data)?;
            let train_loss = if self.epoch_losses.is_empty() {
                f32::NAN
            } else {
                self.epoch_losses.iter().sum::<f32>() / self.epoch_losses.len() as f32
            };
            let m = EpochMetrics {
                epoch,
                train_loss,
                test_accuracy,
                lr: self.lr.peek(self.step),
                staleness_bytes: self.staleness_bytes(),
                activation_bytes: self.peak_activation_bytes,
                seconds: sw.elapsed_secs(),
            };
            crate::log_info!(
                "[{}] epoch {epoch}: loss {:.4} acc {:.4} ({}s)",
                self.kind.name(),
                m.train_loss,
                m.test_accuracy,
                format!("{:.2}", m.seconds)
            );
            curve.epochs.push(m);
        }
        self.drain()?;
        Ok(curve)
    }
}

// Unit tests for the pure helpers; scheduling-semantics tests live in
// rust/tests/ (integration) against the host backend.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inflight_nbytes_counts_everything() {
        // Chain of input + one output, one-hot labels, and the in-flight
        // gradient — each stored (and counted) exactly once.
        let rec = Inflight {
            t: 0,
            acts: vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[2, 2])],
            onehot: Tensor::zeros(&[2, 4]),
            dy: Some(Tensor::zeros(&[2, 2])),
            next_bwd: Some(0),
            loss: None,
        };
        assert_eq!(rec.nbytes(), (4 + 4 + 8 + 4) * 4);
    }

    #[test]
    fn lr_schedule_for_respects_cosine_flag() {
        let mut cfg = ExperimentConfig::default();
        cfg.optim.cosine = false;
        assert_eq!(lr_schedule_for(&cfg).lr(0), lr_schedule_for(&cfg).lr(999));
        cfg.optim.cosine = true;
        let s = lr_schedule_for(&cfg);
        assert!(s.lr(0) > s.lr(100));
    }
}
