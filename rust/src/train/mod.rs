//! The delayed-gradient trainer: the Fig. 5 experiment engine, and the
//! numerical oracle for the threaded executor in [`crate::pipeline`].
//!
//! Implements pipelined training in *iteration-indexed* form, which the
//! schedule module proves equivalent to the clock-level pipeline: with
//! layer delays `d_l = 2·S(l)` (Eq. 1),
//!
//! - at iteration `t`, batch `t` forwards through all layers using each
//!   layer's **current** weights; per-layer inputs/outputs are stashed
//!   (the activation stashing that §III-B shows is structural);
//! - the backward of batch `t` at layer `l` executes at iteration
//!   `t + d_l`, using the weight version chosen by the
//!   [`crate::strategy::LayerStrategy`] (stashed / latest / EMA-recomputed);
//! - the resulting gradient is applied immediately (SGD + momentum + wd,
//!   cosine lr), so the gradient misses exactly `d_l` updates — the
//!   staleness the paper analyzes.
//!
//! The sequential strategy sets every `d_l = 0`, collapsing to standard
//! backpropagation on the same code path (a true reference curve).
//!
//! Per-stage event order is the contract the multi-threaded executor
//! must reproduce: at iteration `t` a stage sees `forward(t)` first,
//! then `backward(t − d)` — see `DESIGN.md` for the equivalence
//! argument.

use crate::backend::{Backend, Exec};
use crate::config::ExperimentConfig;
use crate::data::{BatchIter, Splits};
use crate::metrics::{EpochMetrics, RunCurve};
use crate::model::{LayerParams, Mlp};
use crate::optim::{ConstantLr, CosineLr, LrBook, LrSchedule, Optimizer, Sgd};
use crate::retiming::StagePartition;
use crate::strategy::{LayerStrategy, StrategyKind};
use crate::tensor::Tensor;
use crate::util::{Rng, Stopwatch};
use anyhow::{ensure, Context, Result};
use std::collections::VecDeque;

/// The learning-rate schedule a config implies (cosine over the full
/// horizon, or constant). Shared by trainer and executor so both see
/// identical rates.
pub fn lr_schedule_for(cfg: &ExperimentConfig) -> Box<dyn LrSchedule> {
    let steps_per_epoch = cfg.data.train_samples / cfg.model.batch;
    let total_steps = steps_per_epoch * cfg.epochs;
    if cfg.optim.cosine {
        Box::new(CosineLr::new(cfg.optim.lr, cfg.optim.min_lr, total_steps.max(1)))
    } else {
        Box::new(ConstantLr(cfg.optim.lr))
    }
}

/// Batched argmax accuracy of a parameter set over the test split, via
/// the backend's full-network forward. Shared eval path for the trainer
/// and the pipelined executor.
pub fn evaluate_params(
    exec: &dyn Exec,
    layers: &[LayerParams],
    batch: usize,
    data: &Splits,
) -> Result<f32> {
    let n = data.test.len() / batch * batch;
    ensure!(n > 0, "test set smaller than one batch");
    let mut correct = 0usize;
    for start in (0..n).step_by(batch) {
        let idx: Vec<usize> = (start..start + batch).collect();
        let (x, _) = data.test.batch(&idx);
        let logits = exec.forward_full(&x, layers)?;
        let c = logits.shape()[1];
        for row in 0..batch {
            let slice = &logits.data()[row * c..(row + 1) * c];
            let mut arg = 0;
            for (j, &v) in slice.iter().enumerate() {
                if v > slice[arg] {
                    arg = j;
                }
            }
            if arg == data.test.labels[start + row] {
                correct += 1;
            }
        }
    }
    Ok(correct as f32 / n as f32)
}

/// Per-layer training state.
struct LayerState {
    strategy: LayerStrategy,
    opt_w: Sgd,
    opt_b: Sgd,
    /// Gradient delay `d_l = 2·S(l)`.
    delay: usize,
}

/// One in-flight batch: everything the delayed backward will need.
struct Inflight {
    /// Iteration at which the batch was forwarded.
    t: u64,
    /// Per-layer saved `(input, output)` activations.
    saved: Vec<(Tensor, Tensor)>,
    /// One-hot labels (consumed by `loss_grad` at backward time).
    onehot: Tensor,
    /// Upstream gradient flowing down the backward chain.
    dy: Option<Tensor>,
    /// Next layer whose backward is pending (`layers-1` → 0), or None
    /// when fully retired.
    next_bwd: Option<usize>,
    /// Loss observed when this batch's loss_grad ran.
    loss: Option<f32>,
}

impl Inflight {
    fn nbytes(&self) -> usize {
        self.saved.iter().map(|(a, b)| a.nbytes() + b.nbytes()).sum::<usize>()
            + self.onehot.nbytes()
            + self.dy.as_ref().map_or(0, Tensor::nbytes)
    }
}

/// The pipelined trainer for one strategy.
pub struct Trainer {
    backend: Backend,
    pub mlp: Mlp,
    cfg: ExperimentConfig,
    kind: StrategyKind,
    partition: StagePartition,
    layers: Vec<LayerState>,
    lr: LrBook,
    inflight: VecDeque<Inflight>,
    step: u64,
    peak_activation_bytes: usize,
    /// Losses observed this epoch (at backward time).
    epoch_losses: Vec<f32>,
}

impl Trainer {
    pub fn new(
        backend: Backend,
        cfg: &ExperimentConfig,
        kind: StrategyKind,
        rng: &mut Rng,
    ) -> Result<Trainer> {
        cfg.validate()?;
        backend.check_model(&cfg.model)?;
        let mlp = Mlp::init(&cfg.model, rng);
        // Sequential runs as a 1-stage pipeline (all delays zero).
        let stages = if kind.is_pipelined() { cfg.pipeline.stages } else { 1 };
        let partition = StagePartition::even(cfg.model.layers, stages)?;
        let delays = partition.gradient_delays();
        let layers = (0..cfg.model.layers)
            .map(|l| {
                let (din, dout) = crate::model::layer_dims(&cfg.model, l);
                LayerState {
                    strategy: LayerStrategy::new(kind, delays[l]),
                    opt_w: Sgd::new(&[din, dout], cfg.optim.momentum, cfg.optim.weight_decay),
                    opt_b: Sgd::new(&[dout], cfg.optim.momentum, 0.0),
                    delay: delays[l],
                }
            })
            .collect();
        let lr = LrBook::new(lr_schedule_for(cfg));
        Ok(Trainer {
            backend,
            mlp,
            cfg: cfg.clone(),
            kind,
            partition,
            layers,
            lr,
            inflight: VecDeque::new(),
            step: 0,
            peak_activation_bytes: 0,
            epoch_losses: Vec::new(),
        })
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    pub fn backend(&self) -> &dyn Exec {
        self.backend.as_ref()
    }

    pub fn partition(&self) -> &StagePartition {
        &self.partition
    }

    pub fn gradient_delays(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.delay).collect()
    }

    /// One pipelined iteration: forward batch `t` (if provided), then run
    /// every backward scheduled for this iteration.
    pub fn iteration(&mut self, batch: Option<(Tensor, Tensor)>) -> Result<()> {
        let t = self.step;

        // ---- forward lane ------------------------------------------------
        if let Some((x, onehot)) = batch {
            let mut saved = Vec::with_capacity(self.mlp.num_layers());
            let mut h = x;
            for l in 0..self.mlp.num_layers() {
                self.layers[l].strategy.on_forward(t, &self.mlp.layers[l].w);
                let y = self.mlp.forward_layer(self.backend.as_ref(), l, &h)?;
                saved.push((h, y.clone()));
                h = y;
            }
            self.inflight.push_back(Inflight {
                t,
                saved,
                onehot,
                dy: None,
                next_bwd: Some(self.mlp.num_layers() - 1),
                loss: None,
            });
            let act_bytes: usize = self.inflight.iter().map(Inflight::nbytes).sum();
            self.peak_activation_bytes = self.peak_activation_bytes.max(act_bytes);
        }

        // ---- backward lane -----------------------------------------------
        // Delays are non-increasing in l, so scanning in-flight batches
        // oldest-first and their layers top-down preserves dataflow order.
        let mut retired = 0;
        for idx in 0..self.inflight.len() {
            loop {
                let rec = &self.inflight[idx];
                let Some(l) = rec.next_bwd else { break };
                if rec.t + self.layers[l].delay as u64 != t {
                    break;
                }
                self.backward_layer(idx, l)
                    .with_context(|| format!("backward layer {l} of batch {}", self.inflight[idx].t))?;
            }
            if self.inflight[idx].next_bwd.is_none() {
                retired += 1;
            }
        }
        for _ in 0..retired {
            let rec = self.inflight.pop_front().expect("retired record");
            debug_assert!(rec.next_bwd.is_none());
            if let Some(loss) = rec.loss {
                self.epoch_losses.push(loss);
            }
        }

        self.step += 1;
        Ok(())
    }

    /// Run one layer's delayed backward for in-flight record `idx`.
    fn backward_layer(&mut self, idx: usize, l: usize) -> Result<()> {
        let t_now = self.step;
        let t0 = self.inflight[idx].t;
        let last = l + 1 == self.mlp.num_layers();

        // Initial gradient from the loss kernel (last layer only).
        if last {
            let rec = &self.inflight[idx];
            let logits = &rec.saved[l].1;
            let (loss, dlogits, _correct) =
                self.mlp.loss_grad(self.backend.as_ref(), logits, &rec.onehot)?;
            let rec = &mut self.inflight[idx];
            rec.loss = Some(loss);
            rec.dy = Some(dlogits);
        }

        // The strategy picks the weight version for this backward.
        // `lr_sum` spans only the iterations where this layer actually
        // updated: updates start at iteration d_l (pipeline fill), so for
        // early batches fewer than d_l updates intervened — and the EMA's
        // cumulative-mean ramp (Eq. 7) holds exactly that many samples,
        // making reconstruction near-exact from the very first backward.
        let first_update = self.layers[l].delay as u64;
        let lr_sum = self.lr.lr_sum(t0.max(first_update), t_now);

        // Move (not clone) the stashed activations and upstream gradient
        // out of the record: layer l's backward is their last consumer.
        let (x, y, dy) = {
            let rec = &mut self.inflight[idx];
            let (x, y) = std::mem::replace(
                &mut rec.saved[l],
                (Tensor::zeros(&[0]), Tensor::zeros(&[0])),
            );
            let dy = rec.dy.take().expect("upstream gradient present");
            (x, y, dy)
        };
        let (dx, dw, db) = {
            let state = &self.layers[l];
            let w_bwd = state
                .strategy
                .backward_weights(t0, &self.mlp.layers[l].w, lr_sum);
            self.mlp
                .backward_layer_with(self.backend.as_ref(), l, &x, &y, &w_bwd, &dy)?
        };

        // Apply immediately: the gradient lands d_l iterations after
        // launch, exactly the Eq. 1 staleness.
        let lr = self.lr.lr(t_now);
        let state = &mut self.layers[l];
        let upd_w = state.opt_w.step(&mut self.mlp.layers[l].w, &dw, lr);
        let _upd_b = state.opt_b.step(&mut self.mlp.layers[l].b, &db, lr);
        state.strategy.on_update(&upd_w);

        let rec = &mut self.inflight[idx];
        rec.dy = Some(dx);
        rec.next_bwd = if l == 0 { None } else { Some(l - 1) };
        Ok(())
    }

    /// Drain: run delay-only iterations until every in-flight batch has
    /// fully retired (end of training).
    pub fn drain(&mut self) -> Result<()> {
        while !self.inflight.is_empty() {
            self.iteration(None)?;
        }
        Ok(())
    }

    /// Test accuracy via the backend's full-network forward.
    pub fn evaluate(&self, data: &Splits) -> Result<f32> {
        evaluate_params(self.backend.as_ref(), &self.mlp.layers, self.cfg.model.batch, data)
    }

    /// Peak staleness-handling bytes across layers (stash + EMA).
    pub fn staleness_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.strategy.peak_staleness_nbytes()).sum()
    }

    pub fn peak_activation_bytes(&self) -> usize {
        self.peak_activation_bytes
    }

    /// Train for the configured epochs, returning the metrics curve.
    pub fn train(&mut self, data: &Splits, rng: &mut Rng) -> Result<RunCurve> {
        let mut curve = RunCurve {
            strategy: self.kind.name().to_string(),
            epochs: Vec::with_capacity(self.cfg.epochs),
        };
        for epoch in 0..self.cfg.epochs {
            let warmup = epoch < self.cfg.pipeline.warmup_epochs;
            for ls in &mut self.layers {
                ls.strategy.set_warmup(warmup);
            }
            let sw = Stopwatch::start();
            self.epoch_losses.clear();
            let iter = BatchIter::new(&data.train, self.cfg.model.batch, rng);
            for (x, onehot) in iter {
                self.iteration(Some((x, onehot)))?;
            }
            let test_accuracy = self.evaluate(data)?;
            let train_loss = if self.epoch_losses.is_empty() {
                f32::NAN
            } else {
                self.epoch_losses.iter().sum::<f32>() / self.epoch_losses.len() as f32
            };
            let m = EpochMetrics {
                epoch,
                train_loss,
                test_accuracy,
                lr: self.lr.peek(self.step),
                staleness_bytes: self.staleness_bytes(),
                activation_bytes: self.peak_activation_bytes,
                seconds: sw.elapsed_secs(),
            };
            crate::log_info!(
                "[{}] epoch {epoch}: loss {:.4} acc {:.4} ({}s)",
                self.kind.name(),
                m.train_loss,
                m.test_accuracy,
                format!("{:.2}", m.seconds)
            );
            curve.epochs.push(m);
        }
        self.drain()?;
        Ok(curve)
    }
}

// Unit tests for the pure helpers; scheduling-semantics tests live in
// rust/tests/ (integration) against the host backend.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inflight_nbytes_counts_everything() {
        let rec = Inflight {
            t: 0,
            saved: vec![(Tensor::zeros(&[2, 2]), Tensor::zeros(&[2, 2]))],
            onehot: Tensor::zeros(&[2, 4]),
            dy: Some(Tensor::zeros(&[2, 2])),
            next_bwd: Some(0),
            loss: None,
        };
        assert_eq!(rec.nbytes(), (4 + 4 + 8 + 4) * 4);
    }

    #[test]
    fn lr_schedule_for_respects_cosine_flag() {
        let mut cfg = ExperimentConfig::default();
        cfg.optim.cosine = false;
        assert_eq!(lr_schedule_for(&cfg).lr(0), lr_schedule_for(&cfg).lr(999));
        cfg.optim.cosine = true;
        let s = lr_schedule_for(&cfg);
        assert!(s.lr(0) > s.lr(100));
    }
}
