//! The delayed-gradient trainer: the Fig. 5 experiment engine, and the
//! numerical oracle for the threaded executor in [`crate::pipeline`].
//!
//! Implements pipelined training in *iteration-indexed* form, which the
//! schedule module proves equivalent to the clock-level pipeline: with
//! layer delays `d_l = 2·S(l)` (Eq. 1),
//!
//! - at iteration `t`, batch `t` forwards through all layers using each
//!   layer's **current** weights; per-layer inputs/outputs are stashed
//!   (the activation stashing that §III-B shows is structural);
//! - the backward of batch `t` at layer `l` executes at iteration
//!   `t + d_l`, using the weight version chosen by the
//!   [`crate::strategy::LayerStrategy`] (stashed / latest / EMA-recomputed);
//! - the resulting gradient is applied immediately (SGD + momentum + wd,
//!   cosine lr), so the gradient misses exactly `d_l` updates — the
//!   staleness the paper analyzes.
//!
//! The sequential strategy sets every `d_l = 0`, collapsing to standard
//! backpropagation on the same code path (a true reference curve).
//!
//! The trainer is layer-kind-agnostic: it drives a [`Network`] of
//! `Box<dyn Layer>` ops (dense, conv, pool, spiking — see
//! [`crate::layers`]), with strategies, optimizers, stashes and EMA
//! accumulators operating uniformly on each layer's parameter tensors
//! (zero-length for parameter-free layers). [`Trainer::new`] builds the
//! legacy dense MLP from the model config with the seed's even
//! partition (bit-identical curves); [`Trainer::with_spec`] accepts any
//! heterogeneous stack and picks stage boundaries by **cost-balanced
//! compute** ([`StagePartition::balanced`], per LayerPipe) — the delay
//! per layer is still `2 ·` downstream stage count, never cost-derived.
//!
//! Per-stage event order is the contract the multi-threaded executor
//! must reproduce: at iteration `t` a stage sees `forward(t)` first,
//! then `backward(t − d)` — see `DESIGN.md` for the equivalence
//! argument.

use crate::backend::{Backend, Exec};
use crate::config::ExperimentConfig;
use crate::data::{BatchIter, Splits};
use crate::layers::{NetLayer, Network, NetworkSpec};
use crate::metrics::{EpochMetrics, RunCurve};
use crate::model::LayerParams;
use crate::optim::{ConstantLr, CosineLr, LrBook, LrSchedule, Optimizer, Sgd};
use crate::retiming::StagePartition;
use crate::strategy::{LayerStrategy, StrategyKind};
use crate::tensor::{BufferPool, Dtype, Tensor};
use crate::util::{Rng, Stopwatch};
use anyhow::{ensure, Context, Result};
use std::collections::VecDeque;

/// The learning-rate schedule a config implies (cosine over the full
/// horizon, or constant). Shared by trainer and executor so both see
/// identical rates.
pub fn lr_schedule_for(cfg: &ExperimentConfig) -> Box<dyn LrSchedule> {
    let steps_per_epoch = cfg.data.train_samples / cfg.model.batch;
    let total_steps = steps_per_epoch * cfg.epochs;
    if cfg.optim.cosine {
        Box::new(CosineLr::new(cfg.optim.lr, cfg.optim.min_lr, total_steps.max(1)))
    } else {
        Box::new(ConstantLr(cfg.optim.lr))
    }
}

/// Argmax-correct row count of `logits` against true labels.
fn count_correct(logits: &Tensor, labels: &[usize], offset: usize) -> usize {
    let (rows, c) = (logits.shape()[0], logits.shape()[1]);
    let mut correct = 0usize;
    for row in 0..rows {
        let slice = &logits.data()[row * c..(row + 1) * c];
        let mut arg = 0;
        for (j, &v) in slice.iter().enumerate() {
            if v > slice[arg] {
                arg = j;
            }
        }
        if arg == labels[offset + row] {
            correct += 1;
        }
    }
    correct
}

/// Batched argmax accuracy of a dense parameter set over the test split,
/// via the backend's full-network forward (kept for the legacy `Mlp`
/// harness; trainers evaluate through [`evaluate_network`]).
pub fn evaluate_params(
    exec: &dyn Exec,
    layers: &[LayerParams],
    batch: usize,
    data: &Splits,
) -> Result<f32> {
    let n = data.test.len() / batch * batch;
    ensure!(n > 0, "test set smaller than one batch");
    let mut correct = 0usize;
    for start in (0..n).step_by(batch) {
        let idx: Vec<usize> = (start..start + batch).collect();
        let (x, _) = data.test.batch(&idx);
        let logits = exec.forward_full(&x, layers)?;
        correct += count_correct(&logits, &data.test.labels, start);
    }
    Ok(correct as f32 / n as f32)
}

/// Batched argmax accuracy of a heterogeneous network over the test
/// split — the shared evaluation path of both training engines (the
/// executor evaluates a snapshot, so both run identical f32 sequences).
///
/// Pure-dense stacks route through [`evaluate_params`] and thus the
/// backend's *fused* full-network forward (one PJRT `fwd_full` artifact
/// dispatch per batch, as the seed did); the host default chains the
/// same per-layer kernels, so the two paths are bitwise identical
/// there. Heterogeneous stacks chain their ops.
pub fn evaluate_network(
    exec: &dyn Exec,
    net: &mut Network,
    batch: usize,
    data: &Splits,
) -> Result<f32> {
    if let Some(params) = net.dense_params() {
        return evaluate_params(exec, &params, batch, data);
    }
    let n = data.test.len() / batch * batch;
    ensure!(n > 0, "test set smaller than one batch");
    let mut correct = 0usize;
    for start in (0..n).step_by(batch) {
        let idx: Vec<usize> = (start..start + batch).collect();
        let (x, _) = data.test.batch(&idx);
        let logits = net.forward_full(exec, &x)?;
        correct += count_correct(&logits, &data.test.labels, start);
    }
    Ok(correct as f32 / n as f32)
}

/// Fail fast at construction when the backend cannot serve a spec:
/// pure-dense stacks go through the backend's own shape check (and on
/// PJRT must match the uniform-MLP geometry its artifacts were lowered
/// at, layer for layer), while conv/pool/spiking ops only have host
/// kernels today (PJRT per-op artifacts: ROADMAP open item). Shared by
/// both engines' `with_spec` constructors.
fn check_backend_serves_spec(
    exec: &dyn Exec,
    cfg: &ExperimentConfig,
    spec: &NetworkSpec,
) -> Result<()> {
    if spec.is_dense() {
        exec.check_model(&cfg.model)?;
        let mlp = NetworkSpec::mlp(&cfg.model);
        ensure!(
            exec.name() != "pjrt" || (spec.input == mlp.input && spec.layers == mlp.layers),
            "PJRT dense artifacts are lowered for the uniform MLP preset of \
             cfg.model; this dense spec's layer geometry differs — use the \
             host backend (LAYERPIPE2_BACKEND=host) or regenerate artifacts"
        );
        Ok(())
    } else {
        ensure!(
            exec.name() != "pjrt",
            "the PJRT backend serves only dense layers; this spec has \
             conv/pool/spiking ops — use the host backend \
             (LAYERPIPE2_BACKEND=host) or see ROADMAP: PJRT conv artifacts"
        );
        Ok(())
    }
}

/// Fail fast at construction when a non-f32 storage dtype cannot be
/// served: the backend must have widening kernels (host does; PJRT
/// artifacts are lowered for f32 literals) and every op must accept the
/// dtype (dense does; conv/pool/LIF kernels read f32 slices directly —
/// ROADMAP open item). Shared by both engines' `assemble` paths.
pub(crate) fn check_dtype_served(exec: &dyn Exec, net: &Network, dtype: Dtype) -> Result<()> {
    if dtype == Dtype::F32 {
        return Ok(());
    }
    ensure!(
        exec.supports_dtype(dtype),
        "backend '{}' cannot execute {dtype} tensors — use the host backend \
         (LAYERPIPE2_BACKEND=host) for mixed precision",
        exec.name()
    );
    for (l, nl) in net.layers.iter().enumerate() {
        ensure!(
            nl.op.supports_dtype(dtype),
            "layer {l} ({}) has no {dtype} kernels — mixed precision currently \
             serves pure-dense stacks (DESIGN.md §11; ROADMAP: conv/pool/LIF \
             bf16 kernels)",
            nl.op.name()
        );
    }
    Ok(())
}

/// The shared `with_spec` front half of both training engines: validate
/// the spec against the config and backend, build the network
/// (consuming `rng` deterministically), and derive the cost-balanced
/// partition. One seam, so the oracle and the threaded executor can
/// never accept different specs or pick different partitions — the
/// precondition of their numerical interchangeability.
pub(crate) fn build_spec_network(
    exec: &dyn Exec,
    cfg: &ExperimentConfig,
    spec: &NetworkSpec,
    kind: StrategyKind,
    rng: &mut Rng,
) -> Result<(Network, StagePartition)> {
    cfg.validate()?;
    let net = Network::build(spec, rng)?;
    ensure!(
        net.input_dim() == cfg.model.input_dim,
        "spec input dim {} vs cfg.model.input_dim {}",
        net.input_dim(),
        cfg.model.input_dim
    );
    ensure!(
        net.out_dim() == cfg.model.classes,
        "spec output dim {} vs cfg.model.classes {}",
        net.out_dim(),
        cfg.model.classes
    );
    ensure!(
        net.num_layers() == cfg.model.layers,
        "spec has {} layers but cfg.model.layers = {}",
        net.num_layers(),
        cfg.model.layers
    );
    check_backend_serves_spec(exec, cfg, spec)?;
    let stages = if kind.is_pipelined() { cfg.pipeline.stages } else { 1 };
    let costs: Vec<u64> = net.costs(cfg.model.batch).iter().map(|c| c.total_flops()).collect();
    let partition = StagePartition::balanced(&costs, stages)?;
    Ok((net, partition))
}

/// Per-layer training state.
struct LayerState {
    strategy: LayerStrategy,
    opt_w: Sgd,
    opt_b: Sgd,
    /// Gradient delay `d_l = 2·S(l)`.
    delay: usize,
    /// Persistent `_into` workspaces for this layer's weight/bias
    /// gradients (overwritten every backward, never reallocated).
    dw_buf: Tensor,
    db_buf: Tensor,
    /// Mixed precision (DESIGN.md §11): the f32 master copy of the
    /// weights. The optimizer steps *this* tensor; the layer's storage
    /// weights are re-quantized from it after every step, so rounding
    /// error never compounds across steps. `None` in f32 runs — the
    /// optimizer then steps the storage weights directly (the
    /// bitwise-identical historical path). Biases stay f32 always.
    master_w: Option<Tensor>,
}

/// One in-flight batch: everything the delayed backward will need.
struct Inflight {
    /// Iteration at which the batch was forwarded.
    t: u64,
    /// Activation chain: `acts[0]` is the batch input, `acts[l + 1]` is
    /// layer `l`'s output (each stored once — a layer's input *is* the
    /// previous layer's output). Entries consumed by retiring backwards
    /// are replaced with empty placeholders and recycled into the pool.
    acts: Vec<Tensor>,
    /// One-hot labels (consumed by `loss_grad` at backward time).
    onehot: Tensor,
    /// Upstream gradient flowing down the backward chain.
    dy: Option<Tensor>,
    /// Next layer whose backward is pending (`layers-1` → 0), or None
    /// when fully retired.
    next_bwd: Option<usize>,
    /// Loss observed when this batch's loss_grad ran.
    loss: Option<f32>,
}

impl Inflight {
    fn nbytes(&self) -> usize {
        self.acts.iter().map(Tensor::nbytes).sum::<usize>()
            + self.onehot.nbytes()
            + self.dy.as_ref().map_or(0, Tensor::nbytes)
    }
}

/// The pipelined trainer for one strategy.
pub struct Trainer {
    backend: Backend,
    pub net: Network,
    cfg: ExperimentConfig,
    kind: StrategyKind,
    partition: StagePartition,
    layers: Vec<LayerState>,
    lr: LrBook,
    inflight: VecDeque<Inflight>,
    step: u64,
    peak_activation_bytes: usize,
    /// Losses observed this epoch (at backward time).
    epoch_losses: Vec<f32>,
    /// Recycled tensor storage for activations and gradients: the
    /// steady-state loop allocates nothing.
    pool: BufferPool,
    /// Pre-activation-gradient workspace shared across layer backwards.
    bwd_scratch: Tensor,
    /// Emptied activation-chain Vecs from retired batches, reused by the
    /// forward lane.
    spare_chains: Vec<Vec<Tensor>>,
    /// Ring mode ([`crate::replica`]): hold every optimizer step of the
    /// current iteration until [`Trainer::apply_pending`], so the staged
    /// gradients can be all-reduced across replica lanes first. Safe to
    /// stage in the per-layer `dw_buf`/`db_buf` workspaces because each
    /// layer backwards at most once per iteration (`t0 + d_l = t` has at
    /// most one solution per layer), and bit-identical to immediate
    /// stepping because within one iteration no event reads another
    /// layer's post-step weights (each event touches only its own
    /// layer's parameters; cross-event dataflow is the `dx`→`dy` chain,
    /// which never reads weights of already-stepped layers).
    defer_steps: bool,
    /// Deferred `(layer, lr)` steps of the current iteration, in event
    /// order (the order immediate stepping would have used).
    pending: Vec<(usize, f32)>,
    /// Storage dtype for weights and stashed activations (`cfg.dtype`).
    dtype: Dtype,
    /// Persistent f32 staging buffer for the bf16 forward lane: kernels
    /// accumulate into f32, the result is quantized into the pooled
    /// bf16 activation. Unused (empty) in f32 runs.
    fwd_scratch: Tensor,
}

impl Trainer {
    /// The legacy dense-MLP trainer: seed-identical parameters (same rng
    /// consumption as `Mlp::init`) and the seed's even layer partition,
    /// so existing curves are unchanged.
    pub fn new(
        backend: Backend,
        cfg: &ExperimentConfig,
        kind: StrategyKind,
        rng: &mut Rng,
    ) -> Result<Trainer> {
        cfg.validate()?;
        backend.check_model(&cfg.model)?;
        let net = Network::build(&NetworkSpec::mlp(&cfg.model), rng)?;
        // Sequential runs as a 1-stage pipeline (all delays zero).
        let stages = if kind.is_pipelined() { cfg.pipeline.stages } else { 1 };
        let partition = StagePartition::even(net.num_layers(), stages)?;
        Self::assemble(backend, cfg, kind, net, partition)
    }

    /// Heterogeneous trainer: any [`NetworkSpec`] (conv / pool / spiking
    /// / dense), with stage boundaries chosen by **cost-balanced
    /// compute** from each layer's [`crate::layers::LayerCost`] report.
    /// `cfg.model` must agree with the spec on batch/input/classes and
    /// carry `layers == spec.layers.len()` (it still drives the data
    /// generator and lr horizon).
    pub fn with_spec(
        backend: Backend,
        cfg: &ExperimentConfig,
        spec: &NetworkSpec,
        kind: StrategyKind,
        rng: &mut Rng,
    ) -> Result<Trainer> {
        let (net, partition) = build_spec_network(backend.as_ref(), cfg, spec, kind, rng)?;
        Self::assemble(backend, cfg, kind, net, partition)
    }

    fn assemble(
        backend: Backend,
        cfg: &ExperimentConfig,
        kind: StrategyKind,
        mut net: Network,
        partition: StagePartition,
    ) -> Result<Trainer> {
        let dtype = cfg.dtype;
        check_dtype_served(backend.as_ref(), &net, dtype)?;
        let delays = partition.gradient_delays();
        let layers = net
            .layers
            .iter_mut()
            .zip(&delays)
            .map(|(nl, &d)| {
                // Mixed precision: the freshly initialized f32 weights
                // become the master copy; storage weights quantize once
                // here and are re-quantized from the master every step.
                let master_w = (dtype != Dtype::F32).then(|| {
                    let master = nl.w.clone();
                    nl.w = nl.w.to_dtype(dtype);
                    master
                });
                LayerState {
                    strategy: LayerStrategy::new_with_dtype(kind, d, dtype),
                    opt_w: Sgd::new(nl.w.shape(), cfg.optim.momentum, cfg.optim.weight_decay),
                    opt_b: Sgd::new(nl.b.shape(), cfg.optim.momentum, 0.0),
                    delay: d,
                    dw_buf: Tensor::empty(),
                    db_buf: Tensor::empty(),
                    master_w,
                }
            })
            .collect();
        let lr = LrBook::new(lr_schedule_for(cfg));
        Ok(Trainer {
            backend,
            net,
            cfg: cfg.clone(),
            kind,
            partition,
            layers,
            lr,
            inflight: VecDeque::new(),
            step: 0,
            peak_activation_bytes: 0,
            epoch_losses: Vec::new(),
            pool: BufferPool::new(),
            bwd_scratch: Tensor::empty(),
            spare_chains: Vec::new(),
            defer_steps: false,
            pending: Vec::new(),
            dtype,
            fwd_scratch: Tensor::empty(),
        })
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    /// Storage dtype of weights and stashed activations (`cfg.dtype`).
    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    pub fn backend(&self) -> &dyn Exec {
        self.backend.as_ref()
    }

    pub fn partition(&self) -> &StagePartition {
        &self.partition
    }

    pub fn gradient_delays(&self) -> Vec<usize> {
        self.layers.iter().map(|l| l.delay).collect()
    }

    /// One pipelined iteration: forward batch `t` (if provided), then run
    /// every backward scheduled for this iteration.
    pub fn iteration(&mut self, batch: Option<(Tensor, Tensor)>) -> Result<()> {
        let t = self.step;

        // ---- forward lane ------------------------------------------------
        if let Some((x, onehot)) = batch {
            crate::obs::span!("train/fwd");
            let nl = self.net.num_layers();
            // Recycled chain Vec + pooled output buffers: the steady-state
            // forward performs zero heap allocation.
            let mut acts = self.spare_chains.pop().unwrap_or_default();
            debug_assert!(acts.is_empty());
            acts.reserve(nl + 1);
            acts.push(x);
            for l in 0..nl {
                let rows = acts[l].shape()[0];
                let dout = self.net.layers[l].op.out_dim();
                let mut y = self.pool.take_dtype(&[rows, dout], self.dtype);
                let layer = &mut self.net.layers[l];
                self.layers[l].strategy.on_forward(t, &layer.w);
                if self.dtype == Dtype::F32 {
                    layer
                        .op
                        .forward_into(self.backend.as_ref(), &acts[l], &layer.w, &layer.b, &mut y)?;
                } else {
                    // bf16 lane: the kernel accumulates into the f32
                    // staging buffer; the stored activation is its
                    // one-rounding quantization. The batch input
                    // `acts[0]` stays f32 (the feed is f32 data).
                    layer.op.forward_into(
                        self.backend.as_ref(),
                        &acts[l],
                        &layer.w,
                        &layer.b,
                        &mut self.fwd_scratch,
                    )?;
                    y.quantize_from(&self.fwd_scratch);
                }
                acts.push(y);
            }
            self.inflight.push_back(Inflight {
                t,
                acts,
                onehot,
                dy: None,
                next_bwd: Some(nl - 1),
                loss: None,
            });
            let act_bytes: usize = self.inflight.iter().map(Inflight::nbytes).sum();
            self.peak_activation_bytes = self.peak_activation_bytes.max(act_bytes);
        }

        // ---- backward lane -----------------------------------------------
        // Delays are non-increasing in l, so scanning in-flight batches
        // oldest-first and their layers top-down preserves dataflow order.
        crate::obs::span!("train/bwd");
        let mut retired = 0;
        for idx in 0..self.inflight.len() {
            loop {
                let rec = &self.inflight[idx];
                let Some(l) = rec.next_bwd else { break };
                if rec.t + self.layers[l].delay as u64 != t {
                    break;
                }
                self.backward_layer(idx, l)
                    .with_context(|| format!("backward layer {l} of batch {}", self.inflight[idx].t))?;
            }
            if self.inflight[idx].next_bwd.is_none() {
                retired += 1;
            }
        }
        for _ in 0..retired {
            let mut rec = self.inflight.pop_front().expect("retired record");
            debug_assert!(rec.next_bwd.is_none());
            if let Some(loss) = rec.loss {
                self.epoch_losses.push(loss);
            }
            // Recycle the record's remaining buffers and chain storage.
            if let Some(dy) = rec.dy.take() {
                self.pool.recycle(dy);
            }
            self.pool.recycle(rec.onehot);
            for a in rec.acts.drain(..) {
                self.pool.recycle(a);
            }
            self.spare_chains.push(rec.acts);
        }

        self.step += 1;
        Ok(())
    }

    /// Run one layer's delayed backward for in-flight record `idx`.
    ///
    /// Hot-path memory discipline: the loss gradient and `dx` come from
    /// the pool, `dw`/`db` land in the layer's persistent workspaces, the
    /// op's mask/patch work uses the shared scratch and op-local
    /// workspaces, and every consumed tensor is recycled — the
    /// steady-state backward allocates nothing.
    fn backward_layer(&mut self, idx: usize, l: usize) -> Result<()> {
        let t_now = self.step;
        let t0 = self.inflight[idx].t;
        let last = l + 1 == self.net.num_layers();

        // Initial gradient from the loss kernel (last layer only).
        if last {
            let mut dl = self.pool.take(self.inflight[idx].acts[l + 1].shape());
            let (loss, _correct) = {
                let rec = &self.inflight[idx];
                self.backend
                    .loss_grad_into(&rec.acts[l + 1], &rec.onehot, &mut dl)?
            };
            let rec = &mut self.inflight[idx];
            rec.loss = Some(loss);
            rec.dy = Some(dl);
        }

        // The strategy picks the weight version for this backward.
        // `lr_sum` spans only the iterations where this layer actually
        // updated: updates start at iteration d_l (pipeline fill), so for
        // early batches fewer than d_l updates intervened — and the EMA's
        // cumulative-mean ramp (Eq. 7) holds exactly that many samples,
        // making reconstruction near-exact from the very first backward.
        let first_update = self.layers[l].delay as u64;
        let lr_sum = self.lr.lr_sum(t0.max(first_update), t_now);

        // Move (not clone) layer l's output and the upstream gradient out
        // of the record — this backward is their last consumer. The input
        // `acts[l]` stays: it is layer l−1's output, still needed there.
        let (y, dy) = {
            let rec = &mut self.inflight[idx];
            let y = std::mem::replace(&mut rec.acts[l + 1], Tensor::empty());
            let dy = rec.dy.take().expect("upstream gradient present");
            (y, dy)
        };
        let mut dx = self.pool.take(self.inflight[idx].acts[l].shape());
        {
            let rec = &self.inflight[idx];
            let state = &mut self.layers[l];
            let NetLayer { op, w, .. } = &mut self.net.layers[l];
            let w_bwd = state.strategy.backward_weights(t0, w, lr_sum);
            op.backward_into(
                self.backend.as_ref(),
                &rec.acts[l],
                &y,
                w_bwd,
                &dy,
                &mut self.bwd_scratch,
                &mut dx,
                &mut state.dw_buf,
                &mut state.db_buf,
            )?;
        }
        self.pool.recycle(y);
        self.pool.recycle(dy);

        // Apply immediately: the gradient lands d_l iterations after
        // launch, exactly the Eq. 1 staleness. Parameter-free layers
        // carry zero-length params — their step is a uniform no-op.
        // In ring mode the step is queued instead: the staged gradient
        // stays in `dw_buf`/`db_buf` until the all-reduce hands back the
        // cross-lane mean and `apply_pending` replays the queue in this
        // exact event order.
        let lr = self.lr.lr(t_now);
        if self.defer_steps {
            debug_assert!(
                self.pending.iter().all(|&(pl, _)| pl != l),
                "layer {l} staged twice in one iteration (apply_pending not called?)"
            );
            self.pending.push((l, lr));
        } else {
            self.step_layer(l, lr);
        }

        let rec = &mut self.inflight[idx];
        rec.dy = Some(dx);
        rec.next_bwd = if l == 0 { None } else { Some(l - 1) };
        Ok(())
    }

    /// Apply layer `l`'s staged gradient: SGD on the f32 master (mixed
    /// precision) or directly on the storage weights (f32 — the
    /// bitwise-identical historical path), then feed the applied update
    /// to the strategy's EMA accumulators.
    fn step_layer(&mut self, l: usize, lr: f32) {
        let state = &mut self.layers[l];
        let layer = &mut self.net.layers[l];
        match &mut state.master_w {
            Some(master) => {
                state.opt_w.step(master, &state.dw_buf, lr);
                layer.w.quantize_from(&*master);
                state.strategy.on_update(state.opt_w.velocity());
            }
            None => {
                let upd_w = state.opt_w.step(&mut layer.w, &state.dw_buf, lr);
                state.strategy.on_update(upd_w);
            }
        }
        state.opt_b.step(&mut layer.b, &state.db_buf, lr);
    }

    // ---- replica-ring hooks (crate-internal; see `crate::replica`) ------

    /// Switch optimizer stepping between immediate (stock) and deferred
    /// (ring) mode. With deferral on, each `iteration` stages its
    /// gradients in the per-layer workspaces and queues `(layer, lr)`
    /// records; the caller must exchange/reduce the staged gradients and
    /// call [`Trainer::apply_pending`] before the next `iteration`.
    pub(crate) fn set_defer_steps(&mut self, on: bool) {
        self.defer_steps = on;
    }

    /// The `(layer, lr)` optimizer steps staged by the last iteration,
    /// in event order.
    pub(crate) fn pending_steps(&self) -> &[(usize, f32)] {
        &self.pending
    }

    /// Mutable access to layer `l`'s staged gradient workspaces, so the
    /// ring codec can flatten them out and write the reduced mean back.
    pub(crate) fn staged_grads_mut(&mut self, l: usize) -> (&mut Tensor, &mut Tensor) {
        let state = &mut self.layers[l];
        (&mut state.dw_buf, &mut state.db_buf)
    }

    /// Replay the deferred optimizer steps in the exact order immediate
    /// stepping would have used. Bit-identical to stock stepping when
    /// the staged gradients are untouched (the single-lane oracle);
    /// in the ring they hold the cross-lane mean by the time this runs.
    pub(crate) fn apply_pending(&mut self) {
        // Indexed loop (entries are Copy): the queue Vec is cleared, not
        // dropped, so its capacity is reused — the steady-state ring
        // loop stays allocation-free.
        for i in 0..self.pending.len() {
            let (l, lr) = self.pending[i];
            self.step_layer(l, lr);
        }
        self.pending.clear();
    }

    /// Number of batches still in the pipeline — the ring's lockstep
    /// drain condition (identical schedules make it agree across lanes).
    pub(crate) fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    /// Pooled feed buffers for external batch drivers (the replica
    /// ring): same closed take→recycle loop as [`Trainer::train`] — the
    /// batch tensors return to this pool when the batch retires.
    pub(crate) fn take_feed(&mut self, rows: usize, d: usize, classes: usize) -> (Tensor, Tensor) {
        (self.pool.take(&[rows, d]), self.pool.take(&[rows, classes]))
    }

    /// Losses observed so far (at backward time). The ring reports the
    /// mean over the whole run instead of per-epoch slices.
    pub(crate) fn observed_losses(&self) -> &[f32] {
        &self.epoch_losses
    }

    /// Drain: run delay-only iterations until every in-flight batch has
    /// fully retired (end of training).
    pub fn drain(&mut self) -> Result<()> {
        while !self.inflight.is_empty() {
            self.iteration(None)?;
        }
        Ok(())
    }

    /// Test accuracy — the identical evaluation sequence the threaded
    /// executor uses ([`evaluate_network`] owns the dense fast-path
    /// dispatch; running it on the live network reuses op workspaces
    /// and clones nothing beyond the dense param view).
    pub fn evaluate(&mut self, data: &Splits) -> Result<f32> {
        evaluate_network(self.backend.as_ref(), &mut self.net, self.cfg.model.batch, data)
    }

    /// Peak staleness-handling bytes across layers (stash + EMA).
    pub fn staleness_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.strategy.peak_staleness_nbytes()).sum()
    }

    pub fn peak_activation_bytes(&self) -> usize {
        self.peak_activation_bytes
    }

    /// Train for the configured epochs, returning the metrics curve.
    pub fn train(&mut self, data: &Splits, rng: &mut Rng) -> Result<RunCurve> {
        let mut curve = RunCurve {
            strategy: self.kind.name().to_string(),
            epochs: Vec::with_capacity(self.cfg.epochs),
        };
        for epoch in 0..self.cfg.epochs {
            let warmup = epoch < self.cfg.pipeline.warmup_epochs;
            for ls in &mut self.layers {
                ls.strategy.set_warmup(warmup);
            }
            let sw = Stopwatch::start();
            self.epoch_losses.clear();
            // Pooled batch extraction (`batch_into`): input and one-hot
            // buffers come from the trainer pool and return to it when
            // the batch retires — feeding data allocates nothing in
            // steady state.
            let d = data.train.input_dim();
            let classes = data.train.classes;
            let mut iter = BatchIter::new(&data.train, self.cfg.model.batch, rng);
            while let Some(idx) = iter.next_indices() {
                let mut x = self.pool.take(&[idx.len(), d]);
                let mut oh = self.pool.take(&[idx.len(), classes]);
                data.train.batch_into(idx, &mut x, &mut oh);
                self.iteration(Some((x, oh)))?;
            }
            let test_accuracy = self.evaluate(data)?;
            let train_loss = if self.epoch_losses.is_empty() {
                f32::NAN
            } else {
                self.epoch_losses.iter().sum::<f32>() / self.epoch_losses.len() as f32
            };
            let m = EpochMetrics {
                epoch,
                train_loss,
                test_accuracy,
                lr: self.lr.peek(self.step),
                staleness_bytes: self.staleness_bytes(),
                activation_bytes: self.peak_activation_bytes,
                seconds: sw.elapsed_secs(),
            };
            crate::log_info!(
                "[{}] epoch {epoch}: loss {:.4} acc {:.4} ({}s)",
                self.kind.name(),
                m.train_loss,
                m.test_accuracy,
                format!("{:.2}", m.seconds)
            );
            curve.epochs.push(m);
        }
        self.drain()?;
        Ok(curve)
    }
}

// Unit tests for the pure helpers; scheduling-semantics tests live in
// rust/tests/ (integration) against the host backend.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inflight_nbytes_counts_everything() {
        // Chain of input + one output, one-hot labels, and the in-flight
        // gradient — each stored (and counted) exactly once.
        let rec = Inflight {
            t: 0,
            acts: vec![Tensor::zeros(&[2, 2]), Tensor::zeros(&[2, 2])],
            onehot: Tensor::zeros(&[2, 4]),
            dy: Some(Tensor::zeros(&[2, 2])),
            next_bwd: Some(0),
            loss: None,
        };
        assert_eq!(rec.nbytes(), (4 + 4 + 8 + 4) * 4);
    }

    #[test]
    fn lr_schedule_for_respects_cosine_flag() {
        let mut cfg = ExperimentConfig::default();
        cfg.optim.cosine = false;
        assert_eq!(lr_schedule_for(&cfg).lr(0), lr_schedule_for(&cfg).lr(999));
        cfg.optim.cosine = true;
        let s = lr_schedule_for(&cfg);
        assert!(s.lr(0) > s.lr(100));
    }
}
