//! Training metrics: per-epoch records, CSV export, run summaries.

use anyhow::{Context, Result};
use std::io::Write;

/// One epoch's measurements for one strategy.
#[derive(Clone, Debug, PartialEq)]
pub struct EpochMetrics {
    pub epoch: usize,
    pub train_loss: f32,
    pub test_accuracy: f32,
    pub lr: f32,
    /// Peak staleness-handling bytes (weight stash + EMA state).
    pub staleness_bytes: usize,
    /// Peak activation-stash bytes.
    pub activation_bytes: usize,
    /// Wall-clock seconds spent in this epoch.
    pub seconds: f64,
}

/// A full training curve for one strategy.
#[derive(Clone, Debug, Default)]
pub struct RunCurve {
    pub strategy: String,
    pub epochs: Vec<EpochMetrics>,
}

impl RunCurve {
    pub fn final_accuracy(&self) -> f32 {
        self.epochs.last().map_or(0.0, |e| e.test_accuracy)
    }

    pub fn best_accuracy(&self) -> f32 {
        self.epochs.iter().map(|e| e.test_accuracy).fold(0.0, f32::max)
    }

    /// Mean accuracy over the last `k` epochs (steady-state comparison —
    /// single-epoch values are noisy at small scale).
    pub fn tail_accuracy(&self, k: usize) -> f32 {
        let n = self.epochs.len().min(k).max(1);
        let s: f32 = self.epochs.iter().rev().take(n).map(|e| e.test_accuracy).sum();
        s / n as f32
    }

    pub fn peak_staleness_bytes(&self) -> usize {
        self.epochs.iter().map(|e| e.staleness_bytes).max().unwrap_or(0)
    }
}

/// Write a set of curves to CSV: `strategy,epoch,train_loss,test_acc,...`.
pub fn write_csv(path: &str, curves: &[RunCurve]) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    writeln!(
        f,
        "strategy,epoch,train_loss,test_accuracy,lr,staleness_bytes,activation_bytes,seconds"
    )?;
    for c in curves {
        for e in &c.epochs {
            writeln!(
                f,
                "{},{},{:.6},{:.4},{:.6},{},{},{:.3}",
                c.strategy,
                e.epoch,
                e.train_loss,
                e.test_accuracy,
                e.lr,
                e.staleness_bytes,
                e.activation_bytes,
                e.seconds
            )?;
        }
    }
    Ok(())
}

/// Render curves as a fixed-width comparison table (stdout reporting).
pub fn accuracy_table(curves: &[RunCurve]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>10} {:>10} {:>10} {:>14}\n",
        "strategy", "final acc", "best acc", "tail3 acc", "staleness KiB"
    ));
    for c in curves {
        out.push_str(&format!(
            "{:<16} {:>10.4} {:>10.4} {:>10.4} {:>14.1}\n",
            c.strategy,
            c.final_accuracy(),
            c.best_accuracy(),
            c.tail_accuracy(3),
            c.peak_staleness_bytes() as f64 / 1024.0
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(name: &str, accs: &[f32]) -> RunCurve {
        RunCurve {
            strategy: name.to_string(),
            epochs: accs
                .iter()
                .enumerate()
                .map(|(i, &a)| EpochMetrics {
                    epoch: i,
                    train_loss: 1.0 / (i + 1) as f32,
                    test_accuracy: a,
                    lr: 0.1,
                    staleness_bytes: 1024 * (i + 1),
                    activation_bytes: 64,
                    seconds: 0.5,
                })
                .collect(),
        }
    }

    #[test]
    fn summaries() {
        let c = curve("stashing", &[0.1, 0.5, 0.4]);
        assert_eq!(c.final_accuracy(), 0.4);
        assert_eq!(c.best_accuracy(), 0.5);
        assert!((c.tail_accuracy(2) - 0.45).abs() < 1e-6);
        assert_eq!(c.peak_staleness_bytes(), 3072);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let path = std::env::temp_dir().join("lp2_metrics_test.csv");
        let path = path.to_str().unwrap();
        write_csv(path, &[curve("a", &[0.1, 0.2]), curve("b", &[0.3])]).unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4); // header + 3 rows
        assert!(lines[0].starts_with("strategy,epoch"));
        assert!(lines[1].starts_with("a,0,"));
        assert!(lines[3].starts_with("b,0,"));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_contains_all_strategies() {
        let t = accuracy_table(&[curve("x", &[0.5]), curve("y", &[0.6])]);
        assert!(t.contains('x') && t.contains('y'));
    }

    #[test]
    fn empty_curve_is_safe() {
        let c = RunCurve { strategy: "e".into(), epochs: vec![] };
        assert_eq!(c.final_accuracy(), 0.0);
        assert_eq!(c.tail_accuracy(3), 0.0);
    }
}
