//! 2-D max pooling over NHWC maps (parameter-free).
//!
//! The backward *recomputes* each window's argmax from the stashed input
//! instead of saving index maps per in-flight batch — the same
//! recompute-over-stash tradeoff as the conv im2col (and deterministic:
//! ties resolve to the first maximum in scan order in both passes).

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::workers;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// `y[b, oy, ox, c] = max` over a `k×k` window with the given stride
/// (no padding).
pub struct MaxPool2d {
    in_h: usize,
    in_w: usize,
    c: usize,
    k: usize,
    stride: usize,
}

impl MaxPool2d {
    pub fn new(in_h: usize, in_w: usize, c: usize, k: usize, stride: usize) -> Result<MaxPool2d> {
        ensure!(in_h > 0 && in_w > 0 && c > 0, "pool input dims must be positive");
        ensure!(k > 0 && stride > 0, "pool k/stride must be positive");
        ensure!(k <= in_h && k <= in_w, "pool window {k} exceeds input {in_h}x{in_w}");
        Ok(MaxPool2d { in_h, in_w, c, k, stride })
    }

    /// Output spatial dims `(oh, ow)`.
    pub fn out_hw(&self) -> (usize, usize) {
        ((self.in_h - self.k) / self.stride + 1, (self.in_w - self.k) / self.stride + 1)
    }

    /// Flat NHWC index of the argmax of window `(oy, ox)`, channel `ch`,
    /// within one sample's map. First maximum in `(ky, kx)` scan order
    /// wins — the single tie rule both passes share.
    fn argmax(&self, map: &[f32], oy: usize, ox: usize, ch: usize) -> usize {
        let (w, c) = (self.in_w, self.c);
        let mut best_at = (oy * self.stride * w + ox * self.stride) * c + ch;
        let mut best = map[best_at];
        for ky in 0..self.k {
            let iy = oy * self.stride + ky;
            for kx in 0..self.k {
                let ix = ox * self.stride + kx;
                let at = (iy * w + ix) * c + ch;
                if map[at] > best {
                    best = map[at];
                    best_at = at;
                }
            }
        }
        best_at
    }

    fn check_input(&self, x: &Tensor, what: &str) -> Result<usize> {
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.in_dim(),
            "max-pool {what}: expected [batch, {}], got {:?}",
            self.in_dim(),
            x.shape()
        );
        Ok(x.shape()[0])
    }

    /// Forward body for one sample: fill `orow` with the window maxima
    /// of `map`.
    fn forward_sample(&self, map: &[f32], orow: &mut [f32]) {
        let (oh, ow) = self.out_hw();
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..self.c {
                    orow[(oy * ow + ox) * self.c + ch] = map[self.argmax(map, oy, ox, ch)];
                }
            }
        }
    }

    /// Backward body for one sample: recompute each window's argmax and
    /// scatter-add `grow` into `xrow` (zero-filled by the caller;
    /// overlapping windows accumulate).
    fn backward_sample(&self, map: &[f32], grow: &[f32], xrow: &mut [f32]) {
        let (oh, ow) = self.out_hw();
        for oy in 0..oh {
            for ox in 0..ow {
                for ch in 0..self.c {
                    xrow[self.argmax(map, oy, ox, ch)] += grow[(oy * ow + ox) * self.c + ch];
                }
            }
        }
    }

    /// Worker count for a pass over `bsz` samples: samples are wholly
    /// owned by one worker each (forward writes and backward scatters
    /// never cross a sample boundary), so any split is bit-identical.
    fn pass_threads(&self, bsz: usize) -> usize {
        let (oh, ow) = self.out_hw();
        let compares = bsz * oh * ow * self.c * self.k * self.k;
        workers::unit_threads(compares, bsz)
    }
}

impl Layer for MaxPool2d {
    fn name(&self) -> String {
        let (oh, ow) = self.out_hw();
        format!(
            "maxpool[{}x{}x{}->{}x{}x{},k{},s{}]",
            self.in_h, self.in_w, self.c, oh, ow, self.c, self.k, self.stride
        )
    }

    fn in_dim(&self) -> usize {
        self.in_h * self.in_w * self.c
    }

    fn out_dim(&self) -> usize {
        let (oh, ow) = self.out_hw();
        oh * ow * self.c
    }

    fn checkpoint_tag(&self) -> u32 {
        4
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let (oh, ow) = self.out_hw();
        let compares = (batch * oh * ow * self.c * self.k * self.k) as u64;
        LayerCost {
            fwd_flops: compares,
            bwd_flops: compares, // argmax recompute + scatter
            act_bytes: (batch * oh * ow * self.c * 4) as u64,
            param_bytes: 0,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, w, b);
        let bsz = self.check_input(x, "forward")?;
        out.resize(&[bsz, self.out_dim()]);
        let xd = x.data();
        let od = out.data_mut();
        let per = self.in_dim();
        let oper = self.out_dim();
        let threads = self.pass_threads(bsz);
        if threads <= 1 {
            for (bi, orow) in od.chunks_mut(oper).enumerate() {
                self.forward_sample(&xd[bi * per..(bi + 1) * per], orow);
            }
            return Ok(());
        }
        let per_task = bsz.div_ceil(threads);
        let op: &MaxPool2d = self; // shared reborrow for the task closures
        workers::run_chunked(od, per_task * oper, &|ci, chunk| {
            for (i, orow) in chunk.chunks_mut(oper).enumerate() {
                let bi = ci * per_task + i;
                op.forward_sample(&xd[bi * per..(bi + 1) * per], orow);
            }
        });
        Ok(())
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, y, w, scratch);
        let bsz = self.check_input(x, "backward")?;
        ensure!(
            dy.shape() == [bsz, self.out_dim()],
            "max-pool backward: dy {:?} vs expected [{bsz}, {}]",
            dy.shape(),
            self.out_dim()
        );
        dx.resize(&[bsz, self.in_dim()]);
        dx.fill(0.0);
        dw.resize(&[0]);
        db.resize(&[0]);
        let xd = x.data();
        let gd = dy.data();
        let xgd = dx.data_mut();
        let per = self.in_dim();
        let oper = self.out_dim();
        let threads = self.pass_threads(bsz);
        if threads <= 1 {
            for (bi, xrow) in xgd.chunks_mut(per).enumerate() {
                self.backward_sample(
                    &xd[bi * per..(bi + 1) * per],
                    &gd[bi * oper..(bi + 1) * oper],
                    xrow,
                );
            }
            return Ok(());
        }
        let per_task = bsz.div_ceil(threads);
        let op: &MaxPool2d = self; // shared reborrow for the task closures
        workers::run_chunked(xgd, per_task * per, &|ci, chunk| {
            for (i, xrow) in chunk.chunks_mut(per).enumerate() {
                let bi = ci * per_task + i;
                op.backward_sample(
                    &xd[bi * per..(bi + 1) * per],
                    &gd[bi * oper..(bi + 1) * oper],
                    xrow,
                );
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;
    use crate::util::Rng;

    #[test]
    fn forward_picks_window_maxima() {
        // 1 sample, 2x2 pool on a 4x4 single-channel map.
        let mut op = MaxPool2d::new(4, 4, 1, 2, 2).unwrap();
        #[rustfmt::skip]
        let x = Tensor::from_vec(&[1, 16], vec![
            1.0, 2.0, 3.0, 4.0,
            5.0, 6.0, 7.0, 8.0,
            9.0, 1.0, 2.0, 3.0,
            4.0, 5.0, 6.0, 7.0,
        ]);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y.shape(), &[1, 4]);
        assert_eq!(y.data(), &[6.0, 8.0, 9.0, 7.0]);
    }

    #[test]
    fn backward_routes_gradient_to_argmax() {
        let mut op = MaxPool2d::new(4, 4, 1, 2, 2).unwrap();
        #[rustfmt::skip]
        let x = Tensor::from_vec(&[1, 16], vec![
            1.0, 2.0, 3.0, 4.0,
            5.0, 6.0, 7.0, 8.0,
            9.0, 1.0, 2.0, 3.0,
            4.0, 5.0, 6.0, 7.0,
        ]);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let dy = Tensor::from_vec(&[1, 4], vec![10.0, 20.0, 30.0, 40.0]);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        assert_eq!(dw.shape(), &[0]);
        assert_eq!(db.shape(), &[0]);
        let mut want = vec![0.0f32; 16];
        want[5] = 10.0; // 6
        want[7] = 20.0; // 8
        want[8] = 30.0; // 9
        want[15] = 40.0; // 7
        assert_eq!(dx.data(), &want[..]);
    }

    #[test]
    fn ties_resolve_identically_in_both_passes() {
        // A constant map: forward's max equals the first window element,
        // so backward must route everything there too.
        let mut op = MaxPool2d::new(2, 2, 1, 2, 2).unwrap();
        let x = Tensor::from_vec(&[1, 4], vec![3.0; 4]);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y.data(), &[3.0]);
        let dy = Tensor::from_vec(&[1, 1], vec![5.0]);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        assert_eq!(dx.data(), &[5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn multichannel_pooling_is_per_channel() {
        let mut rng = Rng::new(8);
        let mut op = MaxPool2d::new(4, 4, 3, 2, 2).unwrap();
        let x = Tensor::randn(&[2, op.in_dim()], 1.0, &mut rng);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y.shape(), &[2, 2 * 2 * 3]);
        // Every output equals the max over its window, per channel.
        for bi in 0..2 {
            for oy in 0..2 {
                for ox in 0..2 {
                    for ch in 0..3 {
                        let got = y.data()[bi * 12 + (oy * 2 + ox) * 3 + ch];
                        let mut want = f32::NEG_INFINITY;
                        for ky in 0..2 {
                            for kx in 0..2 {
                                let at = bi * 48 + ((oy * 2 + ky) * 4 + ox * 2 + kx) * 3 + ch;
                                want = want.max(x.data()[at]);
                            }
                        }
                        assert_eq!(got, want);
                    }
                }
            }
        }
    }

    #[test]
    fn rejects_bad_geometry() {
        assert!(MaxPool2d::new(2, 2, 1, 3, 1).is_err());
        assert!(MaxPool2d::new(4, 4, 0, 2, 2).is_err());
    }
}
