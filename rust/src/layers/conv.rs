//! 2-D convolution via im2col + the packed/worker-pool matmul kernels.
//!
//! Every heavy pass is worker-pool parallel with deterministic results:
//! the im2col gather splits patch rows across workers (pure data
//! movement), the `dw = colsᵀ·dz` reduction rides the fixed-geometry
//! tree of `matmul_tn_into`, the fused mask+`db` epilogue uses the
//! shared fixed-chunk reduction, and the col2im accumulation assigns
//! each *input* row to exactly one worker, which gathers the patch
//! windows touching it in the serial scatter's `(oy, ox)` order — so
//! conv forward *and* backward scale with `LAYERPIPE2_WORKERS` while
//! staying bit-identical across worker counts.
//!
//! Layout: activations are NHWC flattened to `[batch, h·w·c]`, so a conv
//! output (`[batch·oh·ow, out_c]` after the matmul) reshapes to the next
//! layer's input for free — same backing store, no transpose.
//!
//! Workspace lifecycle (hot-path memory discipline): the op owns two
//! persistent buffers, `cols` (im2col patches) and `dcols` (their
//! gradient). Both are resized in place every call — a no-op once shapes
//! repeat — so steady-state conv forward/backward allocates nothing.
//! The backward *recomputes* im2col from the stashed input rather than
//! caching the forward's patches: in pipelined execution the backward of
//! batch `t` runs `d` iterations after its forward, and caching patches
//! per in-flight batch would cost `O(d·k²·c·h·w)` bytes per stage — the
//! recompute trades one gather pass for that stash, mirroring the
//! paper's recompute-over-stash theme.

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::workers;
use crate::tensor::{self, Tensor};
use crate::util::Rng;
use anyhow::{ensure, Result};

/// `y = act(conv2d(x, w) + b)` over NHWC maps.
///
/// `w: [k·k·in_c, out_c]` (patch-major, matching the im2col column
/// order), `b: [out_c]`.
pub struct Conv2d {
    in_h: usize,
    in_w: usize,
    in_c: usize,
    out_c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    relu: bool,
    /// Persistent im2col workspace: `[batch·oh·ow, k·k·in_c]`.
    cols: Tensor,
    /// Persistent patch-gradient workspace (same shape as `cols`).
    dcols: Tensor,
}

impl Conv2d {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        relu: bool,
    ) -> Result<Conv2d> {
        ensure!(in_h > 0 && in_w > 0 && in_c > 0, "conv input dims must be positive");
        ensure!(out_c > 0 && k > 0 && stride > 0, "conv out_c/k/stride must be positive");
        ensure!(
            in_h + 2 * pad >= k && in_w + 2 * pad >= k,
            "conv kernel {k} exceeds padded input {}x{}",
            in_h + 2 * pad,
            in_w + 2 * pad
        );
        Ok(Conv2d {
            in_h,
            in_w,
            in_c,
            out_c,
            k,
            stride,
            pad,
            relu,
            cols: Tensor::empty(),
            dcols: Tensor::empty(),
        })
    }

    /// Output spatial dims `(oh, ow)`.
    pub fn out_hw(&self) -> (usize, usize) {
        (
            (self.in_h + 2 * self.pad - self.k) / self.stride + 1,
            (self.in_w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }

    fn patch(&self) -> usize {
        self.k * self.k * self.in_c
    }

    /// Gather the NHWC patch of one output position (`row` indexes
    /// `bi·oh·ow + oy·ow + ox`) into `dst`, zero-filling out-of-bounds
    /// (padding) positions. Fully overwrites `dst`.
    fn gather_patch_row(&self, xd: &[f32], dst: &mut [f32], row: usize) {
        let (h, w, c) = (self.in_h, self.in_w, self.in_c);
        let (oh, ow) = self.out_hw();
        let bi = row / (oh * ow);
        let rem = row % (oh * ow);
        let (oy, ox) = (rem / ow, rem % ow);
        let xoff = bi * h * w * c;
        let mut p = 0usize;
        for ky in 0..self.k {
            let iy = (oy * self.stride + ky) as isize - self.pad as isize;
            if iy < 0 || iy >= h as isize {
                dst[p..p + self.k * c].fill(0.0);
                p += self.k * c;
                continue;
            }
            let rowoff = xoff + (iy as usize) * w * c;
            for kx in 0..self.k {
                let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                if ix < 0 || ix >= w as isize {
                    dst[p..p + c].fill(0.0);
                } else {
                    let src = rowoff + (ix as usize) * c;
                    dst[p..p + c].copy_from_slice(&xd[src..src + c]);
                }
                p += c;
            }
        }
    }

    /// Gather NHWC patches of `x` into `cols: [batch·oh·ow, k·k·in_c]`,
    /// zero-filling out-of-bounds (padding) positions. Fully overwrites
    /// `cols`, so dirty recycled storage is fine. Large gathers split
    /// rows across pool workers — each patch row is written by exactly
    /// one worker and the gather is pure data movement, so the result is
    /// trivially identical for every worker count.
    fn im2col(&self, x: &Tensor, cols: &mut Tensor) {
        let bsz = x.shape()[0];
        let (oh, ow) = self.out_hw();
        let patch = self.patch();
        let rows = bsz * oh * ow;
        cols.resize(&[rows, patch]);
        let xd = x.data();
        let cd = cols.data_mut();
        let threads = workers::unit_threads(rows * patch, rows);
        if threads <= 1 {
            for (row, dst) in cd.chunks_mut(patch).enumerate() {
                self.gather_patch_row(xd, dst, row);
            }
            return;
        }
        let rows_per = rows.div_ceil(threads);
        workers::run_chunked(cd, rows_per * patch, &|ci, chunk| {
            for (i, dst) in chunk.chunks_mut(patch).enumerate() {
                self.gather_patch_row(xd, dst, ci * rows_per + i);
            }
        });
    }

    /// Accumulate the patch gradients back onto the input map: the
    /// exact transpose of [`Conv2d::im2col`]. `dx` must be resized and
    /// zero-filled by the caller. Large maps split *input* rows across
    /// pool workers — each `(batch, iy)` row of `dx` is owned by
    /// exactly one worker, which gathers every patch window touching it
    /// in the serial scatter's accumulation order, so the result is
    /// bitwise identical at every worker count.
    fn col2im_add(&self, dcols: &Tensor, dx: &mut Tensor) {
        let bsz = dx.shape()[0];
        let (oh, ow) = self.out_hw();
        let threads = workers::unit_threads(bsz * oh * ow * self.patch(), bsz * self.in_h);
        self.col2im_add_with_threads(dcols, dx, threads);
    }

    /// [`Conv2d::col2im_add`] with an explicit worker count — exposed to
    /// the tests so the bitwise serial-vs-parallel sweep is direct.
    fn col2im_add_with_threads(&self, dcols: &Tensor, dx: &mut Tensor, threads: usize) {
        if threads <= 1 {
            self.col2im_add_serial(dcols, dx);
            return;
        }
        let bsz = dx.shape()[0];
        let (h, w, c) = (self.in_h, self.in_w, self.in_c);
        let rows = bsz * h;
        let gd = dcols.data();
        let xd = dx.data_mut();
        let rows_per = rows.div_ceil(threads);
        workers::run_chunked(xd, rows_per * w * c, &|ci, chunk| {
            for (i, dst) in chunk.chunks_mut(w * c).enumerate() {
                self.col2im_gather_row(gd, dst, ci * rows_per + i);
            }
        });
    }

    /// Gather-accumulate every patch-gradient contribution landing on
    /// one input row of `dx` (`row` indexes `bi·in_h + iy`; `dst` is
    /// that row's `[in_w · in_c]` slice).
    ///
    /// Bit-compatibility with the serial scatter: for a fixed `dx`
    /// element, the scatter's contributions arrive ordered by
    /// `(oy asc, ox asc)` (the `ky`/`kx` taps are determined by
    /// `(oy, ox)` once the element is fixed). This gather walks the
    /// same `(oy asc, ox asc)` order, so every element accumulates in
    /// the identical f32 sequence.
    fn col2im_gather_row(&self, gd: &[f32], dst: &mut [f32], row: usize) {
        let (w, c) = (self.in_w, self.in_c);
        let (oh, ow) = self.out_hw();
        let patch = self.patch();
        let bi = row / self.in_h;
        let iy = row % self.in_h;
        // Output rows whose kernel window covers input row iy:
        // ky = iy + pad − oy·stride must lie in [0, k).
        let t = iy + self.pad;
        let oy_lo = t.saturating_sub(self.k - 1).div_ceil(self.stride);
        let oy_hi = (t / self.stride).min(oh - 1);
        for oy in oy_lo..=oy_hi {
            let ky = t - oy * self.stride;
            let src_base = (bi * oh + oy) * ow;
            for ox in 0..ow {
                let src = &gd[(src_base + ox) * patch..(src_base + ox + 1) * patch];
                for kx in 0..self.k {
                    let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                    if ix >= 0 && (ix as usize) < w {
                        let at = ix as usize * c;
                        let p = (ky * self.k + kx) * c;
                        for (xv, gv) in dst[at..at + c].iter_mut().zip(src[p..p + c].iter()) {
                            *xv += gv;
                        }
                    }
                }
            }
        }
    }

    /// The reference serial scatter (the gather paths must reproduce it
    /// bit for bit; also the small-shape fast path).
    fn col2im_add_serial(&self, dcols: &Tensor, dx: &mut Tensor) {
        let bsz = dx.shape()[0];
        let (h, w, c) = (self.in_h, self.in_w, self.in_c);
        let (oh, ow) = self.out_hw();
        let patch = self.patch();
        let gd = dcols.data();
        let xd = dx.data_mut();
        let mut row = 0usize;
        for bi in 0..bsz {
            let xoff = bi * h * w * c;
            for oy in 0..oh {
                for ox in 0..ow {
                    let src = &gd[row * patch..(row + 1) * patch];
                    let mut p = 0usize;
                    for ky in 0..self.k {
                        let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                        if iy < 0 || iy >= h as isize {
                            p += self.k * c;
                            continue;
                        }
                        let rowoff = xoff + (iy as usize) * w * c;
                        for kx in 0..self.k {
                            let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                            if ix >= 0 && ix < w as isize {
                                let at = rowoff + (ix as usize) * c;
                                for (xv, gv) in
                                    xd[at..at + c].iter_mut().zip(src[p..p + c].iter())
                                {
                                    *xv += gv;
                                }
                            }
                            p += c;
                        }
                    }
                    row += 1;
                }
            }
        }
    }

    fn check_input(&self, x: &Tensor, what: &str) -> Result<usize> {
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.in_dim(),
            "conv {what}: expected [batch, {}], got {:?}",
            self.in_dim(),
            x.shape()
        );
        Ok(x.shape()[0])
    }
}

impl Layer for Conv2d {
    fn name(&self) -> String {
        let (oh, ow) = self.out_hw();
        format!(
            "conv2d[{}x{}x{}->{}x{}x{},k{},s{},p{}{}]",
            self.in_h,
            self.in_w,
            self.in_c,
            oh,
            ow,
            self.out_c,
            self.k,
            self.stride,
            self.pad,
            if self.relu { ",relu" } else { "" }
        )
    }

    fn in_dim(&self) -> usize {
        self.in_h * self.in_w * self.in_c
    }

    fn out_dim(&self) -> usize {
        let (oh, ow) = self.out_hw();
        oh * ow * self.out_c
    }

    fn checkpoint_tag(&self) -> u32 {
        3
    }

    fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![self.patch(), self.out_c], vec![self.out_c])
    }

    fn init_params(&self, init_scale: f32, rng: &mut Rng) -> (Tensor, Tensor) {
        // He init on the receptive-field fan-in (k·k·in_c).
        let std = init_scale * (2.0 / self.patch() as f32).sqrt();
        (Tensor::randn(&[self.patch(), self.out_c], std, rng), Tensor::zeros(&[self.out_c]))
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let (oh, ow) = self.out_hw();
        let madds = (batch * oh * ow * self.out_c * self.patch()) as u64;
        LayerCost {
            fwd_flops: 2 * madds,
            // dw + dcols matmuls, each the forward's size (the im2col
            // gathers are bandwidth, not flops).
            bwd_flops: 4 * madds,
            act_bytes: (batch * oh * ow * self.out_c * 4) as u64,
            param_bytes: ((self.patch() * self.out_c + self.out_c) * 4) as u64,
        }
    }

    /// im2col → matmul (worker-pool parallel for large shapes) → fused
    /// bias(+ReLU) epilogue → reshape to the flat NHWC wire format.
    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = exec; // host kernels; PJRT conv artifacts are an open item
        let bsz = self.check_input(x, "forward")?;
        ensure!(
            w.shape() == [self.patch(), self.out_c] && b.shape() == [self.out_c],
            "conv forward: param shapes {:?}/{:?} vs expected [{}, {}]/[{}]",
            w.shape(),
            b.shape(),
            self.patch(),
            self.out_c,
            self.out_c
        );
        let mut cols = std::mem::replace(&mut self.cols, Tensor::empty());
        self.im2col(x, &mut cols);
        tensor::matmul_into(&cols, w, out); // [bsz·oh·ow, out_c]
        self.cols = cols;
        tensor::bias_act_inplace(out, b, self.relu);
        out.resize(&[bsz, self.out_dim()]); // same storage, wire shape
        Ok(())
    }

    /// Fused ReLU-mask + per-channel bias-grad epilogue into `scratch`
    /// (= `dz`), then `dw = colsᵀ·dz` and `dx = col2im(dz·wᵀ)`.
    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = exec;
        let bsz = self.check_input(x, "backward")?;
        ensure!(
            y.shape() == [bsz, self.out_dim()] && dy.shape() == y.shape(),
            "conv backward: y {:?} / dy {:?} vs expected [{bsz}, {}]",
            y.shape(),
            dy.shape(),
            self.out_dim()
        );
        ensure!(
            w.shape() == [self.patch(), self.out_c],
            "conv backward: weight shape {:?} vs expected [{}, {}]",
            w.shape(),
            self.patch(),
            self.out_c
        );
        let (oh, ow) = self.out_hw();
        let rows = bsz * oh * ow;
        let oc = self.out_c;

        // dz = dy ⊙ (y > 0 when relu), db[ch] = Σ dz[·, ch], over the
        // [rows, out_c] channel-major view — the shared fused epilogue
        // kernel (worker-pool parallel past its threshold, fixed-chunk
        // db reduction, same element order as the dense path).
        scratch.resize(&[rows, oc]);
        db.resize(&[oc]);
        tensor::grad_col_sum_rows(
            y.data(),
            dy.data(),
            scratch.data_mut(),
            db.data_mut(),
            rows,
            oc,
            self.relu,
        );

        // dw = colsᵀ @ dz — im2col recomputed from the stashed input
        // (see module docs on the recompute-over-stash tradeoff).
        let mut cols = std::mem::replace(&mut self.cols, Tensor::empty());
        self.im2col(x, &mut cols);
        tensor::matmul_tn_into(&cols, scratch, dw);
        self.cols = cols;

        // dx = col2im(dz @ wᵀ).
        let mut dcols = std::mem::replace(&mut self.dcols, Tensor::empty());
        tensor::matmul_nt_into(scratch, w, &mut dcols);
        dx.resize(&[bsz, self.in_dim()]);
        dx.fill(0.0);
        self.col2im_add(&dcols, dx);
        self.dcols = dcols;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    /// Direct (quadruple-loop) conv reference in NHWC.
    fn naive_conv(op: &Conv2d, x: &Tensor, w: &Tensor, b: &Tensor) -> Tensor {
        let bsz = x.shape()[0];
        let (oh, ow) = op.out_hw();
        let (h, wd, c, oc, k) = (op.in_h, op.in_w, op.in_c, op.out_c, op.k);
        let mut out = Tensor::zeros(&[bsz, oh * ow * oc]);
        for bi in 0..bsz {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ch in 0..oc {
                        let mut s = b.data()[ch];
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * op.stride + ky) as isize - op.pad as isize;
                                let ix = (ox * op.stride + kx) as isize - op.pad as isize;
                                if iy < 0 || iy >= h as isize || ix < 0 || ix >= wd as isize {
                                    continue;
                                }
                                for ci in 0..c {
                                    let xv = x.data()
                                        [bi * h * wd * c + (iy as usize * wd + ix as usize) * c + ci];
                                    let wv = w.data()[((ky * k + kx) * c + ci) * oc + ch];
                                    s += xv * wv;
                                }
                            }
                        }
                        if op.relu {
                            s = s.max(0.0);
                        }
                        out.data_mut()[bi * oh * ow * oc + (oy * ow + ox) * oc + ch] = s;
                    }
                }
            }
        }
        out
    }

    fn mk(relu: bool) -> (Conv2d, Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(11);
        let op = Conv2d::new(5, 6, 2, 3, 3, 1, 1, relu).unwrap();
        let (w, b0) = op.init_params(1.0, &mut rng);
        let mut b = b0;
        rng.fill_normal_f32(b.data_mut(), 0.1); // nonzero bias for coverage
        let x = Tensor::randn(&[2, op.in_dim()], 1.0, &mut rng);
        (op, x, w, b)
    }

    #[test]
    fn forward_matches_naive_conv() {
        for relu in [false, true] {
            let (mut op, x, w, b) = mk(relu);
            let be = HostBackend::new();
            let mut y = Tensor::empty();
            op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
            assert_eq!(y.shape(), &[2, op.out_dim()]);
            let want = naive_conv(&op, &x, &w, &b);
            assert!(y.max_abs_diff(&want) < 1e-4, "relu={relu}");
        }
    }

    #[test]
    fn forward_into_dirty_buffer_is_clean() {
        let (mut op, x, w, b) = mk(true);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let mut dirty = Tensor::randn(&[3, 7], 9.0, &mut Rng::new(1));
        op.forward_into(&be, &x, &w, &b, &mut dirty).unwrap();
        assert_eq!(y, dirty);
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Scalar-project the output and check every gradient against
        // central differences (strides/padding exercised).
        let mut rng = Rng::new(21);
        let mut op = Conv2d::new(4, 4, 2, 3, 3, 2, 1, true).unwrap();
        let (w, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[2, op.in_dim()], 1.0, &mut rng);
        let proj = Tensor::randn(&[2, op.out_dim()], 1.0, &mut rng);
        let be = HostBackend::new();
        let mut fwd = |op: &mut Conv2d, x: &Tensor, w: &Tensor, b: &Tensor| -> f32 {
            let mut y = Tensor::empty();
            op.forward_into(&be, x, w, b, &mut y).unwrap();
            y.data().iter().zip(proj.data()).map(|(a, p)| a * p).sum()
        };
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &proj, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        let eps = 1e-2;
        let mut check = |which: &str, grad: &Tensor| {
            let target = match which {
                "w" => &w,
                "b" => &b,
                _ => &x,
            };
            for idx in 0..target.len() {
                let (mut tp, mut tm) = (target.clone(), target.clone());
                tp.data_mut()[idx] += eps;
                tm.data_mut()[idx] -= eps;
                let (fp, fm) = match which {
                    "w" => (fwd(&mut op, &x, &tp, &b), fwd(&mut op, &x, &tm, &b)),
                    "b" => (fwd(&mut op, &x, &w, &tp), fwd(&mut op, &x, &w, &tm)),
                    _ => (fwd(&mut op, &tp, &w, &b), fwd(&mut op, &tm, &w, &b)),
                };
                let fd = (fp - fm) / (2.0 * eps);
                assert!(
                    (fd - grad.data()[idx]).abs() < 3e-2,
                    "{which}[{idx}]: fd {fd} vs analytic {}",
                    grad.data()[idx]
                );
            }
        };
        check("w", &dw);
        check("b", &db);
        check("x", &dx);
    }

    #[test]
    fn workspaces_persist_across_calls() {
        let (mut op, x, w, b) = mk(true);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let cap0 = op.cols.len();
        assert!(cap0 > 0, "im2col workspace materialized");
        let y0 = y.clone();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y, y0, "repeat forward is deterministic");
        assert_eq!(op.cols.len(), cap0, "workspace reused, not regrown");
    }

    #[test]
    fn rejects_bad_geometry_and_shapes() {
        assert!(Conv2d::new(2, 2, 1, 1, 5, 1, 0, true).is_err()); // kernel > input
        assert!(Conv2d::new(4, 4, 1, 0, 3, 1, 1, true).is_err()); // zero out_c
        let (mut op, _, w, b) = mk(true);
        let bad = Tensor::zeros(&[2, 7]);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        assert!(op.forward_into(&be, &bad, &w, &b, &mut y).is_err());
    }

    #[test]
    fn col2im_parallel_matches_serial_bitwise() {
        // Strided + padded + unit geometries on batches big enough to
        // split many ways: every worker count must reproduce the serial
        // scatter bit for bit (per-element accumulation order is
        // (oy asc, ox asc) on both paths).
        let mut rng = Rng::new(31);
        for (h, w, c, k, stride, pad) in
            [(5, 6, 2, 3, 1, 1), (7, 5, 3, 3, 2, 1), (4, 4, 1, 2, 2, 0), (3, 3, 2, 3, 1, 2)]
        {
            let op = Conv2d::new(h, w, c, 3, k, stride, pad, false).unwrap();
            let (oh, ow) = op.out_hw();
            let bsz = 3;
            let dcols = Tensor::randn(&[bsz * oh * ow, op.patch()], 1.0, &mut rng);
            let mut want = Tensor::zeros(&[bsz, op.in_dim()]);
            op.col2im_add_serial(&dcols, &mut want);
            for threads in 1..=8 {
                let mut got = Tensor::zeros(&[bsz, op.in_dim()]);
                op.col2im_add_with_threads(&dcols, &mut got, threads);
                for (i, (g, e)) in got.data().iter().zip(want.data()).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        e.to_bits(),
                        "col2im drift at elem {i}, threads={threads}, \
                         geo=({h},{w},{c},k{k},s{stride},p{pad})"
                    );
                }
            }
        }
    }

    #[test]
    fn cost_counts_receptive_field() {
        let op = Conv2d::new(8, 8, 2, 4, 3, 1, 1, true).unwrap();
        let c = op.cost(2);
        // 2 · B·oh·ow·oc·k²·ic = 2 · 2·8·8·4·18
        assert_eq!(c.fwd_flops, 2 * 2 * 8 * 8 * 4 * 18);
        assert_eq!(c.bwd_flops, 2 * c.fwd_flops);
        assert_eq!(c.act_bytes, (2 * 8 * 8 * 4 * 4) as u64);
    }
}
