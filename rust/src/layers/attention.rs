//! Single-head self-attention on the packed/worker-pool matmul kernels.
//!
//! The wire format stays the flat `[batch, seq·d_model]` activation
//! every other layer speaks; internally rows reinterpret as
//! `[batch·seq, d_model]` (same backing order, one copy into a
//! persistent workspace so the matmul family sees a 2-D operand).
//!
//! Forward: one fused QKV projection `[batch·seq, 3·d_model]` on the
//! packed matmul (worker-pool parallel past the usual threshold), then
//! per sample: scaled scores `s = q·kᵀ/√d`, the optional causal mask
//! through [`crate::tensor::masked_softmax_rows_into`] (total on every
//! input — fully-masked rows yield zero rows, never NaN), and the
//! attention-weighted value aggregation `y = p·v`.
//!
//! The projection is deliberately *bias-free* (`b` is the `[0]`-shaped
//! paramless placeholder, the convention of most modern transformer
//! stacks): [`Layer::backward_into`] receives only `(x, y, w, dy)`, and
//! LayerPipe's delayed backward substitutes historical/EMA weights per
//! iteration — so everything the backward recomputes must be a pure
//! function of exactly those inputs. With a bias the recomputed scores
//! would need a `b` the contract does not provide.
//!
//! Backward mirrors conv's recompute-over-stash: scores and softmax
//! probabilities are *recomputed* from the stashed input instead of
//! cached per in-flight batch (a `d`-deep stash of `[seq, seq]` prob
//! matrices per stage otherwise). Gradients:
//! `dV = pᵀ·dy`, `dP = dy·vᵀ`,
//! `dS = p ⊙ (dP − rowsum(dP ⊙ p)) / √d`, `dQ = dS·k`, `dK = dSᵀ·q`,
//! then the fused projection backward `dw = xᵀ·dqkv`,
//! `dx = dqkv·wᵀ`. Every matmul rides the deterministic kernel family
//! (fixed chunk geometry, gap-doubling `tn` tree) and the per-sample
//! loop and softmax passes are serial, so results are bit-identical
//! across `LAYERPIPE2_WORKERS`.

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::{self, Tensor};
use crate::util::Rng;
use anyhow::{ensure, Result};

/// `y[b] = softmax(mask(q·kᵀ/√d))·v` per sample, with fused bias-free
/// QKV projection `w: [d_model, 3·d_model]` (`q | k | v` column blocks).
pub struct SelfAttention {
    seq: usize,
    d_model: usize,
    causal: bool,
    /// `1/√d_model`, applied to the scores before masking.
    scale: f32,
    /// Additive `[seq, seq]` causal mask (`0` keep / `-inf` drop);
    /// `None` when not causal.
    mask: Option<Tensor>,
    /// Persistent `[batch·seq, d_model]` row view of the input.
    xr: Tensor,
    /// Persistent fused projection output `[batch·seq, 3·d_model]`.
    qkv: Tensor,
    // Per-sample workspaces, all `[seq, d_model]` or `[seq, seq]`.
    q: Tensor,
    k: Tensor,
    v: Tensor,
    /// Scores `[seq, seq]`.
    sc: Tensor,
    /// Softmax probabilities `[seq, seq]`.
    pr: Tensor,
    /// One sample's upstream gradient rows `[seq, d_model]`.
    dyb: Tensor,
    /// `dP`, overwritten in place into `dS` `[seq, seq]`.
    dp: Tensor,
    gq: Tensor,
    gk: Tensor,
    gv: Tensor,
    /// Weighted aggregation output for one sample `[seq, d_model]`.
    yb: Tensor,
}

impl SelfAttention {
    pub fn new(seq: usize, d_model: usize, causal: bool) -> Result<SelfAttention> {
        ensure!(seq > 0 && d_model > 0, "attention seq/d_model must be positive");
        let mask = causal.then(|| {
            let mut m = Tensor::zeros(&[seq, seq]);
            for i in 0..seq {
                for j in (i + 1)..seq {
                    m.set2(i, j, f32::NEG_INFINITY);
                }
            }
            m
        });
        Ok(SelfAttention {
            seq,
            d_model,
            causal,
            scale: 1.0 / (d_model as f32).sqrt(),
            mask,
            xr: Tensor::empty(),
            qkv: Tensor::empty(),
            q: Tensor::empty(),
            k: Tensor::empty(),
            v: Tensor::empty(),
            sc: Tensor::empty(),
            pr: Tensor::empty(),
            dyb: Tensor::empty(),
            dp: Tensor::empty(),
            gq: Tensor::empty(),
            gk: Tensor::empty(),
            gv: Tensor::empty(),
            yb: Tensor::empty(),
        })
    }

    fn check_input(&self, x: &Tensor, what: &str) -> Result<usize> {
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.in_dim(),
            "attention {what}: expected [batch, {}], got {:?}",
            self.in_dim(),
            x.shape()
        );
        Ok(x.shape()[0])
    }

    fn check_params(&self, w: &Tensor, what: &str) -> Result<()> {
        ensure!(
            w.shape() == [self.d_model, 3 * self.d_model],
            "attention {what}: weight shape {:?} vs expected [{}, {}]",
            w.shape(),
            self.d_model,
            3 * self.d_model
        );
        Ok(())
    }

    /// Copy `x: [batch, seq·d]` into the persistent `[batch·seq, d]`
    /// row view (same element order; the copy exists so the matmul
    /// family sees a plain 2-D operand).
    fn load_rows(&mut self, x: &Tensor, bsz: usize) {
        self.xr.resize(&[bsz * self.seq, self.d_model]);
        self.xr.data_mut().copy_from_slice(x.data());
    }

    /// Recompute the fused projection `qkv = xr · w` (bias-free).
    fn project(&mut self, w: &Tensor) {
        tensor::matmul_into(&self.xr, w, &mut self.qkv);
    }

    /// Slice sample `bi`'s `q/k/v` `[seq, d_model]` blocks out of the
    /// fused `[batch·seq, 3·d_model]` projection.
    fn split_sample(&mut self, bi: usize) {
        let (seq, dm) = (self.seq, self.d_model);
        self.q.resize(&[seq, dm]);
        self.k.resize(&[seq, dm]);
        self.v.resize(&[seq, dm]);
        let stride = 3 * dm;
        let base = bi * seq * stride;
        let src = self.qkv.data();
        let qd = self.q.data_mut();
        let kd = self.k.data_mut();
        let vd = self.v.data_mut();
        for r in 0..seq {
            let row = &src[base + r * stride..base + (r + 1) * stride];
            qd[r * dm..(r + 1) * dm].copy_from_slice(&row[..dm]);
            kd[r * dm..(r + 1) * dm].copy_from_slice(&row[dm..2 * dm]);
            vd[r * dm..(r + 1) * dm].copy_from_slice(&row[2 * dm..]);
        }
    }

    /// Sample `bi`'s masked softmax probabilities into `self.pr`
    /// (recomputed from `self.q`/`self.k`; shared by both passes so
    /// forward and backward can never disagree on the scores).
    fn probs_sample(&mut self) {
        tensor::matmul_nt_into(&self.q, &self.k, &mut self.sc);
        self.sc.scale(self.scale);
        tensor::masked_softmax_rows_into(&self.sc, self.mask.as_ref(), &mut self.pr);
    }
}

impl Layer for SelfAttention {
    fn name(&self) -> String {
        format!(
            "self_attn[{}x{}{}]",
            self.seq,
            self.d_model,
            if self.causal { ",causal" } else { "" }
        )
    }

    fn in_dim(&self) -> usize {
        self.seq * self.d_model
    }

    fn out_dim(&self) -> usize {
        self.seq * self.d_model
    }

    fn checkpoint_tag(&self) -> u32 {
        7
    }

    fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![self.d_model, 3 * self.d_model], vec![0])
    }

    fn init_params(&self, init_scale: f32, rng: &mut Rng) -> (Tensor, Tensor) {
        // Xavier-style on the d_model fan-in: the projection feeds a
        // softmax, not a ReLU, so no He factor of 2.
        let std = init_scale * (1.0 / self.d_model as f32).sqrt();
        (Tensor::randn(&[self.d_model, 3 * self.d_model], std, rng), Tensor::zeros(&[0]))
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let (b, s, d) = (batch as u64, self.seq as u64, self.d_model as u64);
        // m1: fused-projection madds; m2: one score-shaped matmul's
        // madds (scores and the weighted aggregation are both m2);
        // e: softmax surface elements (~5 ops each: mask add, max, sub,
        // exp≈1, div).
        let m1 = b * s * d * 3 * d;
        let m2 = b * s * s * d;
        let e = b * s * s;
        LayerCost {
            fwd_flops: 2 * m1 + 4 * m2 + 5 * e,
            // Recompute (projection + scores + softmax) + the four
            // score-shaped gradient matmuls (dV/dP/dQ/dK) + the softmax
            // backward (~4 ops/elem) + projection backward (dw, dx).
            bwd_flops: 6 * m1 + 10 * m2 + 9 * e,
            act_bytes: b * s * d * 4,
            param_bytes: d * 3 * d * 4,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = exec; // host kernels; PJRT attention artifacts are an open item
        let bsz = self.check_input(x, "forward")?;
        self.check_params(w, "forward")?;
        ensure!(
            b.shape() == [0],
            "attention forward: projection is bias-free, expected [0], got {:?}",
            b.shape()
        );
        let (seq, dm) = (self.seq, self.d_model);
        self.load_rows(x, bsz);
        self.project(w);
        out.resize(&[bsz * seq, dm]);
        for bi in 0..bsz {
            self.split_sample(bi);
            self.probs_sample();
            tensor::matmul_into(&self.pr, &self.v, &mut self.yb);
            out.data_mut()[bi * seq * dm..(bi + 1) * seq * dm].copy_from_slice(self.yb.data());
        }
        out.resize(&[bsz, seq * dm]); // same storage, wire shape
        Ok(())
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = exec;
        let bsz = self.check_input(x, "backward")?;
        self.check_params(w, "backward")?;
        ensure!(
            y.shape() == [bsz, self.out_dim()] && dy.shape() == y.shape(),
            "attention backward: y {:?} / dy {:?} vs expected [{bsz}, {}]",
            y.shape(),
            dy.shape(),
            self.out_dim()
        );
        let (seq, dm) = (self.seq, self.d_model);
        let rows = bsz * seq;

        // Recompute the fused projection from the stashed input and the
        // (possibly strategy-substituted) weights — see module docs.
        self.load_rows(x, bsz);
        self.project(w);

        // dqkv assembles per sample into the shared scratch.
        scratch.resize(&[rows, 3 * dm]);
        for bi in 0..bsz {
            self.split_sample(bi);
            self.probs_sample();
            self.dyb.resize(&[seq, dm]);
            self.dyb.data_mut().copy_from_slice(&dy.data()[bi * seq * dm..(bi + 1) * seq * dm]);
            // dV = pᵀ·dy_b, dP = dy_b·vᵀ.
            tensor::matmul_tn_into(&self.pr, &self.dyb, &mut self.gv);
            tensor::matmul_nt_into(&self.dyb, &self.v, &mut self.dp);
            // Softmax backward in place: dS = p ⊙ (dP − Σⱼ dPⱼpⱼ), then
            // the score scale. Fully-masked rows have p ≡ 0 ⇒ dS ≡ 0,
            // finite by the masked-softmax contract.
            {
                let pd = self.pr.data();
                let dpd = self.dp.data_mut();
                for i in 0..seq {
                    let prow = &pd[i * seq..(i + 1) * seq];
                    let drow = &mut dpd[i * seq..(i + 1) * seq];
                    let mut dot = 0.0f32;
                    for (dv, pv) in drow.iter().zip(prow) {
                        dot += dv * pv;
                    }
                    for (dv, pv) in drow.iter_mut().zip(prow) {
                        *dv = pv * (*dv - dot) * self.scale;
                    }
                }
            }
            // dQ = dS·k, dK = dSᵀ·q.
            tensor::matmul_into(&self.dp, &self.k, &mut self.gq);
            tensor::matmul_tn_into(&self.dp, &self.q, &mut self.gk);
            // Interleave back into the fused dqkv rows.
            let stride = 3 * dm;
            let base = bi * seq * stride;
            let sd = scratch.data_mut();
            for r in 0..seq {
                let row = &mut sd[base + r * stride..base + (r + 1) * stride];
                row[..dm].copy_from_slice(&self.gq.data()[r * dm..(r + 1) * dm]);
                row[dm..2 * dm].copy_from_slice(&self.gk.data()[r * dm..(r + 1) * dm]);
                row[2 * dm..].copy_from_slice(&self.gv.data()[r * dm..(r + 1) * dm]);
            }
        }

        // Projection backward: dw = xrᵀ·dqkv (deterministic tn tree),
        // dx = dqkv·wᵀ, bias-free ⇒ db stays the [0] placeholder.
        tensor::matmul_tn_into(&self.xr, scratch, dw);
        tensor::matmul_nt_into(scratch, w, dx);
        dx.resize(&[bsz, seq * dm]);
        db.resize(&[0]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;
    use crate::tensor::ops::{matmul_into_with_threads, matmul_nt_into_with_threads};

    /// Plain-loop attention reference (no kernels, no masking tricks).
    fn naive_attn(op: &SelfAttention, x: &Tensor, w: &Tensor) -> Tensor {
        let bsz = x.shape()[0];
        let (seq, dm) = (op.seq, op.d_model);
        let mut out = Tensor::zeros(&[bsz, seq * dm]);
        for bi in 0..bsz {
            // qkv rows for this sample.
            let mut qkv = vec![0.0f32; seq * 3 * dm];
            for t in 0..seq {
                for o in 0..3 * dm {
                    let mut s = 0.0;
                    for i in 0..dm {
                        s += x.data()[bi * seq * dm + t * dm + i] * w.data()[i * 3 * dm + o];
                    }
                    qkv[t * 3 * dm + o] = s;
                }
            }
            for t in 0..seq {
                // Scores against every (visible) position.
                let mut sc = vec![f32::NEG_INFINITY; seq];
                let lim = if op.causal { t + 1 } else { seq };
                for u in 0..lim {
                    let mut s = 0.0;
                    for i in 0..dm {
                        s += qkv[t * 3 * dm + i] * qkv[u * 3 * dm + dm + i];
                    }
                    sc[u] = s * op.scale;
                }
                let mx = sc.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut p: Vec<f32> = sc.iter().map(|&s| (s - mx).exp()).collect();
                let sum: f32 = p.iter().sum();
                for v in p.iter_mut() {
                    *v /= sum;
                }
                for i in 0..dm {
                    let mut s = 0.0;
                    for u in 0..seq {
                        s += p[u] * qkv[u * 3 * dm + 2 * dm + i];
                    }
                    out.data_mut()[bi * seq * dm + t * dm + i] = s;
                }
            }
        }
        out
    }

    fn mk(causal: bool, seq: usize, dm: usize) -> (SelfAttention, Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(17);
        let op = SelfAttention::new(seq, dm, causal).unwrap();
        let (w, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[2, op.in_dim()], 1.0, &mut rng);
        (op, x, w, b)
    }

    #[test]
    fn forward_matches_naive_attention() {
        for causal in [false, true] {
            let (mut op, x, w, b) = mk(causal, 5, 4);
            let be = HostBackend::new();
            let mut y = Tensor::empty();
            op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
            assert_eq!(y.shape(), &[2, op.out_dim()]);
            let want = naive_attn(&op, &x, &w);
            assert!(y.max_abs_diff(&want) < 1e-4, "causal={causal}");
        }
    }

    #[test]
    fn causal_mask_blocks_future_positions() {
        // Perturbing token t may only change outputs at positions ≥ t.
        let (mut op, x, w, b) = mk(true, 6, 4);
        let be = HostBackend::new();
        let mut y0 = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y0).unwrap();
        let t = 4usize;
        let mut x2 = x.clone();
        for i in 0..op.d_model {
            let v = x2.at2(0, t * op.d_model + i) + 3.0;
            x2.set2(0, t * op.d_model + i, v);
        }
        let mut y1 = Tensor::empty();
        op.forward_into(&be, &x2, &w, &b, &mut y1).unwrap();
        for u in 0..t {
            for i in 0..op.d_model {
                let (a, c) = (y0.at2(0, u * op.d_model + i), y1.at2(0, u * op.d_model + i));
                assert_eq!(a.to_bits(), c.to_bits(), "position {u} saw the future token {t}");
            }
        }
        // …and the perturbed position itself must actually change.
        let mut moved = false;
        for i in 0..op.d_model {
            moved |= y0.at2(0, t * op.d_model + i) != y1.at2(0, t * op.d_model + i);
        }
        assert!(moved, "perturbation had no effect at its own position");
    }

    #[test]
    fn backward_matches_finite_differences() {
        for causal in [false, true] {
            let mut rng = Rng::new(23);
            let mut op = SelfAttention::new(4, 3, causal).unwrap();
            let (w, b) = op.init_params(1.0, &mut rng);
            let x = Tensor::randn(&[2, op.in_dim()], 0.8, &mut rng);
            let proj = Tensor::randn(&[2, op.out_dim()], 1.0, &mut rng);
            let be = HostBackend::new();
            let mut fwd = |op: &mut SelfAttention, x: &Tensor, w: &Tensor| -> f32 {
                let mut y = Tensor::empty();
                op.forward_into(&be, x, w, &b, &mut y).unwrap();
                y.data().iter().zip(proj.data()).map(|(a, p)| a * p).sum()
            };
            let mut y = Tensor::empty();
            op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
            let (mut scr, mut dx, mut dw, mut db) =
                (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
            op.backward_into(&be, &x, &y, &w, &proj, &mut scr, &mut dx, &mut dw, &mut db)
                .unwrap();
            assert_eq!(db.shape(), &[0], "bias-free projection");
            let eps = 1e-2;
            for (which, grad, target) in [("w", &dw, &w), ("x", &dx, &x)] {
                for idx in 0..target.len() {
                    let (mut tp, mut tm) = (target.clone(), target.clone());
                    tp.data_mut()[idx] += eps;
                    tm.data_mut()[idx] -= eps;
                    let (fp, fm) = match which {
                        "w" => (fwd(&mut op, &x, &tp), fwd(&mut op, &x, &tm)),
                        _ => (fwd(&mut op, &tp, &w), fwd(&mut op, &tm, &w)),
                    };
                    let fd = (fp - fm) / (2.0 * eps);
                    assert!(
                        (fd - grad.data()[idx]).abs() < 3e-2,
                        "causal={causal} {which}[{idx}]: fd {fd} vs analytic {}",
                        grad.data()[idx]
                    );
                }
            }
        }
    }

    #[test]
    fn forward_equals_kernel_composition_bitwise_across_thread_counts() {
        // Shapes past PAR_MIN_MADDS so the fused projection engages the
        // worker pool; the op must equal an explicit kernel composition
        // bit for bit at EVERY thread count 1..=8 (the kernel family's
        // worker-count invariance lifted to the layer — this is the
        // layer zoo's bit-determinism sweep, same shape as conv's
        // col2im sweep).
        let mut rng = Rng::new(31);
        let (bsz, seq, dm) = (4usize, 32usize, 48usize);
        let mut op = SelfAttention::new(seq, dm, true).unwrap();
        let (w, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[bsz, seq * dm], 1.0, &mut rng);
        assert!(bsz * seq * dm * 3 * dm > 1 << 20, "projection must cross the pool threshold");
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();

        let mut xr = Tensor::zeros(&[bsz * seq, dm]);
        xr.data_mut().copy_from_slice(x.data());
        for threads in 1..=8 {
            let mut qkv = Tensor::empty();
            matmul_into_with_threads(&xr, &w, &mut qkv, threads);
            let mut want = Tensor::zeros(&[bsz, seq * dm]);
            let (mut q, mut k, mut v) =
                (Tensor::zeros(&[seq, dm]), Tensor::zeros(&[seq, dm]), Tensor::zeros(&[seq, dm]));
            for bi in 0..bsz {
                for r in 0..seq {
                    let row = &qkv.data()[(bi * seq + r) * 3 * dm..(bi * seq + r + 1) * 3 * dm];
                    q.data_mut()[r * dm..(r + 1) * dm].copy_from_slice(&row[..dm]);
                    k.data_mut()[r * dm..(r + 1) * dm].copy_from_slice(&row[dm..2 * dm]);
                    v.data_mut()[r * dm..(r + 1) * dm].copy_from_slice(&row[2 * dm..]);
                }
                let mut sc = Tensor::empty();
                matmul_nt_into_with_threads(&q, &k, &mut sc, threads);
                sc.scale(op.scale);
                let mut pr = Tensor::empty();
                tensor::masked_softmax_rows_into(&sc, op.mask.as_ref(), &mut pr);
                let mut yb = Tensor::empty();
                matmul_into_with_threads(&pr, &v, &mut yb, threads);
                want.data_mut()[bi * seq * dm..(bi + 1) * seq * dm].copy_from_slice(yb.data());
            }
            for (i, (g, e)) in y.data().iter().zip(want.data()).enumerate() {
                assert_eq!(g.to_bits(), e.to_bits(), "forward drift at elem {i}, threads={threads}");
            }
        }
    }

    #[test]
    fn backward_projection_grads_equal_kernel_composition_across_thread_counts() {
        // The backward's pool-parallel kernels are the projection pair
        // `dw = xrᵀ·dqkv` / `dx = dqkv·wᵀ` (everything between them is a
        // serial per-sample loop). After `backward_into`, `scratch`
        // holds the assembled dqkv — recompute both products with
        // explicit thread counts 1..=8 and demand bit-equality with
        // what the layer produced.
        let mut rng = Rng::new(41);
        let (bsz, seq, dm) = (4usize, 32usize, 48usize);
        let mut op = SelfAttention::new(seq, dm, true).unwrap();
        let (w, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[bsz, seq * dm], 1.0, &mut rng);
        let dy = Tensor::randn(&[bsz, seq * dm], 1.0, &mut rng);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        let mut xr = Tensor::zeros(&[bsz * seq, dm]);
        xr.data_mut().copy_from_slice(x.data());
        for threads in 1..=8 {
            let mut dw_ref = Tensor::empty();
            crate::tensor::ops::matmul_tn_into_with_threads(&xr, &scr, &mut dw_ref, threads);
            for (i, (g, e)) in dw.data().iter().zip(dw_ref.data()).enumerate() {
                assert_eq!(g.to_bits(), e.to_bits(), "dw drift at elem {i}, threads={threads}");
            }
            let mut dx_ref = Tensor::empty();
            matmul_nt_into_with_threads(&scr, &w, &mut dx_ref, threads);
            for (i, (g, e)) in dx.data().iter().zip(dx_ref.data()).enumerate() {
                assert_eq!(g.to_bits(), e.to_bits(), "dx drift at elem {i}, threads={threads}");
            }
        }
    }

    #[test]
    fn repeat_calls_are_bitwise_deterministic_and_workspaces_persist() {
        let (mut op, x, w, b) = mk(true, 6, 5);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let cap0 = op.qkv.len();
        assert!(cap0 > 0, "projection workspace materialized");
        let y0 = y.clone();
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y0, &w, &y0, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        let (dx0, dw0) = (dx.clone(), dw.clone());
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y, y0, "repeat forward drifted");
        op.backward_into(&be, &x, &y0, &w, &y0, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        assert_eq!(dx, dx0, "repeat backward drifted (dx)");
        assert_eq!(dw, dw0, "repeat backward drifted (dw)");
        assert_eq!(op.qkv.len(), cap0, "workspace reused, not regrown");
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(SelfAttention::new(0, 4, false).is_err());
        assert!(SelfAttention::new(4, 0, false).is_err());
        let (mut op, _, w, b) = mk(false, 5, 4);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        let bad = Tensor::zeros(&[2, 7]);
        assert!(op.forward_into(&be, &bad, &w, &b, &mut y).is_err());
        let badw = Tensor::zeros(&[4, 8]);
        let goodx = Tensor::zeros(&[2, op.in_dim()]);
        assert!(op.forward_into(&be, &goodx, &badw, &b, &mut y).is_err());
        let badb = Tensor::zeros(&[3]);
        assert!(op.forward_into(&be, &goodx, &w, &badb, &mut y).is_err());
    }

    #[test]
    fn cost_counts_projection_scores_and_softmax() {
        let op = SelfAttention::new(8, 6, true).unwrap();
        let c = op.cost(2);
        let (m1, m2, e) = (2u64 * 8 * 6 * 18, 2u64 * 8 * 8 * 6, 2u64 * 8 * 8);
        assert_eq!(c.fwd_flops, 2 * m1 + 4 * m2 + 5 * e);
        assert_eq!(c.bwd_flops, 6 * m1 + 10 * m2 + 9 * e);
        assert_eq!(c.act_bytes, 2 * 8 * 6 * 4);
        assert_eq!(c.param_bytes, 6 * 18 * 4);
    }
}
