//! Fully connected layer — the port of the seed's `LayerRole` path.
//!
//! Compute still dispatches through [`Exec`]'s dense methods, so the
//! PJRT backend keeps serving dense layers from its lowered artifacts
//! while conv/pool/LIF run on host kernels (PJRT artifacts for those are
//! a ROADMAP open item).

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::model::LayerRole;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::Result;

/// `y = act(x @ w + b)` with `w: [din, dout]`, optional fused ReLU.
pub struct Dense {
    din: usize,
    dout: usize,
    role: LayerRole,
}

impl Dense {
    /// `index` is the layer's position in the stack; the role (and thus
    /// the artifact name + ReLU) follows [`super::dense_role`].
    pub fn new(din: usize, dout: usize, relu: bool, index: usize) -> Dense {
        Dense { din, dout, role: super::dense_role(index, relu) }
    }

    pub fn role(&self) -> LayerRole {
        self.role
    }
}

impl Layer for Dense {
    fn name(&self) -> String {
        format!(
            "dense[{}x{}{}]",
            self.din,
            self.dout,
            if self.role.has_relu() { ",relu" } else { "" }
        )
    }

    fn in_dim(&self) -> usize {
        self.din
    }

    fn out_dim(&self) -> usize {
        self.dout
    }

    fn checkpoint_tag(&self) -> u32 {
        // Mirrors the v1 checkpoint role tags (Input/Hidden/Output).
        match self.role {
            LayerRole::Input => 0,
            LayerRole::Hidden => 1,
            LayerRole::Output => 2,
        }
    }

    fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![self.din, self.dout], vec![self.dout])
    }

    fn supports_dtype(&self, _dtype: crate::tensor::Dtype) -> bool {
        // The dense kernel family widens bf16 operands during packing
        // (DESIGN.md §11), so every storage dtype is servable.
        true
    }

    fn init_params(&self, init_scale: f32, rng: &mut Rng) -> (Tensor, Tensor) {
        // He init (ReLU nets), zero biases — identical to `Mlp::init`.
        let std = init_scale * (2.0 / self.din as f32).sqrt();
        (Tensor::randn(&[self.din, self.dout], std, rng), Tensor::zeros(&[self.dout]))
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let madds = (batch * self.din * self.dout) as u64;
        LayerCost {
            fwd_flops: 2 * madds,
            // Backward runs two matmuls (dx, dw) of the forward's size.
            bwd_flops: 4 * madds,
            act_bytes: (batch * self.dout * 4) as u64,
            param_bytes: ((self.din * self.dout + self.dout) * 4) as u64,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        exec.forward_into(self.role, x, w, b, out)
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        exec.backward_into(self.role, x, y, w, dy, scratch, dx, dw, db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    #[test]
    fn dense_matches_exec_role_dispatch() {
        let mut rng = Rng::new(4);
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let be = HostBackend::new();
        let mut op = Dense::new(5, 4, true, 1);
        assert_eq!(op.role(), LayerRole::Hidden);
        let (w, b) = op.init_params(1.0, &mut rng);
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y, be.forward(LayerRole::Hidden, &x, &w, &b).unwrap());

        let dy = Tensor::randn(&[3, 4], 1.0, &mut rng);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        let (dx2, dw2, db2) = be.backward(LayerRole::Hidden, &x, &y, &w, &dy).unwrap();
        assert_eq!((dx, dw, db), (dx2, dw2, db2));
    }

    #[test]
    fn dense_alone_serves_bf16() {
        use crate::layers::{LayerSpec, Network, NetworkSpec, Feature};
        use crate::tensor::Dtype;
        let spec = NetworkSpec {
            input: Feature::Flat(4),
            layers: vec![
                LayerSpec::Dense { units: 4, relu: true },
                LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            ],
            init_scale: 1.0,
        };
        let net = Network::build(&spec, &mut Rng::new(1)).unwrap();
        assert!(net.layers[0].op.supports_dtype(Dtype::Bf16));
        assert!(net.layers[0].op.supports_dtype(Dtype::F32));
        assert!(!net.layers[1].op.supports_dtype(Dtype::Bf16), "LIF is f32-only");
        assert!(net.layers[1].op.supports_dtype(Dtype::F32));
    }

    #[test]
    fn role_assignment_matches_seed_table() {
        assert_eq!(Dense::new(4, 4, true, 0).role(), LayerRole::Input);
        assert_eq!(Dense::new(4, 4, true, 2).role(), LayerRole::Hidden);
        assert_eq!(Dense::new(4, 4, false, 2).role(), LayerRole::Output);
        assert_eq!(Dense::new(4, 4, false, 0).role(), LayerRole::Output);
    }

    #[test]
    fn cost_scales_with_batch() {
        let op = Dense::new(8, 16, true, 1);
        let c1 = op.cost(1);
        let c4 = op.cost(4);
        assert_eq!(c4.fwd_flops, 4 * c1.fwd_flops);
        assert_eq!(c4.param_bytes, c1.param_bytes);
        assert_eq!(c1.fwd_flops, 2 * 8 * 16);
    }
}
