//! Token embedding: gather forward, fixed-order scatter-add backward.
//!
//! Input is `[batch, seq]` of f32-encoded token ids (the pipeline's
//! activation wire is f32 end to end; ids must be exact non-negative
//! integers below `vocab` — enforced, not truncated). Output is the
//! flat `[batch, seq·dim]` activation every downstream layer speaks.
//!
//! Determinism: the backward scatter-add walks flat positions in
//! strictly ascending order (sample-major, then sequence position) on a
//! single thread, so duplicate token ids accumulate their gradient
//! contributions in one fixed order regardless of
//! `LAYERPIPE2_WORKERS` — bit-identical by construction, no atomics or
//! per-worker partials to reduce. The table is `vocab·dim` reads of
//! pure gather in forward; neither pass is matmul-shaped, so nothing
//! here touches the worker pool.
//!
//! Token ids are not differentiable, so `dx` is a correctly-shaped
//! all-zero tensor: upstream of an `Embedding` there is nothing to
//! train, but the executor still threads a `dx` buffer through every
//! stage boundary uniformly.

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{ensure, Result};

/// `y[b, t] = table[x[b, t]]` with table `[vocab, dim]`.
pub struct Embedding {
    seq: usize,
    vocab: usize,
    dim: usize,
}

impl Embedding {
    pub fn new(seq: usize, vocab: usize, dim: usize) -> Result<Embedding> {
        ensure!(seq > 0 && vocab > 0 && dim > 0, "embedding seq/vocab/dim must be positive");
        Ok(Embedding { seq, vocab, dim })
    }

    fn check_input(&self, x: &Tensor, what: &str) -> Result<usize> {
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.seq,
            "embedding {what}: expected [batch, {}], got {:?}",
            self.seq,
            x.shape()
        );
        Ok(x.shape()[0])
    }

    fn check_params(&self, w: &Tensor, what: &str) -> Result<()> {
        ensure!(
            w.shape() == [self.vocab, self.dim],
            "embedding {what}: table shape {:?} vs expected [{}, {}]",
            w.shape(),
            self.vocab,
            self.dim
        );
        Ok(())
    }

    /// Validate and decode one f32-encoded token id.
    fn token_id(&self, raw: f32, flat: usize) -> Result<usize> {
        ensure!(
            raw >= 0.0 && raw.fract() == 0.0 && (raw as usize) < self.vocab,
            "embedding: input[{flat}] = {raw} is not an integer token id in [0, {})",
            self.vocab
        );
        Ok(raw as usize)
    }
}

impl Layer for Embedding {
    fn name(&self) -> String {
        format!("embed[{}->{}x{}]", self.vocab, self.seq, self.dim)
    }

    fn in_dim(&self) -> usize {
        self.seq
    }

    fn out_dim(&self) -> usize {
        self.seq * self.dim
    }

    fn checkpoint_tag(&self) -> u32 {
        8
    }

    fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![self.vocab, self.dim], vec![0])
    }

    fn init_params(&self, init_scale: f32, rng: &mut Rng) -> (Tensor, Tensor) {
        let std = init_scale * (1.0 / self.dim as f32).sqrt();
        (Tensor::randn(&[self.vocab, self.dim], std, rng), Tensor::zeros(&[0]))
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let moved = (batch * self.seq * self.dim) as u64;
        LayerCost {
            // Gather/scatter are bandwidth, not FLOPs; count one unit
            // per moved element so the partitioner still sees the work.
            fwd_flops: moved,
            bwd_flops: moved,
            act_bytes: moved * 4,
            param_bytes: (self.vocab * self.dim * 4) as u64,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = exec;
        let bsz = self.check_input(x, "forward")?;
        self.check_params(w, "forward")?;
        ensure!(
            b.shape() == [0],
            "embedding forward: no bias, expected [0], got {:?}",
            b.shape()
        );
        out.resize(&[bsz, self.seq * self.dim]);
        let dim = self.dim;
        for flat in 0..bsz * self.seq {
            let id = self.token_id(x.data()[flat], flat)?;
            out.data_mut()[flat * dim..(flat + 1) * dim]
                .copy_from_slice(&w.data()[id * dim..(id + 1) * dim]);
        }
        Ok(())
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, scratch);
        let bsz = self.check_input(x, "backward")?;
        self.check_params(w, "backward")?;
        ensure!(
            y.shape() == [bsz, self.out_dim()] && dy.shape() == y.shape(),
            "embedding backward: y {:?} / dy {:?} vs expected [{bsz}, {}]",
            y.shape(),
            dy.shape(),
            self.out_dim()
        );
        // Token ids carry no gradient.
        dx.resize(&[bsz, self.seq]);
        dx.fill(0.0);
        dw.resize(&[self.vocab, self.dim]);
        dw.fill(0.0);
        db.resize(&[0]);
        let dim = self.dim;
        // Flat-position-ascending scatter-add: one fixed accumulation
        // order for duplicate ids, independent of worker count.
        for flat in 0..bsz * self.seq {
            let id = self.token_id(x.data()[flat], flat)?;
            let src = &dy.data()[flat * dim..(flat + 1) * dim];
            let dst = &mut dw.data_mut()[id * dim..(id + 1) * dim];
            for (d, s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    fn mk() -> (Embedding, Tensor, Tensor, Tensor) {
        let mut rng = Rng::new(29);
        let op = Embedding::new(3, 5, 4).unwrap();
        let (w, b) = op.init_params(1.0, &mut rng);
        // Deliberate duplicate token (id 2 twice in sample 0).
        let x = Tensor::from_vec(&[2, 3], vec![2.0, 0.0, 2.0, 4.0, 1.0, 3.0]);
        (op, x, w, b)
    }

    #[test]
    fn forward_gathers_table_rows() {
        let (mut op, x, w, b) = mk();
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y.shape(), &[2, 12]);
        for (flat, &idf) in x.data().iter().enumerate() {
            let id = idf as usize;
            for j in 0..4 {
                assert_eq!(
                    y.data()[flat * 4 + j].to_bits(),
                    w.at2(id, j).to_bits(),
                    "gather mismatch at flat {flat} col {j}"
                );
            }
        }
    }

    #[test]
    fn rejects_invalid_token_ids() {
        let (mut op, _, w, b) = mk();
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        for bad in [
            Tensor::from_vec(&[2, 3], vec![0.0, 1.0, 5.0, 0.0, 0.0, 0.0]), // out of range
            Tensor::from_vec(&[2, 3], vec![0.0, 1.5, 2.0, 0.0, 0.0, 0.0]), // fractional
            Tensor::from_vec(&[2, 3], vec![0.0, -1.0, 2.0, 0.0, 0.0, 0.0]), // negative
        ] {
            assert!(op.forward_into(&be, &bad, &w, &b, &mut y).is_err());
        }
        let badshape = Tensor::zeros(&[2, 4]);
        assert!(op.forward_into(&be, &badshape, &w, &b, &mut y).is_err());
    }

    #[test]
    fn backward_scatter_matches_finite_difference_with_duplicates() {
        let (mut op, x, w, b) = mk();
        let be = HostBackend::new();
        let mut rng = Rng::new(37);
        let proj = Tensor::randn(&[2, op.out_dim()], 1.0, &mut rng);
        let mut fwd = |op: &mut Embedding, w: &Tensor| -> f32 {
            let mut y = Tensor::empty();
            op.forward_into(&be, &x, w, &b, &mut y).unwrap();
            y.data().iter().zip(proj.data()).map(|(a, p)| a * p).sum()
        };
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &proj, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        assert_eq!(dx.shape(), &[2, 3]);
        assert!(dx.data().iter().all(|&v| v == 0.0), "token ids are not differentiable");
        assert_eq!(db.shape(), &[0]);
        let eps = 1e-2;
        for idx in 0..w.len() {
            let (mut wp, mut wm) = (w.clone(), w.clone());
            wp.data_mut()[idx] += eps;
            wm.data_mut()[idx] -= eps;
            let fd = (fwd(&mut op, &wp) - fwd(&mut op, &wm)) / (2.0 * eps);
            assert!(
                (fd - dw.data()[idx]).abs() < 3e-2,
                "dw[{idx}]: fd {fd} vs analytic {}",
                dw.data()[idx]
            );
        }
        // Row for the duplicated token accumulated both positions.
        for j in 0..4 {
            let want = proj.data()[j] + proj.data()[8 + j];
            assert!((dw.at2(2, j) - want).abs() < 1e-6);
        }
    }

    #[test]
    fn cost_counts_moved_elements() {
        let op = Embedding::new(3, 5, 4).unwrap();
        let c = op.cost(2);
        assert_eq!(c.fwd_flops, 24);
        assert_eq!(c.bwd_flops, 24);
        assert_eq!(c.act_bytes, 96);
        assert_eq!(c.param_bytes, 80);
    }
}
