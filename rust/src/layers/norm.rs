//! Layer normalization over the trailing feature axis.
//!
//! The wire activation `[batch, t·d]` reinterprets as `batch·t` rows of
//! `d` features; each row is normalized to zero mean / unit variance
//! (f32 accumulation, biased variance) and affinely mapped by the
//! learned per-feature `gamma` (stored in the `w` slot, shape `[d]`)
//! and `beta` (the `b` slot, `[d]`).
//!
//! Backward is the standard three-term formula. With
//! `x̂ = (x − μ)·inv`, `inv = 1/√(σ² + ε)` and `dx̂ = dy ⊙ γ`:
//!
//! `dx = inv · (dx̂ − mean(dx̂) − x̂ ⊙ mean(dx̂ ⊙ x̂))`
//!
//! `dγ[j] = Σ_rows dy·x̂`, `dβ[j] = Σ_rows dy`, accumulated in
//! row-ascending order. Everything is serial per row — the per-row
//! reductions are tiny next to the matmuls on either side, and serial
//! loops are bit-identical across `LAYERPIPE2_WORKERS` for free. μ/inv
//! and x̂ are recomputed from the stashed input in backward (no stash
//! beyond the executor's usual x), matching the recompute-over-stash
//! discipline of conv and attention.

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{ensure, Result};

/// Per-row normalization: `y = γ ⊙ (x − μ)/√(σ² + ε) + β`.
pub struct LayerNorm {
    t: usize,
    d: usize,
    eps: f32,
}

impl LayerNorm {
    pub fn new(t: usize, d: usize, eps: f32) -> Result<LayerNorm> {
        ensure!(t > 0 && d > 0, "layernorm t/d must be positive");
        ensure!(eps > 0.0 && eps.is_finite(), "layernorm eps must be a positive finite value");
        Ok(LayerNorm { t, d, eps })
    }

    fn check_input(&self, x: &Tensor, what: &str) -> Result<usize> {
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.in_dim(),
            "layernorm {what}: expected [batch, {}], got {:?}",
            self.in_dim(),
            x.shape()
        );
        Ok(x.shape()[0])
    }

    fn check_params(&self, w: &Tensor, b: &Tensor, what: &str) -> Result<()> {
        ensure!(
            w.shape() == [self.d] && b.shape() == [self.d],
            "layernorm {what}: gamma {:?} / beta {:?} vs expected [{}]",
            w.shape(),
            b.shape(),
            self.d
        );
        Ok(())
    }

    /// Row mean and `1/√(σ²+ε)` with f32 accumulation (two passes —
    /// numerically safer than the single-pass E[x²]−E[x]² form).
    fn row_stats(&self, row: &[f32]) -> (f32, f32) {
        let n = self.d as f32;
        let mut mean = 0.0f32;
        for &v in row {
            mean += v;
        }
        mean /= n;
        let mut var = 0.0f32;
        for &v in row {
            let c = v - mean;
            var += c * c;
        }
        var /= n;
        (mean, 1.0 / (var + self.eps).sqrt())
    }
}

impl Layer for LayerNorm {
    fn name(&self) -> String {
        format!("layernorm[{}x{}]", self.t, self.d)
    }

    fn in_dim(&self) -> usize {
        self.t * self.d
    }

    fn out_dim(&self) -> usize {
        self.t * self.d
    }

    fn checkpoint_tag(&self) -> u32 {
        9
    }

    fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![self.d], vec![self.d])
    }

    fn init_params(&self, _init_scale: f32, _rng: &mut Rng) -> (Tensor, Tensor) {
        // Identity transform at init: γ = 1, β = 0. Draws nothing from
        // the rng so the layers after it see the same stream whether or
        // not a LayerNorm sits between them.
        let mut gamma = Tensor::zeros(&[self.d]);
        gamma.fill(1.0);
        (gamma, Tensor::zeros(&[self.d]))
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let rows = (batch * self.t) as u64;
        let d = self.d as u64;
        LayerCost {
            // ~8 ops/element forward (two stat passes + normalize +
            // affine), ~16 backward (recompute + three-term formula) —
            // documented approximations, tiny next to any matmul.
            fwd_flops: 8 * rows * d,
            bwd_flops: 16 * rows * d,
            act_bytes: rows * d * 4,
            param_bytes: 2 * d * 4,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = exec;
        let bsz = self.check_input(x, "forward")?;
        self.check_params(w, b, "forward")?;
        out.resize(&[bsz, self.in_dim()]);
        let d = self.d;
        for r in 0..bsz * self.t {
            let row = &x.data()[r * d..(r + 1) * d];
            let (mean, inv) = self.row_stats(row);
            let orow = &mut out.data_mut()[r * d..(r + 1) * d];
            for j in 0..d {
                orow[j] = w.data()[j] * (row[j] - mean) * inv + b.data()[j];
            }
        }
        Ok(())
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = exec;
        let bsz = self.check_input(x, "backward")?;
        ensure!(
            w.shape() == [self.d],
            "layernorm backward: gamma {:?} vs expected [{}]",
            w.shape(),
            self.d
        );
        ensure!(
            y.shape() == [bsz, self.out_dim()] && dy.shape() == y.shape(),
            "layernorm backward: y {:?} / dy {:?} vs expected [{bsz}, {}]",
            y.shape(),
            dy.shape(),
            self.out_dim()
        );
        let d = self.d;
        dx.resize(&[bsz, self.in_dim()]);
        dw.resize(&[d]);
        dw.fill(0.0);
        db.resize(&[d]);
        db.fill(0.0);
        // Per-row x̂ buffer lives in the shared scratch.
        scratch.resize(&[d]);
        let n = d as f32;
        for r in 0..bsz * self.t {
            let row = &x.data()[r * d..(r + 1) * d];
            let (mean, inv) = self.row_stats(row);
            let xhat = scratch.data_mut();
            for j in 0..d {
                xhat[j] = (row[j] - mean) * inv;
            }
            let dyrow = &dy.data()[r * d..(r + 1) * d];
            // Row-ascending parameter accumulation (bit-stable order).
            for j in 0..d {
                dw.data_mut()[j] += dyrow[j] * xhat[j];
                db.data_mut()[j] += dyrow[j];
            }
            // Three-term formula on dx̂ = dy ⊙ γ.
            let (mut m1, mut m2) = (0.0f32, 0.0f32);
            for j in 0..d {
                let dxh = dyrow[j] * w.data()[j];
                m1 += dxh;
                m2 += dxh * xhat[j];
            }
            m1 /= n;
            m2 /= n;
            let xhat = scratch.data();
            let dxrow = &mut dx.data_mut()[r * d..(r + 1) * d];
            for j in 0..d {
                let dxh = dyrow[j] * w.data()[j];
                dxrow[j] = inv * (dxh - m1 - xhat[j] * m2);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    #[test]
    fn identity_affine_normalizes_rows() {
        let mut rng = Rng::new(43);
        let mut op = LayerNorm::new(3, 8, 1e-5).unwrap();
        let (w, b) = op.init_params(1.0, &mut rng);
        let x = Tensor::randn(&[2, op.in_dim()], 2.5, &mut rng);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        for r in 0..6 {
            let row = &y.data()[r * 8..(r + 1) * 8];
            let mean: f32 = row.iter().sum::<f32>() / 8.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 8.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn forward_matches_naive_reference_with_random_affine() {
        let mut rng = Rng::new(47);
        let mut op = LayerNorm::new(2, 5, 1e-5).unwrap();
        let w = Tensor::randn(&[5], 1.0, &mut rng);
        let b = Tensor::randn(&[5], 1.0, &mut rng);
        let x = Tensor::randn(&[3, 10], 1.7, &mut rng);
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        for r in 0..6 {
            let row = &x.data()[r * 5..(r + 1) * 5];
            let mean: f32 = row.iter().sum::<f32>() / 5.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 5.0;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for j in 0..5 {
                let want = w.data()[j] * (row[j] - mean) * inv + b.data()[j];
                assert!((y.data()[r * 5 + j] - want).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Rng::new(53);
        let mut op = LayerNorm::new(2, 4, 1e-5).unwrap();
        let w = Tensor::randn(&[4], 0.9, &mut rng);
        let b = Tensor::randn(&[4], 0.5, &mut rng);
        let x = Tensor::randn(&[2, 8], 1.2, &mut rng);
        let proj = Tensor::randn(&[2, 8], 1.0, &mut rng);
        let be = HostBackend::new();
        let mut fwd = |op: &mut LayerNorm, x: &Tensor, w: &Tensor, b: &Tensor| -> f32 {
            let mut y = Tensor::empty();
            op.forward_into(&be, x, w, b, &mut y).unwrap();
            y.data().iter().zip(proj.data()).map(|(a, p)| a * p).sum()
        };
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &proj, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        let eps = 1e-2;
        for idx in 0..x.len() {
            let (mut xp, mut xm) = (x.clone(), x.clone());
            xp.data_mut()[idx] += eps;
            xm.data_mut()[idx] -= eps;
            let fd = (fwd(&mut op, &xp, &w, &b) - fwd(&mut op, &xm, &w, &b)) / (2.0 * eps);
            assert!((fd - dx.data()[idx]).abs() < 3e-2, "dx[{idx}]: fd {fd} vs {}", dx.data()[idx]);
        }
        for idx in 0..4 {
            let (mut wp, mut wm) = (w.clone(), w.clone());
            wp.data_mut()[idx] += eps;
            wm.data_mut()[idx] -= eps;
            let fd = (fwd(&mut op, &x, &wp, &b) - fwd(&mut op, &x, &wm, &b)) / (2.0 * eps);
            assert!((fd - dw.data()[idx]).abs() < 3e-2, "dw[{idx}]: fd {fd} vs {}", dw.data()[idx]);
            let (mut bp, mut bm) = (b.clone(), b.clone());
            bp.data_mut()[idx] += eps;
            bm.data_mut()[idx] -= eps;
            let fd = (fwd(&mut op, &x, &w, &bp) - fwd(&mut op, &x, &w, &bm)) / (2.0 * eps);
            assert!((fd - db.data()[idx]).abs() < 3e-2, "db[{idx}]: fd {fd} vs {}", db.data()[idx]);
        }
    }

    #[test]
    fn init_params_consumes_no_rng_and_is_identity() {
        let mut r1 = Rng::new(61);
        let mut r2 = Rng::new(61);
        let op = LayerNorm::new(1, 6, 1e-5).unwrap();
        let (g, beta) = op.init_params(1.0, &mut r1);
        assert!(g.data().iter().all(|&v| v == 1.0));
        assert!(beta.data().iter().all(|&v| v == 0.0));
        // Same next draw from both rngs ⇒ init consumed nothing.
        let a = Tensor::randn(&[4], 1.0, &mut r1);
        let c = Tensor::randn(&[4], 1.0, &mut r2);
        assert_eq!(a, c);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(LayerNorm::new(0, 4, 1e-5).is_err());
        assert!(LayerNorm::new(2, 0, 1e-5).is_err());
        assert!(LayerNorm::new(2, 4, 0.0).is_err());
        assert!(LayerNorm::new(2, 4, f32::NAN).is_err());
        let mut op = LayerNorm::new(2, 4, 1e-5).unwrap();
        let be = HostBackend::new();
        let mut y = Tensor::empty();
        let w = Tensor::zeros(&[4]);
        let b = Tensor::zeros(&[4]);
        assert!(op.forward_into(&be, &Tensor::zeros(&[2, 7]), &w, &b, &mut y).is_err());
        assert!(op
            .forward_into(&be, &Tensor::zeros(&[2, 8]), &Tensor::zeros(&[3]), &b, &mut y)
            .is_err());
    }

    #[test]
    fn cost_is_linear_in_rows_and_features() {
        let op = LayerNorm::new(3, 16, 1e-5).unwrap();
        let c = op.cost(2);
        assert_eq!(c.fwd_flops, 8 * 6 * 16);
        assert_eq!(c.bwd_flops, 16 * 6 * 16);
        assert_eq!(c.act_bytes, 6 * 16 * 4);
        assert_eq!(c.param_bytes, 2 * 16 * 4);
    }
}
