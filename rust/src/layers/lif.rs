//! Leaky-integrate-and-fire spiking activation with a surrogate gradient.
//!
//! The layer treats its input as the membrane potential `v` (produced by
//! the preceding linear/conv synapse layer) and emits a binary spike
//! `s = 𝟙[v ≥ v_th]`. The spike function's true derivative is zero
//! almost everywhere, so the backward substitutes the standard
//! triangular surrogate (STBP/SuperSpike family):
//!
//! ```text
//! ∂s/∂v ≈ max(0, 1 − |v − v_th| / α) / α
//! ```
//!
//! a unit-mass tent centered on the threshold whose width `α` bounds the
//! gradient support. The surrogate reads the *stashed* membrane
//! potential (the layer input, which the pipeline already retains for
//! the delayed backward), so spiking layers ride the existing
//! DLMS-style delayed-update machinery unchanged: their upstream synapse
//! weights receive gradients delayed by `d = 2·S(l)` and every
//! weight-version strategy (stash / latest / EMA recompute) applies
//! as-is.
//!
//! Single-timestep rate-free formulation: with one pipeline iteration
//! per batch there is no temporal membrane state to carry, which keeps
//! the layer stateless and the oracle/executor equivalence exact.

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Spiking activation: `s = 𝟙[v ≥ v_th]`, triangular surrogate backward.
pub struct Lif {
    dim: usize,
    v_th: f32,
    alpha: f32,
}

impl Lif {
    pub fn new(dim: usize, v_th: f32, alpha: f32) -> Result<Lif> {
        ensure!(dim > 0, "lif width must be positive");
        ensure!(alpha > 0.0, "lif surrogate width must be positive, got {alpha}");
        Ok(Lif { dim, v_th, alpha })
    }

    /// The surrogate derivative at membrane potential `v`.
    pub fn surrogate(&self, v: f32) -> f32 {
        (1.0 - (v - self.v_th).abs() / self.alpha).max(0.0) / self.alpha
    }
}

impl Layer for Lif {
    fn name(&self) -> String {
        format!("lif[{},vth={},alpha={}]", self.dim, self.v_th, self.alpha)
    }

    fn in_dim(&self) -> usize {
        self.dim
    }

    fn out_dim(&self) -> usize {
        self.dim
    }

    fn checkpoint_tag(&self) -> u32 {
        6
    }

    fn cost(&self, batch: usize) -> LayerCost {
        let n = (batch * self.dim) as u64;
        LayerCost {
            fwd_flops: n,      // one threshold compare per element
            bwd_flops: 2 * n,  // tent eval + multiply
            act_bytes: (batch * self.dim * 4) as u64,
            param_bytes: 0,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, w, b);
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.dim,
            "lif: expected [batch, {}], got {:?}",
            self.dim,
            x.shape()
        );
        out.resize(x.shape());
        let th = self.v_th;
        for (ov, xv) in out.data_mut().iter_mut().zip(x.data().iter()) {
            *ov = if *xv >= th { 1.0 } else { 0.0 };
        }
        Ok(())
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, y, w, scratch);
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.dim && dy.shape() == x.shape(),
            "lif backward: x {:?} / dy {:?} vs width {}",
            x.shape(),
            dy.shape(),
            self.dim
        );
        dx.resize(x.shape());
        // One surrogate definition: the tent the unit tests verify is
        // exactly the gradient the backward applies.
        let (dxd, xd, dyd) = (dx.data_mut(), x.data(), dy.data());
        for ((gv, &xv), dv) in dxd.iter_mut().zip(xd.iter()).zip(dyd.iter()) {
            *gv = dv * self.surrogate(xv);
        }
        dw.resize(&[0]);
        db.resize(&[0]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;

    #[test]
    fn spikes_are_binary_thresholded() {
        let mut op = Lif::new(4, 0.5, 1.0).unwrap();
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 0.5, 0.49, 2.0]);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y.data(), &[0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn surrogate_is_a_unit_tent_at_threshold() {
        let op = Lif::new(1, 1.0, 0.5).unwrap();
        assert_eq!(op.surrogate(1.0), 2.0); // peak 1/α
        assert_eq!(op.surrogate(1.5), 0.0); // support edge
        assert_eq!(op.surrogate(0.4), 0.0); // outside support
        let mid = op.surrogate(1.25);
        assert!((mid - 1.0).abs() < 1e-6, "half-way down the tent: {mid}");
        // Unit mass: ∫ tent = α·(1/α) = 1 — spot-check by symmetry.
        assert_eq!(op.surrogate(0.75), op.surrogate(1.25));
    }

    #[test]
    fn backward_masks_gradient_by_membrane_distance() {
        let mut op = Lif::new(3, 0.0, 1.0).unwrap();
        let x = Tensor::from_vec(&[1, 3], vec![0.0, 0.5, 5.0]);
        let dy = Tensor::from_vec(&[1, 3], vec![1.0, 1.0, 1.0]);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        assert_eq!(dx.data(), &[1.0, 0.5, 0.0]);
        assert_eq!(dw.shape(), &[0]);
        assert_eq!(db.shape(), &[0]);
    }

    #[test]
    fn surrogate_matches_finite_difference_of_smoothed_spike() {
        // The tent is the exact derivative of the piecewise-linear
        // "hard sigmoid" relaxation clamp((v - v_th + α)/(2α)·2, 0, 1)…
        // verified here as: integral of the surrogate from far-left to v
        // equals the relaxed spike value.
        let op = Lif::new(1, 0.0, 1.0).unwrap();
        let relaxed = |v: f32| -> f32 {
            // ∫ tent = piecewise quadratic ramp from 0 to 1 over [−α, α].
            if v <= -1.0 {
                0.0
            } else if v >= 1.0 {
                1.0
            } else if v < 0.0 {
                0.5 * (1.0 + v) * (1.0 + v)
            } else {
                1.0 - 0.5 * (1.0 - v) * (1.0 - v)
            }
        };
        let eps = 1e-3;
        for v in [-0.9f32, -0.3, 0.0, 0.4, 0.8] {
            let fd = (relaxed(v + eps) - relaxed(v - eps)) / (2.0 * eps);
            assert!(
                (fd - op.surrogate(v)).abs() < 1e-2,
                "v={v}: fd {fd} vs tent {}",
                op.surrogate(v)
            );
        }
    }
}
