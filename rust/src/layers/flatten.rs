//! Spatial → flat marker layer.
//!
//! Activations are already flattened NHWC on the wire, so flatten is an
//! identity copy; it exists so specs state the spatial/flat transition
//! explicitly and so stage partitions can place the boundary on a
//! zero-FLOP layer when that balances compute.

use super::{Layer, LayerCost};
use crate::backend::Exec;
use crate::tensor::Tensor;
use anyhow::{ensure, Result};

/// Identity on `[batch, dim]` (parameter-free).
pub struct Flatten {
    dim: usize,
}

impl Flatten {
    pub fn new(dim: usize) -> Flatten {
        Flatten { dim }
    }
}

impl Layer for Flatten {
    fn name(&self) -> String {
        format!("flatten[{}]", self.dim)
    }

    fn in_dim(&self) -> usize {
        self.dim
    }

    fn out_dim(&self) -> usize {
        self.dim
    }

    fn checkpoint_tag(&self) -> u32 {
        5
    }

    fn cost(&self, batch: usize) -> LayerCost {
        LayerCost {
            fwd_flops: 0,
            bwd_flops: 0,
            act_bytes: (batch * self.dim * 4) as u64,
            param_bytes: 0,
        }
    }

    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, w, b);
        ensure!(
            x.ndim() == 2 && x.shape()[1] == self.dim,
            "flatten: expected [batch, {}], got {:?}",
            self.dim,
            x.shape()
        );
        out.copy_from(x);
        Ok(())
    }

    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()> {
        let _ = (exec, x, y, w, scratch);
        ensure!(
            dy.ndim() == 2 && dy.shape()[1] == self.dim,
            "flatten backward: expected [batch, {}], got {:?}",
            self.dim,
            dy.shape()
        );
        dx.copy_from(dy);
        dw.resize(&[0]);
        db.resize(&[0]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;
    use crate::util::Rng;

    #[test]
    fn flatten_is_identity_both_ways() {
        let mut rng = Rng::new(2);
        let mut op = Flatten::new(12);
        let x = Tensor::randn(&[3, 12], 1.0, &mut rng);
        let dy = Tensor::randn(&[3, 12], 1.0, &mut rng);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        op.forward_into(&be, &x, &w, &b, &mut y).unwrap();
        assert_eq!(y, x);
        let (mut scr, mut dx, mut dw, mut db) =
            (Tensor::empty(), Tensor::empty(), Tensor::empty(), Tensor::empty());
        op.backward_into(&be, &x, &y, &w, &dy, &mut scr, &mut dx, &mut dw, &mut db).unwrap();
        assert_eq!(dx, dy);
        assert_eq!(dw.shape(), &[0]);
        assert_eq!(op.cost(4).total_flops(), 0);
    }

    #[test]
    fn width_mismatch_is_an_error() {
        let mut op = Flatten::new(8);
        let be = HostBackend::new();
        let (w, b) = (Tensor::zeros(&[0]), Tensor::zeros(&[0]));
        let mut y = Tensor::empty();
        assert!(op.forward_into(&be, &Tensor::zeros(&[2, 9]), &w, &b, &mut y).is_err());
    }
}
