//! Heterogeneous layer subsystem: conv + spiking + dense behind one trait.
//!
//! The seed hard-wired every trainer to a dense MLP (`model::Mlp` +
//! `LayerRole` dispatch into `backend::Exec`). The paper's claims,
//! however, cover "convolutional, fully connected, and spiking neural
//! networks", and LayerPipe's stage assignment is driven by per-layer
//! *compute cost*, not layer count. This module is the seam that opens
//! those workloads:
//!
//! - [`Layer`] — the op contract: `forward_into` / `backward_into` on
//!   caller-owned buffers (hot-path memory discipline, PR 2), explicit
//!   parameter tensors (so the weight-version strategies keep
//!   substituting stashed/EMA-reconstructed weights without knowing the
//!   op), and a [`LayerCost`] report (FLOPs + activation bytes) that
//!   drives cost-balanced stage partitioning
//!   ([`crate::retiming::StagePartition::balanced`]).
//! - [`Dense`] — the port of the seed's `LayerRole` path; still
//!   dispatches through [`Exec`], so PJRT dense artifacts keep serving
//!   it unchanged.
//! - [`Conv2d`] — NHWC im2col into a persistent workspace, then the
//!   existing blocked/worker-pool matmuls; [`MaxPool2d`], [`Flatten`].
//! - [`Lif`] — a surrogate-gradient spiking activation: the delayed
//!   updates its upstream synapse weights receive are exactly the
//!   DLMS-style delayed-update setting the paper analyzes.
//! - [`Network`] / [`NetworkSpec`] — the heterogeneous model: a stack of
//!   `Box<dyn Layer>` ops with their parameter tensors, built
//!   deterministically from a spec (seed-identical with `Mlp::init` for
//!   pure-dense stacks, so legacy curves are unchanged).
//!
//! Activations stay 2-D `[batch, features]` end to end; spatial layers
//! interpret the feature axis as NHWC (`h·w·c`), which makes a conv
//! output directly reinterpretable as the next layer's flat input with
//! no data movement.
//!
//! Parameter-free layers (pool / flatten / LIF) carry zero-length
//! `[0]`-shaped parameter tensors so optimizers, strategies, stashes and
//! EMA accumulators run uniformly over every layer with no special
//! cases — a zero-length SGD step, stash push or EMA update is a no-op.

mod attention;
mod conv;
mod dense;
mod embedding;
mod flatten;
mod lif;
mod norm;
mod pool2d;

pub use attention::SelfAttention;
pub use conv::Conv2d;
pub use dense::Dense;
pub use embedding::Embedding;
pub use flatten::Flatten;
pub use lif::Lif;
pub use norm::LayerNorm;
pub use pool2d::MaxPool2d;

use crate::backend::Exec;
use crate::config::ModelConfig;
use crate::model::LayerRole;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::{bail, ensure, Context, Result};

/// Build the fused-eval `LayerParams` view from `(spec, w, b)` triples
/// in global layer order — `None` as soon as any non-dense layer
/// appears. One rule serving both [`Network::dense_params`] and the
/// executor's stage-distributed weights, so the two evaluation paths
/// can never derive the view differently.
pub fn dense_params_view<'a, I>(layers: I) -> Option<Vec<crate::model::LayerParams>>
where
    I: Iterator<Item = (&'a LayerSpec, &'a Tensor, &'a Tensor)>,
{
    layers
        .enumerate()
        .map(|(i, (spec, w, b))| match *spec {
            LayerSpec::Dense { relu, .. } => Some(crate::model::LayerParams {
                w: w.clone(),
                b: b.clone(),
                role: dense_role(i, relu),
            }),
            _ => None,
        })
        .collect()
}

/// The artifact-role rule for a dense layer at stack position `index`:
/// non-ReLU layers dispatch as `Output`, the stack's first layer as
/// `Input`, everything else as `Hidden`. One function shared by the op
/// builder ([`Dense::new`]) and the fused-eval view
/// ([`Network::dense_params`]) so the two can never disagree.
pub fn dense_role(index: usize, relu: bool) -> LayerRole {
    if !relu {
        LayerRole::Output
    } else if index == 0 {
        LayerRole::Input
    } else {
        LayerRole::Hidden
    }
}

/// Per-layer compute/memory report — the input to cost-balanced stage
/// partitioning (LayerPipe schedules stages by per-layer compute).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerCost {
    /// Forward FLOP-equivalents per batch. Unit convention, shared by
    /// every op so the balanced partition compares like with like: a
    /// multiply-add counts as 2 (its two arithmetic ops), a single
    /// compare/select or elementwise op counts as 1.
    pub fwd_flops: u64,
    /// Backward FLOP-equivalents per batch (same unit convention).
    pub bwd_flops: u64,
    /// Output activation bytes per batch (what one in-flight iteration
    /// stashes for this layer).
    pub act_bytes: u64,
    /// Parameter bytes (weights + biases).
    pub param_bytes: u64,
}

impl LayerCost {
    /// Total per-iteration compute — the stage-balancing objective
    /// (a pipelined stage executes one forward *and* one backward per
    /// iteration in steady state).
    pub fn total_flops(&self) -> u64 {
        self.fwd_flops + self.bwd_flops
    }
}

/// The op contract every layer honors. Parameters are *external* (owned
/// by [`Network`] / the trainers) so weight-version strategies can
/// substitute historical or reconstructed weights per backward; the op
/// itself holds only geometry and recycled compute workspaces (hence
/// `&mut self`: im2col buffers etc. are overwritten every call and never
/// reallocated in steady state).
pub trait Layer: Send {
    /// Human-readable description (logs, partition reports).
    fn name(&self) -> String;

    /// Flattened input feature width this op expects.
    fn in_dim(&self) -> usize;

    /// Flattened output feature width this op produces.
    fn out_dim(&self) -> usize;

    /// Checkpoint record tag (stable across versions).
    fn checkpoint_tag(&self) -> u32;

    /// Whether this op can compute on parameters/activations stored in
    /// `dtype`. Defaults to f32-only: conv, pool and LIF kernels read
    /// `data()` slices directly. [`Dense`] overrides — its matmul family
    /// widens bf16 operand panels during packing (DESIGN.md §11), so a
    /// dense stack is the mixed-precision-servable case. Trainers check
    /// this at construction and fail fast with a readable error.
    fn supports_dtype(&self, dtype: crate::tensor::Dtype) -> bool {
        dtype == crate::tensor::Dtype::F32
    }

    /// `(w, b)` shapes. Parameter-free layers report `[0]`/`[0]`.
    fn param_shapes(&self) -> (Vec<usize>, Vec<usize>) {
        (vec![0], vec![0])
    }

    /// Freshly initialized `(w, b)`. The default covers parameter-free
    /// layers (zero-length tensors, no rng consumption — deterministic
    /// builds do not depend on where paramless layers sit in the stack).
    fn init_params(&self, init_scale: f32, rng: &mut Rng) -> (Tensor, Tensor) {
        let _ = (init_scale, rng);
        let (ws, bs) = self.param_shapes();
        (Tensor::zeros(&ws), Tensor::zeros(&bs))
    }

    /// Compute/memory report for one batch of `batch` samples.
    fn cost(&self, batch: usize) -> LayerCost;

    /// `out = op(x; w, b)` into a caller-owned buffer (resized in place;
    /// contents fully overwritten).
    fn forward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
        out: &mut Tensor,
    ) -> Result<()>;

    /// Gradients into caller-owned buffers given the saved forward pair
    /// `(x, y)` and upstream gradient `dy`. `scratch` is a shared
    /// workspace (contents unspecified on return). `dw`/`db` are resized
    /// to the parameter shapes (`[0]` for parameter-free layers).
    #[allow(clippy::too_many_arguments)]
    fn backward_into(
        &mut self,
        exec: &dyn Exec,
        x: &Tensor,
        y: &Tensor,
        w: &Tensor,
        dy: &Tensor,
        scratch: &mut Tensor,
        dx: &mut Tensor,
        dw: &mut Tensor,
        db: &mut Tensor,
    ) -> Result<()>;
}

/// Shape flowing between layers while building a network.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Feature {
    /// Flat feature vector of the given width.
    Flat(usize),
    /// NHWC spatial feature map (flattened to `h·w·c` on the wire).
    Image { h: usize, w: usize, c: usize },
    /// Token sequence of `t` positions × `d` model features (flattened
    /// to `t·d` on the wire, position-major like NHWC flattens `h·w·c`).
    Seq { t: usize, d: usize },
}

impl Feature {
    /// Flattened element count per sample.
    pub fn numel(&self) -> usize {
        match *self {
            Feature::Flat(d) => d,
            Feature::Image { h, w, c } => h * w * c,
            Feature::Seq { t, d } => t * d,
        }
    }
}

/// Declarative layer description (checkpointable, cheap to clone).
#[derive(Clone, Debug, PartialEq)]
pub enum LayerSpec {
    /// Fully connected `[din] → [units]`, optional fused ReLU.
    Dense { units: usize, relu: bool },
    /// 2-D convolution over NHWC maps, optional fused ReLU.
    Conv2d { out_c: usize, k: usize, stride: usize, pad: usize, relu: bool },
    /// 2-D max pooling (no padding).
    MaxPool2d { k: usize, stride: usize },
    /// Spatial → flat marker (identity on the flattened wire format).
    Flatten,
    /// Leaky-integrate-and-fire spiking activation with a triangular
    /// surrogate gradient; treats its input as the membrane potential.
    Lif { v_th: f32, alpha: f32 },
    /// Token-id gather `[seq] → [seq·dim]` with a learned `[vocab, dim]`
    /// table; inputs are f32-encoded integer ids.
    Embedding { vocab: usize, dim: usize },
    /// Single-head self-attention over `[seq, d_model]` rows with a
    /// fused bias-free QKV projection; `causal` adds the strictly-lower-
    /// triangular visibility mask.
    SelfAttention { seq: usize, d_model: usize, causal: bool },
    /// Per-row (trailing-axis) layer normalization with learned affine.
    LayerNorm { eps: f32 },
}

/// A full heterogeneous model description.
#[derive(Clone, Debug, PartialEq)]
pub struct NetworkSpec {
    pub input: Feature,
    pub layers: Vec<LayerSpec>,
    pub init_scale: f32,
}

impl NetworkSpec {
    /// The spec equivalent of the seed MLP: dense + ReLU everywhere,
    /// linear output. Building it consumes the rng exactly like
    /// `Mlp::init`, so legacy training curves are bit-identical.
    pub fn mlp(cfg: &ModelConfig) -> NetworkSpec {
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let last = l + 1 == cfg.layers;
            layers.push(LayerSpec::Dense {
                units: if last { cfg.classes } else { cfg.hidden_dim },
                relu: !last,
            });
        }
        NetworkSpec {
            input: Feature::Flat(cfg.input_dim),
            layers,
            init_scale: cfg.init_scale,
        }
    }

    /// Whether every layer is fully connected (the PJRT-servable case).
    pub fn is_dense(&self) -> bool {
        self.layers.iter().all(|l| matches!(l, LayerSpec::Dense { .. }))
    }

    /// Output feature width of the full stack (validates shapes).
    pub fn out_dim(&self) -> Result<usize> {
        let mut cur = self.input.clone();
        for (l, spec) in self.layers.iter().enumerate() {
            let (_, next) = build_op(spec, &cur, l)?;
            cur = next;
        }
        Ok(cur.numel())
    }
}

/// Instantiate one op from its spec at the given input feature shape.
/// `index` is the layer's position (first dense layers map to the
/// `Input` artifact role, matching the seed's artifact table).
pub fn build_op(spec: &LayerSpec, cur: &Feature, index: usize) -> Result<(Box<dyn Layer>, Feature)> {
    match *spec {
        LayerSpec::Dense { units, relu } => {
            ensure!(units > 0, "layer {index}: dense units must be positive");
            let din = cur.numel();
            let op = Dense::new(din, units, relu, index);
            Ok((Box::new(op), Feature::Flat(units)))
        }
        LayerSpec::Conv2d { out_c, k, stride, pad, relu } => {
            let Feature::Image { h, w, c } = *cur else {
                bail!("layer {index}: conv needs a spatial input, got flat features");
            };
            let op = Conv2d::new(h, w, c, out_c, k, stride, pad, relu)
                .with_context(|| format!("layer {index}"))?;
            let (oh, ow) = op.out_hw();
            Ok((Box::new(op), Feature::Image { h: oh, w: ow, c: out_c }))
        }
        LayerSpec::MaxPool2d { k, stride } => {
            let Feature::Image { h, w, c } = *cur else {
                bail!("layer {index}: max-pool needs a spatial input, got flat features");
            };
            let op = MaxPool2d::new(h, w, c, k, stride)
                .with_context(|| format!("layer {index}"))?;
            let (oh, ow) = op.out_hw();
            Ok((Box::new(op), Feature::Image { h: oh, w: ow, c }))
        }
        LayerSpec::Flatten => {
            let dim = cur.numel();
            ensure!(dim > 0, "layer {index}: flatten on empty features");
            Ok((Box::new(Flatten::new(dim)), Feature::Flat(dim)))
        }
        LayerSpec::Lif { v_th, alpha } => {
            let dim = cur.numel();
            // Spiking activations preserve the feature shape (spatial or
            // flat) — they are elementwise on the membrane potential.
            let op = Lif::new(dim, v_th, alpha).with_context(|| format!("layer {index}"))?;
            Ok((Box::new(op), cur.clone()))
        }
        LayerSpec::Embedding { vocab, dim } => {
            // Every incoming feature element is one token id.
            let seq = cur.numel();
            let op = Embedding::new(seq, vocab, dim).with_context(|| format!("layer {index}"))?;
            Ok((Box::new(op), Feature::Seq { t: seq, d: dim }))
        }
        LayerSpec::SelfAttention { seq, d_model, causal } => {
            // Accept a matching Seq shape, or any feature whose flat
            // width factors as seq·d_model (a Dense output re-entering
            // the attention wire format).
            if let Feature::Seq { t, d } = *cur {
                ensure!(
                    t == seq && d == d_model,
                    "layer {index}: attention [{seq}x{d_model}] on sequence [{t}x{d}]"
                );
            }
            ensure!(
                cur.numel() == seq * d_model,
                "layer {index}: attention needs {}x{}={} input features, got {}",
                seq,
                d_model,
                seq * d_model,
                cur.numel()
            );
            let op =
                SelfAttention::new(seq, d_model, causal).with_context(|| format!("layer {index}"))?;
            Ok((Box::new(op), Feature::Seq { t: seq, d: d_model }))
        }
        LayerSpec::LayerNorm { eps } => {
            // Normalize over the trailing feature axis: per-position
            // d_model features for sequences, the whole flat vector
            // otherwise (t = 1).
            let (t, d) = match *cur {
                Feature::Seq { t, d } => (t, d),
                ref f => (1, f.numel()),
            };
            let op = LayerNorm::new(t, d, eps).with_context(|| format!("layer {index}"))?;
            Ok((Box::new(op), cur.clone()))
        }
    }
}

/// One layer of a built network: the op plus its parameter tensors.
/// Parameters live *here* (not inside the op) so trainers can hand
/// strategies and optimizers direct tensor access while the op stays a
/// pure compute object.
pub struct NetLayer {
    pub spec: LayerSpec,
    pub op: Box<dyn Layer>,
    pub w: Tensor,
    pub b: Tensor,
}

impl NetLayer {
    pub fn nbytes(&self) -> usize {
        self.w.nbytes() + self.b.nbytes()
    }
}

/// A built heterogeneous model: ordered layers with parameters.
pub struct Network {
    pub input: Feature,
    pub layers: Vec<NetLayer>,
    pub init_scale: f32,
}

impl Network {
    /// Build with freshly initialized parameters. Deterministic: the rng
    /// is consumed layer by layer in order (paramless layers consume
    /// nothing), and a pure-dense spec consumes it exactly like the
    /// seed's `Mlp::init`.
    pub fn build(spec: &NetworkSpec, rng: &mut Rng) -> Result<Network> {
        ensure!(!spec.layers.is_empty(), "network needs at least one layer");
        let mut cur = spec.input.clone();
        let mut layers = Vec::with_capacity(spec.layers.len());
        for (l, ls) in spec.layers.iter().enumerate() {
            let (op, next) = build_op(ls, &cur, l)?;
            let (w, b) = op.init_params(spec.init_scale, rng);
            layers.push(NetLayer { spec: ls.clone(), op, w, b });
            cur = next;
        }
        Ok(Network { input: spec.input.clone(), layers, init_scale: spec.init_scale })
    }

    /// Rebuild a network around existing parameter tensors (weight
    /// snapshots, checkpoint restore, executor evaluation). Ops are
    /// reconstructed from the specs with fresh (empty) workspaces.
    pub fn from_parts(
        input: Feature,
        init_scale: f32,
        parts: Vec<(LayerSpec, Tensor, Tensor)>,
    ) -> Result<Network> {
        ensure!(!parts.is_empty(), "network needs at least one layer");
        let mut cur = input.clone();
        let mut layers = Vec::with_capacity(parts.len());
        for (l, (spec, w, b)) in parts.into_iter().enumerate() {
            let (op, next) = build_op(&spec, &cur, l)?;
            let (ws, bs) = op.param_shapes();
            ensure!(
                w.shape() == ws.as_slice() && b.shape() == bs.as_slice(),
                "layer {l} ({}): param shapes {:?}/{:?} do not match op {:?}/{:?}",
                op.name(),
                w.shape(),
                b.shape(),
                ws,
                bs
            );
            layers.push(NetLayer { spec, op, w, b });
            cur = next;
        }
        Ok(Network { input, layers, init_scale })
    }

    /// Deep copy with fresh op workspaces (the evaluation path).
    pub fn snapshot(&self) -> Result<Network> {
        let parts = self
            .layers
            .iter()
            .map(|nl| (nl.spec.clone(), nl.w.clone(), nl.b.clone()))
            .collect();
        Network::from_parts(self.input.clone(), self.init_scale, parts)
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Flattened input feature width.
    pub fn input_dim(&self) -> usize {
        self.input.numel()
    }

    /// Flattened output feature width (logit count for classifiers).
    pub fn out_dim(&self) -> usize {
        self.layers.last().map_or(self.input_dim(), |nl| nl.op.out_dim())
    }

    /// Total parameter bytes.
    pub fn nbytes(&self) -> usize {
        self.layers.iter().map(NetLayer::nbytes).sum()
    }

    /// Total scalar parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|nl| nl.w.len() + nl.b.len()).sum()
    }

    /// Per-layer cost reports at the given batch size.
    pub fn costs(&self, batch: usize) -> Vec<LayerCost> {
        self.layers.iter().map(|nl| nl.op.cost(batch)).collect()
    }

    /// For pure-dense stacks, the `LayerParams` view (cloned weights,
    /// roles re-derived by the builder's rule) that lets evaluation use
    /// the backend's *fused* full-network forward — the PJRT `fwd_full`
    /// artifact. `None` as soon as any non-dense layer is present.
    pub fn dense_params(&self) -> Option<Vec<crate::model::LayerParams>> {
        dense_params_view(self.layers.iter().map(|nl| (&nl.spec, &nl.w, &nl.b)))
    }

    /// Full-network forward (evaluation path; allocates per layer).
    pub fn forward_full(&mut self, exec: &dyn Exec, x: &Tensor) -> Result<Tensor> {
        let mut h = x.clone();
        for nl in self.layers.iter_mut() {
            let mut y = Tensor::empty();
            nl.op.forward_into(exec, &h, &nl.w, &nl.b, &mut y)?;
            h = y;
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;
    use crate::model::Mlp;

    fn mcfg() -> ModelConfig {
        ModelConfig { batch: 4, input_dim: 8, hidden_dim: 6, classes: 3, layers: 3, init_scale: 1.0 }
    }

    #[test]
    fn mlp_spec_build_matches_seed_init_bitwise() {
        // Same seed ⇒ the dense network and the legacy Mlp must hold
        // identical parameters (rng consumed in the same order), which is
        // what keeps every legacy curve unchanged.
        let cfg = mcfg();
        let net = Network::build(&NetworkSpec::mlp(&cfg), &mut Rng::new(9)).unwrap();
        let mlp = Mlp::init(&cfg, &mut Rng::new(9));
        assert_eq!(net.num_layers(), mlp.num_layers());
        for (nl, lp) in net.layers.iter().zip(&mlp.layers) {
            assert_eq!(nl.w, lp.w);
            assert_eq!(nl.b, lp.b);
        }
        assert_eq!(net.num_params(), mlp.num_params());
        assert_eq!(net.nbytes(), mlp.nbytes());
    }

    #[test]
    fn dense_network_forward_matches_mlp_forward_full() {
        let cfg = mcfg();
        let mut net = Network::build(&NetworkSpec::mlp(&cfg), &mut Rng::new(3)).unwrap();
        let mlp = Mlp::init(&cfg, &mut Rng::new(3));
        let x = Tensor::randn(&[4, 8], 1.0, &mut Rng::new(7));
        let be = HostBackend::new();
        let a = net.forward_full(&be, &x).unwrap();
        let b = mlp.forward_full(&be, &x).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn dense_params_view_matches_seed_roles() {
        let cfg = mcfg();
        let spec = NetworkSpec::mlp(&cfg);
        assert!(spec.is_dense());
        let net = Network::build(&spec, &mut Rng::new(9)).unwrap();
        let params = net.dense_params().expect("pure-dense stack");
        let mlp = Mlp::init(&cfg, &mut Rng::new(9));
        for (a, b) in params.iter().zip(&mlp.layers) {
            assert_eq!(a.role, b.role);
            assert_eq!(a.w, b.w);
            assert_eq!(a.b, b.b);
        }
        // Any non-dense layer disables the fused view.
        let hetero = NetworkSpec {
            input: Feature::Flat(8),
            layers: vec![
                LayerSpec::Dense { units: 4, relu: false },
                LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            ],
            init_scale: 1.0,
        };
        assert!(!hetero.is_dense());
        let hnet = Network::build(&hetero, &mut Rng::new(1)).unwrap();
        assert!(hnet.dense_params().is_none());
    }

    #[test]
    fn conv_stack_shapes_flow() {
        let spec = NetworkSpec {
            input: Feature::Image { h: 8, w: 8, c: 2 },
            layers: vec![
                LayerSpec::Conv2d { out_c: 4, k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::MaxPool2d { k: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 10, relu: true },
                LayerSpec::Dense { units: 3, relu: false },
            ],
            init_scale: 1.0,
        };
        assert_eq!(spec.out_dim().unwrap(), 3);
        let net = Network::build(&spec, &mut Rng::new(1)).unwrap();
        assert_eq!(net.input_dim(), 128);
        assert_eq!(net.out_dim(), 3);
        // conv: [3·3·2, 4] weights; pool/flatten paramless.
        assert_eq!(net.layers[0].w.shape(), &[18, 4]);
        assert_eq!(net.layers[1].w.shape(), &[0]);
        assert_eq!(net.layers[2].w.shape(), &[0]);
        assert_eq!(net.layers[3].w.shape(), &[64, 10]);
    }

    #[test]
    fn transformer_stack_shapes_flow() {
        let (seq, dm, vocab) = (6, 4, 11);
        let spec = NetworkSpec {
            input: Feature::Flat(seq),
            layers: vec![
                LayerSpec::Embedding { vocab, dim: dm },
                LayerSpec::SelfAttention { seq, d_model: dm, causal: true },
                LayerSpec::LayerNorm { eps: 1e-5 },
                LayerSpec::Dense { units: seq * dm, relu: true },
                LayerSpec::SelfAttention { seq, d_model: dm, causal: true },
                LayerSpec::LayerNorm { eps: 1e-5 },
                LayerSpec::Dense { units: 3, relu: false },
            ],
            init_scale: 1.0,
        };
        assert_eq!(spec.out_dim().unwrap(), 3);
        let net = Network::build(&spec, &mut Rng::new(1)).unwrap();
        assert_eq!(net.input_dim(), seq);
        assert_eq!(net.out_dim(), 3);
        assert_eq!(net.layers[0].w.shape(), &[vocab, dm]); // embedding table
        assert_eq!(net.layers[0].b.shape(), &[0]);
        assert_eq!(net.layers[1].w.shape(), &[dm, 3 * dm]); // fused QKV, bias-free
        assert_eq!(net.layers[1].b.shape(), &[0]);
        assert_eq!(net.layers[2].w.shape(), &[dm]); // gamma/beta per feature
        assert_eq!(net.layers[2].b.shape(), &[dm]);
        // The Dense output (Flat(seq·dm)) re-enters attention by width.
        assert_eq!(net.layers[4].w.shape(), &[dm, 3 * dm]);
        assert!(!spec.is_dense());
        assert!(net.dense_params().is_none());
        // Mismatched attention geometry fails at build time.
        let bad = NetworkSpec {
            input: Feature::Flat(seq),
            layers: vec![
                LayerSpec::Embedding { vocab, dim: dm },
                LayerSpec::SelfAttention { seq: seq + 1, d_model: dm, causal: false },
            ],
            init_scale: 1.0,
        };
        assert!(Network::build(&bad, &mut Rng::new(1)).is_err());
    }

    #[test]
    fn spec_errors_are_readable() {
        // Conv on flat features must fail at build time.
        let spec = NetworkSpec {
            input: Feature::Flat(16),
            layers: vec![LayerSpec::Conv2d { out_c: 2, k: 3, stride: 1, pad: 0, relu: true }],
            init_scale: 1.0,
        };
        let err = Network::build(&spec, &mut Rng::new(1)).unwrap_err();
        assert!(format!("{err:#}").contains("spatial"));
    }

    #[test]
    fn snapshot_preserves_params_and_forward() {
        let spec = NetworkSpec {
            input: Feature::Image { h: 4, w: 4, c: 1 },
            layers: vec![
                LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 5, relu: false },
            ],
            init_scale: 1.0,
        };
        let mut net = Network::build(&spec, &mut Rng::new(2)).unwrap();
        let mut snap = net.snapshot().unwrap();
        let x = Tensor::randn(&[2, 16], 1.0, &mut Rng::new(5));
        let be = HostBackend::new();
        assert_eq!(net.forward_full(&be, &x).unwrap(), snap.forward_full(&be, &x).unwrap());
    }

    #[test]
    fn costs_reflect_geometry() {
        let cfg = mcfg();
        let net = Network::build(&NetworkSpec::mlp(&cfg), &mut Rng::new(1)).unwrap();
        let costs = net.costs(cfg.batch);
        // Dense fwd = 2·B·din·dout madd-flops.
        assert_eq!(costs[0].fwd_flops, 2 * 4 * 8 * 6);
        assert_eq!(costs[2].fwd_flops, 2 * 4 * 6 * 3);
        assert!(costs[0].bwd_flops > costs[0].fwd_flops);
        assert_eq!(costs[0].act_bytes, (4 * 6 * 4) as u64);
    }
}
