//! # LayerPipe2
//!
//! A production-grade reproduction of *"LayerPipe2: Multistage Pipelining
//! and Weight Recompute via Improved Exponential Moving Average for
//! Training Neural Networks"* (Unnikrishnan & Parhi, 2025).
//!
//! The library is the L3 (Rust) layer of a three-layer Rust + JAX + Pallas
//! stack: JAX/Pallas author the per-layer compute graphs at build time and
//! AOT-lower them to HLO text (`make artifacts`); this crate executes them
//! through a pluggable [`backend`] — the PJRT C API ([`runtime`], behind
//! the `pjrt` feature) or a pure-Rust host backend — and owns everything
//! else:
//!
//! - the paper's **retiming-theoretic pipeline derivation** ([`graph`],
//!   [`retiming`]) including the closed form `Delay(l) = 2·S(l)` and
//!   grouped multistage partitions;
//! - the **DLMS delayed-gradient foundation** ([`dlms`]);
//! - the **pipeline schedule model** ([`schedule`]) and a real threaded
//!   pipeline runtime ([`pipeline`]) whose multi-threaded training
//!   executor physically overlaps forward and delayed backward per the
//!   retiming schedule, reproducing the iteration-indexed [`train`]
//!   oracle's curves;
//! - **weight/activation stashing** with byte-level accounting ([`stash`])
//!   and the paper's **pipeline-aware EMA weight recompute** ([`ema`]);
//! - the five weight-handling **strategies** of the paper's Fig. 5
//!   ([`strategy`]) and the delayed-gradient **trainer** ([`train`]);
//! - a **heterogeneous layer zoo** ([`layers`]): dense, conv (im2col),
//!   max-pool, flatten and surrogate-gradient spiking layers behind one
//!   `Layer` trait, with per-layer cost reports driving cost-balanced
//!   stage partitioning;
//! - a **batched inference server** ([`serving`]): multi-client
//!   request queue, coalescing batcher, forward-cost-balanced stage
//!   workers and atomic epoch-versioned checkpoint hot-reload —
//!   bitwise-equal to the sequential forward oracle;
//! - **weight-ring replica parallelism** ([`replica`]): 2D (pipeline ×
//!   data) training over N in-process replica workers with a
//!   deterministic fixed-tree all-reduce — bit-identical weights at any
//!   replica count — gradients circulating as flat codec buffers on
//!   ping-pong ring links;
//! - **unified runtime telemetry** ([`obs`]): a process-wide registry of
//!   lock-free counters, gauges, log-scale histograms and scoped span
//!   timers instrumenting all four runtimes (pipeline bubble accounting,
//!   serving latency histograms, ring link traffic, pool/scratch
//!   hit rates), with snapshot/diff/JSON export and an optional
//!   Chrome-trace span dump — never perturbing bit-determinism;
//! - supporting substrates written from scratch for this offline
//!   environment: deterministic RNG, JSON, a TOML-subset config system,
//!   host tensors, a bench harness and a property-test helper.
//!
//! See `DESIGN.md` for the system inventory, the backend trait contract
//! and the executor threading model.

pub mod util;
pub mod obs;
pub mod config;
pub mod tensor;
pub mod backend;
pub mod graph;
pub mod retiming;
pub mod dlms;
pub mod schedule;
pub mod stash;
pub mod ema;
pub mod optim;
pub mod strategy;
pub mod model;
pub mod layers;
pub mod runtime;
pub mod data;
pub mod train;
pub mod pipeline;
pub mod serving;
pub mod replica;
pub mod coordinator;
pub mod metrics;
pub mod bench_util;
pub mod testing;

/// Crate-wide result alias (anyhow-based; `eyre` is unavailable offline).
pub type Result<T> = anyhow::Result<T>;
