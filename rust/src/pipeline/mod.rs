//! Threaded pipeline runtime: real concurrent stage execution.
//!
//! Two layers of machinery live here:
//!
//! 1. [`forward_throughput`] / [`forward_sequential`] — the forward-only
//!    throughput harness the seed shipped, now backend-generic. The
//!    live multi-client generalization of this stage loop (request
//!    queue, batching, hot-reload) is [`crate::serving`].
//! 2. [`PipelinedTrainer`] — a **pipelined training executor**: one OS
//!    thread per stage, each owning its layers' parameters, optimizers
//!    and weight-version strategy, interleaving the forward of batch `t`
//!    with the delayed backward of batch `t − d_s` exactly per the
//!    retiming schedule (`d_s = 2·S(stage)`, Eq. 1). Activations flow
//!    forward and gradients flow backward through bounded channels; no
//!    locks sit on the hot path because every weight is owned by exactly
//!    one stage thread.
//!
//! Stages own heterogeneous `Box<dyn Layer>` ops ([`crate::layers`]):
//! conv, pool, spiking and dense layers all ride the same worker loop,
//! and [`PipelinedTrainer::with_spec`] places stage boundaries by
//! cost-balanced compute (LayerPipe) while [`PipelinedTrainer::new`]
//! keeps the seed's even dense partition bit-compatible.
//!
//! ### Equivalence with the iteration-indexed oracle
//!
//! [`crate::train::Trainer`] executes, per stage, the event sequence
//! `…, fwd(t), bwd(t − d_s), fwd(t+1), bwd(t+1 − d_s), …` with gradients
//! applied stage-locally the moment they materialize. The executor runs
//! the *same local sequence* on each stage thread and communicates only
//! through dataflow (activations down, gradients up), so every f32
//! operation happens in the same order on the same operands — the loss
//! curves match the oracle bit-for-bit while the stages physically
//! overlap in wall-clock time. Epoch boundaries are barriers (the
//! trainer evaluates between epochs), and a final drain span retires the
//! pipeline tail, mirroring `Trainer::drain`.
//!
//! Batch feeding is arena-based: the trainer keeps one persistent
//! `Vec<Tensor>` pair refilled in place via `Dataset::batch_into` each
//! epoch, spans borrow it as slices, and stage 0 pulls pooled copies —
//! after the first epoch the feed path allocates nothing.
//!
//! tokio is unavailable offline; `std::thread` + `mpsc::sync_channel`
//! provide the same bounded-queue backpressure structure.

use crate::backend::{Backend, Exec};
use crate::config::ExperimentConfig;
use crate::data::{BatchIter, Splits};
use crate::layers::{Feature, LayerSpec, Network, NetworkSpec};
use crate::metrics::{EpochMetrics, RunCurve};
use crate::model::Mlp;
use crate::obs;
use crate::optim::{LrBook, Optimizer, Sgd};
use crate::retiming::StagePartition;
use crate::strategy::{LayerStrategy, StrategyKind};
use crate::tensor::{BufferPool, Dtype, Tensor};
use crate::train::{evaluate_network, lr_schedule_for};
use crate::util::{Rng, Stopwatch};
use anyhow::{anyhow, Context, Result};
use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{mpsc, Arc};

/// Throughput measurement of one run.
#[derive(Clone, Debug)]
pub struct ThroughputReport {
    pub stages: usize,
    pub batches: usize,
    pub seconds: f64,
    pub batches_per_sec: f64,
}

/// One stage's wall-clock breakdown over a telemetry window — see
/// [`PipelinedTrainer::bubble_report`]. All durations are span sums in
/// nanoseconds; `compute_ns + recv_ns + send_ns + other_ns == wall_ns`
/// by construction (`other_ns` is the derived remainder).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageBubble {
    /// Stage index (thread `stage{s}`).
    pub stage: usize,
    /// Wall time inside the worker loop (`pipeline/stage`).
    pub wall_ns: u64,
    /// Forward + backward + EMA-reconstruct + optimizer time.
    pub compute_ns: u64,
    /// Time blocked receiving activations / gradients.
    pub recv_ns: u64,
    /// Time blocked sending into a full bounded channel.
    pub send_ns: u64,
    /// Unlabelled remainder (stash bookkeeping, pool ops, loop overhead).
    pub other_ns: u64,
    /// `(recv_ns + send_ns) / wall_ns` — the pipeline-bubble share.
    pub bubble_fraction: f64,
    /// Stage share of total model FLOPs (the partitioner's cost model).
    pub predicted_share: f64,
    /// Stage share of total measured compute time.
    pub measured_share: f64,
}

/// Run `batches` forward passes through a `stages`-stage pipeline — one
/// OS thread per stage, pre-built inputs cycled through the feeder —
/// returning the measured throughput.
///
/// `depth` bounds each inter-stage queue (backpressure): the number of
/// in-flight batches ≈ `stages · depth`, mirroring the activation-stash
/// budget of the schedule model.
pub fn forward_throughput(
    backend: &Backend,
    mlp: &Mlp,
    partition: &StagePartition,
    inputs: Vec<Tensor>,
    batches: usize,
    depth: usize,
) -> Result<ThroughputReport> {
    let k = partition.stages();
    assert!(k >= 1 && depth >= 1 && batches >= 1 && !inputs.is_empty());

    let sw = Stopwatch::start();
    let mut txs = Vec::with_capacity(k + 1);
    let mut rxs = Vec::with_capacity(k + 1);
    for _ in 0..=k {
        let (tx, rx) = mpsc::sync_channel::<Tensor>(depth);
        txs.push(tx);
        rxs.push(rx);
    }
    let mut rx_iter = rxs.into_iter();
    let mut handles = Vec::with_capacity(k);
    for s in 0..k {
        let rx = rx_iter.next().expect("stage rx");
        let tx = txs[s + 1].clone();
        let backend = Arc::clone(backend);
        let params: Vec<(Tensor, Tensor, crate::model::LayerRole)> = partition
            .layers_in_stage(s)
            .iter()
            .map(|&l| (mlp.layers[l].w.clone(), mlp.layers[l].b.clone(), mlp.layers[l].role))
            .collect();
        handles.push(std::thread::spawn(move || -> Result<usize> {
            let mut count = 0usize;
            // Stage-local recycling: inputs retire into the pool as each
            // layer's output (pooled) replaces them — no steady-state
            // allocation in the forward loop.
            let mut pool = BufferPool::new();
            while let Ok(mut h) = rx.recv() {
                for (w, b, role) in &params {
                    let mut y = pool.take(&[h.shape()[0], w.shape()[1]]);
                    backend.forward_into(*role, &h, w, b, &mut y).context("stage forward")?;
                    pool.recycle(std::mem::replace(&mut h, y));
                }
                count += 1;
                if tx.send(h).is_err() {
                    break;
                }
            }
            Ok(count)
        }));
    }
    let feeder = txs.remove(0);
    drop(txs);
    let collector = rx_iter.next().expect("collector rx");

    let feed = std::thread::spawn(move || {
        for i in 0..batches {
            let x = inputs[i % inputs.len()].clone();
            if feeder.send(x).is_err() {
                break;
            }
        }
    });

    let mut received = 0usize;
    while received < batches {
        collector
            .recv()
            .map_err(|_| anyhow!("pipeline closed early at {received}/{batches}"))?;
        received += 1;
    }
    drop(collector);
    feed.join().expect("feeder join");
    for h in handles {
        let processed = h.join().expect("stage join")?;
        debug_assert!(processed >= batches);
    }
    let seconds = sw.elapsed_secs();
    Ok(ThroughputReport {
        stages: k,
        batches,
        seconds,
        batches_per_sec: batches as f64 / seconds,
    })
}

/// Sequential reference: the same `batches` forwards on one thread.
pub fn forward_sequential(
    backend: &Backend,
    mlp: &Mlp,
    inputs: &[Tensor],
    batches: usize,
) -> Result<ThroughputReport> {
    let sw = Stopwatch::start();
    for i in 0..batches {
        let mut h = inputs[i % inputs.len()].clone();
        for l in 0..mlp.num_layers() {
            h = mlp.forward_layer(backend.as_ref(), l, &h)?;
        }
    }
    let seconds = sw.elapsed_secs();
    Ok(ThroughputReport { stages: 1, batches, seconds, batches_per_sec: batches as f64 / seconds })
}

// ---------------------------------------------------------------------------
// The pipelined training executor.
// ---------------------------------------------------------------------------

/// A batch-tagged tensor moving between stages.
type Packet = (u64, Tensor);

/// One layer owned by a stage worker. The gradient delay is not stored
/// per layer: every layer of a stage shares the stage's `delay`.
/// Parameter-free ops (pool / flatten / LIF) carry zero-length `w`/`b`,
/// making their optimizer/strategy traffic a uniform no-op.
struct StageLayer {
    spec: LayerSpec,
    op: Box<dyn crate::layers::Layer>,
    w: Tensor,
    b: Tensor,
    strategy: LayerStrategy,
    opt_w: Sgd,
    opt_b: Sgd,
    /// Persistent `_into` workspaces for this layer's weight/bias
    /// gradients (overwritten every backward, never reallocated).
    dw_buf: Tensor,
    db_buf: Tensor,
    /// Mixed precision: f32 master weights, stepped by the optimizer;
    /// the bf16 storage weights re-quantize from them after every step.
    /// `None` in f32 runs (the optimizer steps `w` directly) — see
    /// `train::LayerState::master_w`.
    master_w: Option<Tensor>,
}

/// Everything one stage thread owns: its layers, its slice of the lr
/// bookkeeping, the activations stashed for pending backwards, and the
/// recycled-buffer workspaces that make its steady-state loop
/// allocation-free.
struct StageState {
    stage: usize,
    /// Layers in ascending global-layer order.
    layers: Vec<StageLayer>,
    /// The stage's gradient delay `d_s = 2·(K − 1 − s)`.
    delay: u64,
    lr: LrBook,
    /// FIFO of `(t, activation chain)` awaiting backward: `chain[0]` is
    /// the stage input, `chain[i + 1]` is stage-local layer `i`'s output
    /// (each stored once).
    saved: VecDeque<(u64, Vec<Tensor>)>,
    saved_bytes: usize,
    peak_saved_bytes: usize,
    /// Last stage only: `(t, loss)` records awaiting epoch attribution.
    losses: VecDeque<(u64, f32)>,
    /// Stage-local recycled tensor storage. Gradients arriving from
    /// downstream retire into this pool while same-shaped outputs are
    /// drawn from it — flows balance in steady state.
    pool: BufferPool,
    /// Pre-activation-gradient workspace shared across layer backwards.
    scratch: Tensor,
    /// Emptied activation-chain Vecs, reused by the forward lane.
    spare_chains: Vec<Vec<Tensor>>,
    /// Storage dtype for weights and stashed activations (`cfg.dtype`).
    dtype: Dtype,
    /// Persistent f32 staging buffer for the bf16 forward lane (kernels
    /// accumulate f32; the stored activation is its quantization).
    fwd_scratch: Tensor,
}

impl StageState {
    fn is_last(&self, stages: usize) -> bool {
        self.stage + 1 == stages
    }
}

/// The channel endpoints a stage keeps across spans. Messages buffered at
/// an epoch barrier (gradients produced upstream but not yet consumed)
/// survive inside the channels.
#[derive(Default)]
struct StageLinks {
    act_in: Option<Receiver<Packet>>,
    act_out: Option<SyncSender<Packet>>,
    grad_in: Option<Receiver<Packet>>,
    grad_out: Option<SyncSender<Packet>>,
}

/// The multi-threaded pipelined trainer: same constructor inputs and
/// curve outputs as [`crate::train::Trainer`], but executed by one worker
/// thread per stage with physically overlapped forward/backward.
pub struct PipelinedTrainer {
    backend: Backend,
    cfg: ExperimentConfig,
    kind: StrategyKind,
    partition: StagePartition,
    /// Input feature shape + init scale (for network snapshots).
    input: Feature,
    init_scale: f32,
    stages: Vec<StageState>,
    links: Vec<StageLinks>,
    /// Persistent feed arenas: refilled in place per epoch via
    /// `Dataset::batch_into`, borrowed by spans as slices.
    feed_x: Vec<Tensor>,
    feed_oh: Vec<Tensor>,
    /// Reporting schedule (per-stage books do the hot-path sums).
    report_lr: LrBook,
    /// Batches fed so far == the next global iteration index.
    step: u64,
}

impl PipelinedTrainer {
    /// Seed-identical construction: consumes `rng` exactly like
    /// `Trainer::new`, so both start from the same parameters.
    pub fn new(
        backend: Backend,
        cfg: &ExperimentConfig,
        kind: StrategyKind,
        rng: &mut Rng,
    ) -> Result<PipelinedTrainer> {
        cfg.validate()?;
        backend.check_model(&cfg.model)?;
        let net = Network::build(&NetworkSpec::mlp(&cfg.model), rng)?;
        let stages_n = if kind.is_pipelined() { cfg.pipeline.stages } else { 1 };
        let partition = StagePartition::even(net.num_layers(), stages_n)?;
        Self::assemble(backend, cfg, kind, net, partition)
    }

    /// Heterogeneous executor: any [`NetworkSpec`], stage boundaries by
    /// cost-balanced compute — mirrors [`crate::train::Trainer::with_spec`]
    /// (identical rng consumption and partition, so the two engines stay
    /// numerically interchangeable on heterogeneous stacks too).
    pub fn with_spec(
        backend: Backend,
        cfg: &ExperimentConfig,
        spec: &NetworkSpec,
        kind: StrategyKind,
        rng: &mut Rng,
    ) -> Result<PipelinedTrainer> {
        let (net, partition) =
            crate::train::build_spec_network(backend.as_ref(), cfg, spec, kind, rng)?;
        Self::assemble(backend, cfg, kind, net, partition)
    }

    fn assemble(
        backend: Backend,
        cfg: &ExperimentConfig,
        kind: StrategyKind,
        net: Network,
        partition: StagePartition,
    ) -> Result<PipelinedTrainer> {
        let stages_n = partition.stages();
        let delays = partition.gradient_delays();
        let stage_of = partition.stage_of().to_vec();
        let input = net.input.clone();
        let init_scale = net.init_scale;
        let dtype = cfg.dtype;
        crate::train::check_dtype_served(backend.as_ref(), &net, dtype)?;

        let mut stages: Vec<StageState> = (0..stages_n)
            .map(|s| StageState {
                stage: s,
                layers: Vec::new(),
                delay: 0, // set below from the partition's layer delays
                lr: LrBook::new(lr_schedule_for(cfg)),
                saved: VecDeque::new(),
                saved_bytes: 0,
                peak_saved_bytes: 0,
                losses: VecDeque::new(),
                pool: BufferPool::new(),
                scratch: Tensor::empty(),
                spare_chains: Vec::new(),
                dtype,
                fwd_scratch: Tensor::empty(),
            })
            .collect();
        for (l, mut nl) in net.layers.into_iter().enumerate() {
            // Mixed precision: keep the f32 init as the master copy and
            // quantize the storage weights once (train::assemble does
            // the same, so both engines start from identical bits).
            let master_w = (dtype != Dtype::F32).then(|| {
                let master = nl.w.clone();
                nl.w = nl.w.to_dtype(dtype);
                master
            });
            // All layers of a stage share one delay (d = 2·S(stage));
            // deriving the stage delay from the same `delays` vector the
            // strategies use keeps scheduler and stash windows in lockstep.
            stages[stage_of[l]].delay = delays[l] as u64;
            stages[stage_of[l]].layers.push(StageLayer {
                strategy: LayerStrategy::new_with_dtype(kind, delays[l], dtype),
                opt_w: Sgd::new(nl.w.shape(), cfg.optim.momentum, cfg.optim.weight_decay),
                opt_b: Sgd::new(nl.b.shape(), cfg.optim.momentum, 0.0),
                spec: nl.spec,
                op: nl.op,
                w: nl.w,
                b: nl.b,
                dw_buf: Tensor::empty(),
                db_buf: Tensor::empty(),
                master_w,
            });
        }

        // Channel capacity: a stage can run at most ~d_max iterations
        // ahead of its neighbors (then its own delayed backward blocks on
        // the upstream gradient), so this depth makes sends non-blocking
        // in steady state while still bounding in-flight memory.
        let cap = partition.max_delay() + 4;
        let mut links: Vec<StageLinks> = (0..stages_n).map(|_| StageLinks::default()).collect();
        for s in 0..stages_n.saturating_sub(1) {
            let (atx, arx) = mpsc::sync_channel::<Packet>(cap);
            links[s].act_out = Some(atx);
            links[s + 1].act_in = Some(arx);
            let (gtx, grx) = mpsc::sync_channel::<Packet>(cap);
            links[s + 1].grad_out = Some(gtx);
            links[s].grad_in = Some(grx);
        }

        Ok(PipelinedTrainer {
            backend,
            cfg: cfg.clone(),
            kind,
            partition,
            input,
            init_scale,
            stages,
            links,
            feed_x: Vec::new(),
            feed_oh: Vec::new(),
            report_lr: LrBook::new(lr_schedule_for(cfg)),
            step: 0,
        })
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    pub fn partition(&self) -> &StagePartition {
        &self.partition
    }

    pub fn num_layers(&self) -> usize {
        self.stages.iter().map(|st| st.layers.len()).sum()
    }

    pub fn gradient_delays(&self) -> Vec<usize> {
        self.stages
            .iter()
            .flat_map(|st| st.layers.iter().map(move |_| st.delay as usize))
            .collect()
    }

    /// Snapshot the stage-distributed parameters as a [`Network`]
    /// (fresh op workspaces, cloned weights) in global layer order.
    pub fn network(&self) -> Result<Network> {
        let parts = self
            .stages
            .iter()
            .flat_map(|st| st.layers.iter())
            .map(|sl| (sl.spec.clone(), sl.w.clone(), sl.b.clone()))
            .collect();
        Network::from_parts(self.input.clone(), self.init_scale, parts)
    }

    /// Peak staleness-handling bytes across layers (stash + EMA).
    pub fn staleness_bytes(&self) -> usize {
        self.stages
            .iter()
            .flat_map(|st| st.layers.iter())
            .map(|sl| sl.strategy.peak_staleness_nbytes())
            .sum()
    }

    /// `(hits, misses)` summed over the stage buffer pools — the
    /// executor's allocs-per-iteration proxy: steady-state takes are
    /// pool hits (no allocation); misses happen only while the pools
    /// warm up during pipeline fill.
    pub fn pool_stats(&self) -> (u64, u64) {
        self.stages
            .iter()
            .fold((0, 0), |(h, m), st| (h + st.pool.hits(), m + st.pool.misses()))
    }

    /// Per-stage pipeline-bubble accounting over a telemetry `window`
    /// (a [`obs::TelemetrySnapshot::diff`] spanning one or more epochs).
    ///
    /// For each stage the worker's wall time is split into compute
    /// (`pipeline/fwd` + `pipeline/bwd` + `pipeline/ema` +
    /// `pipeline/opt`), channel-blocked time (recv / send per bounded
    /// link), and the unlabelled remainder — so the breakdown sums to
    /// wall time by construction. The *bubble fraction* is the
    /// channel-blocked share: time the stage sat on a bounded channel
    /// while a neighbor ran long. `predicted_share` is the stage's slice
    /// of total model FLOPs — what [`StagePartition::balanced`]
    /// equalizes — and `measured_share` is its slice of measured compute
    /// time; comparing the two grades the partitioner against reality.
    ///
    /// Spans require [`obs::enabled`]; with the gate off every field is
    /// zero.
    pub fn bubble_report(&self, window: &obs::TelemetrySnapshot) -> Vec<StageBubble> {
        let batch = self.cfg.model.batch;
        let flops: Vec<f64> = self
            .stages
            .iter()
            .map(|st| st.layers.iter().map(|sl| sl.op.cost(batch).total_flops() as f64).sum())
            .collect();
        let total_flops: f64 = flops.iter().sum();
        let span_ns = |thread: &str, label: &str| -> u64 {
            window.span(thread, label).map_or(0, |s| s.total_ns)
        };
        let mut out: Vec<StageBubble> = self
            .stages
            .iter()
            .enumerate()
            .map(|(s, _)| {
                let th = format!("stage{s}");
                let wall_ns = span_ns(&th, "pipeline/stage");
                let compute_ns = span_ns(&th, "pipeline/fwd")
                    + span_ns(&th, "pipeline/bwd")
                    + span_ns(&th, "pipeline/ema")
                    + span_ns(&th, "pipeline/opt");
                let recv_ns = span_ns(&th, "pipeline/recv_act") + span_ns(&th, "pipeline/recv_grad");
                let send_ns = span_ns(&th, "pipeline/send_act") + span_ns(&th, "pipeline/send_grad");
                let other_ns = wall_ns.saturating_sub(compute_ns + recv_ns + send_ns);
                let bubble_fraction = if wall_ns == 0 {
                    0.0
                } else {
                    (recv_ns + send_ns) as f64 / wall_ns as f64
                };
                let predicted_share =
                    if total_flops > 0.0 { flops[s] / total_flops } else { 0.0 };
                StageBubble {
                    stage: s,
                    wall_ns,
                    compute_ns,
                    recv_ns,
                    send_ns,
                    other_ns,
                    bubble_fraction,
                    predicted_share,
                    measured_share: 0.0, // filled below from the compute total
                }
            })
            .collect();
        let total_compute: u64 = out.iter().map(|b| b.compute_ns).sum();
        if total_compute > 0 {
            for b in &mut out {
                b.measured_share = b.compute_ns as f64 / total_compute as f64;
            }
        }
        out
    }

    /// Peak bytes of stage-local activation stash, summed over stages.
    ///
    /// Accounting note: this counts the activation chains (stage input +
    /// one output per layer, each stored once) each stage holds for
    /// pending backwards. The oracle `Trainer` additionally counts each
    /// in-flight record's one-hot labels and the gradient flowing down
    /// its backward chain, so the `activation_bytes` metric is *not*
    /// comparable across the two engines (loss, accuracy and staleness
    /// bytes are).
    pub fn peak_activation_bytes(&self) -> usize {
        self.stages.iter().map(|st| st.peak_saved_bytes).sum()
    }

    /// Test accuracy of the current (stage-distributed) parameters —
    /// the same f32 sequence as the oracle trainer's evaluation. Pure-
    /// dense stacks collect the fused-eval `LayerParams` view straight
    /// off the stage weights (one clone, the PR 2 cost); heterogeneous
    /// stacks evaluate a network snapshot.
    pub fn evaluate(&self, data: &Splits) -> Result<f32> {
        let dense = crate::layers::dense_params_view(
            self.stages
                .iter()
                .flat_map(|st| st.layers.iter())
                .map(|sl| (&sl.spec, &sl.w, &sl.b)),
        );
        if let Some(params) = dense {
            return crate::train::evaluate_params(
                self.backend.as_ref(),
                &params,
                self.cfg.model.batch,
                data,
            );
        }
        let mut net = self.network()?;
        evaluate_network(self.backend.as_ref(), &mut net, self.cfg.model.batch, data)
    }

    /// Run all stage workers concurrently over global iterations
    /// `[t0, t1)`. `xs`/`ohs` are this span's batches, borrowed from the
    /// feed arenas (empty for a drain span); `fed_total` is the total
    /// number of batches ever fed once this span completes, which bounds
    /// which backwards are due.
    #[allow(clippy::too_many_arguments)]
    fn run_span(
        backend: &Backend,
        stages: &mut [StageState],
        links: &mut [StageLinks],
        xs: &[Tensor],
        ohs: &[Tensor],
        t0: u64,
        t1: u64,
        fed_total: u64,
    ) -> Result<()> {
        let k = stages.len();
        let fwd_count = xs.len();
        debug_assert_eq!(ohs.len(), fwd_count);
        debug_assert!(t0 + fwd_count as u64 <= t1);

        let results: Vec<Result<()>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(k);
            for (s, (st, lk)) in stages.iter_mut().zip(links.iter_mut()).enumerate() {
                let backend = Arc::clone(backend);
                let sxs: &[Tensor] = if s == 0 { xs } else { &[] };
                let sohs: &[Tensor] = if s + 1 == k { ohs } else { &[] };
                handles.push(scope.spawn(move || {
                    run_stage_span(
                        backend.as_ref(),
                        k,
                        st,
                        lk,
                        sxs,
                        sohs,
                        t0,
                        t1,
                        fwd_count,
                        fed_total,
                    )
                }));
            }
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(r) => r,
                    Err(payload) => std::panic::resume_unwind(payload),
                })
                .collect()
        });
        for r in results {
            r?;
        }
        Ok(())
    }

    /// Train for the configured epochs, returning the metrics curve.
    /// Matches `Trainer::train` batch-for-batch: same rng consumption,
    /// same epoch barriers, same loss attribution (a batch's loss counts
    /// toward the epoch in which it fully retires).
    pub fn train(&mut self, data: &Splits, rng: &mut Rng) -> Result<RunCurve> {
        let mut curve = RunCurve {
            strategy: self.kind.name().to_string(),
            epochs: Vec::with_capacity(self.cfg.epochs),
        };
        // Delay of the deepest (stage-0) layers: the retirement lag.
        let d0 = self.stages[0].delay;
        for epoch in 0..self.cfg.epochs {
            let warmup = epoch < self.cfg.pipeline.warmup_epochs;
            for st in &mut self.stages {
                for sl in &mut st.layers {
                    sl.strategy.set_warmup(warmup);
                }
            }
            let sw = Stopwatch::start();
            // Bubble accounting window: snapshot before the span, diff
            // after. Capture only reads atomics — it cannot perturb the
            // numeric stream (obs never branches on measurements).
            let obs_before = obs::enabled().then(obs::TelemetrySnapshot::capture);
            // Refill the persistent feed arenas in place (`batch_into`
            // fully overwrites): past the first epoch this allocates
            // nothing but the shuffle permutation.
            let mut nb = 0usize;
            let mut iter = BatchIter::new(&data.train, self.cfg.model.batch, rng);
            while let Some(idx) = iter.next_indices() {
                if self.feed_x.len() <= nb {
                    self.feed_x.push(Tensor::empty());
                    self.feed_oh.push(Tensor::empty());
                }
                data.train.batch_into(idx, &mut self.feed_x[nb], &mut self.feed_oh[nb]);
                nb += 1;
            }
            let t0 = self.step;
            let t1 = t0 + nb as u64;
            Self::run_span(
                &self.backend,
                &mut self.stages,
                &mut self.links,
                &self.feed_x[..nb],
                &self.feed_oh[..nb],
                t0,
                t1,
                t1,
            )
            .with_context(|| format!("executor epoch {epoch}"))?;
            self.step = t1;

            // Losses of batches that fully retired this epoch: batch tb
            // retires when its stage-0 backward lands at iteration tb+d0.
            let mut epoch_losses = Vec::new();
            let last = self.stages.last_mut().expect("at least one stage");
            while let Some(&(tb, loss)) = last.losses.front() {
                if tb + d0 < t1 {
                    epoch_losses.push(loss);
                    last.losses.pop_front();
                } else {
                    break;
                }
            }
            let train_loss = if epoch_losses.is_empty() {
                f32::NAN
            } else {
                epoch_losses.iter().sum::<f32>() / epoch_losses.len() as f32
            };
            let test_accuracy = self.evaluate(data)?;
            let m = EpochMetrics {
                epoch,
                train_loss,
                test_accuracy,
                lr: self.report_lr.peek(self.step),
                staleness_bytes: self.staleness_bytes(),
                activation_bytes: self.peak_activation_bytes(),
                seconds: sw.elapsed_secs(),
            };
            crate::log_info!(
                "[{}/threaded] epoch {epoch}: loss {:.4} acc {:.4} ({}s)",
                self.kind.name(),
                m.train_loss,
                m.test_accuracy,
                format!("{:.2}", m.seconds)
            );
            if let Some(before) = obs_before {
                let window = obs::TelemetrySnapshot::capture().diff(&before);
                for b in self.bubble_report(&window) {
                    crate::log_info!(
                        "[stats] stage {}: wall {} compute {} ({:.0}% vs {:.0}% predicted) \
                         recv {} send {} bubble {:.1}%",
                        b.stage,
                        crate::util::timer::fmt_duration(b.wall_ns as f64 / 1e9),
                        crate::util::timer::fmt_duration(b.compute_ns as f64 / 1e9),
                        b.measured_share * 100.0,
                        b.predicted_share * 100.0,
                        crate::util::timer::fmt_duration(b.recv_ns as f64 / 1e9),
                        crate::util::timer::fmt_duration(b.send_ns as f64 / 1e9),
                        b.bubble_fraction * 100.0
                    );
                }
            }
            curve.epochs.push(m);
        }
        // Final drain: retire the pipeline tail (no new batches).
        let t_end = self.step;
        let d_max = self.partition.max_delay() as u64;
        if d_max > 0 {
            Self::run_span(
                &self.backend,
                &mut self.stages,
                &mut self.links,
                &[],
                &[],
                t_end,
                t_end + d_max,
                t_end,
            )
            .context("executor drain")?;
        }
        self.step = t_end + d_max;
        Ok(curve)
    }
}

/// One stage worker's span, with fail-fast teardown: if the span loop
/// errors, this stage's channel endpoints are dropped so neighbors
/// blocked in `recv()`/`send()` see a disconnect and unwind too —
/// otherwise a single failing stage would deadlock the scope join.
#[allow(clippy::too_many_arguments)]
fn run_stage_span(
    backend: &dyn Exec,
    stages: usize,
    st: &mut StageState,
    links: &mut StageLinks,
    xs: &[Tensor],
    ohs: &[Tensor],
    t0: u64,
    t1: u64,
    fwd_count: usize,
    fed_total: u64,
) -> Result<()> {
    // Reborrow st/links inside the closure (rather than moving the &mut
    // bindings) so they stay usable for the teardown below.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        stage_span_loop(backend, stages, &mut *st, &mut *links, xs, ohs, t0, t1, fwd_count, fed_total)
    }));
    let ok = matches!(result, Ok(Ok(())));
    if !ok {
        // Unblock neighbors: dropping our endpoints disconnects their
        // recv()/send(), cascading the shutdown instead of deadlocking.
        // The stage state may be mid-iteration here, which is fine —
        // the error/panic aborts the whole training run.
        links.act_in = None;
        links.act_out = None;
        links.grad_in = None;
        links.grad_out = None;
    }
    match result {
        Ok(r) => r,
        Err(payload) => std::panic::resume_unwind(payload),
    }
}

/// The per-iteration body of a stage worker: for each global iteration
/// `t` in `[t0, t1)`, run the forward of batch `t` (when one exists) and
/// then the delayed backward of batch `t − d_s` (when due) — the exact
/// per-stage event order of the iteration-indexed oracle.
#[allow(clippy::too_many_arguments)]
fn stage_span_loop(
    backend: &dyn Exec,
    stages: usize,
    st: &mut StageState,
    links: &mut StageLinks,
    xs: &[Tensor],
    ohs: &[Tensor],
    t0: u64,
    t1: u64,
    fwd_count: usize,
    fed_total: u64,
) -> Result<()> {
    let s = st.stage;
    let last = st.is_last(stages);
    let fwd_end = t0 + fwd_count as u64;

    // Telemetry (DESIGN.md §12): spans aggregate by logical thread name,
    // so the per-epoch respawned worker keeps accumulating into the same
    // `stage{s}` slot. The outer span is the stage's wall clock for this
    // span; the inner labels partition it into compute
    // (fwd/bwd/ema/opt), channel-blocked (recv/send per direction), and
    // the unlabelled remainder — the bubble report reads the diff.
    // Instrumentation only reads clocks; the f32 stream is untouched.
    if crate::obs::enabled() {
        crate::obs::set_thread_name(&format!("stage{s}"));
    }
    crate::obs::span!("pipeline/stage");

    for t in t0..t1 {
        // ---- forward lane -------------------------------------------
        if t < fwd_end {
            let h_in = match &links.act_in {
                Some(rx) => {
                    let recvd = {
                        crate::obs::span!("pipeline/recv_act");
                        rx.recv()
                    };
                    let (tin, h) = recvd
                        .map_err(|_| anyhow!("stage {s}: upstream closed before act {t}"))?;
                    debug_assert_eq!(tin, t, "activation arrived out of order");
                    h
                }
                // Feeder stage: pooled copy of the arena batch (the
                // arena persists across epochs, the copy retires into
                // the stage pool with the rest of the chain).
                None => st.pool.take_copy(&xs[(t - t0) as usize]),
            };
            // Recycled chain Vec + pooled outputs: steady-state forwards
            // allocate nothing (hot-path memory discipline).
            let mut acts = st.spare_chains.pop().unwrap_or_default();
            debug_assert!(acts.is_empty());
            acts.reserve(st.layers.len() + 1);
            acts.push(h_in);
            {
                crate::obs::span!("pipeline/fwd");
                for sl in st.layers.iter_mut() {
                    sl.strategy.on_forward(t, &sl.w);
                    let rows = acts.last().expect("chain nonempty").shape()[0];
                    let mut y = st.pool.take_dtype(&[rows, sl.op.out_dim()], st.dtype);
                    if st.dtype == Dtype::F32 {
                        sl.op.forward_into(
                            backend,
                            acts.last().expect("chain nonempty"),
                            &sl.w,
                            &sl.b,
                            &mut y,
                        )?;
                    } else {
                        // bf16 lane: f32 accumulation in the staging buffer,
                        // one quantization into the stashed activation —
                        // identical to the oracle trainer's forward lane.
                        sl.op.forward_into(
                            backend,
                            acts.last().expect("chain nonempty"),
                            &sl.w,
                            &sl.b,
                            &mut st.fwd_scratch,
                        )?;
                        y.quantize_from(&st.fwd_scratch);
                    }
                    acts.push(y);
                }
            }
            st.saved_bytes += acts.iter().map(Tensor::nbytes).sum::<usize>();
            st.peak_saved_bytes = st.peak_saved_bytes.max(st.saved_bytes);
            if let Some(tx) = &links.act_out {
                // The stash keeps the original; downstream gets a pooled
                // copy (one copy per stage boundary, not per layer).
                let out = st.pool.take_copy(acts.last().expect("chain nonempty"));
                let sent = {
                    crate::obs::span!("pipeline/send_act");
                    tx.send((t, out))
                };
                sent.map_err(|_| anyhow!("stage {s}: downstream closed at act {t}"))?;
            }
            st.saved.push_back((t, acts));
        }

        // ---- backward lane ------------------------------------------
        if t < st.delay || t - st.delay >= fed_total {
            continue;
        }
        let tb = t - st.delay;
        let mut dy = if last {
            let (_, chain) = st.saved.front().expect("logits saved for loss");
            let logits = chain.last().expect("output layer activation");
            // Last stage has delay 0 ⇒ tb ∈ [t0, fwd_end): the arena
            // one-hot row is borrowed in place, never copied.
            let onehot = &ohs[(tb - t0) as usize];
            let mut dl = st.pool.take(logits.shape());
            let (loss, _correct) = {
                crate::obs::span!("pipeline/bwd");
                backend.loss_grad_into(logits, onehot, &mut dl)?
            };
            st.losses.push_back((tb, loss));
            dl
        } else {
            let recvd = {
                crate::obs::span!("pipeline/recv_grad");
                links
                    .grad_in
                    .as_ref()
                    .expect("inner stage has a gradient input")
                    .recv()
            };
            let (tg, g) =
                recvd.map_err(|_| anyhow!("stage {s}: downstream closed before grad {tb}"))?;
            debug_assert_eq!(tg, tb, "gradient arrived out of order");
            g
        };
        let (tb2, mut acts) = st.saved.pop_front().expect("stashed activations for backward");
        debug_assert_eq!(tb2, tb, "activation stash out of order");
        st.saved_bytes -= acts.iter().map(Tensor::nbytes).sum::<usize>();
        // Every layer of the stage shares the delay, so the Eq. 9 lr sum
        // (spanning only iterations where the layer actually updated —
        // updates start at iteration d_s) and the step lr are uniform.
        let lr_sum = st.lr.lr_sum(tb.max(st.delay), t);
        let lr = st.lr.lr(t);
        // Layers top-down, exactly as the oracle's backward chain. Each
        // layer's output is popped off the chain (its last consumer);
        // spent gradients and outputs retire into the stage pool.
        for sl in st.layers.iter_mut().rev() {
            let y = acts.pop().expect("layer output present");
            let mut dx = st.pool.take(acts.last().expect("layer input present").shape());
            let StageLayer { op, w, b, strategy, opt_w, opt_b, dw_buf, db_buf, master_w, .. } = sl;
            // The span guard borrows nothing, so the reconstructed
            // weight reference flows out of the timed block freely.
            let w_bwd = {
                crate::obs::span!("pipeline/ema");
                strategy.backward_weights(tb, w, lr_sum)
            };
            {
                crate::obs::span!("pipeline/bwd");
                op.backward_into(
                    backend,
                    acts.last().expect("layer input present"),
                    &y,
                    w_bwd,
                    &dy,
                    &mut st.scratch,
                    &mut dx,
                    dw_buf,
                    db_buf,
                )?;
            }
            {
                crate::obs::span!("pipeline/opt");
                match master_w {
                    Some(master) => {
                        // Mixed precision: step the f32 master, re-quantize
                        // the storage weights from it (one rounding per
                        // step, no compounding), feed the EMA the update.
                        opt_w.step(master, dw_buf, lr);
                        w.quantize_from(&*master);
                        strategy.on_update(opt_w.velocity());
                    }
                    None => {
                        let upd_w = opt_w.step(w, dw_buf, lr);
                        strategy.on_update(upd_w);
                    }
                }
                opt_b.step(b, db_buf, lr);
            }
            st.pool.recycle(y);
            let spent = std::mem::replace(&mut dy, dx);
            st.pool.recycle(spent);
        }
        if let Some(tx) = &links.grad_out {
            let sent = {
                crate::obs::span!("pipeline/send_grad");
                tx.send((tb, dy))
            };
            sent.map_err(|_| anyhow!("stage {s}: upstream closed at grad {tb}"))?;
        } else {
            st.pool.recycle(dy);
        }
        // The remaining chain entry is the stage input — pooled here
        // whether it arrived from upstream or was copied off the feed
        // arena, so it always retires into the stage pool.
        for a in acts.drain(..) {
            st.pool.recycle(a);
        }
        st.spare_chains.push(acts);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::HostBackend;
    use crate::config::DataConfig;
    use crate::data::teacher_dataset;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.model.batch = 8;
        cfg.model.input_dim = 12;
        cfg.model.hidden_dim = 10;
        cfg.model.classes = 4;
        cfg.model.layers = 4;
        cfg.pipeline.stages = 4;
        cfg.epochs = 2;
        cfg.data = DataConfig {
            train_samples: 64,
            test_samples: 32,
            teacher_hidden: 8,
            label_noise: 0.0,
            seed: 3,
        };
        cfg
    }

    fn backend() -> Backend {
        Arc::new(HostBackend::new())
    }

    #[test]
    fn executor_construction_matches_partition() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let ex = PipelinedTrainer::new(backend(), &cfg, StrategyKind::Stashing, &mut rng).unwrap();
        assert_eq!(ex.gradient_delays(), vec![6, 4, 2, 0]);
        assert_eq!(ex.num_layers(), 4);
        assert_eq!(ex.network().unwrap().num_layers(), 4);
        let seq =
            PipelinedTrainer::new(backend(), &cfg, StrategyKind::Sequential, &mut Rng::new(1))
                .unwrap();
        assert_eq!(seq.gradient_delays(), vec![0; 4]);
    }

    #[test]
    fn executor_trains_and_learns_on_host_backend() {
        let cfg = tiny_cfg();
        let data = teacher_dataset(&cfg.model, &cfg.data);
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::new(backend(), &cfg, StrategyKind::Stashing, &mut rng).unwrap();
        let mut batch_rng = Rng::new(5);
        let curve = ex.train(&data, &mut batch_rng).unwrap();
        assert_eq!(curve.epochs.len(), cfg.epochs);
        // After the drain, every stash is empty and all losses attributed
        // or queued for the dropped tail.
        for st in &ex.stages {
            assert!(st.saved.is_empty(), "stage {} stash not drained", st.stage);
        }
        assert!(curve.final_accuracy() > 0.0);
    }

    #[test]
    fn executor_steady_state_is_pool_served() {
        // The zero-allocation discipline, asserted for the *threaded*
        // executor: after a few epochs, buffer-pool hits (recycled
        // storage, no allocation) must dominate misses (fresh
        // allocations, which only happen while the stage pools warm up
        // during pipeline fill).
        let mut cfg = tiny_cfg();
        cfg.epochs = 4;
        let data = teacher_dataset(&cfg.model, &cfg.data);
        let mut rng = Rng::new(cfg.seed);
        let mut ex =
            PipelinedTrainer::new(backend(), &cfg, StrategyKind::PipelineAwareEma, &mut rng)
                .unwrap();
        let mut batch_rng = Rng::new(5);
        ex.train(&data, &mut batch_rng).unwrap();
        let (hits, misses) = ex.pool_stats();
        assert!(hits > 0, "stage pools never served a take");
        assert!(
            hits >= 3 * misses,
            "stage pools not steady: {hits} hits vs {misses} misses"
        );
    }

    #[test]
    fn bubble_report_shares_follow_layer_costs() {
        // Cost-model plumbing only — the live span path is exercised by
        // tests/obs_determinism.rs (the obs gate is process-global, so
        // lib unit tests leave it alone). An empty window yields zeroed
        // durations; predicted shares still reflect the partition.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let ex = PipelinedTrainer::new(backend(), &cfg, StrategyKind::Stashing, &mut rng).unwrap();
        let snap = obs::TelemetrySnapshot::capture();
        let report = ex.bubble_report(&snap.diff(&snap));
        assert_eq!(report.len(), cfg.pipeline.stages);
        let predicted: f64 = report.iter().map(|b| b.predicted_share).sum();
        assert!((predicted - 1.0).abs() < 1e-9, "shares must sum to 1, got {predicted}");
        for b in &report {
            assert_eq!(b.wall_ns, 0, "empty window must carry no wall time");
            assert_eq!(b.compute_ns + b.recv_ns + b.send_ns + b.other_ns, b.wall_ns);
            assert!(b.predicted_share > 0.0, "every stage owns some compute");
        }
    }

    #[test]
    fn executor_is_deterministic() {
        let cfg = tiny_cfg();
        let data = teacher_dataset(&cfg.model, &cfg.data);
        let run = || {
            let mut rng = Rng::new(cfg.seed);
            let mut ex =
                PipelinedTrainer::new(backend(), &cfg, StrategyKind::PipelineAwareEma, &mut rng)
                    .unwrap();
            let mut batch_rng = Rng::new(5);
            ex.train(&data, &mut batch_rng).unwrap()
        };
        let (a, b) = (run(), run());
        for (ea, eb) in a.epochs.iter().zip(&b.epochs) {
            assert!(
                ea.train_loss == eb.train_loss
                    || (ea.train_loss.is_nan() && eb.train_loss.is_nan())
            );
            assert_eq!(ea.test_accuracy, eb.test_accuracy);
        }
    }
}
