//! Threaded pipeline runtime: real concurrent stage execution.
//!
//! The [`crate::train::Trainer`] runs the pipeline's *semantics*
//! (delayed gradients) single-threaded for deterministic Fig. 5 curves;
//! this module runs the pipeline *physically*: one OS thread per stage,
//! activations flowing through bounded channels, each stage executing
//! its layers' forward artifacts through the shared PJRT engine. It
//! measures the throughput side of LayerPipe — speedup and utilization
//! versus sequential execution — on real XLA compute rather than the
//! abstract cost model of [`crate::schedule`].
//!
//! tokio is unavailable offline; `std::thread` + `mpsc::sync_channel`
//! provide the same bounded-queue backpressure structure.

use crate::model::Mlp;
use crate::retiming::StagePartition;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::Stopwatch;
use anyhow::{Context, Result};
use std::sync::mpsc;
use std::sync::Arc;

/// Throughput measurement of one run.
#[derive(Clone, Debug)]
pub struct ThroughputReport {
    pub stages: usize,
    pub batches: usize,
    pub seconds: f64,
    pub batches_per_sec: f64,
}

/// Run `batches` forward passes through a `stages`-stage pipeline — one
/// OS thread per stage, pre-built inputs cycled through the feeder —
/// returning the measured throughput.
///
/// `depth` bounds each inter-stage queue (backpressure): the number of
/// in-flight batches ≈ `stages · depth`, mirroring the activation-stash
/// budget of the schedule model.
pub fn forward_throughput(
    engine: &Arc<Engine>,
    mlp: &Mlp,
    partition: &StagePartition,
    inputs: Vec<Tensor>,
    batches: usize,
    depth: usize,
) -> Result<ThroughputReport> {
    let k = partition.stages();
    assert!(k >= 1 && depth >= 1 && batches >= 1 && !inputs.is_empty());

    let sw = Stopwatch::start();
    let mut txs = Vec::with_capacity(k + 1);
    let mut rxs = Vec::with_capacity(k + 1);
    for _ in 0..=k {
        let (tx, rx) = mpsc::sync_channel::<Tensor>(depth);
        txs.push(tx);
        rxs.push(rx);
    }
    let mut rx_iter = rxs.into_iter();
    let mut handles = Vec::with_capacity(k);
    for s in 0..k {
        let rx = rx_iter.next().expect("stage rx");
        let tx = txs[s + 1].clone();
        let engine = Arc::clone(engine);
        let params: Vec<(Tensor, Tensor, crate::model::LayerRole)> = partition
            .layers_in_stage(s)
            .iter()
            .map(|&l| (mlp.layers[l].w.clone(), mlp.layers[l].b.clone(), mlp.layers[l].role))
            .collect();
        handles.push(std::thread::spawn(move || -> Result<usize> {
            let mut count = 0usize;
            while let Ok(mut h) = rx.recv() {
                for (w, b, role) in &params {
                    let out = engine
                        .run(role.fwd_artifact(), &[&h, w, b])
                        .context("stage forward")?;
                    h = out.into_iter().next().expect("activation");
                }
                count += 1;
                if tx.send(h).is_err() {
                    break;
                }
            }
            Ok(count)
        }));
    }
    let feeder = txs.remove(0);
    drop(txs);
    let collector = rx_iter.next().expect("collector rx");

    let feed = std::thread::spawn(move || {
        for i in 0..batches {
            let x = inputs[i % inputs.len()].clone();
            if feeder.send(x).is_err() {
                break;
            }
        }
    });

    let mut received = 0usize;
    while received < batches {
        collector
            .recv()
            .map_err(|_| anyhow::anyhow!("pipeline closed early at {received}/{batches}"))?;
        received += 1;
    }
    drop(collector);
    feed.join().expect("feeder join");
    for h in handles {
        let processed = h.join().expect("stage join")?;
        debug_assert!(processed >= batches);
    }
    let seconds = sw.elapsed_secs();
    Ok(ThroughputReport {
        stages: k,
        batches,
        seconds,
        batches_per_sec: batches as f64 / seconds,
    })
}

/// Sequential reference: the same `batches` forwards on one thread.
pub fn forward_sequential(
    engine: &Arc<Engine>,
    mlp: &Mlp,
    inputs: &[Tensor],
    batches: usize,
) -> Result<ThroughputReport> {
    let sw = Stopwatch::start();
    for i in 0..batches {
        let mut h = inputs[i % inputs.len()].clone();
        for l in 0..mlp.num_layers() {
            h = mlp.forward_layer(engine, l, &h)?;
        }
    }
    let seconds = sw.elapsed_secs();
    Ok(ThroughputReport { stages: 1, batches, seconds, batches_per_sec: batches as f64 / seconds })
}
