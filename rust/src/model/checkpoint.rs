//! Checkpointing: save/restore model parameters deterministically.
//!
//! Own binary format (serde is unavailable offline): a small header,
//! then per-layer `(tag, shape, f32 data)` records, little-endian, with
//! a trailing FNV-1a checksum so truncated/corrupted files are rejected
//! rather than silently loaded.
//!
//! Two record formats share the container: version 1 is the seed's
//! dense-MLP layout (role tags), version 2 covers heterogeneous
//! [`Network`]s (per-op `checkpoint_tag` + zero-length params for
//! parameter-free layers). Both restore only into an
//! architecture-matching model, so a checkpoint can never silently
//! reshape a network.

use super::{LayerRole, Mlp};
use crate::layers::Network;
use crate::tensor::Tensor;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"LPIPE2CK";
const VERSION: u32 = 1;
const NET_VERSION: u32 = 2;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "checkpoint truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

fn role_tag(role: LayerRole) -> u32 {
    match role {
        LayerRole::Input => 0,
        LayerRole::Hidden => 1,
        LayerRole::Output => 2,
    }
}

fn tag_role(tag: u32) -> Result<LayerRole> {
    Ok(match tag {
        0 => LayerRole::Input,
        1 => LayerRole::Hidden,
        2 => LayerRole::Output,
        other => bail!("unknown layer role tag {other}"),
    })
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_u32(out, t.ndim() as u32);
    for &d in t.shape() {
        put_u64(out, d as u64);
    }
    for &v in t.data() {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn read_tensor(r: &mut Reader<'_>) -> Result<Tensor> {
    let ndim = r.u32()? as usize;
    ensure!(ndim <= 8, "implausible tensor rank {ndim}");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u64()? as usize);
    }
    let n: usize = shape.iter().product();
    ensure!(n <= 1 << 28, "implausible tensor size {n}");
    let raw = r.take(4 * n)?;
    let data = raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
        .collect();
    Ok(Tensor::from_vec(&shape, data))
}

/// Serialize the model parameters.
pub fn to_bytes(mlp: &Mlp) -> Vec<u8> {
    let mut out = Vec::with_capacity(mlp.nbytes() + 256);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, mlp.layers.len() as u32);
    for lp in &mlp.layers {
        put_u32(&mut out, role_tag(lp.role));
        put_tensor(&mut out, &lp.w);
        put_tensor(&mut out, &lp.b);
    }
    let sum = fnv1a(&out);
    put_u64(&mut out, sum);
    out
}

/// Restore parameters into an existing (architecture-matching) model.
pub fn from_bytes(mlp: &mut Mlp, bytes: &[u8]) -> Result<()> {
    ensure!(bytes.len() >= 8 + 4 + 4 + 8, "checkpoint too short");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().expect("8 bytes"));
    ensure!(fnv1a(body) == want, "checkpoint checksum mismatch (corrupted file)");

    let mut r = Reader { buf: body, pos: 0 };
    ensure!(r.take(8)? == MAGIC, "not a layerpipe2 checkpoint");
    let version = r.u32()?;
    ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let layers = r.u32()? as usize;
    ensure!(
        layers == mlp.layers.len(),
        "checkpoint has {layers} layers, model has {}",
        mlp.layers.len()
    );
    for (i, lp) in mlp.layers.iter_mut().enumerate() {
        let role = tag_role(r.u32()?)?;
        ensure!(role == lp.role, "layer {i}: role mismatch");
        let w = read_tensor(&mut r)?;
        let b = read_tensor(&mut r)?;
        ensure!(w.shape() == lp.w.shape(), "layer {i}: weight shape mismatch");
        ensure!(b.shape() == lp.b.shape(), "layer {i}: bias shape mismatch");
        lp.w = w;
        lp.b = b;
    }
    ensure!(r.pos == body.len(), "trailing bytes in checkpoint");
    Ok(())
}

/// Serialize a heterogeneous network's parameters (version-2 records:
/// per-op tag + `(w, b)`, zero-length tensors for parameter-free layers).
pub fn network_to_bytes(net: &Network) -> Vec<u8> {
    let mut out = Vec::with_capacity(net.nbytes() + 256);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, NET_VERSION);
    put_u32(&mut out, net.layers.len() as u32);
    for nl in &net.layers {
        put_u32(&mut out, nl.op.checkpoint_tag());
        put_tensor(&mut out, &nl.w);
        put_tensor(&mut out, &nl.b);
    }
    let sum = fnv1a(&out);
    put_u64(&mut out, sum);
    out
}

/// Restore parameters into an existing architecture-matching network
/// (op tags and parameter shapes must agree layer by layer).
pub fn network_from_bytes(net: &mut Network, bytes: &[u8]) -> Result<()> {
    ensure!(bytes.len() >= 8 + 4 + 4 + 8, "checkpoint too short");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().expect("8 bytes"));
    ensure!(fnv1a(body) == want, "checkpoint checksum mismatch (corrupted file)");

    let mut r = Reader { buf: body, pos: 0 };
    ensure!(r.take(8)? == MAGIC, "not a layerpipe2 checkpoint");
    let version = r.u32()?;
    ensure!(
        version == NET_VERSION,
        "checkpoint version {version} is not a network checkpoint (expected {NET_VERSION})"
    );
    let layers = r.u32()? as usize;
    ensure!(
        layers == net.layers.len(),
        "checkpoint has {layers} layers, network has {}",
        net.layers.len()
    );
    for (i, nl) in net.layers.iter_mut().enumerate() {
        let tag = r.u32()?;
        ensure!(
            tag == nl.op.checkpoint_tag(),
            "layer {i} ({}): checkpoint op tag {tag} vs model tag {}",
            nl.op.name(),
            nl.op.checkpoint_tag()
        );
        let w = read_tensor(&mut r)?;
        let b = read_tensor(&mut r)?;
        ensure!(w.shape() == nl.w.shape(), "layer {i}: weight shape mismatch");
        ensure!(b.shape() == nl.b.shape(), "layer {i}: bias shape mismatch");
        nl.w = w;
        nl.b = b;
    }
    ensure!(r.pos == body.len(), "trailing bytes in checkpoint");
    Ok(())
}

/// Save a heterogeneous network to a file.
pub fn save_network(net: &Network, path: &str) -> Result<()> {
    let bytes = network_to_bytes(net);
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Load from a file into an architecture-matching network.
pub fn load_network(net: &mut Network, path: &str) -> Result<()> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path}"))?
        .read_to_end(&mut bytes)?;
    network_from_bytes(net, &bytes)
}

/// Save to a file.
pub fn save(mlp: &Mlp, path: &str) -> Result<()> {
    let bytes = to_bytes(mlp);
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Load from a file into an architecture-matching model.
pub fn load(mlp: &mut Mlp, path: &str) -> Result<()> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path}"))?
        .read_to_end(&mut bytes)?;
    from_bytes(mlp, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::Rng;

    fn model() -> Mlp {
        let cfg = ModelConfig {
            batch: 4,
            input_dim: 8,
            hidden_dim: 6,
            classes: 3,
            layers: 3,
            init_scale: 1.0,
        };
        let mut rng = Rng::new(77);
        Mlp::init(&cfg, &mut rng)
    }

    #[test]
    fn roundtrip_is_exact() {
        let src = model();
        let bytes = to_bytes(&src);
        let mut dst = model();
        // Perturb so restore is observable.
        dst.layers[1].w.scale(0.0);
        from_bytes(&mut dst, &bytes).unwrap();
        for (a, b) in src.layers.iter().zip(&dst.layers) {
            assert_eq!(a.w, b.w);
            assert_eq!(a.b, b.b);
        }
    }

    #[test]
    fn corruption_is_detected() {
        let src = model();
        let mut bytes = to_bytes(&src);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let mut dst = model();
        let err = from_bytes(&mut dst, &bytes).err().expect("must fail");
        assert!(format!("{err:#}").contains("checksum"));
    }

    #[test]
    fn truncation_is_detected() {
        let src = model();
        let bytes = to_bytes(&src);
        let mut dst = model();
        assert!(from_bytes(&mut dst, &bytes[..bytes.len() - 9]).is_err());
        assert!(from_bytes(&mut dst, &bytes[..4]).is_err());
    }

    #[test]
    fn architecture_mismatch_is_rejected() {
        let src = model();
        let bytes = to_bytes(&src);
        let cfg = ModelConfig {
            batch: 4,
            input_dim: 8,
            hidden_dim: 6,
            classes: 3,
            layers: 4, // one more layer
            init_scale: 1.0,
        };
        let mut rng = Rng::new(1);
        let mut other = Mlp::init(&cfg, &mut rng);
        assert!(from_bytes(&mut other, &bytes).is_err());
    }

    fn hetero_net() -> Network {
        use crate::layers::{Feature, LayerSpec, NetworkSpec};
        let spec = NetworkSpec {
            input: Feature::Image { h: 4, w: 4, c: 1 },
            layers: vec![
                LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::MaxPool2d { k: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 6, relu: false },
                LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            ],
            init_scale: 1.0,
        };
        Network::build(&spec, &mut Rng::new(31)).unwrap()
    }

    #[test]
    fn network_roundtrip_is_exact() {
        let src = hetero_net();
        let bytes = network_to_bytes(&src);
        let mut dst = hetero_net();
        dst.layers[0].w.scale(0.0);
        dst.layers[3].w.scale(0.0);
        network_from_bytes(&mut dst, &bytes).unwrap();
        for (a, b) in src.layers.iter().zip(&dst.layers) {
            assert_eq!(a.w, b.w);
            assert_eq!(a.b, b.b);
        }
    }

    #[test]
    fn network_checkpoint_rejects_v1_and_vice_versa() {
        let mlp_bytes = to_bytes(&model());
        let mut net = hetero_net();
        let err = network_from_bytes(&mut net, &mlp_bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"));
        let net_bytes = network_to_bytes(&hetero_net());
        let mut mlp = model();
        assert!(from_bytes(&mut mlp, &net_bytes).is_err());
    }

    #[test]
    fn network_checkpoint_rejects_op_mismatch() {
        // Same parameter shapes, different op kind at layer 4 (LIF vs
        // flatten are both paramless) — the tag check must catch it.
        use crate::layers::{Feature, LayerSpec, NetworkSpec};
        let bytes = network_to_bytes(&hetero_net());
        let spec = NetworkSpec {
            input: Feature::Image { h: 4, w: 4, c: 1 },
            layers: vec![
                LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::MaxPool2d { k: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 6, relu: false },
                LayerSpec::Flatten,
            ],
            init_scale: 1.0,
        };
        let mut other = Network::build(&spec, &mut Rng::new(1)).unwrap();
        let err = network_from_bytes(&mut other, &bytes).unwrap_err();
        assert!(format!("{err:#}").contains("tag"));
    }

    #[test]
    fn network_file_roundtrip() {
        let src = hetero_net();
        let path = std::env::temp_dir().join(format!("lp2_net_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save_network(&src, &path).unwrap();
        let mut dst = hetero_net();
        dst.layers[3].b.data_mut()[0] = 9.0;
        load_network(&mut dst, &path).unwrap();
        assert_eq!(src.layers[3].b, dst.layers[3].b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_roundtrip() {
        let src = model();
        let path = std::env::temp_dir().join(format!("lp2_ck_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save(&src, &path).unwrap();
        let mut dst = model();
        dst.layers[0].b.data_mut()[0] = 42.0;
        load(&mut dst, &path).unwrap();
        assert_eq!(src.layers[0].b, dst.layers[0].b);
        std::fs::remove_file(&path).ok();
    }
}
