//! Checkpointing: save/restore model parameters deterministically.
//!
//! Own binary format (serde is unavailable offline): a small header,
//! then per-layer `(tag, shape, f32 data)` records, little-endian, with
//! a trailing FNV-1a checksum so truncated/corrupted files are rejected
//! rather than silently loaded.
//!
//! Three record formats share the container: version 1 is the seed's
//! dense-MLP layout (role tags), version 2 covers heterogeneous
//! [`Network`]s (per-op `checkpoint_tag` + zero-length params for
//! parameter-free layers, always f32), and version 3 adds a per-tensor
//! dtype tag ahead of each record so bf16 parameters persist in their
//! storage width (u16 payloads, half the bytes). The writer emits
//! version 2 — byte-identical to the pre-dtype format — whenever every
//! parameter is f32, and version 3 only when a bf16 tensor is present;
//! the reader accepts both, so old f32 checkpoints keep loading and old
//! readers are never handed a file they would misparse. All restore
//! only into an architecture-matching model, so a checkpoint can never
//! silently reshape a network.

use super::{LayerRole, Mlp};
use crate::layers::Network;
use crate::tensor::{Dtype, Tensor};
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

const MAGIC: &[u8; 8] = b"LPIPE2CK";
const VERSION: u32 = 1;
const NET_VERSION: u32 = 2;
const NET_VERSION_DTYPE: u32 = 3;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(self.pos + n <= self.buf.len(), "checkpoint truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
}

fn role_tag(role: LayerRole) -> u32 {
    match role {
        LayerRole::Input => 0,
        LayerRole::Hidden => 1,
        LayerRole::Output => 2,
    }
}

fn tag_role(tag: u32) -> Result<LayerRole> {
    Ok(match tag {
        0 => LayerRole::Input,
        1 => LayerRole::Hidden,
        2 => LayerRole::Output,
        other => bail!("unknown layer role tag {other}"),
    })
}

fn dtype_tag(d: Dtype) -> u32 {
    match d {
        Dtype::F32 => 0,
        Dtype::Bf16 => 1,
    }
}

fn tag_dtype(tag: u32) -> Result<Dtype> {
    Ok(match tag {
        0 => Dtype::F32,
        1 => Dtype::Bf16,
        other => bail!("unknown tensor dtype tag {other}"),
    })
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    put_u32(out, t.ndim() as u32);
    for &d in t.shape() {
        put_u64(out, d as u64);
    }
    match t.dtype() {
        Dtype::F32 => {
            for &v in t.data() {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Dtype::Bf16 => {
            for &b in t.bits() {
                out.extend_from_slice(&b.to_le_bytes());
            }
        }
    }
}

/// Version-3 record: the dtype tag leads, then the version-2 layout
/// (rank, dims, payload) with the payload in the tagged width.
fn put_tensor_tagged(out: &mut Vec<u8>, t: &Tensor) {
    put_u32(out, dtype_tag(t.dtype()));
    put_tensor(out, t);
}

fn read_tensor_dtype(r: &mut Reader<'_>, dtype: Dtype) -> Result<Tensor> {
    let ndim = r.u32()? as usize;
    ensure!(ndim <= 8, "implausible tensor rank {ndim}");
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u64()? as usize);
    }
    let n: usize = shape.iter().product();
    ensure!(n <= 1 << 28, "implausible tensor size {n}");
    match dtype {
        Dtype::F32 => {
            let raw = r.take(4 * n)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().expect("4 bytes")))
                .collect();
            Ok(Tensor::from_vec(&shape, data))
        }
        Dtype::Bf16 => {
            let raw = r.take(2 * n)?;
            let mut t = Tensor::zeros_dtype(&shape, Dtype::Bf16);
            for (o, c) in t.bits_mut().iter_mut().zip(raw.chunks_exact(2)) {
                *o = u16::from_le_bytes(c.try_into().expect("2 bytes"));
            }
            Ok(t)
        }
    }
}

fn read_tensor(r: &mut Reader<'_>) -> Result<Tensor> {
    read_tensor_dtype(r, Dtype::F32)
}

fn read_tensor_tagged(r: &mut Reader<'_>) -> Result<Tensor> {
    let dtype = tag_dtype(r.u32()?)?;
    read_tensor_dtype(r, dtype)
}

/// Serialize the model parameters.
pub fn to_bytes(mlp: &Mlp) -> Vec<u8> {
    let mut out = Vec::with_capacity(mlp.nbytes() + 256);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u32(&mut out, mlp.layers.len() as u32);
    for lp in &mlp.layers {
        put_u32(&mut out, role_tag(lp.role));
        put_tensor(&mut out, &lp.w);
        put_tensor(&mut out, &lp.b);
    }
    let sum = fnv1a(&out);
    put_u64(&mut out, sum);
    out
}

/// Restore parameters into an existing (architecture-matching) model.
pub fn from_bytes(mlp: &mut Mlp, bytes: &[u8]) -> Result<()> {
    ensure!(bytes.len() >= 8 + 4 + 4 + 8, "checkpoint too short");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().expect("8 bytes"));
    ensure!(fnv1a(body) == want, "checkpoint checksum mismatch (corrupted file)");

    let mut r = Reader { buf: body, pos: 0 };
    ensure!(r.take(8)? == MAGIC, "not a layerpipe2 checkpoint");
    let version = r.u32()?;
    ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let layers = r.u32()? as usize;
    ensure!(
        layers == mlp.layers.len(),
        "checkpoint has {layers} layers, model has {}",
        mlp.layers.len()
    );
    for (i, lp) in mlp.layers.iter_mut().enumerate() {
        let role = tag_role(r.u32()?)?;
        ensure!(role == lp.role, "layer {i}: role mismatch");
        let w = read_tensor(&mut r)?;
        let b = read_tensor(&mut r)?;
        ensure!(w.shape() == lp.w.shape(), "layer {i}: weight shape mismatch");
        ensure!(b.shape() == lp.b.shape(), "layer {i}: bias shape mismatch");
        lp.w = w;
        lp.b = b;
    }
    ensure!(r.pos == body.len(), "trailing bytes in checkpoint");
    Ok(())
}

/// Serialize a heterogeneous network's parameters (per-op tag + `(w,
/// b)` records, zero-length tensors for parameter-free layers). Emits
/// version 2 — byte-identical to the pre-dtype format — when every
/// parameter is f32, version 3 (dtype-tagged records, bf16 payloads at
/// half width) as soon as any tensor stores bf16.
pub fn network_to_bytes(net: &Network) -> Vec<u8> {
    let all_f32 = net
        .layers
        .iter()
        .all(|nl| nl.w.dtype() == Dtype::F32 && nl.b.dtype() == Dtype::F32);
    let mut out = Vec::with_capacity(net.nbytes() + 256);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, if all_f32 { NET_VERSION } else { NET_VERSION_DTYPE });
    put_u32(&mut out, net.layers.len() as u32);
    for nl in &net.layers {
        put_u32(&mut out, nl.op.checkpoint_tag());
        if all_f32 {
            put_tensor(&mut out, &nl.w);
            put_tensor(&mut out, &nl.b);
        } else {
            put_tensor_tagged(&mut out, &nl.w);
            put_tensor_tagged(&mut out, &nl.b);
        }
    }
    let sum = fnv1a(&out);
    put_u64(&mut out, sum);
    out
}

/// Restore parameters into an existing architecture-matching network
/// (op tags and parameter shapes must agree layer by layer). Accepts
/// version 2 (all-f32) and version 3 (dtype-tagged) files; restored
/// tensors carry the dtype the file recorded, so a v2 checkpoint
/// restores f32 weights even into a session that trains bf16 — the
/// kernels widen per operand, so the mixture is servable either way.
pub fn network_from_bytes(net: &mut Network, bytes: &[u8]) -> Result<()> {
    ensure!(bytes.len() >= 8 + 4 + 4 + 8, "checkpoint too short");
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let want = u64::from_le_bytes(tail.try_into().expect("8 bytes"));
    ensure!(fnv1a(body) == want, "checkpoint checksum mismatch (corrupted file)");

    let mut r = Reader { buf: body, pos: 0 };
    ensure!(r.take(8)? == MAGIC, "not a layerpipe2 checkpoint");
    let version = r.u32()?;
    ensure!(
        version == NET_VERSION || version == NET_VERSION_DTYPE,
        "checkpoint version {version} is not a network checkpoint (expected {NET_VERSION} or {NET_VERSION_DTYPE})"
    );
    let layers = r.u32()? as usize;
    ensure!(
        layers == net.layers.len(),
        "checkpoint has {layers} layers, network has {}",
        net.layers.len()
    );
    for (i, nl) in net.layers.iter_mut().enumerate() {
        let tag = r.u32()?;
        ensure!(
            tag == nl.op.checkpoint_tag(),
            "layer {i} ({}): checkpoint op tag {tag} vs model tag {}",
            nl.op.name(),
            nl.op.checkpoint_tag()
        );
        let (w, b) = if version == NET_VERSION {
            (read_tensor(&mut r)?, read_tensor(&mut r)?)
        } else {
            (read_tensor_tagged(&mut r)?, read_tensor_tagged(&mut r)?)
        };
        ensure!(w.shape() == nl.w.shape(), "layer {i}: weight shape mismatch");
        ensure!(b.shape() == nl.b.shape(), "layer {i}: bias shape mismatch");
        nl.w = w;
        nl.b = b;
    }
    ensure!(r.pos == body.len(), "trailing bytes in checkpoint");
    Ok(())
}

/// Save a heterogeneous network to a file.
pub fn save_network(net: &Network, path: &str) -> Result<()> {
    let bytes = network_to_bytes(net);
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Load from a file into an architecture-matching network.
pub fn load_network(net: &mut Network, path: &str) -> Result<()> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path}"))?
        .read_to_end(&mut bytes)?;
    network_from_bytes(net, &bytes)
}

/// Save to a file.
pub fn save(mlp: &Mlp, path: &str) -> Result<()> {
    let bytes = to_bytes(mlp);
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path}"))?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Load from a file into an architecture-matching model.
pub fn load(mlp: &mut Mlp, path: &str) -> Result<()> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {path}"))?
        .read_to_end(&mut bytes)?;
    from_bytes(mlp, &bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::util::Rng;

    fn model() -> Mlp {
        let cfg = ModelConfig {
            batch: 4,
            input_dim: 8,
            hidden_dim: 6,
            classes: 3,
            layers: 3,
            init_scale: 1.0,
        };
        let mut rng = Rng::new(77);
        Mlp::init(&cfg, &mut rng)
    }

    #[test]
    fn roundtrip_is_exact() {
        let src = model();
        let bytes = to_bytes(&src);
        let mut dst = model();
        // Perturb so restore is observable.
        dst.layers[1].w.scale(0.0);
        from_bytes(&mut dst, &bytes).unwrap();
        for (a, b) in src.layers.iter().zip(&dst.layers) {
            assert_eq!(a.w, b.w);
            assert_eq!(a.b, b.b);
        }
    }

    #[test]
    fn corruption_is_detected() {
        let src = model();
        let mut bytes = to_bytes(&src);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let mut dst = model();
        let err = from_bytes(&mut dst, &bytes).err().expect("must fail");
        assert!(format!("{err:#}").contains("checksum"));
    }

    #[test]
    fn truncation_is_detected() {
        let src = model();
        let bytes = to_bytes(&src);
        let mut dst = model();
        assert!(from_bytes(&mut dst, &bytes[..bytes.len() - 9]).is_err());
        assert!(from_bytes(&mut dst, &bytes[..4]).is_err());
    }

    #[test]
    fn architecture_mismatch_is_rejected() {
        let src = model();
        let bytes = to_bytes(&src);
        let cfg = ModelConfig {
            batch: 4,
            input_dim: 8,
            hidden_dim: 6,
            classes: 3,
            layers: 4, // one more layer
            init_scale: 1.0,
        };
        let mut rng = Rng::new(1);
        let mut other = Mlp::init(&cfg, &mut rng);
        assert!(from_bytes(&mut other, &bytes).is_err());
    }

    fn hetero_net() -> Network {
        use crate::layers::{Feature, LayerSpec, NetworkSpec};
        let spec = NetworkSpec {
            input: Feature::Image { h: 4, w: 4, c: 1 },
            layers: vec![
                LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::MaxPool2d { k: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 6, relu: false },
                LayerSpec::Lif { v_th: 0.5, alpha: 1.0 },
            ],
            init_scale: 1.0,
        };
        Network::build(&spec, &mut Rng::new(31)).unwrap()
    }

    #[test]
    fn network_roundtrip_is_exact() {
        let src = hetero_net();
        let bytes = network_to_bytes(&src);
        let mut dst = hetero_net();
        dst.layers[0].w.scale(0.0);
        dst.layers[3].w.scale(0.0);
        network_from_bytes(&mut dst, &bytes).unwrap();
        for (a, b) in src.layers.iter().zip(&dst.layers) {
            assert_eq!(a.w, b.w);
            assert_eq!(a.b, b.b);
        }
    }

    #[test]
    fn network_checkpoint_rejects_v1_and_vice_versa() {
        let mlp_bytes = to_bytes(&model());
        let mut net = hetero_net();
        let err = network_from_bytes(&mut net, &mlp_bytes).unwrap_err();
        assert!(format!("{err:#}").contains("version"));
        let net_bytes = network_to_bytes(&hetero_net());
        let mut mlp = model();
        assert!(from_bytes(&mut mlp, &net_bytes).is_err());
    }

    #[test]
    fn network_checkpoint_rejects_op_mismatch() {
        // Same parameter shapes, different op kind at layer 4 (LIF vs
        // flatten are both paramless) — the tag check must catch it.
        use crate::layers::{Feature, LayerSpec, NetworkSpec};
        let bytes = network_to_bytes(&hetero_net());
        let spec = NetworkSpec {
            input: Feature::Image { h: 4, w: 4, c: 1 },
            layers: vec![
                LayerSpec::Conv2d { out_c: 3, k: 3, stride: 1, pad: 1, relu: true },
                LayerSpec::MaxPool2d { k: 2, stride: 2 },
                LayerSpec::Flatten,
                LayerSpec::Dense { units: 6, relu: false },
                LayerSpec::Flatten,
            ],
            init_scale: 1.0,
        };
        let mut other = Network::build(&spec, &mut Rng::new(1)).unwrap();
        let err = network_from_bytes(&mut other, &bytes).unwrap_err();
        assert!(format!("{err:#}").contains("tag"));
    }

    /// The byte offset of the version field (right after the magic).
    fn version_of(bytes: &[u8]) -> u32 {
        u32::from_le_bytes(bytes[8..12].try_into().unwrap())
    }

    #[test]
    fn all_f32_network_still_writes_version_2() {
        // The pre-dtype format is the compatibility contract: a reader
        // from before the mixed-precision work must keep loading every
        // checkpoint an all-f32 session writes.
        let bytes = network_to_bytes(&hetero_net());
        assert_eq!(version_of(&bytes), NET_VERSION);
    }

    #[test]
    fn bf16_network_writes_version_3_and_roundtrips_bitwise() {
        let mut src = hetero_net();
        src.layers[3].w = src.layers[3].w.to_dtype(Dtype::Bf16);
        let bytes = network_to_bytes(&src);
        assert_eq!(version_of(&bytes), NET_VERSION_DTYPE);
        // bf16 payloads are half-width: the v3 file must be smaller
        // than the same network's all-f32 v2 image by exactly
        // 2 bytes/element minus the per-record dtype tags.
        let f32_bytes = network_to_bytes(&hetero_net());
        let tags = 4 * 2 * src.layers.len();
        assert_eq!(bytes.len() + 2 * src.layers[3].w.len(), f32_bytes.len() + tags);

        let mut dst = hetero_net();
        network_from_bytes(&mut dst, &bytes).unwrap();
        assert_eq!(dst.layers[3].w.dtype(), Dtype::Bf16);
        assert_eq!(dst.layers[3].w.bits(), src.layers[3].w.bits());
        for (a, b) in src.layers.iter().zip(&dst.layers) {
            assert_eq!(a.b, b.b, "f32 records restore bitwise through v3 too");
        }
    }

    #[test]
    fn v2_checkpoint_restores_into_bf16_session() {
        // Cross-version restore: an old all-f32 file loads into a
        // network whose weights currently store bf16 — the restored
        // tensors carry the file's dtype (f32), which every kernel
        // accepts alongside bf16 activations.
        let src = hetero_net();
        let v2 = network_to_bytes(&src);
        assert_eq!(version_of(&v2), NET_VERSION);
        let mut dst = hetero_net();
        dst.layers[3].w = dst.layers[3].w.to_dtype(Dtype::Bf16);
        network_from_bytes(&mut dst, &v2).unwrap();
        assert_eq!(dst.layers[3].w.dtype(), Dtype::F32);
        assert_eq!(dst.layers[3].w, src.layers[3].w);
    }

    #[test]
    fn v3_corruption_and_bad_dtype_tag_are_detected() {
        let mut src = hetero_net();
        src.layers[3].w = src.layers[3].w.to_dtype(Dtype::Bf16);
        let mut bytes = network_to_bytes(&src);
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        let mut dst = hetero_net();
        let err = network_from_bytes(&mut dst, &bytes).unwrap_err();
        assert!(format!("{err:#}").contains("checksum"));
    }

    #[test]
    fn network_file_roundtrip() {
        let src = hetero_net();
        let path = std::env::temp_dir().join(format!("lp2_net_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save_network(&src, &path).unwrap();
        let mut dst = hetero_net();
        dst.layers[3].b.data_mut()[0] = 9.0;
        load_network(&mut dst, &path).unwrap();
        assert_eq!(src.layers[3].b, dst.layers[3].b);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_roundtrip() {
        let src = model();
        let path = std::env::temp_dir().join(format!("lp2_ck_{}.bin", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        save(&src, &path).unwrap();
        let mut dst = model();
        dst.layers[0].b.data_mut()[0] = 42.0;
        load(&mut dst, &path).unwrap();
        assert_eq!(src.layers[0].b, dst.layers[0].b);
        std::fs::remove_file(&path).ok();
    }
}
