//! Model layer: the dense MLP whose per-layer compute runs on an
//! [`Exec`] backend (AOT artifacts under PJRT, host kernels otherwise).
//!
//! Rust owns the parameters (host tensors), their initialization, and the
//! layer→kernel mapping; the backend owns the math. One `dense_fwd_hid` /
//! `dense_bwd_hid` artifact serves every hidden layer because all hidden
//! layers share the `[H, H]` shape — the artifact set stays O(1) in depth.
//!
//! Both trainers now execute heterogeneous [`crate::layers::Network`]
//! stacks (dense/conv/pool/spiking behind the `Layer` trait); `Mlp`
//! remains the dense parameter container for the PJRT artifact surface,
//! the forward-throughput harness and the v1 checkpoint format.
//! [`crate::layers::NetworkSpec::mlp`] builds the trait-object
//! equivalent with bit-identical initialization.

pub mod checkpoint;

use crate::backend::Exec;
use crate::config::ModelConfig;
use crate::tensor::Tensor;
use crate::util::Rng;
use anyhow::Result;

/// Which artifact pair a layer dispatches to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerRole {
    /// First layer: `[B, D] → [B, H]`, ReLU.
    Input,
    /// Middle layers: `[B, H] → [B, H]`, ReLU.
    Hidden,
    /// Last layer: `[B, H] → [B, C]`, linear (logits).
    Output,
}

impl LayerRole {
    pub fn of(layer: usize, layers: usize) -> LayerRole {
        if layer == 0 {
            LayerRole::Input
        } else if layer + 1 == layers {
            LayerRole::Output
        } else {
            LayerRole::Hidden
        }
    }

    pub fn fwd_artifact(&self) -> &'static str {
        match self {
            LayerRole::Input => "dense_fwd_in",
            LayerRole::Hidden => "dense_fwd_hid",
            LayerRole::Output => "dense_fwd_out",
        }
    }

    pub fn bwd_artifact(&self) -> &'static str {
        match self {
            LayerRole::Input => "dense_bwd_in",
            LayerRole::Hidden => "dense_bwd_hid",
            LayerRole::Output => "dense_bwd_out",
        }
    }

    pub fn has_relu(&self) -> bool {
        !matches!(self, LayerRole::Output)
    }
}

/// One layer's parameters.
#[derive(Clone, Debug)]
pub struct LayerParams {
    pub w: Tensor,
    pub b: Tensor,
    pub role: LayerRole,
}

impl LayerParams {
    pub fn nbytes(&self) -> usize {
        self.w.nbytes() + self.b.nbytes()
    }
}

/// The full MLP parameter set.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<LayerParams>,
    pub cfg: ModelConfig,
}

impl Mlp {
    /// He-initialized parameters (ReLU network), biases at zero.
    pub fn init(cfg: &ModelConfig, rng: &mut Rng) -> Mlp {
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let role = LayerRole::of(l, cfg.layers);
            let (din, dout) = layer_dims(cfg, l);
            let std = cfg.init_scale * (2.0 / din as f32).sqrt();
            layers.push(LayerParams {
                w: Tensor::randn(&[din, dout], std, rng),
                b: Tensor::zeros(&[dout]),
                role,
            });
        }
        Mlp { layers, cfg: cfg.clone() }
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Total parameter bytes (memory accounting baseline).
    pub fn nbytes(&self) -> usize {
        self.layers.iter().map(LayerParams::nbytes).sum()
    }

    /// Total scalar parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len() + l.b.len()).sum()
    }

    /// Forward one layer through the backend. Returns the activation.
    pub fn forward_layer(&self, exec: &dyn Exec, l: usize, x: &Tensor) -> Result<Tensor> {
        self.forward_layer_with(exec, l, x, &self.layers[l].w, &self.layers[l].b)
    }

    /// Forward one layer with an explicit weight version (strategies may
    /// substitute stashed/reconstructed weights).
    pub fn forward_layer_with(
        &self,
        exec: &dyn Exec,
        l: usize,
        x: &Tensor,
        w: &Tensor,
        b: &Tensor,
    ) -> Result<Tensor> {
        exec.forward(self.layers[l].role, x, w, b)
    }

    /// Forward one layer into a caller-owned output buffer (the
    /// backend's `_into` path — zero allocation with recycled buffers).
    pub fn forward_layer_into(
        &self,
        exec: &dyn Exec,
        l: usize,
        x: &Tensor,
        out: &mut Tensor,
    ) -> Result<()> {
        let lp = &self.layers[l];
        exec.forward_into(lp.role, x, &lp.w, &lp.b, out)
    }

    /// Full-network forward (eval path): one fused dispatch on backends
    /// that support it, a layer chain otherwise.
    pub fn forward_full(&self, exec: &dyn Exec, x: &Tensor) -> Result<Tensor> {
        exec.forward_full(x, &self.layers)
    }
}

/// `(din, dout)` of layer `l` under a config.
pub fn layer_dims(cfg: &ModelConfig, l: usize) -> (usize, usize) {
    let din = if l == 0 { cfg.input_dim } else { cfg.hidden_dim };
    let dout = if l + 1 == cfg.layers { cfg.classes } else { cfg.hidden_dim };
    (din, dout)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig { batch: 4, input_dim: 8, hidden_dim: 8, classes: 4, layers: 3, init_scale: 1.0 }
    }

    #[test]
    fn roles_and_artifacts() {
        assert_eq!(LayerRole::of(0, 3), LayerRole::Input);
        assert_eq!(LayerRole::of(1, 3), LayerRole::Hidden);
        assert_eq!(LayerRole::of(2, 3), LayerRole::Output);
        assert_eq!(LayerRole::Input.fwd_artifact(), "dense_fwd_in");
        assert_eq!(LayerRole::Output.bwd_artifact(), "dense_bwd_out");
        assert!(LayerRole::Hidden.has_relu());
        assert!(!LayerRole::Output.has_relu());
    }

    #[test]
    fn two_layer_net_has_no_hidden() {
        assert_eq!(LayerRole::of(0, 2), LayerRole::Input);
        assert_eq!(LayerRole::of(1, 2), LayerRole::Output);
    }

    #[test]
    fn init_shapes_and_counts() {
        let mut rng = Rng::new(1);
        let m = Mlp::init(&cfg(), &mut rng);
        assert_eq!(m.num_layers(), 3);
        assert_eq!(m.layers[0].w.shape(), &[8, 8]);
        assert_eq!(m.layers[2].w.shape(), &[8, 4]);
        assert_eq!(m.layers[2].b.shape(), &[4]);
        assert_eq!(m.num_params(), 8 * 8 + 8 + 8 * 8 + 8 + 8 * 4 + 4);
        assert_eq!(m.nbytes(), m.num_params() * 4);
    }

    #[test]
    fn he_init_scale() {
        let mut rng = Rng::new(2);
        let c = ModelConfig { input_dim: 512, hidden_dim: 512, ..cfg() };
        let m = Mlp::init(&c, &mut rng);
        let w = &m.layers[0].w;
        let var: f32 =
            w.data().iter().map(|x| x * x).sum::<f32>() / w.len() as f32;
        let expect = 2.0 / 512.0;
        assert!((var - expect).abs() < 0.2 * expect, "var {var} vs {expect}");
    }

    #[test]
    fn layer_dims_table() {
        let c = cfg();
        assert_eq!(layer_dims(&c, 0), (8, 8));
        assert_eq!(layer_dims(&c, 1), (8, 8));
        assert_eq!(layer_dims(&c, 2), (8, 4));
    }
}
