//! Gradient averaging for weight recompute (paper §III-D, Eqs. 4–9).
//!
//! Pipelined execution needs the historical weight `W(t−d)` when a delayed
//! gradient arrives. Instead of stashing `d` weight versions, the paper
//! reconstructs it from the current weight plus an estimate of the
//! intervening updates (Eq. 3):
//!
//! ```text
//! W(t−d) = W(t) + Σ_{i<d} lr(t−i)·U(t−i)          (exact)
//!        ≈ W(t) + lr·d·Ḡ                           (averaged)
//! ```
//!
//! Three averagers implement the `Ḡ` estimate:
//!
//! - [`ExactWindow`] — ring buffer of the last `d` applied updates; makes
//!   Eq. 3 an identity. O(d) memory; used as the ground-truth oracle in
//!   tests and as an ablation point.
//! - [`PipelineAwareEma`] — the paper's proposal: the incremental-mean
//!   recurrence `Ḡ(k) = k/(k+1)·Ḡ(k−1) + 1/(k+1)·G(k)` (Eq. 7) whose decay
//!   `β(k) = k/(k+1)` (Eq. 8) is *matched to the layer's own delay*: the
//!   window ramps exactly like a cumulative mean until it spans `d`
//!   samples, then holds `β = d/(d+1)`. O(1) memory.
//! - [`FixedEma`] — conventional EMA with delay-independent `β` (the
//!   paper's fixed-decay baseline, `β = 0.9`).

use crate::tensor::{Dtype, Tensor};

/// Online estimator of the average recent update/gradient for one tensor.
pub trait GradientAverager: Send {
    /// Feed the applied update of one optimizer step.
    fn push(&mut self, update: &Tensor);

    /// Current estimate `Ḡ` of the mean update over the target window.
    /// `None` until at least one sample has been pushed.
    fn mean(&self) -> Option<&Tensor>;

    /// Number of samples pushed so far.
    fn count(&self) -> usize;

    /// Bytes of estimator state (memory-footprint experiment).
    fn state_nbytes(&self) -> usize;

    /// Reconstruct `Ŵ(t−d) = W(t) + lr_sum·Ḡ` where `lr_sum` is the sum of
    /// learning rates over the delay window (`lr·d` for constant lr —
    /// paper Eq. 9 with `lr_sum = α(2n+1)`). Returns a copy of `current`
    /// when no samples exist yet (warm-up behaviour).
    fn reconstruct(&self, current: &Tensor, lr_sum: f32) -> Tensor {
        let mut w = Tensor::empty();
        self.reconstruct_into(current, lr_sum, &mut w);
        w
    }

    /// [`GradientAverager::reconstruct`] without the allocation: copy +
    /// axpy into a caller-owned buffer (the per-layer reconstruction
    /// workspace of `strategy::LayerStrategy` on the hot path).
    ///
    /// The output is always f32: bf16 `current`/`Ḡ` are widened and the
    /// axpy accumulates at full precision, so the reconstructed weights
    /// feed the backward matmuls without a second rounding. For f32
    /// inputs `widen_from` is a bitwise copy — the historical behaviour.
    fn reconstruct_into(&self, current: &Tensor, lr_sum: f32, out: &mut Tensor) {
        out.widen_from(current);
        if let Some(g) = self.mean() {
            out.axpy(lr_sum, g);
        }
    }
}

/// Exact sliding-window mean via a ring buffer of the last `window`
/// updates. Makes the Eq. 3 reconstruction exact (up to fp rounding).
#[derive(Clone, Debug)]
pub struct ExactWindow {
    window: usize,
    buf: Vec<Tensor>,
    next: usize,
    count: usize,
    mean: Option<Tensor>,
}

impl ExactWindow {
    pub fn new(window: usize) -> Self {
        assert!(window > 0, "window must be positive");
        ExactWindow { window, buf: Vec::new(), next: 0, count: 0, mean: None }
    }

    /// Sum (not mean) over the stored window — what Eq. 3 needs directly.
    pub fn window_sum(&self) -> Option<Tensor> {
        self.mean.as_ref().map(|m| {
            let mut s = m.clone();
            s.scale(self.count.min(self.window) as f32);
            s
        })
    }
}

impl GradientAverager for ExactWindow {
    fn push(&mut self, update: &Tensor) {
        // Ring slots reuse their allocations once the window has filled
        // (copy into the evicted slot, never a fresh clone), and the mean
        // accumulator is recomputed in place — steady-state pushes are
        // copy + axpy only (hot-path memory discipline).
        if self.buf.len() < self.window {
            self.buf.push(update.clone());
        } else {
            self.buf[self.next].copy_from(update);
        }
        self.next = (self.next + 1) % self.window;
        self.count += 1;
        // Recompute the mean from the buffer (O(window·n)); exactness over
        // speed — the O(1)-memory EMA is the production path.
        let k = self.buf.len();
        let mean = self.mean.get_or_insert_with(Tensor::empty);
        mean.resize(update.shape());
        mean.fill(0.0);
        for t in &self.buf {
            mean.axpy(1.0 / k as f32, t);
        }
    }

    fn mean(&self) -> Option<&Tensor> {
        self.mean.as_ref()
    }

    fn count(&self) -> usize {
        self.count
    }

    fn state_nbytes(&self) -> usize {
        self.buf.iter().map(Tensor::nbytes).sum::<usize>()
            + self.mean.as_ref().map_or(0, Tensor::nbytes)
    }
}

/// The paper's pipeline-aware EMA (Eqs. 7–8): cumulative-mean ramp to the
/// delay-matched window, then fixed `β = d/(d+1)`.
#[derive(Clone, Debug)]
pub struct PipelineAwareEma {
    /// Target window length == the layer's gradient delay `d` (+1 samples).
    window: usize,
    mean: Option<Tensor>,
    count: usize,
    /// Storage dtype of the accumulator (`Ḡ` history halves to bf16 in
    /// mixed-precision runs; arithmetic still widens to f32 per element).
    dtype: Dtype,
}

impl PipelineAwareEma {
    pub fn new(window: usize) -> Self {
        PipelineAwareEma::new_with_dtype(window, Dtype::F32)
    }

    /// [`PipelineAwareEma::new`] with the accumulator stored in `dtype`
    /// (DESIGN.md §11: bf16 history, f32 reconstruction arithmetic).
    pub fn new_with_dtype(window: usize, dtype: Dtype) -> Self {
        assert!(window > 0, "window must be positive");
        PipelineAwareEma { window, mean: None, count: 0, dtype }
    }

    /// The delay-conditioned decay currently in effect (Eq. 8).
    pub fn beta(&self) -> f32 {
        let k = self.count.min(self.window);
        k as f32 / (k as f32 + 1.0)
    }

    pub fn window(&self) -> usize {
        self.window
    }
}

impl GradientAverager for PipelineAwareEma {
    fn push(&mut self, update: &Tensor) {
        // β(k) = k/(k+1) with k capped at the delay-matched window,
        // i.e. exact cumulative mean while k < window (Eq. 7), then
        // a fixed-β EMA whose effective window stays `window+1`.
        let beta = self.beta();
        match &mut self.mean {
            None => {
                self.mean = Some(update.to_dtype(self.dtype));
            }
            Some(m) => {
                m.ema_update(beta, update);
            }
        }
        self.count += 1;
    }

    fn mean(&self) -> Option<&Tensor> {
        self.mean.as_ref()
    }

    fn count(&self) -> usize {
        self.count
    }

    fn state_nbytes(&self) -> usize {
        self.mean.as_ref().map_or(0, Tensor::nbytes)
    }
}

/// Conventional fixed-decay EMA (the paper's `β = 0.9` baseline): the
/// decay ignores the pipeline delay entirely.
#[derive(Clone, Debug)]
pub struct FixedEma {
    beta: f32,
    mean: Option<Tensor>,
    count: usize,
    /// Storage dtype of the accumulator (see [`PipelineAwareEma`]).
    dtype: Dtype,
}

impl FixedEma {
    pub fn new(beta: f32) -> Self {
        FixedEma::new_with_dtype(beta, Dtype::F32)
    }

    /// [`FixedEma::new`] with the accumulator stored in `dtype`.
    pub fn new_with_dtype(beta: f32, dtype: Dtype) -> Self {
        assert!((0.0..1.0).contains(&beta), "beta must be in [0,1)");
        FixedEma { beta, mean: None, count: 0, dtype }
    }

    pub fn beta(&self) -> f32 {
        self.beta
    }
}

impl GradientAverager for FixedEma {
    fn push(&mut self, update: &Tensor) {
        match &mut self.mean {
            None => self.mean = Some(update.to_dtype(self.dtype)),
            Some(m) => m.ema_update(self.beta, update),
        }
        self.count += 1;
    }

    fn mean(&self) -> Option<&Tensor> {
        self.mean.as_ref()
    }

    fn count(&self) -> usize {
        self.count
    }

    fn state_nbytes(&self) -> usize {
        self.mean.as_ref().map_or(0, Tensor::nbytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, property};
    use crate::util::Rng;

    fn t1(v: f32) -> Tensor {
        Tensor::from_vec(&[1], vec![v])
    }

    #[test]
    fn exact_window_mean_is_sliding_mean() {
        let mut w = ExactWindow::new(3);
        for v in [1.0, 2.0, 3.0, 4.0] {
            w.push(&t1(v));
        }
        // last 3: (2+3+4)/3 = 3
        assert!((w.mean().unwrap().data()[0] - 3.0).abs() < 1e-6);
        assert!((w.window_sum().unwrap().data()[0] - 9.0).abs() < 1e-6);
    }

    #[test]
    fn pipeline_aware_matches_cumulative_mean_during_ramp() {
        // Eq. 7 is the exact recurrence for the running mean: while
        // count <= window the EMA must equal the cumulative mean exactly.
        let mut ema = PipelineAwareEma::new(10);
        let mut sum = 0.0;
        for k in 1..=10 {
            let v = (k * k) as f32;
            sum += v;
            ema.push(&t1(v));
            let cm = sum / k as f32;
            assert!(
                (ema.mean().unwrap().data()[0] - cm).abs() < 1e-4,
                "k={k}"
            );
        }
    }

    #[test]
    fn pipeline_aware_beta_ramps_then_holds() {
        let mut ema = PipelineAwareEma::new(4);
        let betas: Vec<f32> = (0..7)
            .map(|_| {
                let b = ema.beta();
                ema.push(&t1(1.0));
                b
            })
            .collect();
        assert_allclose(
            &betas,
            &[0.0, 0.5, 2.0 / 3.0, 0.75, 0.8, 0.8, 0.8],
            1e-6,
            0.0,
            "beta ramp",
        );
    }

    #[test]
    fn exact_reconstruction_inverts_sgd() {
        // Plain SGD + ExactWindow: Ŵ(t−d) must equal the true stored
        // W(t−d) to fp rounding — the paper's Eq. 3 identity.
        property(16, |rng, _case| {
            let d = 1 + rng.index(8);
            let steps = d + 2 + rng.index(20);
            let lr = 0.05;
            let mut w = Tensor::randn(&[6], 1.0, rng);
            let mut hist = vec![w.clone()];
            let mut win = ExactWindow::new(d);
            for _ in 0..steps {
                let g = Tensor::randn(&[6], 1.0, rng);
                // plain SGD step: U = g
                w.axpy(-lr, &g);
                win.push(&g);
                hist.push(w.clone());
            }
            // Eq. 3: W(t−d) = W(t) + lr·Σ last-d updates
            let target = &hist[hist.len() - 1 - d];
            let mut recon = w.clone();
            recon.axpy(lr, &win.window_sum().unwrap());
            assert!(
                recon.max_abs_diff(target) < 1e-4,
                "d={d} diff={}",
                recon.max_abs_diff(target)
            );
        });
    }

    #[test]
    fn pipeline_aware_approximates_exact_window() {
        // On a slowly-varying update stream the O(1) EMA should track the
        // exact window mean closely (the DLMS slow-variation assumption).
        let mut rng = Rng::new(42);
        let d = 6;
        let mut exact = ExactWindow::new(d);
        let mut ema = PipelineAwareEma::new(d);
        let mut drift = 0.0f32;
        for t in 0..200 {
            drift += 0.01;
            let v = drift + 0.05 * rng.gauss() as f32;
            exact.push(&t1(v));
            ema.push(&t1(v));
            if t > 3 * d {
                let e = exact.mean().unwrap().data()[0];
                let a = ema.mean().unwrap().data()[0];
                assert!((e - a).abs() < 0.15, "t={t}: exact {e} vs ema {a}");
            }
        }
    }

    #[test]
    fn fixed_ema_is_standard() {
        let mut ema = FixedEma::new(0.9);
        ema.push(&t1(1.0));
        ema.push(&t1(0.0));
        assert!((ema.mean().unwrap().data()[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn memory_footprint_ordering() {
        // The whole point: pipeline-aware EMA is O(1) in the delay, the
        // exact window is O(d).
        let shape = [64, 64];
        let upd = Tensor::zeros(&shape);
        let mut exact = ExactWindow::new(14);
        let mut ema = PipelineAwareEma::new(14);
        for _ in 0..20 {
            exact.push(&upd);
            ema.push(&upd);
        }
        assert!(exact.state_nbytes() >= 14 * upd.nbytes());
        assert_eq!(ema.state_nbytes(), upd.nbytes());
    }

    #[test]
    fn exact_window_ring_reuse_keeps_sliding_mean_exact() {
        // The ring slots are overwritten in place once the window fills;
        // the sliding mean must stay exact far past the first wrap.
        let mut w = ExactWindow::new(3);
        for v in 1..=20u32 {
            w.push(&t1(v as f32));
            let k = v.min(3);
            let lo = v - k + 1;
            let expect: f32 = (lo..=v).map(|x| x as f32).sum::<f32>() / k as f32;
            assert!((w.mean().unwrap().data()[0] - expect).abs() < 1e-5, "v={v}");
        }
        assert_eq!(w.count(), 20);
        assert_eq!(w.state_nbytes(), 4 * 4, "3 slots + mean, all width 1");
    }

    #[test]
    fn reconstruct_into_matches_reconstruct() {
        let mut ema = PipelineAwareEma::new(4);
        for v in [1.0, 2.0, 3.0] {
            ema.push(&t1(v));
        }
        let cur = t1(10.0);
        let a = ema.reconstruct(&cur, 0.7);
        let mut b = t1(-99.0); // dirty buffer
        ema.reconstruct_into(&cur, 0.7, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn reconstruct_without_samples_returns_current() {
        let ema = PipelineAwareEma::new(4);
        let cur = t1(3.5);
        let r = ema.reconstruct(&cur, 0.7);
        assert_eq!(r.data(), cur.data());
    }

    #[test]
    fn bf16_accumulator_halves_state_and_tracks_within_eps() {
        // Mixed-precision accumulators: half the bytes, per-push error
        // bounded by the bf16 quantization step (each ema_update widens,
        // combines in f32, and re-rounds once).
        let shape = [32, 16];
        let mut rng = Rng::new(9);
        let mut q = PipelineAwareEma::new_with_dtype(6, Dtype::Bf16);
        let mut full = PipelineAwareEma::new(6);
        for _ in 0..12 {
            let u = Tensor::randn(&shape, 1.0, &mut rng);
            q.push(&u);
            full.push(&u);
        }
        assert_eq!(q.state_nbytes() * 2, full.state_nbytes());
        assert_eq!(q.mean().unwrap().dtype(), Dtype::Bf16);
        let (qm, fm) = (q.mean().unwrap().to_dtype(Dtype::F32), full.mean().unwrap());
        // 12 pushes, each contributing ≤ eps relative rounding on values
        // of magnitude ≲ 4: a loose absolute budget of 12·4·eps.
        let budget = 12.0 * 4.0 * crate::tensor::EPS_BF16;
        assert!(qm.max_abs_diff(fm) < budget, "diff {}", qm.max_abs_diff(fm));
    }

    #[test]
    fn bf16_reconstruction_widens_to_f32() {
        // reconstruct_into on bf16 current + bf16 mean must produce an
        // f32 tensor computed as widen(cur) + lr_sum·widen(mean).
        let mut ema = FixedEma::new_with_dtype(0.9, Dtype::Bf16);
        ema.push(&t1(2.0).to_dtype(Dtype::Bf16));
        let cur = t1(10.0).to_dtype(Dtype::Bf16);
        let r = ema.reconstruct(&cur, 0.5);
        assert_eq!(r.dtype(), Dtype::F32);
        assert_eq!(r.data()[0], 10.0 + 0.5 * 2.0);
    }
}
