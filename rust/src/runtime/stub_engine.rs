//! Stub engine compiled when the `pjrt` feature is off.
//!
//! Keeps every `runtime::Engine` call site compiling (CLI subcommands,
//! benches, throughput tools) while making the unavailability explicit at
//! runtime: [`Engine::load`] fails with an actionable message and nothing
//! else can ever be reached, because no `Engine` value can be
//! constructed. Consumers that want compute should go through
//! [`crate::backend::from_env`], which falls back to the host backend.

use super::manifest::Manifest;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Placeholder for the compiled-artifact handle (never constructed).
pub struct Executable {
    _unconstructible: std::convert::Infallible,
}

/// Placeholder engine (never constructed; `load` always errors).
pub struct Engine {
    manifest: Manifest,
    _unconstructible: std::convert::Infallible,
}

impl Engine {
    /// Always fails: the crate was built without PJRT support.
    pub fn load(dir: &str) -> Result<Engine> {
        bail!(
            "PJRT runtime unavailable: built without the `pjrt` cargo feature \
             (artifacts dir: {dir}). Use the pure-Rust host backend \
             (LAYERPIPE2_BACKEND=host, the default fallback) or rebuild with \
             `--features pjrt` after enabling the `xla` dependency in Cargo.toml"
        );
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn get(&self, _name: &str) -> Result<&Executable> {
        match self._unconstructible {}
    }

    pub fn run(&self, _name: &str, _inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        match self._unconstructible {}
    }

    pub fn exec_count(&self) -> u64 {
        match self._unconstructible {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_missing_feature() {
        let err = Engine::load("artifacts").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "actionable message, got: {msg}");
    }
}
