//! PJRT runtime: load AOT artifacts and execute them from the hot path.
//!
//! `make artifacts` (Python, build-time only) lowers every L2 entry point
//! to HLO text plus `manifest.json`. This module is the request-path
//! bridge: parse the manifest, compile each module once on the PJRT CPU
//! client (`xla` crate), and expose typed, shape-checked execution over
//! host [`crate::tensor::Tensor`]s. Nothing here ever calls back into
//! Python.
//!
//! The `xla` crate (and the libpjrt build it wraps) is only available on
//! prepared machines, so the whole execution path sits behind the `pjrt`
//! cargo feature. Without the feature, [`Engine::load`] returns a
//! readable error and every consumer falls back to
//! [`crate::backend::HostBackend`] — manifest *parsing* stays available
//! unconditionally so tooling can still inspect artifact directories.

mod manifest;

pub use manifest::{Manifest, ManifestEntry};

#[cfg(feature = "pjrt")]
mod pjrt_engine;
#[cfg(feature = "pjrt")]
pub use pjrt_engine::{Engine, Executable};

#[cfg(not(feature = "pjrt"))]
mod stub_engine;
#[cfg(not(feature = "pjrt"))]
pub use stub_engine::{Engine, Executable};
