//! The real PJRT engine (`pjrt` feature): compile every manifest entry on
//! the PJRT CPU client and execute artifacts by name.

use super::manifest::{Manifest, ManifestEntry};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// A compiled artifact plus its manifest signature.
pub struct Executable {
    entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    pub fn entry(&self) -> &ManifestEntry {
        &self.entry
    }

    /// Execute with shape-checked tensor inputs, returning one host
    /// tensor per declared output.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.entry.inputs.len() {
            bail!(
                "{}: got {} inputs, manifest declares {}",
                self.entry.name,
                inputs.len(),
                self.entry.inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            if t.shape() != spec.as_slice() {
                bail!(
                    "{}: input {i} shape {:?} != manifest {:?}",
                    self.entry.name,
                    t.shape(),
                    spec
                );
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| to_literal(t)).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.entry.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("device→host copy")?
            .to_tuple()
            .context("unwrapping result tuple")?;
        if tuple.len() != self.entry.outputs {
            bail!(
                "{}: runtime produced {} outputs, manifest declares {}",
                self.entry.name,
                tuple.len(),
                self.entry.outputs
            );
        }
        tuple
            .into_iter()
            .zip(&self.entry.output_shapes)
            .map(|(lit, shape)| {
                let data = lit.to_vec::<f32>().context("literal→vec")?;
                Ok(Tensor::from_vec(shape, data))
            })
            .collect()
    }
}

fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    // Single host→literal copy: build directly at the target shape
    // (the vec1 + reshape route copies twice).
    let bytes = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.nbytes())
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, t.shape(), bytes)
        .context("literal from tensor")
}

/// The runtime engine: a PJRT client plus every compiled artifact.
pub struct Engine {
    manifest: Manifest,
    executables: HashMap<String, Executable>,
    exec_count: AtomicU64,
}

impl Engine {
    /// Load `manifest.json` from `dir` and compile every entry.
    pub fn load(dir: &str) -> Result<Engine> {
        let manifest = Manifest::load(&Path::new(dir).join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = HashMap::new();
        for entry in &manifest.entries {
            let path = Path::new(dir).join(&entry.file);
            let path_str = path
                .to_str()
                .with_context(|| format!("non-utf8 path {path:?}"))?;
            let proto = xla::HloModuleProto::from_text_file(path_str)
                .with_context(|| format!("parsing HLO text {path_str}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?;
            executables.insert(entry.name.clone(), Executable { entry: entry.clone(), exe });
        }
        crate::log_info!(
            "runtime: compiled {} artifacts from {dir} (preset {})",
            executables.len(),
            manifest.preset
        );
        Ok(Engine { manifest, executables, exec_count: AtomicU64::new(0) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Look up a compiled artifact by name.
    pub fn get(&self, name: &str) -> Result<&Executable> {
        self.executables
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Execute an artifact by name.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.exec_count.fetch_add(1, Ordering::Relaxed);
        self.get(name)?.run(inputs)
    }

    /// Total executions since startup (metrics).
    pub fn exec_count(&self) -> u64 {
        self.exec_count.load(Ordering::Relaxed)
    }
}

// SAFETY: the PJRT client and loaded executables wrap refcounted,
// internally-synchronized XLA C++ objects; the CPU client supports
// concurrent Execute calls. The manifest is immutable after load and the
// counter is atomic.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

// Engine tests that need real artifacts live in rust/tests/ (integration)
// since `make artifacts` must run first; manifest parsing is unit-tested
// in manifest.rs.
