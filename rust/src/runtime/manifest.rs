//! `artifacts/manifest.json` schema (written by `python/compile/aot.py`).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One lowered artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    /// Input shapes, in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Number of tuple outputs.
    pub outputs: usize,
    /// Output shapes, in tuple order.
    pub output_shapes: Vec<Vec<usize>>,
}

/// Shape parameters the artifacts were lowered at.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelShape {
    pub batch: usize,
    pub input_dim: usize,
    pub hidden_dim: usize,
    pub classes: usize,
    pub layers: usize,
}

impl ModelShape {
    /// The experiment model config these artifacts serve (the single
    /// source for every tool that sizes models off a manifest).
    pub fn to_model_config(&self) -> crate::config::ModelConfig {
        crate::config::ModelConfig {
            batch: self.batch,
            input_dim: self.input_dim,
            hidden_dim: self.hidden_dim,
            classes: self.classes,
            layers: self.layers,
            init_scale: 1.0,
        }
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub preset: String,
    pub fingerprint: String,
    pub model: ModelShape,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Model config for the artifacts in `dir`, or the default preset
    /// when no manifest is readable there — the shared sizing policy for
    /// every tool that runs with or without artifacts (CLI throughput,
    /// benches, examples).
    pub fn model_config_or_default(dir: &str) -> crate::config::ModelConfig {
        Self::load(&Path::new(dir).join("manifest.json"))
            .map(|m| m.model.to_model_config())
            .unwrap_or_default()
    }

    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {path:?} — run `make artifacts` first"))?;
        Self::parse(&text).with_context(|| format!("parsing manifest {path:?}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest is not valid JSON")?;
        let need_str = |v: &Json, k: &str| -> Result<String> {
            Ok(v.get(k)
                .and_then(Json::as_str)
                .with_context(|| format!("manifest missing string '{k}'"))?
                .to_string())
        };
        let model_v = root.get("model").context("manifest missing 'model'")?;
        let need_dim = |k: &str| -> Result<usize> {
            model_v
                .get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("model missing '{k}'"))
        };
        let model = ModelShape {
            batch: need_dim("batch")?,
            input_dim: need_dim("input_dim")?,
            hidden_dim: need_dim("hidden_dim")?,
            classes: need_dim("classes")?,
            layers: need_dim("layers")?,
        };
        let mut entries = Vec::new();
        for e in root
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest missing 'entries'")?
        {
            let shapes = |k: &str| -> Result<Vec<Vec<usize>>> {
                e.get(k)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("entry missing '{k}'"))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .context("shape must be an array")?
                            .iter()
                            .map(|d| d.as_usize().context("dim must be a non-negative int"))
                            .collect()
                    })
                    .collect()
            };
            let entry = ManifestEntry {
                name: need_str(e, "name")?,
                file: need_str(e, "file")?,
                inputs: shapes("inputs")?,
                outputs: e
                    .get("outputs")
                    .and_then(Json::as_usize)
                    .context("entry missing 'outputs'")?,
                output_shapes: shapes("output_shapes")?,
            };
            if entry.outputs != entry.output_shapes.len() {
                bail!(
                    "entry {}: outputs {} != output_shapes len {}",
                    entry.name,
                    entry.outputs,
                    entry.output_shapes.len()
                );
            }
            entries.push(entry);
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Manifest {
            preset: need_str(&root, "preset")?,
            fingerprint: need_str(&root, "fingerprint")?,
            model,
            entries,
        })
    }

    pub fn entry(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "preset": "tiny", "fingerprint": "abc123",
      "model": {"batch": 4, "input_dim": 8, "hidden_dim": 8, "classes": 4, "layers": 3},
      "entries": [
        {"name": "dense_fwd_hid", "file": "dense_fwd_hid.hlo.txt",
         "inputs": [[4, 8], [8, 8], [8]], "outputs": 1, "output_shapes": [[4, 8]]},
        {"name": "loss_grad", "file": "loss_grad.hlo.txt",
         "inputs": [[4, 4], [4, 4]], "outputs": 3,
         "output_shapes": [[], [4, 4], []]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.model.batch, 4);
        assert_eq!(m.entries.len(), 2);
        let e = m.entry("loss_grad").unwrap();
        assert_eq!(e.outputs, 3);
        assert_eq!(e.output_shapes[0], Vec::<usize>::new()); // scalar
        assert_eq!(e.inputs[0], vec![4, 4]);
    }

    #[test]
    fn rejects_inconsistent_outputs() {
        let bad = SAMPLE.replace(r#""outputs": 3"#, r#""outputs": 2"#);
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_empty_and_garbage() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
        assert!(Manifest::parse(r#"{"preset":"x","fingerprint":"y","model":{"batch":1,"input_dim":1,"hidden_dim":1,"classes":1,"layers":2},"entries":[]}"#).is_err());
    }

    #[test]
    fn entry_lookup() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.entry("dense_fwd_hid").is_some());
        assert!(m.entry("nope").is_none());
    }
}
