//! Lightweight property-testing harness.
//!
//! The offline registry lacks `proptest`, so this module provides the
//! pieces our invariant tests need: seeded random case generation with a
//! configurable case count, and failure reports that include the seed and
//! case index so any failure replays deterministically.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use layerpipe2::testing::property;
//! property(64, |rng, case| {
//!     let n = 1 + rng.index(100);
//!     assert!(n >= 1, "case {case}");
//! });
//! ```

use crate::util::Rng;

/// Default base seed; override with `LAYERPIPE2_PROP_SEED` to reproduce a
/// CI failure locally.
fn base_seed() -> u64 {
    std::env::var("LAYERPIPE2_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00)
}

/// Run `body` for `cases` independently-seeded cases. On panic, re-raises
/// with the seed and case index prepended so the case can be replayed.
pub fn property(cases: usize, body: impl Fn(&mut Rng, usize) + std::panic::RefUnwindSafe) {
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(|| {
            let mut r = rng.clone();
            body(&mut r, case);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed at case {case}/{cases} (LAYERPIPE2_PROP_SEED={seed}): {msg}"
            );
        }
        // keep rng "used" for clarity; each case derives its own stream
        let _ = rng.next_u64();
    }
}

/// Assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs();
        assert!(
            (x - y).abs() <= tol,
            "{what}: index {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn property_runs_all_cases() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static COUNT: AtomicUsize = AtomicUsize::new(0);
        property(10, |_rng, _case| {
            COUNT.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(COUNT.load(Ordering::Relaxed), 10);
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn property_reports_case() {
        property(5, |_rng, case| {
            assert!(case < 3, "boom");
        });
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-7, 2.0], 1e-5, 1e-5, "ok");
        let r = std::panic::catch_unwind(|| {
            assert_allclose(&[1.0], &[1.1], 1e-5, 1e-5, "bad");
        });
        assert!(r.is_err());
    }
}
