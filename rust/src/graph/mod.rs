//! Backpropagation dataflow graphs (paper §III-A/B).
//!
//! Training one layer involves four computation nodes — forward `F_l`,
//! activation gradient `D_l` (the paper's δ), weight gradient `G_l`, and
//! the weight update `W_l` — wired into the nested feedback structure of
//! Fig. 1/3. Edges carry integer *delay* counts (the `D` elements of DSP
//! retiming); one delay = one training iteration of temporal separation.
//!
//! The module provides the graph representation, the standard backprop
//! builder, feedforward-cutset detection, cycle analysis (including the
//! zero-delay gradient loop that makes naive pipelining impossible), and
//! the classical iteration bound `T∞ = max_cycles (Σcompute / Σdelay)`
//! from Ito & Parhi [12] used by the schedule model.

use std::collections::BTreeSet;

/// Role of a node in the training dataflow graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeKind {
    /// Environment (data source / gradient sink), outside all stages.
    Env,
    /// Forward computation of layer `l`.
    Forward(usize),
    /// Activation-gradient (δ) computation of layer `l`.
    ActGrad(usize),
    /// Weight-gradient (G) computation of layer `l`.
    WeightGrad(usize),
    /// Weight update/storage of layer `l`.
    Weight(usize),
    /// Loss + initial gradient computation (lives in the last stage).
    Loss,
}

impl NodeKind {
    /// Layer index, if the node belongs to a layer.
    pub fn layer(&self) -> Option<usize> {
        match self {
            NodeKind::Forward(l)
            | NodeKind::ActGrad(l)
            | NodeKind::WeightGrad(l)
            | NodeKind::Weight(l) => Some(*l),
            _ => None,
        }
    }

    /// `true` for nodes on the forward/weight side of a stage (`F`, `W`),
    /// `false` for backward-side nodes (`D`, `G`), `None` for env/loss.
    pub fn is_forward_side(&self) -> Option<bool> {
        match self {
            NodeKind::Forward(_) | NodeKind::Weight(_) => Some(true),
            NodeKind::ActGrad(_) | NodeKind::WeightGrad(_) => Some(false),
            _ => None,
        }
    }
}

/// Semantic role of an edge (used to read stash depths off the graph).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Forward activation `F_l → F_{l+1}` (or into Loss).
    Activation,
    /// Stashed activation into the backward pass: `F_l → {G_l, D_l}`.
    ActStash,
    /// Backward gradient flow `D_{l+1} → {D_l, G_l}` (or from Loss).
    GradFlow,
    /// Weights consumed by forward: `W_l → F_l`.
    WeightUse,
    /// Weights consumed by backward (δ needs `Wᵀ`): `W_l → D_l`.
    WeightUseBwd,
    /// Gradient→update feedback `G_l → W_l` — the DLMS insertion site.
    GradToWeight,
    /// Weight state self-loop `W_l → W_l` (the iteration boundary).
    WeightState,
    /// Env → first forward (network input feedforward cutset edge).
    EnvIn,
    /// First act-grad → env (network output-side cutset edge).
    EnvOut,
}

/// A node with an optional pipeline-stage assignment.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    /// Stage index; `None` for Env.
    pub stage: Option<usize>,
    /// Abstract compute time (for iteration-bound / schedule analysis).
    pub compute: f64,
}

/// A directed edge carrying `delay` pipeline registers.
#[derive(Clone, Debug)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    pub delay: i64,
    pub kind: EdgeKind,
}

/// The training dataflow graph.
#[derive(Clone, Debug, Default)]
pub struct Dfg {
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
}

impl Dfg {
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn add_node(&mut self, kind: NodeKind, stage: Option<usize>, compute: f64) -> usize {
        self.nodes.push(Node { kind, stage, compute });
        self.nodes.len() - 1
    }

    pub fn add_edge(&mut self, from: usize, to: usize, delay: i64, kind: EdgeKind) -> usize {
        assert!(from < self.nodes.len() && to < self.nodes.len(), "edge endpoint out of range");
        assert!(delay >= 0, "initial edge delay must be non-negative");
        self.edges.push(Edge { from, to, delay, kind });
        self.edges.len() - 1
    }

    /// Find the unique node of a given kind.
    pub fn find(&self, kind: NodeKind) -> Option<usize> {
        self.nodes.iter().position(|n| n.kind == kind)
    }

    /// The delay on the unique edge `(from_kind → to_kind)`.
    pub fn edge_delay(&self, from: NodeKind, to: NodeKind) -> Option<i64> {
        let f = self.find(from)?;
        let t = self.find(to)?;
        self.edges
            .iter()
            .find(|e| e.from == f && e.to == t)
            .map(|e| e.delay)
    }

    // ------------------------------------------------------------------
    // Construction of the standard backprop graph
    // ------------------------------------------------------------------

    /// Build the backpropagation dataflow graph for `layers` dense layers
    /// with the stage assignment `stage_of[l]` (contiguous, ascending).
    /// Compute weights default to 1.0 per node (override for schedule
    /// experiments via [`Dfg::set_layer_compute`]).
    ///
    /// All edges start with 0 delays except the weight-state self-loops
    /// (1 delay: updates take effect next iteration) — the *sequential*
    /// semantics the paper's construction starts from.
    pub fn backprop(layers: usize, stage_of: &[usize]) -> Dfg {
        assert!(layers >= 1);
        assert_eq!(stage_of.len(), layers, "need a stage per layer");
        for w in stage_of.windows(2) {
            assert!(w[1] >= w[0], "stage assignment must be ascending");
            assert!(w[1] - w[0] <= 1, "stages must be contiguous");
        }
        assert_eq!(stage_of[0], 0, "first layer must be in stage 0");
        let num_stages = stage_of[layers - 1] + 1;

        let mut g = Dfg::default();
        let env = g.add_node(NodeKind::Env, None, 0.0);
        let fwd: Vec<usize> = (0..layers)
            .map(|l| g.add_node(NodeKind::Forward(l), Some(stage_of[l]), 1.0))
            .collect();
        let act: Vec<usize> = (0..layers)
            .map(|l| g.add_node(NodeKind::ActGrad(l), Some(stage_of[l]), 1.0))
            .collect();
        let wgrad: Vec<usize> = (0..layers)
            .map(|l| g.add_node(NodeKind::WeightGrad(l), Some(stage_of[l]), 1.0))
            .collect();
        let weight: Vec<usize> = (0..layers)
            .map(|l| g.add_node(NodeKind::Weight(l), Some(stage_of[l]), 0.0))
            .collect();
        let loss = g.add_node(NodeKind::Loss, Some(num_stages - 1), 1.0);

        g.add_edge(env, fwd[0], 0, EdgeKind::EnvIn);
        for l in 0..layers {
            if l + 1 < layers {
                g.add_edge(fwd[l], fwd[l + 1], 0, EdgeKind::Activation);
            } else {
                g.add_edge(fwd[l], loss, 0, EdgeKind::Activation);
            }
            // Stashed activations feed both backward components.
            g.add_edge(fwd[l], wgrad[l], 0, EdgeKind::ActStash);
            g.add_edge(fwd[l], act[l], 0, EdgeKind::ActStash);
            // Backward gradient flow from the following layer (or loss).
            if l + 1 < layers {
                g.add_edge(act[l + 1], act[l], 0, EdgeKind::GradFlow);
                g.add_edge(act[l + 1], wgrad[l], 0, EdgeKind::GradFlow);
            } else {
                g.add_edge(loss, act[l], 0, EdgeKind::GradFlow);
                g.add_edge(loss, wgrad[l], 0, EdgeKind::GradFlow);
            }
            // Weight uses and the gradient-update feedback loop.
            g.add_edge(weight[l], fwd[l], 0, EdgeKind::WeightUse);
            g.add_edge(weight[l], act[l], 0, EdgeKind::WeightUseBwd);
            g.add_edge(wgrad[l], weight[l], 0, EdgeKind::GradToWeight);
            g.add_edge(weight[l], weight[l], 1, EdgeKind::WeightState);
        }
        g.add_edge(act[0], env, 0, EdgeKind::EnvOut);
        g
    }

    /// Set per-layer compute times: forward `f`, backward components get
    /// `b/2` each (δ and G), mirroring backward ≈ 2× forward cost.
    pub fn set_layer_compute(&mut self, layer: usize, f: f64, b: f64) {
        for n in &mut self.nodes {
            match n.kind {
                NodeKind::Forward(l) if l == layer => n.compute = f,
                NodeKind::ActGrad(l) | NodeKind::WeightGrad(l) if l == layer => {
                    n.compute = b / 2.0
                }
                _ => {}
            }
        }
    }

    // ------------------------------------------------------------------
    // Cutsets
    // ------------------------------------------------------------------

    /// Classify the cut `(set, V∖set)`:
    /// `Some(true)` — feedforward cutset, all crossing edges leave `set`;
    /// `Some(false)` — feedforward cutset entering `set`;
    /// `None` — edges cross in both directions (a feedback cutset).
    pub fn feedforward_cutset_direction(&self, set: &BTreeSet<usize>) -> Option<bool> {
        let mut out = false;
        let mut inb = false;
        for e in &self.edges {
            let f_in = set.contains(&e.from);
            let t_in = set.contains(&e.to);
            if f_in && !t_in {
                out = true;
            } else if !f_in && t_in {
                inb = true;
            }
        }
        match (out, inb) {
            (true, false) => Some(true),
            (false, true) => Some(false),
            _ => None,
        }
    }

    /// The two feedforward cutsets the paper identifies (§III-A): the
    /// network-input cut `{Env}` complement side and the network-output
    /// cut. Returns `(input_cut, output_cut)` as node sets whose crossing
    /// edges are exactly `EnvIn` / `EnvOut`.
    ///
    /// Note: in the *training* graph (forward and backward both present)
    /// the only feedforward cutsets separate Env from the rest; every
    /// layer boundary is a feedback cutset — that is precisely why naive
    /// pipelining is illegal and DLMS-style insertion is needed.
    pub fn env_cutsets(&self) -> (BTreeSet<usize>, BTreeSet<usize>) {
        let env = self.find(NodeKind::Env).expect("graph has an Env node");
        let input_cut: BTreeSet<usize> = [env].into_iter().collect();
        let output_cut: BTreeSet<usize> =
            (0..self.nodes.len()).filter(|&i| i != env).collect();
        (input_cut, output_cut)
    }

    // ------------------------------------------------------------------
    // Cycles & legality
    // ------------------------------------------------------------------

    /// `true` if every edge has a non-negative delay.
    pub fn delays_legal(&self) -> bool {
        self.edges.iter().all(|e| e.delay >= 0)
    }

    /// Minimum total delay over all directed cycles, or `None` if acyclic.
    /// A zero result identifies the algorithmic loops that retiming alone
    /// cannot pipeline (the gradient feedback loops of §II).
    pub fn min_cycle_delay(&self) -> Option<i64> {
        // Bellman-Ford over edge weight = delay, detecting the minimum
        // mean first is unnecessary: we only need min over cycles of the
        // (integer, non-negative) sum. Use DP: for increasing path length,
        // dist[k][v] = min delay of a k-edge walk ending at v; a cycle is
        // found when a walk returns to its start. n·m DP (Karp-style).
        let n = self.nodes.len();
        if n == 0 {
            return None;
        }
        let mut best: Option<i64> = None;
        for start in 0..n {
            // Dijkstra-like relaxation works since delays >= 0.
            let mut dist = vec![i64::MAX; n];
            // Initialize with edges out of `start`.
            let mut heap = std::collections::BinaryHeap::new();
            for e in self.edges.iter().filter(|e| e.from == start) {
                if e.to == start {
                    best = Some(best.map_or(e.delay, |b: i64| b.min(e.delay)));
                } else if e.delay < dist[e.to] {
                    dist[e.to] = e.delay;
                    heap.push(std::cmp::Reverse((e.delay, e.to)));
                }
            }
            while let Some(std::cmp::Reverse((d, v))) = heap.pop() {
                if d > dist[v] {
                    continue;
                }
                for e in self.edges.iter().filter(|e| e.from == v) {
                    if e.to == start {
                        let cyc = d + e.delay;
                        best = Some(best.map_or(cyc, |b: i64| b.min(cyc)));
                    } else if d + e.delay < dist[e.to] {
                        dist[e.to] = d + e.delay;
                        heap.push(std::cmp::Reverse((dist[e.to], e.to)));
                    }
                }
            }
        }
        best
    }

    /// Classical iteration bound `T∞ = max over cycles of Σcompute/Σdelay`
    /// (Ito & Parhi [12]). Returns `None` if some cycle has zero delay
    /// (unbounded — the graph is not pipelineable as-is) and `Some(0.0)`
    /// for acyclic graphs.
    ///
    /// Computed by binary search on `λ`: `λ ≥ T∞` iff the graph with edge
    /// weight `compute(from) − λ·delay(e)` has no positive cycle
    /// (Bellman-Ford detection).
    pub fn iteration_bound(&self) -> Option<f64> {
        match self.min_cycle_delay() {
            None => return Some(0.0),
            Some(0) => return None,
            Some(_) => {}
        }
        let total_compute: f64 = self.nodes.iter().map(|n| n.compute).sum();
        let (mut lo, mut hi) = (0.0f64, total_compute.max(1.0));
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if self.has_positive_cycle(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        Some(hi)
    }

    /// `true` if the graph with edge weight `compute(from) − λ·delay` has
    /// a positive-weight cycle.
    fn has_positive_cycle(&self, lambda: f64) -> bool {
        let n = self.nodes.len();
        // Longest-path Bellman-Ford from a virtual source to all nodes.
        let mut dist = vec![0.0f64; n];
        for _ in 0..n {
            let mut changed = false;
            for e in &self.edges {
                let w = self.nodes[e.from].compute - lambda * e.delay as f64;
                if dist[e.from] + w > dist[e.to] + 1e-12 {
                    dist[e.to] = dist[e.from] + w;
                    changed = true;
                }
            }
            if !changed {
                return false;
            }
        }
        true
    }

    /// Sum of delays around an explicit node cycle (for invariance tests).
    /// `cycle` lists node ids; consecutive pairs (wrapping) must each have
    /// at least one edge, the minimum-delay edge is taken.
    pub fn cycle_delay(&self, cycle: &[usize]) -> Option<i64> {
        let mut total = 0i64;
        for i in 0..cycle.len() {
            let (u, v) = (cycle[i], cycle[(i + 1) % cycle.len()]);
            let d = self
                .edges
                .iter()
                .filter(|e| e.from == u && e.to == v)
                .map(|e| e.delay)
                .min()?;
            total += d;
        }
        Some(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn per_layer_stages(l: usize) -> Vec<usize> {
        (0..l).collect()
    }

    #[test]
    fn backprop_graph_shape() {
        let g = Dfg::backprop(4, &per_layer_stages(4));
        // env + 4*(F,D,G,W) + loss
        assert_eq!(g.node_count(), 1 + 16 + 1);
        // per layer: act(1) + stash(2) + gradflow(2) + uses(2) + g2w(1) + self(1) = 9
        // plus env-in and env-out
        assert_eq!(g.edges.len(), 4 * 9 + 2);
        assert!(g.delays_legal());
    }

    #[test]
    fn sequential_graph_has_zero_delay_gradient_loop() {
        // The W→F→…→G→W loop carries no delay: retiming alone cannot
        // pipeline backprop (the paper's §II observation).
        let g = Dfg::backprop(3, &per_layer_stages(3));
        assert_eq!(g.min_cycle_delay(), Some(0));
        assert!(g.iteration_bound().is_none());
    }

    #[test]
    fn env_cutsets_are_feedforward() {
        let g = Dfg::backprop(3, &per_layer_stages(3));
        let (inp, out) = g.env_cutsets();
        // Env-only set: EnvIn leaves it, EnvOut enters it → feedback as a
        // *bidirectional* pair, but each individual edge set is checked by
        // direction, so classify the complement cut.
        assert_eq!(g.feedforward_cutset_direction(&out), None,
            "training graph layer cut contains both directions");
        // A pure-forward subgraph cut IS feedforward: take only F nodes.
        let fwd_prefix: BTreeSet<usize> = g
            .nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| matches!(n.kind, NodeKind::Forward(l) if l < 2))
            .map(|(i, _)| i)
            .collect();
        // F-prefix cut in the full training graph is not feedforward
        // (gradients flow back into it) — this is the key structural fact.
        assert_eq!(g.feedforward_cutset_direction(&fwd_prefix), None);
        let _ = inp;
    }

    #[test]
    fn cycle_delay_reads_weight_loop() {
        let g = Dfg::backprop(2, &per_layer_stages(2));
        let w0 = g.find(NodeKind::Weight(0)).unwrap();
        assert_eq!(g.cycle_delay(&[w0]), Some(1), "self-loop holds one delay");
    }

    #[test]
    fn iteration_bound_simple_loop() {
        // Two-node loop, computes 1.0 each, 2 delays total → T∞ = 1.0.
        let mut g = Dfg::default();
        let a = g.add_node(NodeKind::Loss, None, 1.0);
        let b = g.add_node(NodeKind::Env, None, 1.0);
        g.add_edge(a, b, 1, EdgeKind::Activation);
        g.add_edge(b, a, 1, EdgeKind::Activation);
        let t = g.iteration_bound().unwrap();
        assert!((t - 1.0).abs() < 1e-6, "got {t}");
    }

    #[test]
    fn iteration_bound_acyclic_is_zero() {
        let mut g = Dfg::default();
        let a = g.add_node(NodeKind::Loss, None, 1.0);
        let b = g.add_node(NodeKind::Env, None, 1.0);
        g.add_edge(a, b, 0, EdgeKind::Activation);
        assert_eq!(g.iteration_bound(), Some(0.0));
    }

    #[test]
    fn grouped_stage_assignment_accepted() {
        let g = Dfg::backprop(4, &[0, 0, 1, 1]);
        assert!(g.delays_legal());
        assert_eq!(g.nodes[g.find(NodeKind::Forward(1)).unwrap()].stage, Some(0));
        assert_eq!(g.nodes[g.find(NodeKind::Loss).unwrap()].stage, Some(1));
    }

    #[test]
    #[should_panic(expected = "contiguous")]
    fn rejects_gapped_stages() {
        Dfg::backprop(3, &[0, 2, 2]);
    }
}
